"""VCG mechanism: payment modes agree; DSIC (Thm 4.2); weak budget balance
(Thm 4.3); individual rationality of truthful clients."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.auction import client_utilities, run_auction


@st.composite
def markets(draw):
    n = draw(st.integers(1, 6))
    m = draw(st.integers(1, 4))
    values = np.array([[round(draw(st.floats(0, 5, allow_nan=False)), 3)
                        for _ in range(m)] for _ in range(n)])
    costs = np.array([[round(draw(st.floats(0, 3, allow_nan=False)), 3)
                       for _ in range(m)] for _ in range(n)])
    caps = [draw(st.integers(1, 2)) for _ in range(m)]
    return values, costs, caps


@settings(max_examples=80, deadline=None)
@given(markets())
def test_warmstart_equals_naive_payments(mkt):
    values, costs, caps = mkt
    r1 = run_auction(values, costs, caps, payment_mode="naive")
    r2 = run_auction(values, costs, caps, payment_mode="warmstart")
    assert r1.assignment == r2.assignment
    assert np.allclose(r1.payments, r2.payments, atol=1e-6)


@settings(max_examples=60, deadline=None)
@given(markets(), st.integers(0, 5), st.floats(-2, 2))
def test_truthfulness_dominant_strategy(mkt, j_idx, deviation):
    """Misreporting v_j never increases client j's utility (DSIC)."""
    values, costs, caps = mkt
    n = values.shape[0]
    j = j_idx % n
    honest = run_auction(values, costs, caps)
    u_honest = client_utilities(honest, values)[j]

    lied = values.copy()
    lied[j] = np.maximum(lied[j] + deviation, 0.0)
    strategic = run_auction(lied, costs, caps)
    u_lied = client_utilities(strategic, values)[j]  # utility at TRUE values
    assert u_lied <= u_honest + 1e-6


@settings(max_examples=80, deadline=None)
@given(markets())
def test_weak_budget_balance_and_ir(mkt):
    values, costs, caps = mkt
    r = run_auction(values, costs, caps)
    total_pay = sum(r.payments)
    total_cost = sum(costs[j, i] for j, i in enumerate(r.assignment) if i >= 0)
    assert total_pay >= total_cost - 1e-6  # Theorem 4.3
    # individual rationality under truthful reporting
    u = client_utilities(r, values)
    assert (u >= -1e-6).all()
    # per-transaction non-negative platform surplus (Appendix A.3)
    for j, i in enumerate(r.assignment):
        if i >= 0:
            assert r.payments[j] >= costs[j, i] - 1e-6


def test_payment_equals_externality_simple():
    # two clients compete for one slot: winner pays the displaced welfare
    values = np.array([[10.0], [7.0]])
    costs = np.array([[1.0], [1.0]])
    r = run_auction(values, costs, [1])
    assert r.assignment == [0, -1]
    # w = [9, 6]; p_0 = W(C\{0}) - (W - w_00) + c = 6 - 0 + 1
    assert r.payments[0] == pytest.approx(7.0)


@settings(max_examples=40, deadline=None)
@given(markets())
def test_welfare_monotone_in_agents(mkt):
    """Adding an agent never reduces optimal welfare (market expansion)."""
    values, costs, caps = mkt
    r_full = run_auction(values, costs, caps)
    if values.shape[1] > 1:
        r_less = run_auction(values[:, :-1], costs[:, :-1], caps[:-1])
        assert r_full.welfare >= r_less.welfare - 1e-9


@settings(max_examples=40, deadline=None)
@given(markets())
def test_unmatched_pay_nothing(mkt):
    values, costs, caps = mkt
    r = run_auction(values, costs, caps)
    for j, i in enumerate(r.assignment):
        if i < 0:
            assert r.payments[j] == 0.0
