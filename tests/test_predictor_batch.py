"""Phase-1 batching parity: ``PredictorPool.predict_matrix`` vs the scalar
``AgentPredictor.predict`` loop across cold-start / blended / warm regimes,
and ``route_batch(batched=True)`` vs the ``batched=False`` oracle — both on
synthetic markets and on seeded SimCluster workloads with failure and
straggler injection."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (AgentInfo, CompletionObs, IEMASRouter, Request,
                        TokenPrices)
from repro.core.predictor import (N_FEATURES, PredictorInput, PredictorPool,
                                  feature_tensor)

# n_obs regimes: cold (< warm_n), at the warm boundary, mid-blend
# (w = n_obs/60 < 1), and saturated (w = 1)
WARM_N = 6
REGIMES = (0, WARM_N - 1, WARM_N, 30, 200)


def _trained_pool(rng, m):
    prices = {f"a{i}": TokenPrices(float(rng.uniform(0.005, 0.03)),
                                   float(rng.uniform(0.0005, 0.003)),
                                   float(rng.uniform(0.01, 0.09)))
              for i in range(m)}
    pool = PredictorPool(prices, warm_n=WARM_N)
    for i, aid in enumerate(pool.agents()):
        pred = pool[aid]
        for _ in range(REGIMES[(i + int(rng.integers(0, len(REGIMES)))) % len(REGIMES)]):
            x = PredictorInput(*rng.uniform(0, 80, N_FEATURES))
            pred.update(x, float(rng.uniform(0.01, 2.0)),
                        float(rng.uniform(0.05, 5.0)),
                        float(rng.random() > 0.4))
    return pool


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 7), st.integers(1, 10))
def test_predict_matrix_matches_scalar_loop(seed, m, n):
    rng = np.random.default_rng(seed)
    pool = _trained_pool(rng, m)
    ids = pool.agents()
    X = feature_tensor(
        rng.uniform(1, 300, n), rng.integers(0, 8, n).astype(float),
        rng.uniform(0, 1, (n, m)),
        router_inflight=float(rng.integers(0, 20)),
        router_rps=float(rng.uniform(0, 5)),
        agent_inflight=rng.integers(0, 12, m).astype(float),
        agent_rps=rng.uniform(0, 3, m),
        capacity=rng.integers(1, 16, m).astype(float),
        domain_match=rng.integers(0, 2, (n, m)).astype(float))
    lat, cst, qual = pool.predict_matrix(ids, X)
    for j in range(n):
        for i, aid in enumerate(ids):
            est = pool[aid].predict(PredictorInput(*X[j, i]))
            assert abs(lat[j, i] - est.latency) <= 1e-12
            assert abs(cst[j, i] - est.cost) <= 1e-12
            assert abs(qual[j, i] - est.quality) <= 1e-12


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6))
def test_predict_rows_matches_scalar_including_updates(seed):
    """Per-agent vectorized rows stay exact across mid-stream updates
    (tree recompiles + ewma/n_obs drift)."""
    rng = np.random.default_rng(seed)
    pool = _trained_pool(rng, 1)
    pred = pool[pool.agents()[0]]
    for _ in range(3):
        X = rng.uniform(0, 120, (12, N_FEATURES))
        lat, cst, qual = pred.predict_rows(X)
        for b, row in enumerate(X):
            est = pred.predict(PredictorInput(*row))
            assert abs(lat[b] - est.latency) <= 1e-12
            assert abs(cst[b] - est.cost) <= 1e-12
            assert abs(qual[b] - est.quality) <= 1e-12
        pred.update(PredictorInput(*rng.uniform(0, 80, N_FEATURES)),
                    float(rng.uniform(0, 1)), float(rng.uniform(0, 2)), 1.0)
        pred.ewma_gen = 0.9 * pred.ewma_gen + 0.1 * float(rng.integers(1, 40))


def test_predict_matrix_after_elastic_remove_readd():
    """Regression: a removed-then-re-added agent gets fresh trees whose
    version counters restart at the old values — the stacked-forest cache
    must not serve the removed agent's stale leaf values."""
    rng = np.random.default_rng(0)
    pool = PredictorPool({"a0": TokenPrices(0.01, 0.001, 0.03)}, warm_n=2)

    def train(val, k):
        for _ in range(k):
            pool["a0"].update(PredictorInput(*rng.uniform(0, 50, N_FEATURES)),
                              val, val, 1.0)

    X = feature_tensor(rng.uniform(1, 100, 4), np.zeros(4),
                       rng.uniform(0, 1, (4, 1)), agent_inflight=[0.0],
                       agent_rps=[0.0], capacity=[4.0],
                       domain_match=np.ones((4, 1)))
    train(100.0, 30)
    pool.predict_matrix(["a0"], X)  # populate the stack cache
    pool.remove_agent("a0")
    pool.add_agent("a0", TokenPrices(0.01, 0.001, 0.03), warm_n=2)
    train(0.001, 30)  # same n_obs / tree versions as the removed agent
    lat, cst, qual = pool.predict_matrix(["a0"], X)
    for j in range(4):
        est = pool["a0"].predict(PredictorInput(*X[j, 0]))
        assert abs(lat[j, 0] - est.latency) <= 1e-12
        assert abs(cst[j, 0] - est.cost) <= 1e-12
        assert abs(qual[j, 0] - est.quality) <= 1e-12


# ---------------- end-to-end route_batch parity ----------------

def _decisions_equal(a, b):
    assert a.agent_id == b.agent_id
    assert a.hub_id == b.hub_id
    assert a.payment == b.payment
    assert a.welfare_weight == b.welfare_weight
    if a.estimate is None:
        assert b.estimate is None
    else:
        assert a.estimate.latency == b.estimate.latency
        assert a.estimate.cost == b.estimate.cost
        assert a.estimate.quality == b.estimate.quality


class MirrorRouter:
    """Drives the batched router while shadowing every call on the scalar
    oracle and asserting bit-identical decisions; both receive identical
    completion feedback so their ledgers/predictors stay in lockstep."""

    def __init__(self, primary, oracle):
        self.primary, self.oracle = primary, oracle
        self.compared = 0

    def route_batch(self, requests, telemetry, free_slots=None):
        dp = self.primary.route_batch(list(requests), telemetry,
                                      free_slots=free_slots)
        do = self.oracle.route_batch(list(requests), telemetry,
                                     free_slots=free_slots)
        for a, b in zip(dp, do):
            _decisions_equal(a, b)
        self.compared += len(dp)
        return dp

    def on_complete(self, request_id, obs):
        self.primary.on_complete(request_id, obs)
        self.oracle.on_complete(request_id, obs)

    def reinstate(self, agent_id):
        self.primary.reinstate(agent_id)
        self.oracle.reinstate(agent_id)


def test_route_batch_parity_synthetic_rounds():
    """Multi-round synthetic market: cache_slots LRU, telemetry load, hubs."""
    def agents():
        return [AgentInfo(f"a{i}", TokenPrices(0.01 * (1 + i % 3), 0.001, 0.03),
                          2, ("dialogue",) if i % 2 == 0 else ("reasoning",),
                          scale=4.0 + i, cache_slots=2 if i == 1 else 0)
                for i in range(5)]

    mirror = MirrorRouter(
        IEMASRouter(agents(), n_hubs=2, batched=True,
                    predictor_kw={"warm_n": 2}),
        IEMASRouter(agents(), n_hubs=2, batched=False,
                    predictor_kw={"warm_n": 2}))
    rng = np.random.default_rng(5)
    telem = {"router_inflight": 3, "router_rps": 1.5,
             "agent_inflight": {"a0": 1, "a2": 2}, "agent_rps": {"a1": 0.4}}
    for t in range(10):
        r = np.random.default_rng(500 + t)
        batch = [Request(f"r{t}-{j}", f"d{j % 4}",
                         r.integers(1, 50, 20 + j).astype(np.int32), turn=t,
                         domain="dialogue" if j % 2 else "reasoning")
                 for j in range(6)]
        for dec in mirror.route_batch(batch, telem):
            if dec.agent_id:
                obs = CompletionObs(float(rng.uniform(0.01, 0.2)),
                                    len(dec.request.tokens),
                                    int(rng.integers(0, len(dec.request.tokens))),
                                    int(rng.integers(1, 9)),
                                    float(rng.random()))
                mirror.on_complete(dec.request.request_id, obs)
    assert mirror.compared >= 60
    assert mirror.primary.accounts == mirror.oracle.accounts


def test_route_batch_parity_simcluster_workload():
    """Seeded SimCluster workload (real engines, failures, stragglers):
    batched and scalar Phase 1 must route every request identically."""
    from repro.serving import SimCluster, WorkloadSpec, generate, run_workload

    cluster = SimCluster(n_agents=4, seed=0, max_new_tokens=2,
                         fail_prob=0.1, straggle_prob=0.1)
    mirror = MirrorRouter(
        IEMASRouter(cluster.agent_infos(), batched=True,
                    predictor_kw={"warm_n": 3}),
        IEMASRouter(cluster.agent_infos(), batched=False,
                    predictor_kw={"warm_n": 3}))
    dialogues = generate(WorkloadSpec("coqa_like", n_dialogues=4, seed=11))
    metrics = run_workload(cluster, mirror, dialogues, max_rounds=1200)
    assert metrics["n"] == sum(len(d.turns) for d in dialogues)
    assert mirror.compared >= 30
    assert mirror.primary.accounts == mirror.oracle.accounts
    assert mirror.primary.quarantined == mirror.oracle.quarantined


# ---------------- RequestRecord.output_tokens regression ----------------

def test_request_record_output_tokens_is_a_field():
    from repro.serving.cluster import RequestRecord

    names = [f.name for f in dataclasses.fields(RequestRecord)]
    assert "output_tokens" in names  # no more setattr-with-type-ignore
    rec = RequestRecord(None, "a0", 0.0, 0.0, 0.0, 0.0, 1, 0, 0, 0.0, 0.0,
                        0.0, failed=True)
    assert rec.output_tokens.dtype == np.int32 and len(rec.output_tokens) == 0


def test_run_workload_threads_dialogue_history():
    """Turn t+1's prompt must be turn t's prompt + the engine's ACTUAL
    generated tokens + the next user turn (Appendix C.1 causality)."""
    from repro.serving import SimCluster, WorkloadSpec, generate, run_workload

    cluster = SimCluster(n_agents=2, seed=3, max_new_tokens=2)
    router = IEMASRouter(cluster.agent_infos())
    dialogues = generate(WorkloadSpec("coqa_like", n_dialogues=2, seed=7))
    run_workload(cluster, router, dialogues, max_rounds=600)
    by_dlg = {}
    for rec in cluster.records:
        assert len(rec.output_tokens) == rec.n_gen
        by_dlg.setdefault(rec.request.dialogue_id, []).append(rec)
    checked = 0
    for recs in by_dlg.values():
        recs.sort(key=lambda r: r.request.turn)
        for prev, nxt in zip(recs, recs[1:]):
            p, q = prev.request.tokens, nxt.request.tokens
            assert np.array_equal(q[: len(p)], p)  # prompt extends history
            gen = q[len(p): len(p) + len(prev.output_tokens)]
            assert np.array_equal(gen, prev.output_tokens)
            checked += 1
    assert checked >= 4
