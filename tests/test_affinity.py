"""Prefix ledger / LCP affinity (Eq. 4) incl. recurrent extension-only mode."""
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.affinity import PrefixLedger, lcp_length


def test_lcp_basic():
    assert lcp_length(np.array([1, 2, 3]), np.array([1, 2, 4])) == 2
    assert lcp_length(np.array([1, 2]), np.array([1, 2, 3])) == 2
    assert lcp_length(np.array([], dtype=np.int32), np.array([1])) == 0


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 5), max_size=30),
       st.lists(st.integers(0, 5), max_size=30))
def test_lcp_is_prefix(a, b):
    a, b = np.array(a, np.int32), np.array(b, np.int32)
    l = lcp_length(a, b)
    assert np.array_equal(a[:l], b[:l])
    if l < min(len(a), len(b)):
        assert a[l] != b[l]


def test_affinity_semantics():
    led = PrefixLedger()
    prev = np.arange(10, dtype=np.int32)
    led.update("a1", "d1", prev)
    # exact extension
    ext = np.concatenate([prev, np.array([99, 98], np.int32)])
    assert led.affinity("a1", "d1", ext) == 10 / 12
    assert led.affinity("a1", "d1", ext, extension_only=True) == 10 / 12
    # divergence after 5 tokens
    div = prev.copy()
    div[5] = 77
    assert led.affinity("a1", "d1", div) == 0.5
    assert led.affinity("a1", "d1", div, extension_only=True) == 0.0
    # other agent / session: zero (paper: switching agents loses locality)
    assert led.affinity("a2", "d1", ext) == 0.0
    assert led.affinity("a1", "d2", ext) == 0.0
    # eviction resync
    led.evict("a1", "d1")
    assert led.affinity("a1", "d1", ext) == 0.0


def test_affinity_matrix_python_vs_kernel():
    rng = np.random.default_rng(0)
    led = PrefixLedger()
    agents = [f"a{i}" for i in range(4)]
    prompts, dialogues = [], []
    for j in range(5):
        d = f"d{j}"
        dialogues.append(d)
        base = rng.integers(1, 9, size=rng.integers(4, 24)).astype(np.int32)
        prompts.append(base)
        for i, a in enumerate(agents):
            if (i + j) % 3 == 0:
                led.update(a, d, base[: max(1, len(base) // 2)])
    py = led.affinity_matrix(prompts, dialogues, agents)
    kr = led.affinity_matrix(prompts, dialogues, agents, use_kernel=True)
    assert np.allclose(py, kr, atol=1e-6)
    ext_mask = [True, False, True, False]
    py2 = led.affinity_matrix(prompts, dialogues, agents,
                              extension_only_mask=ext_mask)
    kr2 = led.affinity_matrix(prompts, dialogues, agents,
                              extension_only_mask=ext_mask, use_kernel=True)
    assert np.allclose(py2, kr2, atol=1e-6)
