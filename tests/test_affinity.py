"""Prefix ledger / LCP affinity (Eq. 4) incl. recurrent extension-only mode."""
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.affinity import PrefixLedger, lcp_length


def test_lcp_basic():
    assert lcp_length(np.array([1, 2, 3]), np.array([1, 2, 4])) == 2
    assert lcp_length(np.array([1, 2]), np.array([1, 2, 3])) == 2
    assert lcp_length(np.array([], dtype=np.int32), np.array([1])) == 0


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 5), max_size=30),
       st.lists(st.integers(0, 5), max_size=30))
def test_lcp_is_prefix(a, b):
    a, b = np.array(a, np.int32), np.array(b, np.int32)
    l = lcp_length(a, b)
    assert np.array_equal(a[:l], b[:l])
    if l < min(len(a), len(b)):
        assert a[l] != b[l]


def test_affinity_semantics():
    led = PrefixLedger()
    prev = np.arange(10, dtype=np.int32)
    led.update("a1", "d1", prev)
    # exact extension
    ext = np.concatenate([prev, np.array([99, 98], np.int32)])
    assert led.affinity("a1", "d1", ext) == 10 / 12
    assert led.affinity("a1", "d1", ext, extension_only=True) == 10 / 12
    # divergence after 5 tokens
    div = prev.copy()
    div[5] = 77
    assert led.affinity("a1", "d1", div) == 0.5
    assert led.affinity("a1", "d1", div, extension_only=True) == 0.0
    # other agent / session: zero (paper: switching agents loses locality)
    assert led.affinity("a2", "d1", ext) == 0.0
    assert led.affinity("a1", "d2", ext) == 0.0
    # eviction resync
    led.evict("a1", "d1")
    assert led.affinity("a1", "d1", ext) == 0.0


def test_affinity_matrix_python_vs_kernel():
    rng = np.random.default_rng(0)
    led = PrefixLedger()
    agents = [f"a{i}" for i in range(4)]
    prompts, dialogues = [], []
    for j in range(5):
        d = f"d{j}"
        dialogues.append(d)
        base = rng.integers(1, 9, size=rng.integers(4, 24)).astype(np.int32)
        prompts.append(base)
        for i, a in enumerate(agents):
            if (i + j) % 3 == 0:
                led.update(a, d, base[: max(1, len(base) // 2)])
    py = led.affinity_matrix(prompts, dialogues, agents)
    kr = led.affinity_matrix(prompts, dialogues, agents, use_kernel=True)
    assert np.allclose(py, kr, atol=1e-6)
    ext_mask = [True, False, True, False]
    py2 = led.affinity_matrix(prompts, dialogues, agents,
                              extension_only_mask=ext_mask)
    kr2 = led.affinity_matrix(prompts, dialogues, agents,
                              extension_only_mask=ext_mask, use_kernel=True)
    assert np.allclose(py2, kr2, atol=1e-6)


def test_ledger_session_cap_is_lru_and_behavior_neutral():
    """max_sessions_per_agent LRU-caps tracked sessions; within the cap the
    index behaves exactly like the unbounded ledger."""
    import numpy as np

    from repro.core.affinity import PrefixLedger

    led = PrefixLedger(max_sessions_per_agent=2)
    tok = lambda *xs: np.asarray(xs, dtype=np.int32)
    led.update("a", "d0", tok(1, 2))
    led.update("a", "d1", tok(3, 4))
    led.update("a", "d0", tok(1, 2, 5))   # touch d0 -> d1 is now oldest
    led.update("a", "d2", tok(6))         # evicts d1, not d0
    assert sorted(led.sessions("a")) == ["d0", "d2"]
    assert led.get("a", "d1") is None
    assert led.get("a", "d0") is not None
    assert led.recent_sessions("a", 2) == {"d0", "d2"}
    # cap sized >= cache_slots keeps recent_sessions(cache_slots) identical
    unbounded = PrefixLedger()
    for d in range(6):
        unbounded.update("a", f"d{d}", tok(d))
    capped = PrefixLedger(max_sessions_per_agent=3)
    for d in range(6):
        capped.update("a", f"d{d}", tok(d))
    assert unbounded.recent_sessions("a", 3) == capped.recent_sessions("a", 3)


def test_router_sizes_ledger_cap_from_published_caches():
    """IEMASRouter bounds the ledger iff every agent publishes a cache size."""
    from repro.core import AgentInfo, IEMASRouter, TokenPrices

    def agents(slots):
        return [AgentInfo(f"a{i}", TokenPrices(0.01, 0.001, 0.03), 2,
                          ("dialogue",), cache_slots=s)
                for i, s in enumerate(slots)]

    r = IEMASRouter(agents([12, 8]))
    assert r.ledger.max_sessions_per_agent == 24
    r2 = IEMASRouter(agents([12, 0]))   # 0 = unknown/unbounded -> no cap
    assert r2.ledger.max_sessions_per_agent is None
    r.add_agent(agents([0, 0])[0].__class__("a-new", TokenPrices(0.01, 0.001, 0.03), 2,
                                            ("dialogue",), cache_slots=0))
    assert r.ledger.max_sessions_per_agent is None


def test_padded_store_incremental_dirty_tracking():
    """consume_dirty exposes exactly the rows written since the last drain
    (the device-mirror scatter contract of the fused routing step)."""
    from repro.core.affinity import PAD_LEDGER, PaddedLedgerStore

    st_ = PaddedLedgerStore()
    r1 = st_.put(("a", "d1"), np.arange(3, dtype=np.int32))
    r2 = st_.put(("a", "d2"), np.arange(5, dtype=np.int32))
    assert set(st_.consume_dirty()) == {r1, r2}
    assert st_.consume_dirty().size == 0          # drained
    st_.put(("a", "d2"), np.arange(4, dtype=np.int32))  # overwrite in place
    assert set(st_.consume_dirty()) == {r2}
    assert st_.lens[r2] == 4
    assert np.all(st_.tokens[r2, 4:] == PAD_LEDGER)  # stale tail cleared
    st_.drop(("a", "d1"))
    assert set(st_.consume_dirty()) == {r1}
    assert st_.lens[r1] == 0
    # recycled row is reused for the next entry
    r3 = st_.put(("b", "d9"), np.arange(2, dtype=np.int32))
    assert r3 == r1


def test_padded_store_regrow_bumps_shape_and_dirties_all():
    """A pow-2 regrow moves every row to a fresh buffer: shape_version bumps
    and the whole live row range re-enters the dirty set so device mirrors
    re-upload instead of scattering into a stale arena."""
    from repro.core.affinity import PAD_LEDGER, PaddedLedgerStore

    st_ = PaddedLedgerStore(floor_rows=8, floor_width=8)
    for k in range(3):
        st_.put(("a", f"d{k}"), np.arange(4, dtype=np.int32))
    st_.consume_dirty()
    sv = st_.shape_version
    st_.put(("a", "wide"), np.arange(20, dtype=np.int32))   # width regrow
    assert st_.shape_version == sv + 1
    assert st_.width == 32                     # pow2_bucket(20)
    dirty = set(st_.consume_dirty())
    assert {st_.row_of[("a", f"d{k}")] for k in range(3)} <= dirty
    # old payloads survived the move, padded with PAD_LEDGER
    row = st_.row_of[("a", "d0")]
    assert np.array_equal(st_.tokens[row, :4], np.arange(4))
    assert np.all(st_.tokens[row, 4:] == PAD_LEDGER)
    # row-count regrow: row 0 stays the reserved all-pad sentinel
    for k in range(12):
        st_.put(("b", f"d{k}"), np.arange(2, dtype=np.int32))
    assert st_.lens[0] == 0
    assert np.all(st_.tokens[0] == PAD_LEDGER)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 5), st.integers(2, 8))
def test_parent_credit_vectorized_matches_scalar(seed, m, n):
    """The flattened batched `parent_credit` (segment-max over gathered
    arena rows) is bit-equal to the retired per-pair scalar oracle, across
    extension-only agents, LRU caps and absent parent entries."""
    rng = np.random.default_rng(seed)
    led = PrefixLedger()
    agents = [f"a{i}" for i in range(m)]
    sessions = [f"s{k}" for k in range(6)]
    for s in sessions:
        for i, a in enumerate(agents):
            if rng.random() < 0.6:
                led.update(a, s, rng.integers(0, 6, rng.integers(1, 15))
                           .astype(np.int32))
    prompts = [rng.integers(0, 6, rng.integers(1, 20)).astype(np.int32)
               for _ in range(n)]
    parent_sessions = [
        [sessions[k] for k in rng.choice(6, rng.integers(0, 4),
                                         replace=False)]
        for _ in range(n)]
    ext = rng.random(m) < 0.4
    slots = rng.integers(0, 4, m)
    o0 = rng.random((n, m)) * 0.3
    vec = led.parent_credit(o0.copy(), prompts, parent_sessions, agents,
                            extension_only_mask=ext, cache_slots=slots)
    ref = led._parent_credit_scalar(o0.copy(), prompts, parent_sessions,
                                    agents, extension_only_mask=ext,
                                    cache_slots=slots)
    assert np.allclose(vec, ref, atol=1e-12), np.abs(vec - ref).max()
