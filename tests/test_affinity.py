"""Prefix ledger / LCP affinity (Eq. 4) incl. recurrent extension-only mode."""
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.affinity import PrefixLedger, lcp_length


def test_lcp_basic():
    assert lcp_length(np.array([1, 2, 3]), np.array([1, 2, 4])) == 2
    assert lcp_length(np.array([1, 2]), np.array([1, 2, 3])) == 2
    assert lcp_length(np.array([], dtype=np.int32), np.array([1])) == 0


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 5), max_size=30),
       st.lists(st.integers(0, 5), max_size=30))
def test_lcp_is_prefix(a, b):
    a, b = np.array(a, np.int32), np.array(b, np.int32)
    l = lcp_length(a, b)
    assert np.array_equal(a[:l], b[:l])
    if l < min(len(a), len(b)):
        assert a[l] != b[l]


def test_affinity_semantics():
    led = PrefixLedger()
    prev = np.arange(10, dtype=np.int32)
    led.update("a1", "d1", prev)
    # exact extension
    ext = np.concatenate([prev, np.array([99, 98], np.int32)])
    assert led.affinity("a1", "d1", ext) == 10 / 12
    assert led.affinity("a1", "d1", ext, extension_only=True) == 10 / 12
    # divergence after 5 tokens
    div = prev.copy()
    div[5] = 77
    assert led.affinity("a1", "d1", div) == 0.5
    assert led.affinity("a1", "d1", div, extension_only=True) == 0.0
    # other agent / session: zero (paper: switching agents loses locality)
    assert led.affinity("a2", "d1", ext) == 0.0
    assert led.affinity("a1", "d2", ext) == 0.0
    # eviction resync
    led.evict("a1", "d1")
    assert led.affinity("a1", "d1", ext) == 0.0


def test_affinity_matrix_python_vs_kernel():
    rng = np.random.default_rng(0)
    led = PrefixLedger()
    agents = [f"a{i}" for i in range(4)]
    prompts, dialogues = [], []
    for j in range(5):
        d = f"d{j}"
        dialogues.append(d)
        base = rng.integers(1, 9, size=rng.integers(4, 24)).astype(np.int32)
        prompts.append(base)
        for i, a in enumerate(agents):
            if (i + j) % 3 == 0:
                led.update(a, d, base[: max(1, len(base) // 2)])
    py = led.affinity_matrix(prompts, dialogues, agents)
    kr = led.affinity_matrix(prompts, dialogues, agents, use_kernel=True)
    assert np.allclose(py, kr, atol=1e-6)
    ext_mask = [True, False, True, False]
    py2 = led.affinity_matrix(prompts, dialogues, agents,
                              extension_only_mask=ext_mask)
    kr2 = led.affinity_matrix(prompts, dialogues, agents,
                              extension_only_mask=ext_mask, use_kernel=True)
    assert np.allclose(py2, kr2, atol=1e-6)


def test_ledger_session_cap_is_lru_and_behavior_neutral():
    """max_sessions_per_agent LRU-caps tracked sessions; within the cap the
    index behaves exactly like the unbounded ledger."""
    import numpy as np

    from repro.core.affinity import PrefixLedger

    led = PrefixLedger(max_sessions_per_agent=2)
    tok = lambda *xs: np.asarray(xs, dtype=np.int32)
    led.update("a", "d0", tok(1, 2))
    led.update("a", "d1", tok(3, 4))
    led.update("a", "d0", tok(1, 2, 5))   # touch d0 -> d1 is now oldest
    led.update("a", "d2", tok(6))         # evicts d1, not d0
    assert sorted(led.sessions("a")) == ["d0", "d2"]
    assert led.get("a", "d1") is None
    assert led.get("a", "d0") is not None
    assert led.recent_sessions("a", 2) == {"d0", "d2"}
    # cap sized >= cache_slots keeps recent_sessions(cache_slots) identical
    unbounded = PrefixLedger()
    for d in range(6):
        unbounded.update("a", f"d{d}", tok(d))
    capped = PrefixLedger(max_sessions_per_agent=3)
    for d in range(6):
        capped.update("a", f"d{d}", tok(d))
    assert unbounded.recent_sessions("a", 3) == capped.recent_sessions("a", 3)


def test_router_sizes_ledger_cap_from_published_caches():
    """IEMASRouter bounds the ledger iff every agent publishes a cache size."""
    from repro.core import AgentInfo, IEMASRouter, TokenPrices

    def agents(slots):
        return [AgentInfo(f"a{i}", TokenPrices(0.01, 0.001, 0.03), 2,
                          ("dialogue",), cache_slots=s)
                for i, s in enumerate(slots)]

    r = IEMASRouter(agents([12, 8]))
    assert r.ledger.max_sessions_per_agent == 24
    r2 = IEMASRouter(agents([12, 0]))   # 0 = unknown/unbounded -> no cap
    assert r2.ledger.max_sessions_per_agent is None
    r.add_agent(agents([0, 0])[0].__class__("a-new", TokenPrices(0.01, 0.001, 0.03), 2,
                                            ("dialogue",), cache_slots=0))
    assert r.ledger.max_sessions_per_agent is None
