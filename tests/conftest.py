import os

# tests must see 1 device (the dry-run forces 512 in its own process only)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
