"""Truthfulness battery: per-round DSIC across every fast Phase-2 backend
(incl. capacitated-column degenerate caps), the documented spill-round
caveat pinned as a regression, and ledger reconciliation under spill."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.iemas_cluster import RouterConfig
from repro.core.auction import (SPILL_HUB, client_utilities, run_auction,
                                run_sharded_auction)
from repro.core.solvers import available_solvers
from repro.serving import SimCluster, make_router, run_workload
from repro.serving.workload import WorkloadSpec, generate

ATOL = 1e-6
# the fast backends only; the interpret-mode pallas kernel repeats the same
# mechanism minutes slower and is exercised by the slow-marked solver tests
SOLVERS = [s for s in ("mcmf", "dense", "dense-jax")
           if s in available_solvers()]


@st.composite
def degenerate_markets(draw):
    """Markets with capacitated columns down to cap 0 (dead agents).

    Shape is FIXED at 5x3 so the jitted dense-jax path traces once for the
    whole property run instead of recompiling per example.
    """
    n, m = 5, 3
    values = np.array([[round(draw(st.floats(0, 5, allow_nan=False)), 3)
                        for _ in range(m)] for _ in range(n)])
    costs = np.array([[round(draw(st.floats(0, 3, allow_nan=False)), 3)
                       for _ in range(m)] for _ in range(n)])
    caps = [draw(st.integers(0, 2)) for _ in range(m)]  # 0 = degenerate
    return values, costs, caps


def _slack(solver, *results):
    """DSIC slack: exact backends get ATOL; the float32 eps-scaling path is
    granted its own certified optimality gap on top."""
    if solver in ("mcmf", "dense"):
        return ATOL
    gap = sum(float(r.solver_stats.get("gap_bound", 0.0)) for r in results)
    return max(ATOL, gap + 1e-4)


@settings(max_examples=25, deadline=None)
@given(degenerate_markets(), st.integers(0, 4), st.floats(-2, 2))
def test_dsic_every_backend_degenerate_caps(mkt, j_idx, deviation):
    """Honest utility >= every misreport, per round, on every registered
    fast backend — including markets with zero-capacity columns."""
    values, costs, caps = mkt
    j = j_idx % values.shape[0]
    lied = values.copy()
    lied[j] = np.maximum(lied[j] + deviation, 0.0)
    for solver in SOLVERS:
        honest = run_auction(values, costs, caps, solver=solver)
        strategic = run_auction(lied, costs, caps, solver=solver)
        u_honest = client_utilities(honest, values)[j]
        u_lied = client_utilities(strategic, values)[j]  # at TRUE values
        assert u_lied <= u_honest + _slack(solver, honest, strategic), solver


def test_all_caps_zero_routes_nothing():
    """A fully dead market (every column cap 0) matches and charges nobody
    on every backend."""
    values = np.array([[3.0, 1.0], [2.0, 2.5]])
    costs = np.zeros((2, 2))
    for solver in SOLVERS:
        r = run_auction(values, costs, [0, 0], solver=solver)
        assert r.assignment == [-1, -1], solver
        assert all(p == 0.0 for p in r.payments), solver


# ---------------------------------------------------------------------------
# the spill-round caveat (mechanism.py Phase-2 docstring): Clarke pivots are
# per-market, so a bidder who tanks round 1 to buy uncontested residual
# capacity in the cross-hub spill round can profit.  Pin it.
# ---------------------------------------------------------------------------

# hub 0 owns only agent 0; both requests are pinned there, agent 1 is pure
# residual capacity only the spill round can reach.
_SPILL_VALUES = np.array([[4.9, 0.0], [5.0, 4.8]])
_SPILL_COSTS = np.zeros((2, 2))
_SPILL_CAPS = [1, 1]
_SPILL_BLOCKS = {0: ([0, 1], [0])}


def _true_utility(reported, *, spill):
    """Run the sharded market and return request 1's utility at TRUE values
    (plus which round, if any, served it)."""
    res = run_sharded_auction(reported, _SPILL_COSTS, _SPILL_CAPS,
                              _SPILL_BLOCKS, solver="dense", spill=spill,
                              spill_agents=[0, 1])
    reqs, ags = _SPILL_BLOCKS[0]
    for bj, bi in enumerate(res[0].assignment):
        if reqs[bj] == 1 and bi >= 0:
            return _SPILL_VALUES[1, ags[bi]] - res[0].payments[bj], "round1"
    if spill and SPILL_HUB in res:
        sp = res[SPILL_HUB]
        meta = sp.solver_stats["spill"]
        for bj, bi in enumerate(sp.assignment):
            if meta["r_idx"][bj] == 1 and bi >= 0:
                return (_SPILL_VALUES[1, meta["a_idx"][bi]]
                        - sp.payments[bj], "spill")
    return 0.0, "unmatched"


def test_spill_round_dsic_caveat_regression():
    """With spill=True the documented manipulation PROFITS: request 1 tanks
    its in-hub bid, loses round 1 on purpose, and buys agent 1's
    uncontested residual slot for free in the spill round."""
    u_honest, how_h = _true_utility(_SPILL_VALUES, spill=True)
    assert how_h == "round1"
    assert u_honest == pytest.approx(0.1)  # wins agent 0, pays 4.9
    lied = _SPILL_VALUES.copy()
    lied[1, 0] = 0.0  # tank the contested in-hub bid
    u_lied, how_l = _true_utility(lied, spill=True)
    assert how_l == "spill"
    assert u_lied == pytest.approx(4.8)  # free residual slot, true value
    # the caveat is real: misreporting strictly beats honesty across rounds
    assert u_lied > u_honest + 1.0


def test_no_spill_restores_strict_dsic_on_caveat_instance():
    """spill=False closes the loophole: the same tank now strands request 1
    entirely, so honesty dominates again."""
    u_honest, _ = _true_utility(_SPILL_VALUES, spill=False)
    lied = _SPILL_VALUES.copy()
    lied[1, 0] = 0.0
    u_lied, how = _true_utility(lied, spill=False)
    assert how == "unmatched"
    assert u_lied <= u_honest + ATOL


def test_ledger_reconciles_under_spill_and_faults():
    """End-to-end: sharded router with the spill round live AND injected
    faults — the hash chain must verify and the replay balances must equal
    the router's accounts to the bit."""
    cluster = SimCluster(8, seed=3, fail_prob=0.15, engine_mode="analytic")
    router = make_router(cluster, RouterConfig(
        solver="dense", n_hubs=2, warm_start=True, spill=True,
        audit_ledger=True))
    spec = WorkloadSpec("coqa_like", n_dialogues=8, seed=4)
    run_workload(cluster, router, generate(spec), max_new_tokens=4)
    led = router.settlement
    assert led.verify_chain()
    balances = led.audit(router.accounts)  # raises on any divergence
    # every matched dispatch completes exactly once: as a settlement or as
    # a fault entry (faulted requests re-auction and re-match, so matched
    # counts the retry separately)
    assert balances["settled"] + balances["faults"] == \
        router.accounts["matched"]
    assert balances["faults"] > 0  # the fault path actually fired
    # exact replay: summing the ledger alone reproduces the books
    assert balances["payments"] == router.accounts["payments"]
    assert balances["welfare_realized"] == router.accounts["welfare_realized"]
