"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lcp_affinity import lcp_affinity
from repro.kernels.ref import (attention_ref, decode_attention_ref, lcp_ref,
                               ssd_ref, wkv6_ref)
from repro.kernels.ssd import ssd
from repro.kernels.wkv6 import wkv6

pytestmark = pytest.mark.slow  # excluded from tier-1; run with -m ""


@pytest.mark.parametrize("n,m,l", [(3, 5, 17), (8, 8, 64), (10, 3, 33),
                                   (1, 1, 8), (9, 17, 128)])
def test_lcp_kernel(n, m, l, rng):
    p = rng.integers(0, 4, (n, l)).astype(np.int32)
    led = rng.integers(0, 4, (n, m, l)).astype(np.int32)
    led[0, 0] = p[0]
    got = np.asarray(lcp_affinity(jnp.asarray(p), jnp.asarray(led)))
    assert np.array_equal(got, lcp_ref(p, led))


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,h,hkv,d,causal,win", [
    (2, 64, 4, 2, 32, True, 0),
    (1, 100, 4, 4, 16, True, 0),
    (2, 128, 8, 2, 64, True, 48),
    (1, 37, 2, 1, 32, False, 0),
    (1, 256, 4, 4, 128, True, 0),
])
def test_flash_attention_kernel(b, sq, h, hkv, d, causal, win, dtype, rng):
    q = rng.standard_normal((b, sq, h, d)).astype(dtype)
    k = rng.standard_normal((b, sq, hkv, d)).astype(dtype)
    v = rng.standard_normal((b, sq, hkv, d)).astype(dtype)
    got = np.asarray(flash_attention(q, k, v, causal=causal, window=win,
                                     bq=32, bk=32), np.float32)
    want = np.asarray(attention_ref(q, k, v, causal=causal, window=win),
                      np.float32)
    tol = 2e-5 if dtype == np.float32 else 3e-2
    assert np.max(np.abs(got - want)) < tol


@pytest.mark.parametrize("b,h,hkv,d,m,bk", [
    (2, 4, 2, 32, 100, 32), (1, 8, 8, 64, 257, 64), (3, 6, 2, 16, 48, 16)])
def test_decode_attention_kernel(b, h, hkv, d, m, bk, rng):
    q = rng.standard_normal((b, h, d)).astype(np.float32)
    kc = rng.standard_normal((b, m, hkv, d)).astype(np.float32)
    vc = rng.standard_normal((b, m, hkv, d)).astype(np.float32)
    valid = rng.random((b, m)) < 0.7
    valid[:, 0] = True
    got = np.asarray(decode_attention(q, kc, vc, jnp.asarray(valid), bk=bk))
    want = np.asarray(decode_attention_ref(q, kc, vc, jnp.asarray(valid)))
    assert np.max(np.abs(got - want)) < 2e-5


@pytest.mark.parametrize("b,s,h,dk", [(2, 48, 3, 16), (1, 35, 2, 32),
                                      (2, 16, 1, 8)])
def test_wkv6_kernel_vs_recurrence(b, s, h, dk, rng):
    r, k, v = (rng.standard_normal((b, s, h, dk)).astype(np.float32)
               for _ in range(3))
    lw = -np.exp(rng.standard_normal((b, s, h, dk))).astype(np.float32)
    lw = np.clip(lw, -4.0, -0.001)
    u = rng.standard_normal((h, dk)).astype(np.float32)
    s0 = np.zeros((b, h, dk, dk), np.float32)
    got_o, got_s = wkv6(r, k, v, lw, u)
    want_o, want_s = wkv6_ref(r, k, v, lw, u, s0)
    assert np.max(np.abs(np.asarray(got_o) - np.asarray(want_o))) < 1e-3
    assert np.max(np.abs(np.asarray(got_s) - np.asarray(want_s))) < 1e-3


@pytest.mark.parametrize("b,s,h,hd,ds", [(2, 48, 3, 16, 8), (1, 37, 2, 32, 16)])
def test_ssd_kernel_vs_recurrence(b, s, h, hd, ds, rng):
    x = rng.standard_normal((b, s, h, hd)).astype(np.float32)
    bm = rng.standard_normal((b, s, ds)).astype(np.float32)
    cm = rng.standard_normal((b, s, ds)).astype(np.float32)
    dt = np.abs(rng.standard_normal((b, s, h))).astype(np.float32) * 0.5
    a_log = rng.standard_normal(h).astype(np.float32) * 0.3
    dsk = rng.standard_normal(h).astype(np.float32)
    s0 = np.zeros((b, h, hd, ds), np.float32)
    got_y, got_s = ssd(x, bm, cm, dt, a_log, dsk)
    want_y, want_s = ssd_ref(x, bm, cm, dt, a_log, dsk, s0)
    assert np.max(np.abs(np.asarray(got_y) - np.asarray(want_y))) < 1e-3
    assert np.max(np.abs(np.asarray(got_s) - np.asarray(want_s))) < 1e-3


def test_model_chunked_paths_match_kernels(rng):
    """models/ssm chunked jnp forms == Pallas kernels == stepwise oracle."""
    from repro.models.ssm import ssd_chunked, wkv6_chunked

    b, s, h, dk = 2, 40, 2, 16
    r, k, v = (rng.standard_normal((b, s, h, dk)).astype(np.float32)
               for _ in range(3))
    lw = np.clip(-np.exp(rng.standard_normal((b, s, h, dk))), -4, -1e-3
                 ).astype(np.float32)
    u = rng.standard_normal((h, dk)).astype(np.float32)
    s0 = np.zeros((b, h, dk, dk), np.float32)
    o_jnp, s_jnp = wkv6_chunked(r, k, v, lw, u, s0)
    o_ker, s_ker = wkv6(r, k, v, lw, u)
    assert np.max(np.abs(np.asarray(o_jnp) - np.asarray(o_ker))) < 1e-3
    assert np.max(np.abs(np.asarray(s_jnp) - np.asarray(s_ker))) < 1e-3
