"""Event-driven serving simulator: determinism, closed-loop parity with
run_workload, streaming admission, profiler attribution, 10k smoke.

Everything here runs on ``engine_mode="analytic"`` clusters — deterministic
virtual service times, so records can be compared bit-for-bit."""
import json

import numpy as np
import pytest

from repro.core import IEMASRouter
from repro.serving import (DialogueScript, EventSimulator, PoissonArrivals,
                           RoutingProfiler, SimCluster, SyncArrivals,
                           TraceArrivals, WorkloadSpec, generate,
                           iter_dialogues, load_trace, make_arrivals,
                           run_workload)


def _fresh(seed=0, n_agents=4, fail=0.0, **router_kw):
    cluster = SimCluster(n_agents=n_agents, seed=seed, max_new_tokens=3,
                         engine_mode="analytic", fail_prob=fail)
    kw = dict(solver="dense", n_hubs=2, warm_start=True)
    kw.update(router_kw)
    router = IEMASRouter(cluster.agent_infos(), **kw)
    return cluster, router


def _sig(cluster):
    """Bit-comparable per-record signature, in completion order."""
    return [(r.request.request_id, r.request.dialogue_id, r.request.turn,
             r.agent_id, r.n_prompt, r.n_hit, r.payment, r.latency,
             r.dispatched_at) for r in cluster.records]


# -------------------------------------------------- closed-loop parity --
@pytest.mark.parametrize("fail", [0.0, 0.2])
def test_lockstep_parity_with_run_workload(fail):
    """With synchronous arrivals and quantized round ticks the event
    simulator reproduces run_workload's decisions bit-for-bit — including
    the fault path (same rng draw order)."""
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=7, seed=3))
    c1, r1 = _fresh(fail=fail)
    m1 = run_workload(c1, r1, dlg, max_rounds=3000, max_new_tokens=3,
                      batch_per_round=4)
    c2, r2 = _fresh(fail=fail)
    m2 = EventSimulator(c2, r2, dlg, arrivals=SyncArrivals(), batch_cap=4,
                        quantize=0.05, max_rounds=3000,
                        max_new_tokens=3).run()
    assert _sig(c1) == _sig(c2)
    for key in ("n", "kv_hit_rate", "latency_ms_mean", "cost_mean",
                "quality_mean", "completed_turns", "dispatched_requests"):
        assert m1[key] == m2[key], key
    assert m2["dialogues_completed"] == len(dlg)
    assert not m1["truncated"] and not m2["truncated"]


def test_lockstep_parity_other_workloads():
    """Parity holds across workload families (different turn structure)."""
    for family in ("quac_like", "hotpot_like"):
        dlg = generate(WorkloadSpec(family, n_dialogues=4, seed=1))
        c1, r1 = _fresh(seed=2)
        run_workload(c1, r1, dlg, max_rounds=2000, max_new_tokens=3)
        c2, r2 = _fresh(seed=2)
        EventSimulator(c2, r2, dlg, arrivals=SyncArrivals(), batch_cap=16,
                       quantize=0.05, max_rounds=2000,
                       max_new_tokens=3).run()
        assert _sig(c1) == _sig(c2), family


# ------------------------------------------------------- determinism --
def test_event_ordering_determinism():
    """Two identical open-loop runs (Poisson arrivals, failures on) replay
    the exact same event order, decisions and metrics under a fixed seed."""
    def once():
        cluster, router = _fresh(seed=5, fail=0.15)
        spec = WorkloadSpec("coqa_like", n_dialogues=12, seed=9)
        out = EventSimulator(
            cluster, router, iter_dialogues(spec),
            arrivals=PoissonArrivals(rate=6.0, seed=11), batch_cap=8,
            batch_window=0.02, max_inflight=6, max_new_tokens=3).run()
        return _sig(cluster), out

    sig_a, out_a = once()
    sig_b, out_b = once()
    assert sig_a == sig_b
    drop = ("wall_time_s",)  # the only wall-clock-dependent key
    assert {k: v for k, v in out_a.items() if k not in drop} == \
        {k: v for k, v in out_b.items() if k not in drop}


# ------------------------------------------------ streaming admission --
def test_admission_window_bounds_inflight():
    """10k-style streaming: at most max_inflight dialogues hold state at
    once; the rest queue in the backlog and everything still completes."""
    cluster, router = _fresh(seed=1)
    spec = WorkloadSpec("coqa_like", n_dialogues=10, seed=4)
    out = EventSimulator(cluster, router, iter_dialogues(spec),
                         arrivals=SyncArrivals(), batch_cap=8,
                         batch_window=0.02, max_inflight=3,
                         max_new_tokens=3).run()
    assert out["peak_inflight"] <= 3
    assert out["dialogues_arrived"] == 10
    assert out["dialogues_completed"] == 10
    assert out["unfinished_dialogues"] == 0 and not out["truncated"]
    # a window that can never admit anything is a configuration error, not
    # a silent no-op run
    with pytest.raises(ValueError, match="max_inflight"):
        EventSimulator(cluster, router, [], max_inflight=0)


def test_trace_arrivals_and_open_loop_pacing():
    """TraceArrivals replays explicit timestamps; arrivals pace admission
    (the second dialogue cannot be dispatched before its arrival time)."""
    cluster, router = _fresh(seed=3)
    dlg = generate(WorkloadSpec("hotpot_like", n_dialogues=3, seed=2))
    out = EventSimulator(cluster, router, dlg,
                         arrivals=TraceArrivals((0.0, 2.0, 2.5)),
                         batch_cap=4, batch_window=0.01,
                         max_new_tokens=3).run()
    assert out["dialogues_completed"] == 3
    first_dispatch = {}
    for rec in cluster.records:
        did = rec.request.dialogue_id
        first_dispatch.setdefault(did, rec.dispatched_at)
    times = [first_dispatch[d.dialogue_id] for d in dlg]
    assert times[1] >= 2.0 and times[2] >= 2.5


def test_short_trace_ends_arrivals_loudly():
    """A trace shorter than the dialogue stream stops arrivals (zip
    semantics) but flags the run instead of crashing or dropping silently."""
    cluster, router = _fresh(seed=3)
    dlg = generate(WorkloadSpec("hotpot_like", n_dialogues=5, seed=2))
    with pytest.warns(RuntimeWarning, match="arrival process exhausted"):
        out = EventSimulator(cluster, router, dlg,
                             arrivals=TraceArrivals((0.0, 0.5)),
                             batch_cap=4, batch_window=0.01,
                             max_new_tokens=3).run()
    assert out["truncated"]
    assert out["dialogues_arrived"] == 2
    assert out["dialogues_completed"] == 2


def test_truncation_reported_with_warning():
    """Hitting the round budget surfaces unfinished dialogues + a warning
    instead of returning partial metrics silently."""
    cluster, router = _fresh(seed=0)
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=6, seed=3))
    with pytest.warns(RuntimeWarning, match="truncated"):
        out = EventSimulator(cluster, router, dlg, arrivals=SyncArrivals(),
                             batch_cap=2, quantize=0.05, max_rounds=3,
                             max_new_tokens=3).run()
    assert out["truncated"]
    assert out["unfinished_dialogues"] > 0
    assert out["dialogues_completed"] < 6


# ------------------------------------------------------- profiler --
def test_profiler_attribution():
    """The RoutingProfiler sees every phase the router runs and reports
    overhead as routing wall-clock over simulated engine seconds."""
    cluster, router = _fresh(seed=2)
    prof = RoutingProfiler()
    spec = WorkloadSpec("coqa_like", n_dialogues=6, seed=7)
    out = EventSimulator(cluster, router, iter_dialogues(spec),
                         arrivals=PoissonArrivals(rate=8.0, seed=3),
                         batch_cap=8, profiler=prof, lean=True,
                         max_new_tokens=3).run()
    rep = out["routing"]
    assert rep["engine_compute_s"] > 0
    assert rep["routing_wall_s"] > 0
    assert rep["overhead_frac"] == pytest.approx(
        rep["routing_wall_s"] / rep["engine_compute_s"])
    for phase in ("route_batch", "phase1_predict", "phase2_solve[dense]",
                  "price_book", "phase4_feedback"):
        assert phase in rep["phases"], phase
        assert rep["phases"][phase]["calls"] > 0
    # nested phases are inside the umbrella, never bigger than it
    assert rep["phases"]["phase1_predict"]["wall_s"] <= \
        rep["phases"]["route_batch"]["wall_s"]
    # engine compute matches the telemetry busy-seconds hook
    assert rep["engine_compute_s"] == pytest.approx(
        cluster.telemetry.busy_seconds())


def test_profiler_noop_when_absent():
    """Without a profiler nothing is attached and routing still works."""
    cluster, router = _fresh(seed=2)
    assert cluster.profiler is None and router.profiler is None
    out = EventSimulator(cluster, router,
                         generate(WorkloadSpec("coqa_like", n_dialogues=2,
                                               seed=1)),
                         max_new_tokens=3).run()
    assert "routing" not in out
    assert out["dialogues_completed"] == 2


# ---------------------------------------------- empty-round guard --
def test_no_empty_route_rounds_in_quantize_mode():
    """ISSUE-6 satellite 3 regression (fails pre-fix): the quantize regime
    fires a ROUTE tick on every round boundary even while all dialogues are
    busy; ticks with no ready work must not invoke the router, count a
    round, burn max_rounds budget, or fire on_round."""
    cluster, router = _fresh(seed=2)
    prof = RoutingProfiler()
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=5, seed=3))
    on_round_calls = []
    out = EventSimulator(cluster, router, dlg, arrivals=SyncArrivals(),
                         batch_cap=4, quantize=0.05, profiler=prof,
                         max_new_tokens=3,
                         on_round=lambda r, c: on_round_calls.append(r)).run()
    assert out["dialogues_completed"] == 5 and not out["truncated"]
    # every counted round was one real router invocation with work in it
    assert out["rounds"] == prof.calls["route_batch"]
    assert prof.empty_route_calls == 0
    assert prof.route_requests >= out["dispatched_requests"]
    assert on_round_calls == list(range(1, out["rounds"] + 1))


def test_empty_round_guard_preserves_decisions():
    """The guard is pure accounting: the routed records are bit-identical
    to the run_workload oracle (the lockstep parity contract still holds
    with rounds now counting only real router invocations)."""
    dlg = generate(WorkloadSpec("quac_like", n_dialogues=5, seed=8))
    c1, r1 = _fresh(seed=6)
    run_workload(c1, r1, dlg, max_rounds=2000, max_new_tokens=3,
                 batch_per_round=3)
    c2, r2 = _fresh(seed=6)
    out = EventSimulator(c2, r2, dlg, arrivals=SyncArrivals(), batch_cap=3,
                         quantize=0.05, max_rounds=2000,
                         max_new_tokens=3,
                         profiler=RoutingProfiler()).run()
    assert _sig(c1) == _sig(c2)
    assert out["routing"]["empty_route_calls"] == 0


# ------------------------------------------------- incremental mode --
def test_incremental_mode_dispatches_and_reconciles():
    """incremental=True: once standing duals exist, newly-ready dialogues
    are provisionally dispatched at posted prices (no batch-window wait);
    the next batch auction or the completion path retires every
    provisional, and the run drains cleanly."""
    cluster, router = _fresh(seed=4)
    spec = WorkloadSpec("coqa_like", n_dialogues=10, seed=6)
    out = EventSimulator(cluster, router, iter_dialogues(spec),
                         arrivals=PoissonArrivals(rate=4.0, seed=7),
                         batch_cap=8, batch_window=0.05, incremental=True,
                         max_new_tokens=3).run()
    assert out["dialogues_completed"] == 10 and not out["truncated"]
    acc = router.accounts
    assert out["incremental_dispatched"] == acc["incremental_routed"]
    assert acc["incremental_routed"] > 0
    assert acc["incremental_confirmed"] + acc["incremental_rerouted"] <= \
        acc["incremental_routed"]
    # nothing left provisional after the run drains
    assert not router._provisional and not router._prov_units


def test_incremental_mode_deterministic():
    """Two identical incremental runs replay the same records + metrics."""
    def once():
        cluster, router = _fresh(seed=9)
        spec = WorkloadSpec("coqa_like", n_dialogues=8, seed=5)
        out = EventSimulator(cluster, router, iter_dialogues(spec),
                             arrivals=PoissonArrivals(rate=5.0, seed=13),
                             batch_cap=6, batch_window=0.03,
                             incremental=True, max_new_tokens=3).run()
        return _sig(cluster), out
    sig_a, out_a = once()
    sig_b, out_b = once()
    assert sig_a == sig_b
    drop = ("wall_time_s",)
    assert {k: v for k, v in out_a.items() if k not in drop} == \
        {k: v for k, v in out_b.items() if k not in drop}


def test_incremental_off_is_default_noop():
    """The flag defaults off; without it nothing is provisionally routed."""
    cluster, router = _fresh(seed=1)
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=4, seed=2))
    out = EventSimulator(cluster, router, dlg, arrivals=SyncArrivals(),
                         batch_cap=8, quantize=0.05, max_new_tokens=3).run()
    assert out["incremental_dispatched"] == 0
    assert router.accounts["incremental_routed"] == 0


# ----------------------------------------- id/wait-clock regressions --
def test_request_ids_unique_across_deferral_and_faults():
    """ISSUE-7 satellite 1 regression (fails pre-fix): incremental offers
    that get deferred must still burn their request id — under a mixed
    deferral/fault trace no id may ever be re-issued to a different
    request (router/profiler state is keyed by request_id)."""
    cluster, router = _fresh(seed=4, fail=0.15)
    seen_rids, deferred = [], [0]
    orig_batch, orig_inc = router.route_batch, router.route_incremental

    def batch(reqs, telem, free_slots=None):
        seen_rids.extend(r.request_id for r in reqs)
        return orig_batch(reqs, telem, free_slots=free_slots)

    def inc(reqs, telem, free_slots=None):
        seen_rids.extend(r.request_id for r in reqs)
        decs = orig_inc(reqs, telem, free_slots=free_slots)
        deferred[0] += sum(d.agent_id is None for d in decs)
        return decs

    router.route_batch, router.route_incremental = batch, inc
    spec = WorkloadSpec("coqa_like", n_dialogues=10, seed=6)
    out = EventSimulator(cluster, router, iter_dialogues(spec),
                         arrivals=PoissonArrivals(rate=6.0, seed=7),
                         batch_cap=6, batch_window=0.03, incremental=True,
                         max_new_tokens=3).run()
    assert out["dialogues_completed"] == 10 and not out["truncated"]
    # the trace really mixed the regimes: provisional dispatches AND
    # deferred offers (the pre-fix id-reuse trigger) both happened
    assert out["incremental_dispatched"] > 0
    assert deferred[0] > 0
    assert len(seen_rids) == len(set(seen_rids)), \
        "a request_id was re-issued to a different request"


def test_fault_retry_preserves_wait_clock():
    """ISSUE-7 satellite 2 regression (fails pre-fix): a failed dispatch
    re-queues its turn with the ORIGINAL ready time — resetting the clock
    to the failure completion under-reports queueing wait across retries."""
    cluster = SimCluster(n_agents=1, seed=0, max_new_tokens=3,
                         engine_mode="analytic", quarantine_cooldown=0.5)
    router = IEMASRouter(cluster.agent_infos(), solver="dense", n_hubs=1,
                         warm_start=True)
    rt = next(iter(cluster.agents.values()))
    rt.down_until = 1.0   # first dispatch fails; the agent recovers at t=1
    rng = np.random.default_rng(0)
    dlg = [DialogueScript("w0", next(iter(rt.info.domains)),
                          [rng.integers(1, 255, 20, dtype=np.int32)], 0.3)]
    out = EventSimulator(cluster, router, dlg, arrivals=SyncArrivals(),
                         batch_cap=2, quantize=0.05, max_new_tokens=3).run()
    assert out["dialogues_completed"] == 1 and not out["truncated"]
    [rec] = cluster.records
    t_disp = rec.dispatched_at
    assert t_disp >= 1.0 - 1e-9   # redispatch only after the recovery
    # two dispatches accrued wait: the failed one waited 0 (ready and
    # dispatched at t=0), the retry is charged from the original t=0 ready
    # time -> mean wait is t_disp/2 exactly (pre-fix: (t_disp - 0.05)/2,
    # the clock restarted at the failure completion)
    assert out["queue_wait_mean_s"] == pytest.approx(t_disp / 2)


# ------------------------------------------------- trace CLI wiring --
def test_load_trace_and_make_arrivals(tmp_path):
    """ISSUE-7 satellite 3: load_trace parses timestamp files (comments,
    blanks, loud errors) and make_arrivals wires every process by name."""
    p = tmp_path / "trace.txt"
    p.write_text("# arrival trace\n0.0\n1.5  # second dialogue\n\n2.5\n")
    ts = load_trace(p)
    assert ts == (0.0, 1.5, 2.5)
    arr = make_arrivals("trace", trace=ts)
    assert isinstance(arr, TraceArrivals)
    assert list(arr.times()) == [0.0, 1.5, 2.5]
    assert isinstance(make_arrivals("sync"), SyncArrivals)
    assert isinstance(make_arrivals("poisson", rate=2.0), PoissonArrivals)
    with pytest.raises(ValueError, match="--trace-file"):
        make_arrivals("trace")          # no timestamps supplied
    with pytest.raises(KeyError, match=r"sync\|poisson\|trace"):
        make_arrivals("uniform")
    bad = tmp_path / "bad.txt"
    bad.write_text("0.0\nnot-a-time\n")
    with pytest.raises(ValueError, match=r"bad\.txt:2"):
        load_trace(bad)
    empty = tmp_path / "empty.txt"
    empty.write_text("# nothing here\n\n")
    with pytest.raises(ValueError, match="empty arrival trace"):
        load_trace(empty)


def test_trace_sorted_validation_error_path():
    """An out-of-order trace fails loudly — directly and through a run."""
    with pytest.raises(ValueError, match="non-decreasing"):
        list(TraceArrivals((0.0, 2.0, 1.0)).times())
    cluster, router = _fresh(seed=1)
    dlg = generate(WorkloadSpec("hotpot_like", n_dialogues=3, seed=2))
    with pytest.raises(ValueError, match="non-decreasing"):
        EventSimulator(cluster, router, dlg,
                       arrivals=TraceArrivals((0.0, 2.0, 1.0)),
                       batch_cap=4, batch_window=0.01,
                       max_new_tokens=3).run()


def test_serve_cli_trace_file(tmp_path, capsys, monkeypatch):
    """--trace-file reaches the event simulator end to end (the arrivals
    pace admission), and DAG workloads are rejected in closed mode."""
    from repro.launch import serve
    trace = tmp_path / "arrivals.txt"
    trace.write_text("0.0\n0.4\n")
    monkeypatch.setattr("sys.argv", [
        "serve", "--sim-mode", "event", "--trace-file", str(trace),
        "--workload", "hotpot_like", "--agents", "4", "--dialogues", "2",
        "--solver", "dense", "--router", "iemas"])
    serve.main()
    out = json.loads(capsys.readouterr().out)
    assert out["dialogues_arrived"] == 2
    assert out["dialogues_completed"] == 2 and not out["truncated"]
    # second dialogue cannot dispatch before its traced arrival at t=0.4
    assert out["sim_time_s"] >= 0.4
    monkeypatch.setattr("sys.argv", ["serve", "--workload", "dag_handoff"])
    with pytest.raises(SystemExit):
        serve.main()                     # DAG needs --sim-mode event


# ------------------------------------------------------- 10k smoke --
@pytest.mark.slow
def test_10k_dialogue_scale_smoke():
    """The headline streaming regime: 10k dialogues flow through a bounded
    window on a 64-agent analytic cluster with overhead attribution."""
    cluster = SimCluster(n_agents=64, seed=0, engine_mode="analytic",
                         max_new_tokens=4)
    router = IEMASRouter(cluster.agent_infos(), solver="dense", n_hubs=4,
                         warm_start=True)
    spec = WorkloadSpec("coqa_like", n_dialogues=10_000, seed=1)
    out = EventSimulator(cluster, router, iter_dialogues(spec),
                         arrivals=PoissonArrivals(rate=64.0, seed=2),
                         batch_cap=64, batch_window=0.05, max_inflight=256,
                         profiler=RoutingProfiler(), lean=True,
                         max_new_tokens=4, max_events=20_000_000,
                         max_rounds=2_000_000).run()
    assert out["dialogues_arrived"] == 10_000
    assert out["dialogues_completed"] == 10_000
    assert out["unfinished_dialogues"] == 0 and not out["truncated"]
    assert out["peak_inflight"] <= 256
    assert out["routing"]["engine_compute_s"] > 0
    assert 0 < out["routing"]["overhead_frac"] < 10
