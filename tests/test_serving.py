"""Serving engine + cluster: prefix reuse accounting, TTFT causality,
fault tolerance, straggler pricing, elastic membership."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import IEMASRouter
from repro.core.baselines import RandomRouter
from repro.serving import SimCluster, WorkloadSpec, generate, run_workload
from repro.serving.engine import AgentEngine

pytestmark = pytest.mark.slow  # excluded from tier-1; run with -m ""


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen3-8b").scaled(dtype="float32", vocab_size=64,
                                        qk_norm=False)
    return AgentEngine(cfg, seed=0, max_len=256, max_new_tokens=3)


def test_prefix_reuse_accounting(engine):
    rng = np.random.default_rng(0)
    p1 = rng.integers(1, 60, 40).astype(np.int32)
    r1 = engine.serve("dlg", p1)
    assert r1.n_hit == 0 and r1.n_prompt == 40 and r1.n_gen == 3

    # turn 2 extends turn 1's prompt + the engine's actual answer
    p2 = np.concatenate([p1, r1.output_tokens,
                         rng.integers(1, 60, 7).astype(np.int32)])
    r2 = engine.serve("dlg", p2)
    assert r2.n_hit == 43  # prompt + generated tokens were cached
    assert r2.n_prompt == 50

    # unrelated prompt in the same session: partial/zero reuse only
    p3 = rng.integers(1, 60, 40).astype(np.int32)
    r3 = engine.serve("dlg", p3)
    assert r3.n_hit < 5


def test_cache_hit_reduces_ttft(engine):
    rng = np.random.default_rng(1)
    base = rng.integers(1, 60, 200).astype(np.int32)
    engine.drop_session("t")
    fresh = [engine.serve("t2%d" % i, base, max_new_tokens=1).ttft
             for i in range(3)]
    ext = []
    prev = base
    for i in range(3):
        prev = np.concatenate([prev, rng.integers(1, 60, 4).astype(np.int32)])
        ext.append(engine.serve("t20", prev, max_new_tokens=1).ttft)
    # warm the session first
    engine.serve("t20", base, max_new_tokens=1)
    assert np.median(ext) < np.median(fresh)


def test_lru_eviction(engine):
    engine.sessions.clear()
    engine.cache_slots = 3
    rng = np.random.default_rng(2)
    for i in range(5):
        engine.serve(f"s{i}", rng.integers(1, 60, 20).astype(np.int32),
                     now=float(i))
    assert len(engine.sessions) == 3
    assert "s0" not in engine.sessions and "s4" in engine.sessions


def test_failure_quarantine_and_retry():
    cluster = SimCluster(n_agents=3, seed=0, max_new_tokens=2, fail_prob=0.3)
    router = IEMASRouter(cluster.agent_infos())
    dialogues = generate(WorkloadSpec("coqa_like", n_dialogues=3, seed=2))
    m = run_workload(cluster, router, dialogues, max_rounds=2500)
    # every turn eventually completes despite 30% failure injection
    expected = sum(len(d.turns) for d in dialogues)
    assert m["n"] == expected


def test_straggler_priced_out():
    """The latency predictor learns per-agent slowness and the auction
    shifts traffic away (paper's mechanism IS the mitigation)."""
    cluster = SimCluster(n_agents=4, seed=1, max_new_tokens=2)
    # make one agent a permanent straggler
    straggler = list(cluster.agents)[0]
    cluster.agents[straggler].straggle_prob = 1.0
    cluster.agents[straggler].straggle_factor = 25.0
    router = IEMASRouter(cluster.agent_infos(),
                         predictor_kw={"warm_n": 3})
    dialogues = generate(WorkloadSpec("coqa_like", n_dialogues=6, seed=3))
    run_workload(cluster, router, dialogues, max_rounds=1500)
    share = (sum(1 for r in cluster.records if r.agent_id == straggler)
             / max(len(cluster.records), 1))
    late = [r.agent_id for r in cluster.records[len(cluster.records) // 2:]]
    late_share = late.count(straggler) / max(len(late), 1)
    assert late_share <= share + 1e-9
    assert late_share < 0.25  # well below uniform 1/4 by the end


def test_elastic_add_remove():
    cluster = SimCluster(n_agents=3, seed=0, max_new_tokens=2)
    router = IEMASRouter(cluster.agent_infos())
    from repro.configs.iemas_cluster import agent_profiles
    new_prof = agent_profiles(5, seed=9)[4]
    cluster.add_agent(new_prof, router)
    assert new_prof.agent_id in [a.agent_id for a in router.agents]
    dialogues = generate(WorkloadSpec("coqa_like", n_dialogues=2, seed=4))
    m = run_workload(cluster, router, dialogues, max_rounds=800)
    assert m["n"] > 0
    cluster.remove_agent(new_prof.agent_id, router)
    assert new_prof.agent_id not in [a.agent_id for a in router.agents]
    # routing continues after removal
    d2 = generate(WorkloadSpec("coqa_like", n_dialogues=2, seed=5))
    cluster.records.clear()
    m2 = run_workload(cluster, router, d2, max_rounds=800)
    assert m2["n"] > 0


def test_iemas_beats_random_on_cache_and_cost():
    results = {}
    for name, mk in (("iemas", lambda a: IEMASRouter(a)),
                     ("random", lambda a: RandomRouter(a))):
        cluster = SimCluster(n_agents=4, seed=0, max_new_tokens=3)
        router = mk(cluster.agent_infos())
        dialogues = generate(WorkloadSpec("coqa_like", n_dialogues=5, seed=6))
        results[name] = run_workload(cluster, router, dialogues,
                                     max_rounds=1200)
    assert results["iemas"]["kv_hit_rate"] > 1.3 * results["random"]["kv_hit_rate"]
    assert results["iemas"]["cost_mean"] < 0.8 * results["random"]["cost_mean"]
