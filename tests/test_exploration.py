"""Optimism bonus in the Hoeffding cold-start blend (explore knob).

The PR 7 pathology: KV-affinity is self-reinforcing — once a dialogue
lands on an agent, cache hits make that agent cheaper and faster for
every later turn, so a domain-MISMATCHED placement made under cold-start
uncertainty can entrench forever: the never-sampled in-domain agent keeps
its flat structural prior while the incumbent's affinity advantage grows.

The fix is a standard optimism-under-uncertainty bonus applied to the
blended quality: ``q + explore / sqrt(1 + n_obs)`` (clipped at 1).  An
unsampled agent gets the full bonus; the bonus vanishes as observations
accumulate, so warm estimates are asymptotically untouched.  At the
default ``explore=0.0`` the term is an exact IEEE no-op — every
pre-existing run is bit-identical.
"""
import numpy as np
import pytest

from repro.core import IEMASRouter
from repro.core.mechanism import AgentInfo, CompletionObs, Request
from repro.core.predictor import AgentPredictor, PredictorInput, PredictorPool
from repro.core.pricing import TokenPrices

P = TokenPrices(0.01, 0.002, 0.03)


def _x(**kw):
    base = dict(prompt_len=24, turn=0, affinity=0.0, router_inflight=0,
                router_rps=0.0, agent_inflight=0, agent_rps=0.0,
                capacity=4, utilization=0.0, domain_match=1.0)
    base.update(kw)
    return PredictorInput(**base)


# ----------------------------------------------------------- the bonus --
def test_bonus_full_when_cold_and_decays_with_observations():
    """Cold: the full bonus on top of the structural prior.  Warm: the
    bonus is exactly ``explore / sqrt(1 + n_obs)`` above an explore-free
    twin with identical history — vanishing, never negative."""
    pred = AgentPredictor("a", P, explore=0.3)
    assert pred.predict(_x()).quality == pytest.approx(
        min(1.0, pred.prior_q + 0.3))
    twin = AgentPredictor("a", P)
    for _ in range(40):
        pred.update(_x(), 0.05, 0.5, 0.7)
        twin.update(_x(), 0.05, 0.5, 0.7)
    q, q0 = pred.predict(_x()).quality, twin.predict(_x()).quality
    assert q == min(1.0, q0 + 0.3 / np.sqrt(1.0 + pred.n_obs))
    assert 0.0 <= q - q0 <= 0.3 / np.sqrt(1.0 + pred.n_obs) + 1e-15


def test_explore_zero_is_exact_noop():
    """explore=0.0 must be bit-identical to the pre-knob predictor on
    every path (scalar and matrix)."""
    a = AgentPredictor("a", P)                   # no knob at all (default)
    b = AgentPredictor("a", P, explore=0.0)
    rng = np.random.default_rng(0)
    for _ in range(10):
        x = _x(prompt_len=float(rng.integers(4, 200)),
               affinity=float(rng.uniform()))
        q = float(rng.uniform())
        a.update(x, 0.05, 0.5, q)
        b.update(x, 0.05, 0.5, q)
    xa = _x(prompt_len=33.0)
    ea, eb = a.predict(xa), b.predict(xa)
    assert (ea.latency, ea.cost, ea.quality) == \
        (eb.latency, eb.cost, eb.quality)
    # pool matrix path: an all-zeros explore column changes nothing
    p0 = PredictorPool({"a": P, "b": P})
    p1 = PredictorPool({"a": P, "b": P}, explore=0.0)
    X = np.abs(rng.standard_normal((5, 2, 10)))
    for f0, f1 in zip(p0.predict_matrix(["a", "b"], X),
                      p1.predict_matrix(["a", "b"], X)):
        np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))


def test_scalar_and_matrix_paths_agree_with_explore():
    """The vectorized blend applies the same bonus as the scalar path."""
    pool = PredictorPool({"a": P, "b": P}, explore=0.4)
    pool["a"].update(_x(), 0.05, 0.5, 0.8)   # one warm, one cold
    X = np.stack([np.stack([_x(prompt_len=float(n)).vector()] * 2)
                  for n in (8, 64)])         # (2 requests, 2 agents, F)
    _, _, q_m = pool.predict_matrix(["a", "b"], X)
    for i, aid in enumerate(["a", "b"]):
        for j in range(X.shape[0]):
            est = pool[aid].predict(PredictorInput(*X[j, i]))
            assert float(np.asarray(q_m)[j, i]) == pytest.approx(
                est.quality, abs=1e-12)


# --------------------------------------- the entrenchment scenario test --
def _mismatch_scenario(explore: float):
    """Two agents: ``native`` owns the request domain but is never
    sampled; ``incumbent`` is off-domain but warm, with deep prefix
    affinity from having served every prior turn of the dialogue."""
    prices = TokenPrices(0.01, 0.002, 0.001)
    agents = [
        AgentInfo("incumbent", prices, capacity=4, domains=("code",)),
        AgentInfo("native", prices, capacity=4, domains=("qa",)),
    ]
    kw = dict(predictor_kw={"explore": explore}) if explore else {}
    router = IEMASRouter(agents, solver="dense", n_hubs=1, warm_start=True,
                         **kw)
    telem = {"router_inflight": 0, "router_rps": 0.0,
             "agent_inflight": {}, "agent_rps": {}}
    rng = np.random.default_rng(0)
    tokens = rng.integers(1, 255, 64, np.int32)
    # warm-up: native is busy (zero free slots), so every early turn of
    # the dialogue lands on the off-domain incumbent, which accrues
    # observations AND prefix cache over the growing conversation;
    # alternating 0.7/0.3 scores pin its warm P(good) at the mediocre 0.5
    # an off-domain generalist earns (labels threshold at 0.5)
    for t in range(6):
        req = Request(f"w{t}", "d0", tokens, t, domain="qa")
        [dec] = router.route_batch([req], telem,
                                   free_slots={"native": 0, "incumbent": 4})
        assert dec.agent_id == "incumbent"
        router.on_complete(req.request_id, CompletionObs(
            latency=0.04, n_prompt=len(tokens),
            n_hit=max(0, len(tokens) - 4), n_gen=4,
            quality=0.7 if t % 2 == 0 else 0.3))
        tokens = np.concatenate(
            [tokens, rng.integers(1, 255, 4, np.int32)])
    # the probe: both agents free — who gets the next turn?
    req = Request("probe", "d0", tokens, 6, domain="qa")
    [dec] = router.route_batch([req], telem)
    return dec.agent_id


def test_affinity_entrenches_mismatch_without_explore():
    """Pre-fix behavior (explore=0): the warm incumbent's affinity keeps
    winning the in-domain probe — the documented pathology."""
    assert _mismatch_scenario(0.0) == "incumbent"


def test_optimism_bonus_breaks_entrenchment():
    """With the bonus, the never-sampled in-domain agent's optimistic
    quality (full lift at n_obs=0; the warm incumbent's lift has already
    decayed) outbids the incumbent's affinity advantage — cache affinity
    can no longer permanently entrench a mismatch."""
    assert _mismatch_scenario(0.4) == "native"
