"""Training loop: convergence, bitwise resume after crash, compression parity,
gradient-compression error feedback, checkpoint atomicity."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.training import OptConfig, SyntheticLM
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.compress import (CompressionConfig, compress_with_feedback,
                                     init_feedback)
from repro.training.loop import train_loop

pytestmark = pytest.mark.slow  # excluded from tier-1; run with -m ""


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen3-8b").scaled(dtype="float32", n_layers=2,
                                        d_model=64, d_ff=128, vocab_size=64)
    return build_model(cfg)


@pytest.fixture(scope="module")
def data():
    return SyntheticLM(64, 32, 8, seed=3)


def test_loss_decreases(tiny_model, data):
    out = train_loop(tiny_model, data, steps=40,
                     opt_cfg=OptConfig(lr=3e-3, warmup_steps=10,
                                       total_steps=40))
    first, last = out["losses"][0][1], out["losses"][-1][1]
    assert last < first - 0.5


def test_crash_resume_exact(tiny_model, data, tmp_path):
    d = str(tmp_path / "ckpt")
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    with pytest.raises(RuntimeError, match="injected crash"):
        train_loop(tiny_model, data, steps=30, ckpt_dir=d, ckpt_every=10,
                   crash_at_step=15, opt_cfg=opt)
    assert latest_step(d) == 10
    resumed = train_loop(tiny_model, data, steps=30, ckpt_dir=d,
                         ckpt_every=10, opt_cfg=opt)
    ref = train_loop(tiny_model, data, steps=30, opt_cfg=opt)
    assert resumed["losses"][-1][1] == pytest.approx(ref["losses"][-1][1],
                                                     abs=2e-3)


def test_compression_convergence_parity(tiny_model, data):
    opt = OptConfig(lr=3e-3, warmup_steps=10, total_steps=40)
    plain = train_loop(tiny_model, data, steps=40, opt_cfg=opt)
    comp = train_loop(tiny_model, data, steps=40, opt_cfg=opt,
                      compression=CompressionConfig(enabled=True))
    assert comp["losses"][-1][1] < plain["losses"][0][1] - 0.5
    assert abs(comp["losses"][-1][1] - plain["losses"][-1][1]) < 0.35


def test_error_feedback_preserves_signal():
    """Sum of (compressed grad + residual) equals the true grad exactly."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    fb = init_feedback(g)
    cfg = CompressionConfig(enabled=True, block=64)
    cg, fb2 = compress_with_feedback(g, fb, cfg)
    recon = cg["w"] + fb2["w"]
    assert np.allclose(np.asarray(recon), np.asarray(g["w"]), atol=1e-6)
    # quantization error is bounded by half a quantization step per block
    step = np.abs(np.asarray(g["w"])).reshape(-1, 64).max(1) / 127
    err = np.abs(np.asarray(fb2["w"])).reshape(-1, 64).max(1)
    assert (err <= step * 0.5 + 1e-7).all()


def test_checkpoint_atomicity(tmp_path):
    d = str(tmp_path / "c")
    tree = {"a": np.arange(10, dtype=np.float32), "b": {"c": np.ones((3, 3))}}
    save_checkpoint(d, 5, tree, {"note": "x"})
    # a crashed (partial) save must not shadow the good one
    os.makedirs(os.path.join(d, ".tmp_step_00000007"))
    with open(os.path.join(d, ".tmp_step_00000007", "leaf_00000.npy"), "w") as f:
        f.write("garbage")
    assert latest_step(d) == 5
    restored, meta, step = restore_checkpoint(d, 5, tree)
    assert step == 5 and meta["note"] == "x"
    assert np.array_equal(restored["a"], tree["a"])
    assert np.array_equal(restored["b"]["c"], tree["b"]["c"])


def test_grad_accumulation_equivalence(tiny_model, data):
    """accum_steps=2 gives (nearly) the same first-step grads as accum=1."""
    from repro.training.loop import init_opt_state, make_train_step

    params = tiny_model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    s1 = make_train_step(tiny_model, OptConfig(total_steps=10))
    s2 = make_train_step(tiny_model, OptConfig(total_steps=10), accum_steps=2)
    _, _, m1 = s1(params, opt, batch)
    _, _, m2 = s2(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]),
                                                   rel=1e-3)
