"""ISSUE-6 tentpole: the capacitated-column market.

Parity contract (see ``dense_np``'s module docstring): the column solver is
welfare-equal to the retained slot-expanded oracle within the summed
certificates and payment-equal on the matched set — across every registered
backend, including degenerate capacities (b_i = 0, b_i >= n) and warm
rounds.  Plus the incremental-auction lifecycle: provisional routes issued
against standing duals are confirmed or re-routed consistently by the next
batch auction, with the matched/unmatched ledger closing exactly once per
request.
"""
import numpy as np
import pytest

from repro.core import AgentInfo, CompletionObs, IEMASRouter, Request, TokenPrices
from repro.core.auction import run_auction
from repro.core.solvers import get_solver, solve_dense_auction
from repro.core.solvers.dense_common import package_dense
from repro.core.solvers.dense_np import solve_dense_auction_slots

ATOL = 1e-6
WARM_BACKENDS = ("dense", "dense-jax", "pallas")
ALL_BACKENDS = ("mcmf",) + WARM_BACKENDS


def _market(rng, n_max=20, m_max=10, degenerate=False):
    n = int(rng.integers(1, n_max + 1))
    m = int(rng.integers(1, m_max + 1))
    values = rng.uniform(0, 6, (n, m)) * (rng.random((n, m)) > 0.3)
    costs = rng.uniform(0, 3, (n, m))
    if degenerate:
        # exercise b_i = 0 (agent sells nothing), b_i >= n (slack regime)
        caps = [int(c) for c in rng.choice([0, 1, 2, n, n + 5], m)]
    else:
        caps = rng.integers(1, 4, m).tolist()
    return values, costs, caps


# ----------------------------------------- column vs slot (solver level) --
def test_column_matches_slot_oracle_welfare_and_payments():
    """150 random markets: the column solver and the retained slot-expanded
    oracle certify the same welfare and produce identical Clarke payments.

    (Trajectory parity can only break when two unit prices of one agent
    differ below the ULP of a bidder's weight — the ε-CS certificate
    absorbs that; none of these instances trip it.)
    """
    rng = np.random.default_rng(0)
    for trial in range(150):
        values, costs, caps = _market(rng, degenerate=(trial % 3 == 0))
        costs_m = np.asarray(costs, dtype=np.float64)
        w = np.maximum(np.asarray(values) - costs_m, 0.0)
        col = solve_dense_auction(w, caps)
        slot = solve_dense_auction_slots(w, caps)
        tol = ATOL + col.gap_bound + slot.gap_bound
        assert abs(col.welfare - slot.welfare) <= tol, trial
        assert col.gap_bound == pytest.approx(slot.gap_bound), trial
        assert col.assignment == slot.assignment, trial
        r_col = package_dense("dense", w, costs_m, caps, col)
        r_slot = package_dense("dense", w, costs_m, caps, slot)
        np.testing.assert_allclose(r_col.payments, r_slot.payments,
                                   atol=ATOL, err_msg=f"trial {trial}")


def test_column_result_exposes_per_agent_ascending_duals():
    """The new result format: one ascending price vector per agent, with
    the flat agent-major concatenation as the warm-seed wire format."""
    rng = np.random.default_rng(1)
    w = np.maximum(rng.uniform(-1, 4, (12, 5)), 0.0)
    caps = [3, 1, 0, 20, 2]
    res = solve_dense_auction(w, caps)
    assert len(res.agent_prices) == 5
    for i, (p, c) in enumerate(zip(res.agent_prices, res.unit_counts)):
        assert len(p) == c == min(caps[i], 12)
        assert (np.diff(p) >= 0).all(), i          # ascending
        assert (p >= 0).all(), i
    assert len(res.flat_prices) == int(np.sum(res.unit_counts))
    np.testing.assert_array_equal(res.flat_prices,
                                  np.concatenate(res.agent_prices))


# ------------------------------------------- all backends vs exact oracle --
@pytest.mark.parametrize("solver", ALL_BACKENDS)
def test_backend_welfare_certified_vs_exact(solver):
    """Every backend's column solve lands within its own certificate of the
    MCMF exact optimum, degenerate capacities included."""
    rng = np.random.default_rng(7)
    for trial in range(8):
        values, costs, caps = _market(rng, 16, 8, degenerate=True)
        exact = run_auction(values, costs, caps, solver="mcmf")
        r = run_auction(values, costs, caps, solver=solver)
        cert = get_solver(solver).certificate(r)
        assert r.welfare <= exact.welfare + cert + 1e-4, (solver, trial)
        assert r.welfare >= exact.welfare - cert - 1e-4, (solver, trial)
        # a zero-capacity agent must never win a request
        for j, i in enumerate(r.assignment):
            if i >= 0:
                assert caps[i] > 0, (solver, trial)


@pytest.mark.parametrize("solver", WARM_BACKENDS)
def test_backend_warm_round_parity(solver):
    """Re-solving from the previous round's per-agent duals (the price-book
    wire format) is pure reoptimization: same certified welfare."""
    rng = np.random.default_rng(11)
    for trial in range(4):
        values, costs, caps = _market(rng, 16, 8)
        first = run_auction(values, costs, caps, solver=solver)
        seed = np.concatenate([np.asarray(p) for p in
                               first.solver_stats["agent_prices"]])
        warm = run_auction(values, costs, caps, solver=solver,
                           start_prices=seed)
        assert warm.solver_stats["warm_started"], (solver, trial)
        tol = 1e-4 + first.solver_stats["gap_bound"] \
            + warm.solver_stats["gap_bound"]
        assert abs(warm.welfare - first.welfare) <= tol, (solver, trial)


@pytest.mark.parametrize("solver", WARM_BACKENDS)
def test_backend_degenerate_caps_explicit(solver):
    """b_i = 0 everywhere -> nobody matches; one slack agent -> everybody
    matches there (the K/m-cut regime the column market exists for)."""
    w = np.full((4, 3), 2.0)
    costs = np.full((4, 3), 0.5)
    r = run_auction(w, costs, [0, 0, 0], solver=solver)
    assert r.assignment == [-1] * 4 and r.welfare == 0.0
    r = run_auction(w, costs, [0, 50, 0], solver=solver)
    assert r.assignment == [1] * 4
    assert r.welfare == pytest.approx(4 * 1.5, abs=1e-3)


# ------------------------------------------------- incremental lifecycle --
def _agents(m=6, cap=3):
    return [AgentInfo(f"a{i}", TokenPrices(0.001 * (1 + 0.1 * i), 0.0005,
                                           0.002), cap,
                      ("code",) if i % 2 == 0 else ("math",), scale=4.0 + i)
            for i in range(m)]


def _reqs(tag, n, dom="code"):
    rng = np.random.default_rng(tag)
    return [Request(f"r{tag}-{j}", f"d{tag}-{j}",
                    rng.integers(1, 50, 20).astype(np.int32), turn=0,
                    domain=dom) for j in range(n)]


def test_incremental_provisionals_reconciled_by_next_batch():
    """Provisional routes issued by route_incremental are each confirmed or
    re-routed by the next batch auction — exactly once — and the window
    ledger (matched + unmatched) counts every request exactly once."""
    router = IEMASRouter(_agents(), solver="dense", n_hubs=2,
                         warm_start=True, predictor_kw={"warm_n": 99})
    router.route_batch(_reqs(0, 8), {})          # round 1: standing duals
    inc = router.route_incremental(_reqs(1, 3), {})
    routed = [d for d in inc if d.agent_id is not None]
    assert len(routed) == 3                      # slack market: all route
    assert router.accounts["incremental_routed"] == 3
    assert len(router._provisional) == 3
    # provisionals pay predicted cost + the posted ask (never below cost)
    for d in routed:
        assert d.payment >= d.estimate.cost - ATOL
    out = router.route_batch(_reqs(2, 4, dom="math"), {})
    assert len(out) == 4                         # shadows are not returned
    acc = router.accounts
    assert acc["incremental_confirmed"] + acc["incremental_rerouted"] == 3
    assert not router._provisional and not router._prov_units
    assert acc["matched"] + acc["unmatched"] == 8 + 3 + 4


def test_incremental_misses_are_deferred_not_unmatched():
    """Arrivals the posted-price pass cannot route (no standing duals yet /
    warm starts off) come back agent-less and enter NO ledger column — the
    next batch auction owns their accounting."""
    cold = IEMASRouter(_agents(), solver="dense", n_hubs=2,
                       warm_start=False, predictor_kw={"warm_n": 99})
    dec = cold.route_incremental(_reqs(0, 4), {})
    assert all(d.agent_id is None for d in dec)
    assert cold.accounts["matched"] == cold.accounts["unmatched"] == 0
    warm = IEMASRouter(_agents(), solver="dense", n_hubs=2,
                       warm_start=True, predictor_kw={"warm_n": 99})
    dec = warm.route_incremental(_reqs(0, 4), {})  # no duals stored yet
    assert all(d.agent_id is None for d in dec)
    assert warm.accounts["incremental_routed"] == 0


def test_incremental_walks_up_the_ascending_price_vector():
    """Repeated arrivals drain an agent's provisional units at ask[k] for
    k = 0, 1, ... — never re-selling the same unit price twice — and stop
    at the free-slot bound."""
    agents = _agents(m=2, cap=2)
    router = IEMASRouter(agents, solver="dense", n_hubs=1, warm_start=True,
                         predictor_kw={"warm_n": 99})
    router.route_batch(_reqs(0, 4), {})
    for d in router._provisional.values():
        raise AssertionError("batch must not leave provisionals")
    taken = []
    for t in range(6):                      # 6 arrivals vs 4 total units
        d = router.route_incremental(_reqs(10 + t, 1), {})[0]
        if d.agent_id is not None:
            taken.append(d.agent_id)
    assert 0 < len(taken) <= 4              # capacity-bounded
    counts = {a: taken.count(a) for a in set(taken)}
    assert all(c <= 2 for c in counts.values())
    assert router._prov_units == counts


def test_incremental_provisional_completion_releases_unit():
    """A provisional that completes before the next batch is retired in
    on_complete: its unit frees up and the batch sees no shadow for it."""
    router = IEMASRouter(_agents(), solver="dense", n_hubs=2,
                         warm_start=True, predictor_kw={"warm_n": 99})
    router.route_batch(_reqs(0, 8), {})
    d = router.route_incremental(_reqs(1, 1), {})[0]
    assert d.agent_id is not None
    router.on_complete(d.request.request_id,
                       CompletionObs(0.1, 20, 0, 8, 0.9))
    assert not router._provisional and not router._prov_units
    acc_before = dict(router.accounts)
    router.route_batch(_reqs(2, 2), {})
    acc = router.accounts
    # no shadow existed: confirm/reroute counters untouched by this window
    assert acc["incremental_confirmed"] == acc_before["incremental_confirmed"]
    assert acc["incremental_rerouted"] == acc_before["incremental_rerouted"]
