"""Documentation gates: the docs/ tree exists and is linked, and docstring
coverage (tools/check_docstrings.py, the CI gate) stays above its floors."""
import sys
from pathlib import Path

REPO = Path(__file__).parent.parent
sys.path.insert(0, str(REPO / "tools"))

from check_docstrings import audit  # noqa: E402


def test_core_docstring_coverage_full():
    """`repro.core` is the documented subsystem: 95%+ public-API coverage."""
    documented, total, missing = audit([REPO / "src/repro/core"])
    pct = 100.0 * documented / max(total, 1)
    assert pct >= 95.0, f"core docstring coverage {pct:.1f}% < 95%: {missing}"


def test_solvers_and_kernels_docstring_coverage_full():
    """The solver registry, the kernels layer and the serving layer are
    public surface too: 95%+ coverage each (the CI gate mirrors this)."""
    for sub in ("src/repro/core/solvers", "src/repro/kernels",
                "src/repro/serving"):
        documented, total, missing = audit([REPO / sub])
        pct = 100.0 * documented / max(total, 1)
        assert pct >= 95.0, \
            f"{sub} docstring coverage {pct:.1f}% < 95%: {missing}"


def test_repo_docstring_coverage_floor():
    """Repo-wide floor — raise it as modules get documented, never lower."""
    documented, total, _ = audit([REPO / "src/repro"])
    pct = 100.0 * documented / max(total, 1)
    assert pct >= 60.0, f"src/repro docstring coverage {pct:.1f}% < 60%"


def test_docs_tree_exists_and_is_linked():
    arch = REPO / "docs/architecture.md"
    bench = REPO / "docs/benchmarks.md"
    assert arch.is_file() and bench.is_file()
    readme = (REPO / "README.md").read_text()
    assert "docs/architecture.md" in readme
    assert "docs/benchmarks.md" in readme


def test_docs_cover_every_core_module_and_benchmark():
    """docs/architecture.md has a section per core module; docs/benchmarks.md
    documents every benchmarks/*.py entry point."""
    arch = (REPO / "docs/architecture.md").read_text()
    for mod in sorted((REPO / "src/repro/core").glob("*.py")) + \
            sorted((REPO / "src/repro/core/solvers").glob("*.py")):
        if mod.stem != "__init__":
            assert f"`{mod.stem}" in arch or f"/{mod.stem}" in arch, \
                f"docs/architecture.md misses {mod.parent.name}/{mod.stem}.py"
    bench = (REPO / "docs/benchmarks.md").read_text()
    for b in sorted((REPO / "benchmarks").glob("*.py")):
        if b.stem not in ("common", "run", "__init__"):
            assert b.stem in bench, f"docs/benchmarks.md misses {b.name}"
