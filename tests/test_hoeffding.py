"""Hoeffding trees learn simple structure online."""
import numpy as np

from repro.core.hoeffding import HoeffdingTreeClassifier, HoeffdingTreeRegressor


def test_regressor_learns_threshold_function():
    rng = np.random.default_rng(0)
    tree = HoeffdingTreeRegressor(3)
    f = lambda x: 5.0 if x[0] > 0.5 else 1.0
    for _ in range(1500):
        x = rng.random(3)
        tree.learn_one(x, f(x) + rng.normal(0, 0.1))
    lo = np.mean([tree.predict_one([0.2, rng.random(), rng.random()])
                  for _ in range(50)])
    hi = np.mean([tree.predict_one([0.8, rng.random(), rng.random()])
                  for _ in range(50)])
    assert hi - lo > 2.0  # split found and leaves separate the regimes


def test_regressor_tracks_linear_feature():
    rng = np.random.default_rng(1)
    tree = HoeffdingTreeRegressor(2)
    for _ in range(3000):
        x = rng.random(2)
        tree.learn_one(x, 10.0 * x[1])
    lo, hi = tree.predict_one([0.5, 0.05]), tree.predict_one([0.5, 0.95])
    assert hi > lo + 2.0  # splits on the informative feature


def test_classifier_learns_boundary():
    rng = np.random.default_rng(2)
    tree = HoeffdingTreeClassifier(2)
    for _ in range(2000):
        x = rng.random(2)
        tree.learn_one(x, float(x[1] > 0.6))
    p_hi = tree.predict_one([0.5, 0.9])
    p_lo = tree.predict_one([0.5, 0.2])
    assert p_hi > 0.7 and p_lo < 0.3


def test_cold_start_safe():
    tree = HoeffdingTreeRegressor(4)
    assert tree.predict_one([0, 0, 0, 0]) == 0.0
    cls = HoeffdingTreeClassifier(4)
    assert cls.predict_one([0, 0, 0, 0]) == 0.5
