"""Hub-sharded Phase-2 auctions + cross-round warm-started slot prices.

Covers the ISSUE-3 tentpole invariants:
  * splicing: `run_sharded_auction` over hub blocks is bit-identical to
    running the dense solver on each block independently;
  * warm-start soundness: seeding from a previous solve's duals reaches the
    same assignment and welfare certificate as a cold solve on static agent
    sets (and the round-budgeted warm attempt falls back to a cold solve
    instead of failing);
  * elastic safety: the router's SlotPriceBook cold-starts whenever the
    hub's live agent set changes (join/leave/quarantine/hub-rebuild).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import AgentInfo, CompletionObs, IEMASRouter, Request, TokenPrices
from repro.core.auction import SPILL_HUB, run_auction, run_sharded_auction
from repro.core.auction_dense import solve_dense_auction
from repro.core.hub import SlotPriceBook

ATOL = 1e-6


def _market(rng, n_max=24, m_max=16):
    n = int(rng.integers(2, n_max + 1))
    m = int(rng.integers(2, m_max + 1))
    values = rng.uniform(0, 6, (n, m)) * (rng.random((n, m)) > 0.3)
    costs = rng.uniform(0, 3, (n, m))
    caps = rng.integers(1, 4, m).tolist()
    return values, costs, caps


def _partition(rng, n, m, k):
    """Random request/agent partition into k blocks (every agent somewhere)."""
    a_of = rng.integers(0, k, m)
    r_of = rng.integers(0, k, n)
    blocks = {}
    for h in range(k):
        r_idx = [j for j in range(n) if r_of[j] == h]
        a_idx = [i for i in range(m) if a_of[i] == h]
        if r_idx and a_idx:
            blocks[h] = (r_idx, a_idx)
    return blocks


# ------------------------------------------------------------- splicing --
@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 5))
def test_sharded_equals_per_block_dense(seed, k):
    """The sharded entry point is pure scheduling: per-hub results must be
    bit-identical to solving each block with run_auction alone."""
    rng = np.random.default_rng(seed)
    values, costs, caps = _market(rng)
    blocks = _partition(rng, *values.shape, k)
    sharded = run_sharded_auction(values, costs, caps, blocks, solver="dense")
    assert set(sharded) == set(blocks)
    for h, (r_idx, a_idx) in blocks.items():
        solo = run_auction(values[np.ix_(r_idx, a_idx)],
                           costs[np.ix_(r_idx, a_idx)],
                           [caps[i] for i in a_idx], solver="dense")
        assert sharded[h].assignment == solo.assignment
        assert sharded[h].welfare == solo.welfare
        assert sharded[h].payments == solo.payments


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 4))
def test_sharded_blocks_are_capacity_disjoint(seed, k):
    """Spliced global matching double-spends no agent capacity."""
    rng = np.random.default_rng(seed)
    values, costs, caps = _market(rng)
    blocks = _partition(rng, *values.shape, k)
    sharded = run_sharded_auction(values, costs, caps, blocks, solver="dense")
    used = {}
    for h, (r_idx, a_idx) in blocks.items():
        for local_j, local_i in enumerate(sharded[h].assignment):
            if local_i >= 0:
                gi = a_idx[local_i]
                used[gi] = used.get(gi, 0) + 1
    for gi, count in used.items():
        assert count <= caps[gi]


# ----------------------------------------------------------- warm starts --
@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10**6))
def test_warm_equals_cold_on_static_market(seed):
    """Re-solving the same (generic, untied) market from the previous duals
    reaches the same assignment and the same certificate as a cold solve."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 24))
    m = int(rng.integers(2, 12))
    w = np.maximum(rng.uniform(-1, 4, (n, m)), 0.0)  # continuous -> no ties
    caps = rng.integers(1, 4, m).tolist()
    cold = solve_dense_auction(w, caps)
    warm = solve_dense_auction(w, caps, start_prices=cold.flat_prices)
    assert warm.warm_started
    assert warm.assignment == cold.assignment
    assert warm.welfare == pytest.approx(cold.welfare, abs=ATOL)
    assert warm.gap_bound == pytest.approx(cold.gap_bound)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6))
def test_warm_welfare_optimal_on_perturbed_market(seed):
    """Warm seeds from a *different* (previous-round) market must not cost
    welfare: the certificate only depends on the final epsilon."""
    rng = np.random.default_rng(seed)
    n, m = 16, 8
    w1 = np.maximum(rng.uniform(-1, 4, (n, m)), 0.0)
    w2 = np.maximum(w1 + rng.normal(0, 0.3, (n, m)), 0.0)
    caps = rng.integers(1, 4, m).tolist()
    prev = solve_dense_auction(w1, caps)
    cold = solve_dense_auction(w2, caps)
    warm = solve_dense_auction(w2, caps, start_prices=prev.flat_prices)
    assert warm.welfare == pytest.approx(cold.welfare, abs=ATOL)


def test_warm_budget_trips_to_cold_fallback():
    """A hopeless warm configuration (zero prices, epsilon forced straight
    to eps_final: bidding wars of ~wmax/eps rounds) must trip the warm round
    budget and transparently re-solve cold."""
    rng = np.random.default_rng(7)
    w = np.maximum(rng.uniform(0, 4, (30, 10)), 0.0)
    caps = [2] * 10
    cold = solve_dense_auction(w, caps)
    tripped = solve_dense_auction(w, caps,
                                  start_prices=np.zeros_like(cold.flat_prices),
                                  start_eps=cold.eps)
    assert tripped.warm_started and tripped.fallback
    assert tripped.welfare == pytest.approx(cold.welfare, abs=ATOL)
    assert tripped.assignment == cold.assignment


def test_warm_start_shape_mismatch_rejected():
    w = np.ones((3, 2))
    with pytest.raises(ValueError, match="start_prices"):
        solve_dense_auction(w, [1, 1], start_prices=np.zeros(7))


# --------------------------------------------------------- SlotPriceBook --
def test_price_book_remaps_layout_and_guards_membership():
    book = SlotPriceBook()
    ids = ("a", "b")
    # agent a sold 2 units at (1.0, 2.0); agent b one unit at 3.0
    book.store(0, version=1, agent_ids=ids, caps=[2, 1],
               agent_prices=[np.array([1.0, 2.0]), np.array([3.0])])
    # same layout -> replayed verbatim (flat agent-major)
    np.testing.assert_array_equal(
        book.lookup(0, 1, ids, [2, 1], unit_counts=[2, 1]), [1.0, 2.0, 3.0])
    # fewer/more units exposed this round (batch-size wobble at unchanged
    # capacities): ascending truncation keeps the cheapest unit; growth
    # zero-pads at the free-unit boundary price
    np.testing.assert_array_equal(
        book.lookup(0, 1, ids, [2, 1], unit_counts=[1, 3]),
        [1.0, 3.0, 0.0, 0.0])
    # elastic version bumped -> cold start
    assert book.lookup(0, 2, ids, [2, 1], unit_counts=[2, 1]) is None
    # live agent set changed (e.g. quarantine) -> cold start
    assert book.lookup(0, 1, ("a",), [2], unit_counts=[2]) is None
    # unknown hub -> cold start
    assert book.lookup(5, 1, ids, [2, 1], unit_counts=[2, 1]) is None
    stats = book.stats()
    assert stats["warm_hits"] == 2 and stats["cold_starts"] == 3
    book.invalidate()
    assert book.lookup(0, 1, ids, [2, 1], unit_counts=[2, 1]) is None


def test_price_book_cold_starts_on_capacity_change():
    """ISSUE-6 satellite 1 regression: a capacity change WITHOUT a
    membership change must invalidate the stored splits — pre-fix the book
    keyed on the agent-id tuple only and silently replayed the stale
    per-agent price splits onto the re-laid-out unit columns."""
    book = SlotPriceBook()
    ids = ("a", "b")
    book.store(0, version=1, agent_ids=ids, caps=[2, 1],
               agent_prices=[np.array([1.0, 2.0]), np.array([3.0])])
    # same members, same version; agent a's published capacity 2 -> 3
    assert book.lookup(0, 1, ids, [3, 1], unit_counts=[2, 1]) is None
    assert book.posted_asks(0, 1, ids, [3, 1]) is None
    # matching capacities still replay
    assert book.lookup(0, 1, ids, [2, 1], unit_counts=[2, 1]) is not None
    asks = book.posted_asks(0, 1, ids, [2, 1])
    np.testing.assert_array_equal(asks["a"], [1.0, 2.0])
    np.testing.assert_array_equal(asks["b"], [3.0])


# ------------------------------------------------------- warm spill --
def _overloaded_market(seed=5):
    """Hub 0 saturated (many losers), hub 1 lightly loaded (residual slack
    + live first-round duals): the donor-dual spill-seeding regime."""
    rng = np.random.default_rng(seed)
    n, m = 34, 12
    values = rng.uniform(1.5, 6.0, (n, m))
    costs = rng.uniform(0.2, 1.0, (n, m))
    caps = [3] * m
    blocks = {0: (list(range(30)), list(range(6))),      # 30 reqs, 18 slots
              1: (list(range(30, 34)), list(range(6, 12)))}  # 4 reqs, 18
    return values, costs, caps, blocks


def test_spill_seeded_from_donor_duals_rounds_and_welfare():
    """ISSUE-5 satellite: the cross-hub spill round warm-starts from the
    donor hubs' slot-price duals; warm-spill rounds <= cold-spill rounds,
    welfare unchanged within the certificate, first round untouched."""
    values, costs, caps, blocks = _overloaded_market()
    cold = run_sharded_auction(values, costs, caps, blocks, solver="dense",
                               spill=True, spill_warm=False)
    warm = run_sharded_auction(values, costs, caps, blocks, solver="dense",
                               spill=True, spill_warm=True)
    sp_c, sp_w = cold[SPILL_HUB], warm[SPILL_HUB]
    assert not sp_c.solver_stats["spill"]["warm_started"]
    assert sp_w.solver_stats["spill"]["warm_started"]
    assert sp_w.solver_stats["warm_started"]          # solver saw the seed
    assert sp_w.solver_stats["rounds"] <= sp_c.solver_stats["rounds"], \
        (sp_w.solver_stats["rounds"], sp_c.solver_stats["rounds"])
    # the seed is pure reoptimization state: same rescue welfare (within
    # both runs' certificates) and identical first-round results
    tol = ATOL + sp_c.solver_stats["gap_bound"] + sp_w.solver_stats["gap_bound"]
    assert abs(sp_w.welfare - sp_c.welfare) <= tol
    for h in blocks:
        assert warm[h].assignment == cold[h].assignment
        assert warm[h].payments == cold[h].payments


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6))
def test_spill_warm_seed_never_costs_welfare(seed):
    """Property: across random overload markets, the seeded spill round's
    welfare matches the cold spill round within certificates."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 30))
    m = int(rng.integers(4, 12))
    values = rng.uniform(0, 6, (n, m)) * (rng.random((n, m)) > 0.2)
    costs = rng.uniform(0, 2, (n, m))
    caps = rng.integers(1, 3, m).tolist()
    split = max(1, m // 2)
    blocks = {0: (list(range(n)), list(range(split))),
              1: ([], list(range(split, m)))}
    cold = run_sharded_auction(values, costs, caps, blocks, solver="dense",
                               spill=True, spill_warm=False)
    warm = run_sharded_auction(values, costs, caps, blocks, solver="dense",
                               spill=True, spill_warm=True)
    assert (SPILL_HUB in cold) == (SPILL_HUB in warm)
    if SPILL_HUB in cold:
        sp_c, sp_w = cold[SPILL_HUB], warm[SPILL_HUB]
        tol = ATOL + sp_c.solver_stats["gap_bound"] \
            + sp_w.solver_stats["gap_bound"]
        assert abs(sp_w.welfare - sp_c.welfare) <= tol
        assert sp_w.solver_stats["spill"]["candidates"] == \
            sp_c.solver_stats["spill"]["candidates"]


def test_spill_seed_skipped_for_exact_backend():
    """The mcmf oracle has no persistent duals: spill stays cold there."""
    values, costs, caps, blocks = _overloaded_market()
    res = run_sharded_auction(values, costs, caps, blocks, solver="mcmf",
                              spill=True, spill_warm=True)
    assert SPILL_HUB in res
    assert not res[SPILL_HUB].solver_stats["spill"]["warm_started"]


# ------------------------------------------------------------ router --
def _agents(m=6, cap=2):
    return [AgentInfo(f"a{i}", TokenPrices(0.01 * (1 + 0.1 * i), 0.001, 0.03),
                      cap, ("dialogue",) if i % 2 == 0 else ("reasoning",),
                      scale=4.0 + i) for i in range(m)]


def _requests(n, tag=0):
    rng = np.random.default_rng(tag)
    return [Request(f"r{tag}-{j}", f"d{j % 3}",
                    rng.integers(1, 50, 20).astype(np.int32), turn=j // 3,
                    domain="dialogue" if j % 2 else "reasoning")
            for j in range(n)]


def test_router_warm_start_hits_after_first_round():
    router = IEMASRouter(_agents(), solver="dense", n_hubs=2, warm_start=True,
                         predictor_kw={"warm_n": 99})
    for t in range(4):
        router.route_batch(_requests(8, t), {})
    stats = router.price_book.stats()
    assert stats["warm_hits"] >= 3           # every round after the first
    assert stats["stores"] >= 4


def test_router_warm_start_welfare_matches_cold_router():
    """Warm starting is pure reoptimization: round-by-round matched welfare
    must equal a cold-start router's on the identical request stream (the
    specific assignment may differ only among exact welfare ties)."""
    warm = IEMASRouter(_agents(), solver="dense", n_hubs=2, warm_start=True,
                       predictor_kw={"warm_n": 99})
    cold = IEMASRouter(_agents(), solver="dense", n_hubs=2, warm_start=False,
                       predictor_kw={"warm_n": 99})
    for t in range(4):
        dw = warm.route_batch(_requests(8, t), {})
        dc = cold.route_batch(_requests(8, t), {})
        w_w = sum(d.welfare_weight for d in dw if d.agent_id)
        w_c = sum(d.welfare_weight for d in dc if d.agent_id)
        assert w_w == pytest.approx(w_c, abs=ATOL)


def test_router_cold_starts_on_membership_change():
    router = IEMASRouter(_agents(), solver="dense", n_hubs=2, warm_start=True,
                         predictor_kw={"warm_n": 99})
    for t in range(2):
        router.route_batch(_requests(8, t), {})
    version_before = router.agent_set_version.version
    router.add_agent(AgentInfo("a-new", TokenPrices(0.01, 0.001, 0.03), 2,
                               ("dialogue",)))
    assert router.agent_set_version.version > version_before
    before = dict(router.price_book.stats())
    router.route_batch(_requests(8, 5), {})
    after = router.price_book.stats()
    assert after["warm_hits"] == before["warm_hits"]       # nothing replayed
    assert after["cold_starts"] > before["cold_starts"]
    # next round warm again (membership stable at the new version)
    router.route_batch(_requests(8, 6), {})
    assert router.price_book.stats()["warm_hits"] > after["warm_hits"]


def test_router_cold_starts_on_capacity_change():
    """ISSUE-6 satellite 1, router level: a published-capacity change with
    the membership (and elastic version) unchanged must cold-start the
    changed agent's hub instead of replaying its stale price splits."""
    router = IEMASRouter(_agents(), solver="dense", n_hubs=2, warm_start=True,
                         predictor_kw={"warm_n": 99})
    for t in range(2):
        router.route_batch(_requests(8, t), {})
    before = dict(router.price_book.stats())
    router.agents[0].capacity += 1     # b_i changed, same agents, same hubs
    router.route_batch(_requests(8, 5), {})
    after = router.price_book.stats()
    assert after["cold_starts"] > before["cold_starts"]
    # and the refreshed entry (keyed on the new capacity) warms again
    router.route_batch(_requests(8, 6), {})
    assert router.price_book.stats()["warm_hits"] > after["warm_hits"]


def test_router_cold_starts_on_quarantine():
    """Quarantine shrinks a hub's live set without a version bump: the exact
    agent-id tuple in the price-book key must force the cold start."""
    router = IEMASRouter(_agents(), solver="dense", n_hubs=1, warm_start=True,
                         predictor_kw={"warm_n": 99})
    decisions = router.route_batch(_requests(6, 0), {})
    victim = next(d.agent_id for d in decisions if d.agent_id)
    router.on_complete(
        next(d.request.request_id for d in decisions if d.agent_id == victim),
        CompletionObs(0, 10, 0, 0, 0, failed=True))
    before = dict(router.price_book.stats())
    router.route_batch(_requests(6, 1), {})
    after = router.price_book.stats()
    assert after["warm_hits"] == before["warm_hits"]
    assert after["cold_starts"] > before["cold_starts"]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_spill_accounting_exactly_once_per_window(seed):
    """ISSUE-6 satellite 2 property: across randomized spill-heavy windows
    (tight capacities force cross-hub rescues) every request lands in the
    ledger exactly once — matched XOR unmatched, with spill rescues counted
    inside matched, never as an unmatched-then-rescued double entry."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(2, 7))
    router = IEMASRouter(_agents(m, cap=1), solver="dense", n_hubs=2,
                         spill=True, warm_start=bool(rng.integers(0, 2)),
                         predictor_kw={"warm_n": 99})
    total = routed_total = 0
    for t in range(3):
        n = int(rng.integers(1, 12))
        decisions = router.route_batch(_requests(n, tag=seed % 997 + t), {})
        assert len(decisions) == n
        total += n
        routed_total += sum(1 for d in decisions if d.agent_id is not None)
        a = router.accounts
        assert a["matched"] + a["unmatched"] == total, (t, dict(a))
        assert a["matched"] == routed_total, (t, dict(a))
        assert 0 <= a["spill_rescued"] <= a["matched"]


def test_accounting_counts_unmatched_when_no_live_agents():
    """ISSUE-6 satellite 2 regression (fails pre-fix): with every agent
    quarantined, route_batch returned all-None decisions WITHOUT tallying
    them — the whole window vanished from matched + unmatched."""
    router = IEMASRouter(_agents(4), solver="dense", n_hubs=2, spill=True,
                         predictor_kw={"warm_n": 99})
    for a in list(router.agents):
        router.quarantine(a.agent_id)
    decisions = router.route_batch(_requests(5, 0), {})
    assert len(decisions) == 5
    assert all(d.agent_id is None for d in decisions)
    assert router.accounts["matched"] == 0
    assert router.accounts["unmatched"] == 5
    # reinstating closes the next window's ledger on the same counters
    for a in list(router.agents):
        router.reinstate(a.agent_id)
    router.route_batch(_requests(3, 1), {})
    acc = router.accounts
    assert acc["matched"] + acc["unmatched"] == 8


def test_router_warm_start_noop_for_mcmf():
    router = IEMASRouter(_agents(), solver="mcmf", warm_start=True)
    assert router.warm_start is False
    router.route_batch(_requests(4, 0), {})
    assert router.price_book.stats()["stores"] == 0


# ---------------------------------------------------------- jax batching --
@pytest.mark.slow
def test_jax_batch_matches_single_solves():
    """Padded + vmapped hub blocks must match per-block jax solves exactly
    (zero padding is behavior-neutral by construction)."""
    from repro.core.auction_dense import (solve_dense_auction_jax,
                                          solve_dense_auction_jax_batch)

    rng = np.random.default_rng(11)
    ws, caps_list = [], []
    for _ in range(6):
        n, m = int(rng.integers(2, 40)), int(rng.integers(2, 12))
        ws.append(np.maximum(rng.uniform(-1, 4, (n, m)), 0.0))
        caps_list.append(rng.integers(1, 4, m).tolist())
    batch = solve_dense_auction_jax_batch(ws, caps_list)
    for w, caps, b in zip(ws, caps_list, batch):
        solo = solve_dense_auction_jax(w, caps)
        assert b.assignment == solo.assignment
        assert b.welfare == pytest.approx(solo.welfare, abs=1e-4)


@pytest.mark.slow
def test_sharded_dense_jax_matches_dense():
    rng = np.random.default_rng(13)
    values, costs, caps = _market(rng, 20, 10)
    blocks = _partition(rng, *values.shape, 3)
    jx = run_sharded_auction(values, costs, caps, blocks, solver="dense-jax")
    ref = run_sharded_auction(values, costs, caps, blocks, solver="dense")
    for h in blocks:
        tol = max(1e-4, jx[h].solver_stats["gap_bound"])
        assert abs(jx[h].welfare - ref[h].welfare) <= tol


@pytest.mark.slow
def test_sharded_dense_jax_warm_start_roundtrip():
    rng = np.random.default_rng(17)
    values, costs, caps = _market(rng, 20, 10)
    blocks = _partition(rng, *values.shape, 3)
    first = run_sharded_auction(values, costs, caps, blocks, solver="dense-jax")
    seeds = {h: np.concatenate([np.asarray(p) for p in
                                first[h].solver_stats["agent_prices"]])
             for h in first}
    warm = run_sharded_auction(values, costs, caps, blocks,
                               solver="dense-jax", start_prices=seeds)
    for h in blocks:
        assert warm[h].solver_stats["warm_started"]
        tol = max(1e-4, warm[h].solver_stats["gap_bound"])
        assert abs(warm[h].welfare - first[h].welfare) <= tol
