"""Fused device-resident routing step (`core/routing_fused`) vs the staged
oracle: decision parity, welfare optimality, construction guards, retrace
bounds.

The fused program runs float32 on device while the staged Phase 1 is
float64 NumPy, so parity tests use HETEROGENEOUS agents (distinct per-agent
token prices) — under exact column ties the two precisions can break a tie
into different equally-optimal permutations (same welfare, same payments),
which is degeneracy, not divergence.  With a unique optimum the contract is
strict: identical assignments, payments and QoS estimates within float32
tolerance, on every batch of a lockstep run with synchronized Phase-4
feedback."""
import numpy as np
import pytest

from repro.core.mechanism import (AgentInfo, CompletionObs, IEMASRouter,
                                  Request)
from repro.core.pricing import TokenPrices
from repro.core.routing_fused import FUSED_SOLVERS

PAY_TOL = 1e-5          # float32 welfare -> float64 Clarke pivot drift
EST_TOL = 1e-4          # QoS estimate drift (relative scale ~1)


def hetero_agents(m: int = 5, cap: int = 2) -> list[AgentInfo]:
    """Distinct per-agent prices => unique welfare optimum (no ties)."""
    out = []
    for i in range(m):
        pr = TokenPrices(0.01 * (1 + i / m), 0.001 * (1 + i / m),
                         0.03 * (1 + i / m))
        out.append(AgentInfo(f"a{i}", pr, cap,
                             ("dialogue",) if i % 2 == 0
                             else ("dialogue", "reasoning"),
                             scale=4.0 + i, recurrent=(i == 3),
                             cache_slots=2 if i == 1 else 0))
    return out


def make_batch(n: int, t: int, seed: int, parents: bool = False):
    rng = np.random.default_rng(seed * 1000 + t)
    reqs = []
    for j in range(n):
        meta = {}
        if parents and j % 3 == 1:
            meta["parent_sessions"] = (f"d{(j + 1) % 4}", f"d{(j + 2) % 4}")
        reqs.append(Request(f"r{t}_{j}", f"d{j % 4}",
                            rng.integers(0, 50, int(rng.integers(5, 30))),
                            turn=t, domain="dialogue" if j % 2 == 0
                            else "reasoning", meta=meta))
    return reqs


TELEMETRY = {"router_inflight": 2, "router_rps": 1.0,
             "agent_inflight": {"a0": 1}, "agent_rps": {"a1": 0.5}}


def clone(reqs):
    return [Request(r.request_id, r.dialogue_id, r.tokens.copy(), r.turn,
                    r.domain, meta=dict(r.meta)) for r in reqs]


def lockstep(ref, fused, n_batches: int, seed: int, parents: bool = False,
             rng=None):
    """Route identical batches through both routers with synchronized
    feedback; yields (batch index, ref decisions, fused decisions)."""
    rng = rng or np.random.default_rng(seed + 99)
    for t in range(n_batches):
        reqs = make_batch(int(rng.integers(2, 9)), t, seed, parents=parents)
        dr = ref.route_batch(reqs, dict(TELEMETRY))
        df = fused.route_batch(clone(reqs), dict(TELEMETRY))
        yield t, dr, df
        for d in dr:            # identical Phase-4 observations to both
            if d.agent_id:
                obs = CompletionObs(latency=0.03 + 0.01 * rng.random(),
                                    n_prompt=len(d.request.tokens), n_hit=0,
                                    n_gen=20, quality=0.7)
                ref.on_complete(d.request.request_id, obs)
                fused.on_complete(d.request.request_id, obs)


def assert_decisions_match(t, dr, df):
    """Two-tier parity gate.

    Tier 1 (the common case): identical assignments => payments and QoS
    estimates must agree to float32 tolerance.  Tier 2: when the float32
    welfare bits flip the ε-scaling auction onto a DIFFERENT assignment,
    that assignment must be welfare-equivalent — total welfare within the
    auction's own ε-optimality gap (measured ~1e-6 relative on the seeds
    that hit this; payments then differ because Clarke pivots price two
    different equilibria, which is tie degeneracy, not an error)."""
    a_r = [d.agent_id for d in dr]
    a_f = [d.agent_id for d in df]
    w_r = sum(d.welfare_weight for d in dr)
    w_f = sum(d.welfare_weight for d in df)
    if a_f != a_r:
        assert abs(w_f - w_r) <= 1e-5 * max(1.0, abs(w_r)), \
            f"batch {t}: fused {a_f} (welfare {w_f}) != staged {a_r} " \
            f"(welfare {w_r}) beyond the ε-optimality gap"
        return False
    for r, f in zip(dr, df):
        assert abs(r.payment - f.payment) < PAY_TOL, \
            f"batch {t}: payment {f.payment} vs {r.payment}"
        if r.agent_id:
            assert abs(r.estimate.latency - f.estimate.latency) < EST_TOL
            assert abs(r.estimate.cost - f.estimate.cost) < EST_TOL
            assert abs(r.estimate.quality - f.estimate.quality) < EST_TOL
    return True


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("warm", [False, True])
def test_fused_matches_staged_dense_jax(seed, warm):
    """Full decision parity vs the staged dense-jax path over randomized
    lockstep batches (cold and warm-started)."""
    kw = dict(solver="dense-jax", n_hubs=1, warm_start=warm)
    ref = IEMASRouter(hetero_agents(), **kw)
    fused = IEMASRouter(hetero_agents(), fused=True, **kw)
    for t, dr, df in lockstep(ref, fused, 5, seed):
        if not assert_decisions_match(t, dr, df):
            break   # post-divergence feedback lands on different agents


def test_fused_matches_staged_with_parent_credit():
    """DAG parent-session credit (scatter-max inside the program) keeps
    parity with the staged `parent_credit` host path."""
    kw = dict(solver="dense-jax", n_hubs=1, warm_start=True)
    ref = IEMASRouter(hetero_agents(), **kw)
    fused = IEMASRouter(hetero_agents(), fused=True, **kw)
    for t, dr, df in lockstep(ref, fused, 5, seed=7, parents=True):
        if not assert_decisions_match(t, dr, df):
            break


def test_fused_matches_staged_pallas():
    """The pallas bid-round variant composes into the fused program with
    the same decision parity (interpret mode off-TPU: slow, fewer rounds)."""
    kw = dict(solver="pallas", n_hubs=1, warm_start=False)
    ref = IEMASRouter(hetero_agents(m=4), **kw)
    fused = IEMASRouter(hetero_agents(m=4), fused=True, **kw)
    for t, dr, df in lockstep(ref, fused, 2, seed=3):
        if not assert_decisions_match(t, dr, df):
            break


@pytest.mark.parametrize("ref_solver", ["mcmf", "dense"])
def test_fused_welfare_within_gap_of_reference(ref_solver):
    """Backends that cannot compose into the program (exact MCMF, the
    host-vectorized dense auction) are covered by the ε-scaling optimality
    gap: the fused assignment's total welfare matches the reference
    backend's to within n·ε_final (tiny vs the welfare scale)."""
    kw = dict(n_hubs=1, warm_start=False)
    ref = IEMASRouter(hetero_agents(), solver=ref_solver, **kw)
    fused = IEMASRouter(hetero_agents(), solver="dense-jax", fused=True, **kw)
    for t, dr, df in lockstep(ref, fused, 4, seed=5):
        w_r = sum(d.welfare_weight for d in dr)
        w_f = sum(d.welfare_weight for d in df)
        assert abs(w_f - w_r) <= 1e-3 * max(1.0, w_r), \
            f"batch {t}: fused welfare {w_f} vs {ref_solver} {w_r}"
        if [d.agent_id for d in dr] != [d.agent_id for d in df]:
            break   # states drift once feedback lands on different agents


def test_fused_init_requires_single_hub():
    with pytest.raises(ValueError, match="n_hubs=1"):
        IEMASRouter(hetero_agents(), solver="dense-jax", n_hubs=2,
                    fused=True)


@pytest.mark.parametrize("solver", ["mcmf", "dense"])
def test_fused_init_requires_staged_solver(solver):
    assert solver not in FUSED_SOLVERS
    with pytest.raises(ValueError):
        IEMASRouter(hetero_agents(), solver=solver, n_hubs=1, fused=True)


def test_fused_shape_buckets_bound_retracing():
    """Satellite of the perf contract: every batch size inside one pow-2
    bucket reuses the same traced program (mirrors the `descend_jax`
    retrace test), even with Phase-4 feedback growing the forests between
    batches.  Serving-scale smoke shapes: fleet 16, batches 9..16."""
    router = IEMASRouter(hetero_agents(m=16, cap=2), solver="dense-jax",
                         n_hubs=1, warm_start=False, fused=True)
    rng = np.random.default_rng(11)

    def route(n, t):
        reqs = make_batch(n, t, seed=13)
        for d in router.route_batch(reqs, dict(TELEMETRY)):
            if d.agent_id:
                router.on_complete(
                    d.request.request_id,
                    CompletionObs(latency=0.02 + 0.01 * rng.random(),
                                  n_prompt=len(d.request.tokens), n_hit=0,
                                  n_gen=16, quality=0.75))

    route(12, 0)                       # trace the (nb=16, mb=16) bucket
    before = router._fused.cache_size()
    for t, n in enumerate(range(9, 17)):
        route(n, t + 1)
    grew = router._fused.cache_size() - before
    # headroom 2: a forest split can cross the node-pool pow-2 bucket and
    # the ledger arena can regrow once as sessions accumulate
    assert grew <= 2, f"fused step retraced {grew} times within one bucket"


def test_fused_profiler_counters():
    """Each step notes exactly one host transfer and zero mid-pipeline
    syncs on the attached profiler."""
    from repro.serving.simulator import RoutingProfiler

    router = IEMASRouter(hetero_agents(), solver="dense-jax", n_hubs=1,
                         fused=True)
    router.profiler = prof = RoutingProfiler()
    for t in range(3):
        router.route_batch(make_batch(4, t, seed=17), dict(TELEMETRY))
    rep = prof.report()
    assert rep["fused"]["host_transfers"] == 3
    assert rep["fused"]["mid_pipeline_syncs"] == 0
    assert rep["fused"]["retraces"] >= 1      # first call traced something
