"""Per-arch smoke + decode/extend consistency for the 10 assigned archs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, list_archs, param_counts
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _reduced(name):
    red = get_config(name).scaled(dtype="float32")
    if red.is_moe:  # no-drop capacity so batched/stepwise paths agree
        red = dataclasses.replace(red, capacity_factor=float(red.n_experts))
    return red


def _batch(red, b, s, key=KEY):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, red.vocab_size)}
    if red.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, red.n_patches, red.d_model)) * 0.1
    if red.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (b, red.src_len, red.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("name", list_archs())
def test_smoke_forward_and_train_step(name):
    """Reduced config: one loss + one grad step, output finite."""
    red = _reduced(name)
    m = build_model(red)
    params = m.init(KEY)
    batch = _batch(red, 2, 24)
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", list_archs())
def test_decode_matches_parallel(name):
    red = _reduced(name)
    m = build_model(red)
    params = m.init(KEY)
    b, s = 2, 21
    full = _batch(red, b, s + 1)
    pad = red.n_patches if red.family == "vlm" else 0
    ml = s + pad + 4
    pbA = dict(full)
    pbA["tokens"] = full["tokens"][:, :s]
    pbA["max_len"] = ml
    logA, cache = m.prefill(params, pbA)
    logA2, _ = m.decode_step(params, cache, full["tokens"][:, s])
    pbB = dict(full)
    pbB["max_len"] = ml
    logB, _ = m.prefill(params, pbB)
    scale = float(np.max(np.abs(np.asarray(logB)))) + 1e-9
    err = float(np.max(np.abs(np.asarray(logA2) - np.asarray(logB))))
    assert err / scale < 2e-3


@pytest.mark.parametrize("name", [n for n in list_archs()
                                  if n not in ("seamless-m4t-medium",
                                               "zamba2-7b")])
def test_extend_matches_prefill(name):
    red = _reduced(name)
    m = build_model(red)
    params = m.init(KEY)
    b, s, s0 = 2, 21, 13
    full = _batch(red, b, s)
    pad = red.n_patches if red.family == "vlm" else 0
    ml = s + pad + 4
    ref_b = dict(full)
    ref_b["max_len"] = ml
    logRef, _ = m.prefill(params, ref_b)
    pbC = dict(full)
    pbC["tokens"] = full["tokens"][:, :s0]
    pbC["max_len"] = ml
    _, cacheC = m.prefill(params, pbC)
    lens_new = jnp.full((b,), s - s0, jnp.int32)
    logD, _ = m.extend(params, cacheC, full["tokens"][:, s0:], lens_new)
    scale = float(np.max(np.abs(np.asarray(logRef)))) + 1e-9
    err = float(np.max(np.abs(np.asarray(logD) - np.asarray(logRef))))
    assert err / scale < 2e-3


@pytest.mark.parametrize("name", list_archs())
def test_param_counts_analytic_close(name):
    """configs.param_counts tracks real counts within 8% on full configs
    (the rwkv6 formula approximates the ddlerp LoRA stack; 6.6% there)."""
    from repro.utils.tree import param_count

    cfg = get_config(name)
    m = build_model(cfg)
    abstract = jax.eval_shape(m.init, KEY)
    real = param_count(abstract)
    est = param_counts(cfg)["total"]
    assert abs(real - est) / real < 0.08, (real, est)


def test_sliding_window_ring_cache():
    """mixtral-family ring cache: decode equals parallel past the window."""
    red = _reduced("mixtral-8x22b")
    red = dataclasses.replace(red, sliding_window=12)
    m = build_model(red)
    params = m.init(KEY)
    b, s = 1, 40  # several window wraps
    toks = jax.random.randint(KEY, (b, s + 1), 0, red.vocab_size)
    logA, cache = m.prefill(params, {"tokens": toks[:, :s], "max_len": s + 4})
    logA2, _ = m.decode_step(params, cache, toks[:, s])
    logB, _ = m.prefill(params, {"tokens": toks, "max_len": s + 4})
    scale = float(np.max(np.abs(np.asarray(logB)))) + 1e-9
    assert float(np.max(np.abs(np.asarray(logA2) - np.asarray(logB)))) / scale < 2e-3


def test_mla_absorbed_decode_equivalent():
    """DeepSeek-V2 absorbed decode == naive latent-expansion decode."""
    from repro.models import attention as attn_mod

    red = _reduced("deepseek-v2-lite-16b")
    m = build_model(red)
    params = m.init(KEY)
    toks = jax.random.randint(KEY, (2, 13), 0, red.vocab_size)
    _, cache = m.prefill(params, {"tokens": toks, "max_len": 16})
    nxt = jnp.array([3, 5])
    prev = attn_mod.MLA_ABSORBED
    try:
        attn_mod.MLA_ABSORBED = False
        log_naive, _ = m.decode_step(params, cache, nxt)
        attn_mod.MLA_ABSORBED = True
        log_abs, _ = m.decode_step(params, cache, nxt)
    finally:
        attn_mod.MLA_ABSORBED = prev
    a, b = np.asarray(log_naive), np.asarray(log_abs)
    assert np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9) < 2e-3
