"""MCMF solver: exactness vs brute force (Theorem 4.1), integrality."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.auction import solve_allocation
from repro.core.mcmf import brute_force_matching


@st.composite
def instances(draw):
    n = draw(st.integers(1, 6))
    m = draw(st.integers(1, 4))
    w = [[round(draw(st.floats(-1, 3, allow_nan=False)), 3) for _ in range(m)]
         for _ in range(n)]
    caps = [draw(st.integers(1, 2)) for _ in range(m)]
    return np.array(w), caps


@settings(max_examples=120, deadline=None)
@given(instances())
def test_mcmf_matches_brute_force(inst):
    w, caps = inst
    wp = np.where(w > 0, w, 0.0)
    bf_w, _ = brute_force_matching(wp.tolist(), caps)
    assignment, wf, _ = solve_allocation(wp, caps)
    assert wf == pytest.approx(bf_w, abs=1e-6)
    # feasibility: request matched at most once, capacities respected
    used = {}
    for j, i in enumerate(assignment):
        if i >= 0:
            assert wp[j, i] > 0
            used[i] = used.get(i, 0) + 1
    for i, c in used.items():
        assert c <= caps[i]


def test_welfare_monotone_in_capacity():
    rng = np.random.default_rng(3)
    w = rng.uniform(0, 2, (8, 3))
    _, w1, _ = solve_allocation(w, [1, 1, 1])
    _, w2, _ = solve_allocation(w, [2, 2, 2])
    _, w3, _ = solve_allocation(w, [8, 8, 8])
    assert w1 <= w2 + 1e-9 <= w3 + 2e-9
    # with unlimited capacity every request takes its best agent
    assert w3 == pytest.approx(np.maximum(w, 0).max(axis=1).sum())


def test_prunes_nonpositive_edges():
    w = np.array([[-5.0, -1.0], [-2.0, -3.0]])
    assignment, wf, _ = solve_allocation(np.where(w > 0, w, 0.0), [1, 1])
    assert assignment == [-1, -1]
    assert wf == 0.0
