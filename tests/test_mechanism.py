"""IEMAS router (Algorithm 1) end-to-end + hubs + predictors + properties."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (AgentInfo, CompletionObs, IEMASRouter, Request,
                        TokenPrices, ValuationConfig)
from repro.core.hub import cluster_agents, route_to_hub
from repro.core.predictor import AgentPredictor, PredictorInput
from repro.core.pricing import observed_cost, predicted_cost


def _agents(m=4, cap=2):
    return [AgentInfo(f"a{i}", TokenPrices(0.01, 0.001, 0.03), cap,
                      ("dialogue",) if i % 2 == 0 else ("reasoning",),
                      scale=4.0 + i) for i in range(m)]


def _requests(n=6, domain="dialogue"):
    rng = np.random.default_rng(0)
    return [Request(f"r{j}", f"d{j % 3}", rng.integers(1, 50, 20).astype(np.int32),
                    turn=j // 3, domain=domain) for j in range(n)]


def test_route_batch_respects_capacity():
    router = IEMASRouter(_agents(2, cap=1))
    decisions = router.route_batch(_requests(6), {})
    per_agent = {}
    for d in decisions:
        if d.agent_id:
            per_agent[d.agent_id] = per_agent.get(d.agent_id, 0) + 1
    assert all(v <= 1 for v in per_agent.values())


def test_feedback_updates_predictor_and_ledger():
    router = IEMASRouter(_agents(), predictor_kw={"warm_n": 1})
    reqs = _requests(3)
    decisions = router.route_batch(reqs, {})
    d0 = next(d for d in decisions if d.agent_id)
    router.on_complete(d0.request.request_id, CompletionObs(
        latency=0.05, n_prompt=20, n_hit=0, n_gen=8, quality=1.0))
    assert router.pool[d0.agent_id].n_obs == 1
    # ledger recorded the prompt -> affinity next turn
    o = router.ledger.affinity(d0.agent_id, d0.request.dialogue_id,
                               np.concatenate([d0.request.tokens,
                                               np.array([1, 2], np.int32)]))
    assert o == pytest.approx(20 / 22)


def test_affinity_steers_routing():
    """Turn 2 of a dialogue routes to the agent holding the cache."""
    router = IEMASRouter(_agents(4), predictor_kw={"warm_n": 99})
    req1 = _requests(1)
    d1 = router.route_batch(req1, {})[0]
    router.on_complete(req1[0].request_id, CompletionObs(0.05, 20, 0, 8, 1.0))
    follow = Request("r-next", req1[0].dialogue_id,
                     np.concatenate([req1[0].tokens,
                                     np.arange(1, 9, dtype=np.int32)]),
                     turn=1, domain="dialogue")
    d2 = router.route_batch([follow], {})[0]
    assert d2.agent_id == d1.agent_id


def test_quarantine_excludes_failed_agent():
    router = IEMASRouter(_agents(2))
    reqs = _requests(2)
    decisions = router.route_batch(reqs, {})
    victim = next(d.agent_id for d in decisions if d.agent_id)
    router.on_complete(
        next(d.request.request_id for d in decisions if d.agent_id == victim),
        CompletionObs(0, 10, 0, 0, 0, failed=True))
    assert victim in router.quarantined
    d3 = router.route_batch(_requests(4), {})
    assert all(d.agent_id != victim for d in d3)
    router.reinstate(victim)
    assert victim not in router.quarantined


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4), st.integers(1, 12))
def test_hub_partition_is_exact(k, m):
    domains = [("dialogue",) if i % 2 else ("reasoning",) for i in range(m)]
    scales = [float(i + 1) for i in range(m)]
    hubs = cluster_agents(domains, scales, k)
    seen = sorted(i for h in hubs for i in h.agent_indices)
    assert seen == list(range(m))  # partition: no loss, no duplication
    h = route_to_hub("dialogue", hubs, domains)
    assert 0 <= h < len(hubs)


def test_predictor_prior_uses_affinity():
    p = AgentPredictor("a", TokenPrices(0.01, 0.001, 0.03), warm_n=10)
    base = dict(prompt_len=100, turn=1, router_inflight=0, router_rps=0,
                agent_inflight=0, agent_rps=0, capacity=4, utilization=0,
                domain_match=1)
    cold = p.predict(PredictorInput(affinity=0.0, **base))
    hot = p.predict(PredictorInput(affinity=0.9, **base))
    assert hot.cost < cold.cost      # cached tokens are cheaper (Eq. 6)
    assert hot.latency < cold.latency  # and faster (prefill skipped)


def test_pricing_eq6():
    prices = TokenPrices(0.01, 0.001, 0.03)
    assert observed_cost(prices, 100, 60, 10) == pytest.approx(
        0.01 * 40 + 0.001 * 60 + 0.03 * 10)
    assert predicted_cost(prices, 100, 0.6, 10) == pytest.approx(
        observed_cost(prices, 100, 60, 10))


def test_failed_completion_quarantines_and_charges_nothing():
    """Fault path regression: on_complete(failed=True) must quarantine the
    agent, book NO payment/cost/welfare, skip predictor+ledger updates, and
    drop the pending entry (a duplicate completion is a no-op)."""
    router = IEMASRouter(_agents(2), predictor_kw={"warm_n": 1})
    decisions = router.route_batch(_requests(2), {})
    d0 = next(d for d in decisions if d.agent_id)
    before = dict(router.accounts)
    router.on_complete(d0.request.request_id, CompletionObs(
        latency=0.0, n_prompt=20, n_hit=0, n_gen=0, quality=0.0, failed=True))
    assert d0.agent_id in router.quarantined
    assert router.accounts["payments"] == before["payments"]
    assert router.accounts["agent_costs"] == before["agent_costs"]
    assert router.accounts["surplus"] == before["surplus"]
    assert router.accounts["welfare_realized"] == before["welfare_realized"]
    assert router.pool[d0.agent_id].n_obs == 0
    assert router.ledger.get(d0.agent_id, d0.request.dialogue_id) is None
    assert d0.request.request_id not in router._pending
    # duplicate delivery of the same completion must be inert
    router.on_complete(d0.request.request_id, CompletionObs(
        latency=0.1, n_prompt=20, n_hit=0, n_gen=4, quality=1.0))
    assert router.accounts["payments"] == before["payments"]
    assert router.pool[d0.agent_id].n_obs == 0


def test_cache_slots_lru_zeroes_evicted_affinity():
    """§4.4 published cache summaries: with cache_slots=k, sessions beyond
    the k most-recent are presumed evicted and their affinity zeroed, so the
    cold-start prior prices them as full-prefill; recent sessions keep their
    cache discount. cache_slots=0 means unbounded (no zeroing)."""
    rng = np.random.default_rng(2)
    toks = {d: rng.integers(1, 50, 24).astype(np.int32) for d in ("d0", "d1")}

    def one_agent_router(cache_slots):
        a = AgentInfo("a0", TokenPrices(0.01, 0.001, 0.03), 4, ("dialogue",),
                      cache_slots=cache_slots)
        r = IEMASRouter([a], predictor_kw={"warm_n": 99})
        r.ledger.update("a0", "d0", toks["d0"])  # older session
        r.ledger.update("a0", "d1", toks["d1"])  # most recent session
        return r

    def estimate(router, dlg):
        ext = np.concatenate([toks[dlg], np.array([1, 2], np.int32)])
        req = Request("rx", dlg, ext, turn=1, domain="dialogue")
        return router.route_batch([req], {})[0].estimate

    lru = one_agent_router(cache_slots=1)
    unbounded = one_agent_router(cache_slots=0)
    # evicted session d0: prior must see affinity 0 -> full-prefill pricing
    ev, ok = estimate(lru, "d0"), estimate(unbounded, "d0")
    assert ev.cost > ok.cost and ev.latency > ok.latency
    # the most recent session keeps its discount even under the LRU model
    hot_lru, hot_unb = estimate(lru, "d1"), estimate(unbounded, "d1")
    assert hot_lru.cost == pytest.approx(hot_unb.cost)
    assert hot_lru.cost < ev.cost


def test_hub_auction_welfare_close_to_global():
    """K=2 hubs lose little welfare vs K=1 on a domain-structured market."""
    agents = _agents(8)
    reqs = _requests(8, domain="dialogue") + _requests(4, domain="reasoning")
    for i, r in enumerate(reqs):
        r.meta["i"] = i
    g = IEMASRouter(agents, n_hubs=1, predictor_kw={"warm_n": 99})
    h = IEMASRouter(agents, n_hubs=2, predictor_kw={"warm_n": 99})
    dg = g.route_batch(list(reqs), {})
    dh = h.route_batch(list(reqs), {})
    wg = sum(d.welfare_weight for d in dg if d.agent_id)
    wh = sum(d.welfare_weight for d in dh if d.agent_id)
    assert wh >= 0.75 * wg
