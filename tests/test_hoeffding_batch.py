"""Batched Hoeffding inference is a pure oracle-parity optimization:
``predict_batch`` (compiled flat trees, one vectorized pass) must match
per-row ``predict_one`` to 1e-12 for any training stream — including
mid-stream recompiles after ``learn_one`` splits — and stacked multi-tree
node pools must match their per-tree oracles."""
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.hoeffding import (HoeffdingTreeClassifier,
                                  HoeffdingTreeRegressor, descend,
                                  stack_compiled)

# aggressive split parameters so generated trees actually grow (the default
# Hoeffding bound needs thousands of samples to split on noisy targets)
SPLITTY = dict(grace_period=15, delta=0.2, tie_threshold=0.5, max_depth=5)


def _parity(tree, X):
    batch = tree.predict_batch(X)
    scalar = np.array([tree.predict_one(row) for row in X])
    err = np.max(np.abs(batch - scalar)) if len(X) else 0.0
    assert err <= 1e-12, err


@settings(max_examples=75, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 8), st.integers(30, 250))
def test_regressor_batch_matches_scalar(seed, n_feat, n_samples):
    rng = np.random.default_rng(seed)
    tree = HoeffdingTreeRegressor(n_feat, **SPLITTY)
    probe = rng.uniform(-2, 2, (40, n_feat))
    _parity(tree, probe)  # untrained
    jump = rng.uniform(5.0, 20.0)
    for k in range(n_samples):
        x = rng.uniform(-2, 2, n_feat)
        y = jump * (x[0] > 0.0) + x[-1] + rng.normal(0, 0.1)
        tree.learn_one(x, y)
        if k % 17 == 0:  # mid-stream: parity straddles recompiles
            _parity(tree, probe)
    _parity(tree, probe)
    _parity(tree, rng.uniform(-3, 3, (25, n_feat)))


@settings(max_examples=75, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 8), st.integers(30, 250))
def test_classifier_batch_matches_scalar(seed, n_feat, n_samples):
    rng = np.random.default_rng(seed)
    tree = HoeffdingTreeClassifier(n_feat, **SPLITTY)
    probe = rng.uniform(-2, 2, (40, n_feat))
    _parity(tree, probe)
    for k in range(n_samples):
        x = rng.uniform(-2, 2, n_feat)
        tree.learn_one(x, float(x[1] > 0.3))
        if k % 17 == 0:
            _parity(tree, probe)
    _parity(tree, probe)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 6))
def test_stacked_forest_matches_per_tree(seed, n_trees):
    """One concatenated node pool with per-row roots == per-tree oracles."""
    rng = np.random.default_rng(seed)
    n_feat = 4
    trees = []
    for t in range(n_trees):
        tree = HoeffdingTreeRegressor(n_feat, **SPLITTY)
        for _ in range(int(rng.integers(0, 120))):
            x = rng.uniform(-1, 1, n_feat)
            tree.learn_one(x, 8.0 * (x[t % n_feat] > 0) + rng.normal(0, 0.1))
        trees.append(tree)
    stacked, roots = stack_compiled([t.compiled() for t in trees])
    X = rng.uniform(-1.5, 1.5, (60, n_feat))
    which = rng.integers(0, n_trees, 60)
    out = descend(stacked, X, roots[which])
    ref = np.array([trees[which[i]].predict_one(X[i]) for i in range(60)])
    assert np.max(np.abs(out - ref)) <= 1e-12


def test_recompile_on_split_and_cache_reuse():
    """The compiled form is cached between predictions and invalidated by
    ANY learn_one (leaf means shift without splits), and a split visibly
    changes the flat structure while parity holds throughout."""
    rng = np.random.default_rng(0)
    tree = HoeffdingTreeRegressor(3, **SPLITTY)
    c0 = tree.compiled()
    assert tree.compiled() is c0  # cached: no learning in between
    n_nodes = [1]
    probe = rng.uniform(-1, 1, (30, 3))
    for _ in range(200):
        x = rng.uniform(-1, 1, 3)
        tree.learn_one(x, 10.0 * (x[0] > 0) + rng.normal(0, 0.05))
        _parity(tree, probe)
        n_nodes.append(len(tree.compiled().feature))
    assert tree.compiled() is not c0
    assert max(n_nodes) >= 3  # at least one split happened mid-stream
    assert tree.compiled().depth >= 1


def test_jax_backend_close_to_numpy_oracle():
    """The jit-staged descend (float32 on default configs) tracks the
    NumPy oracle to float32 tolerance."""
    rng = np.random.default_rng(1)
    tree = HoeffdingTreeRegressor(4, **SPLITTY)
    for _ in range(300):
        x = rng.uniform(-1, 1, 4)
        tree.learn_one(x, 6.0 * (x[0] > 0) + x[2] + rng.normal(0, 0.1))
    X = rng.uniform(-1, 1, (50, 4))
    ref = tree.predict_batch(X)
    jx = tree.predict_batch(X, backend="jax")
    assert np.max(np.abs(jx - ref)) < 1e-4


def test_jax_backend_shape_buckets_bound_retracing():
    """descend_jax pads the batch, node pool and depth to pow-2 buckets, so
    batch-size wobble and tree splits reuse O(log) traced programs."""
    from repro.core.hoeffding import _jax_descend

    rng = np.random.default_rng(5)
    tree = HoeffdingTreeRegressor(4, **SPLITTY)
    for _ in range(150):
        x = rng.uniform(-1, 1, 4)
        tree.learn_one(x, 4.0 * (x[1] > 0) + rng.normal(0, 0.1))
    before = _jax_descend()._cache_size()
    # every batch size in one pow-2 bucket (9..16) plus ongoing splits
    for b in range(9, 17):
        X = rng.uniform(-1, 1, (b, 4))
        ref = tree.predict_batch(X)
        jx = tree.predict_batch(X, backend="jax")
        assert np.max(np.abs(jx - ref)) < 1e-4
        tree.learn_one(rng.uniform(-1, 1, 4), rng.normal())
    grew = _jax_descend()._cache_size() - before
    assert grew <= 2, f"descend_jax retraced {grew} times across one bucket"


def test_jax_backend_bucket_padding_is_behavior_neutral():
    """Padded rows/nodes never leak into real outputs, any batch size."""
    rng = np.random.default_rng(7)
    tree = HoeffdingTreeRegressor(3, **SPLITTY)
    for _ in range(200):
        x = rng.uniform(-1, 1, 3)
        tree.learn_one(x, 3.0 * (x[0] > 0) + rng.normal(0, 0.05))
    for b in (1, 2, 7, 8, 9, 31, 64):
        X = rng.uniform(-1, 1, (b, 3))
        ref = tree.predict_batch(X)
        jx = tree.predict_batch(X, backend="jax")
        assert jx.shape == (b,)
        assert np.max(np.abs(jx - ref)) < 1e-4
