"""Adversary layer: seeded assignment, report-only mutation, reputation
countermeasure, fraction-0 bit-neutrality, and the settlement ledger's
hash-chain / tamper / replay guarantees."""
import dataclasses

import numpy as np
import pytest

from repro.configs.iemas_cluster import RouterConfig
from repro.core.adversary import (POLICIES, AdversaryMix, AdversaryPolicy,
                                  CollusionRingPolicy, CostMisreportPolicy,
                                  FreeRiderPolicy)
from repro.core.ledger import GENESIS, SettlementLedger
from repro.core.mechanism import CompletionObs
from repro.core.predictor import AgentPredictor
from repro.core.pricing import TokenPrices
from repro.serving import SimCluster, make_router, run_workload
from repro.serving.workload import WorkloadSpec, generate


def _run(n_dialogues=6, seed=0, mix=None, **router_kw):
    cluster = SimCluster(6, seed=seed, engine_mode="analytic",
                         adversary_mix=mix)
    router = make_router(cluster, RouterConfig(
        solver="dense", n_hubs=2, warm_start=True, **router_kw))
    spec = WorkloadSpec("coqa_like", n_dialogues=n_dialogues, seed=seed + 1)
    metrics = run_workload(cluster, router, generate(spec), max_new_tokens=4)
    return cluster, router, metrics


# --------------------------- AdversaryMix ---------------------------------

def test_mix_fraction_zero_assigns_nobody():
    cluster = SimCluster(5, seed=0, engine_mode="analytic")
    infos = cluster.agent_infos()
    for policy in POLICIES:
        assert AdversaryMix(policy=policy, fraction=0.0).assign(infos) == {}


def test_mix_assignment_deterministic_in_seed():
    cluster = SimCluster(8, seed=1, engine_mode="analytic")
    infos = cluster.agent_infos()
    a = AdversaryMix(policy="misreport", fraction=0.5, seed=9).assign(infos)
    b = AdversaryMix(policy="misreport", fraction=0.5, seed=9).assign(infos)
    c = AdversaryMix(policy="misreport", fraction=0.5, seed=10).assign(infos)
    assert sorted(a) == sorted(b)
    assert len(a) == 4
    # a different seed is allowed to pick a different subset; sizes match
    assert len(c) == 4


def test_mix_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown adversary policy"):
        AdversaryMix(policy="bribery").assign([])


def test_collusion_ring_shares_one_instance_and_a_domain():
    cluster = SimCluster(8, seed=2, engine_mode="analytic")
    infos = cluster.agent_infos()
    adv = AdversaryMix(policy="collusion", fraction=0.25, seed=0).assign(infos)
    policies = list(adv.values())
    assert len(adv) == 2
    assert all(p is policies[0] for p in policies)  # one shared cartel
    assert sorted(adv) == sorted(policies[0].members)
    # the ring seeds from the largest domain cluster: its first two members
    # share at least one domain
    doms = {a.agent_id: set(a.domains) for a in infos}
    ring = list(policies[0].members)
    assert doms[ring[0]] & doms[ring[1]]


# ----------------------- report-only mutation ------------------------------

def test_misreport_publishes_a_deflated_copy():
    cluster = SimCluster(4, seed=3, engine_mode="analytic")
    rt = next(iter(cluster.agents.values()))
    true_prices = rt.info.prices
    pol = CostMisreportPolicy(theta=0.5)
    published = pol.publish(rt.info)
    assert published is not rt.info  # a copy, never the runtime's object
    assert published.prices.out == pytest.approx(true_prices.out * 0.5)
    assert rt.info.prices is true_prices  # ground truth untouched
    inflated = CollusionRingPolicy(theta=0.5).publish(rt.info)
    assert inflated.prices.miss == pytest.approx(true_prices.miss * 1.5)


def test_freerider_inflates_report_but_audit_carries_truth():
    obs = CompletionObs(latency=0.1, n_prompt=10, n_hit=0, n_gen=4,
                        quality=0.7)
    out = FreeRiderPolicy(theta=0.4).report(obs, true_quality=0.7)
    assert out.quality == pytest.approx(1.0)  # 0.7 + 0.4 clipped
    assert out.audit_quality == pytest.approx(0.7)
    # the honest base policy reports truthfully with a zero residual
    base = AdversaryPolicy().report(obs, true_quality=0.7)
    assert base.quality == pytest.approx(0.7)
    assert base.audit_quality == pytest.approx(0.7)


# -------------------- reputation countermeasure ----------------------------

def test_note_residual_ewma_and_exact_fixed_point():
    p = AgentPredictor("a0", TokenPrices(1e-6, 1e-7, 2e-6), rep_alpha=0.25)
    assert p.reputation == 1.0
    p.note_residual(0.0)
    assert p.reputation == 1.0  # zero residual is an EXACT fixed point
    p.note_residual(0.4)
    assert p.reputation == pytest.approx(0.75 * 1.0 + 0.25 * 0.6)
    p.note_residual(2.0)  # residual clips to 1 -> target 0
    assert p.reputation == pytest.approx(0.75 * 0.9)


def test_freerider_reputation_decays_only_for_the_liar():
    # seed chosen so a free-rider both wins traffic and draws a quality-0
    # outcome (the Bernoulli evaluator only exposes inflation when the true
    # draw is below the inflated report)
    mix = AdversaryMix(policy="freerider", fraction=0.34, theta=0.5, seed=9)
    cluster, router, _ = _run(n_dialogues=16, seed=9, mix=mix)
    adv = set(cluster.adversaries)
    assert adv
    reps = router.pool.reputations()
    # honest agents keep reputation at EXACTLY 1.0 (bit-level fixed point)
    for aid, rep in reps.items():
        if aid not in adv:
            assert rep == 1.0, aid
    # at least one free-rider won traffic and got caught inflating
    assert min(reps[a] for a in adv) < 1.0


def test_fraction_zero_mix_is_bit_identical_to_no_mix():
    _, r_plain, m_plain = _run(seed=7, audit_ledger=True)
    mix = AdversaryMix(policy="misreport", fraction=0.0, seed=7)
    _, r_mix, m_mix = _run(seed=7, mix=mix, audit_ledger=True)
    assert m_plain == m_mix
    assert r_plain.accounts == r_mix.accounts
    assert r_plain.settlement.head == r_mix.settlement.head  # same chain


# --------------------------- ledger ----------------------------------------

def test_ledger_chain_verifies_and_detects_tampering():
    led = SettlementLedger()
    assert led.head == GENESIS
    led.append(kind="settle", request_id="r1", agent_id="a1", payment=2.0,
               cost=1.0, reported_quality=0.9, audited_quality=0.9,
               true_value=3.0, reputation_before=1.0, reputation_after=1.0)
    led.append(kind="fault", request_id="r2", agent_id="a2",
               reputation_before=1.0, reputation_after=1.0)
    assert led.verify_chain()
    assert led.entries[1].prev_hash == led.entries[0].entry_hash
    # tamper with a settled payment: the recomputed hash must not match
    led.entries[0] = dataclasses.replace(led.entries[0], payment=99.0)
    assert not led.verify_chain()
    with pytest.raises(ValueError, match="chain"):
        led.audit({"payments": 99.0, "agent_costs": 1.0, "surplus": 98.0,
                   "welfare_realized": 2.0})


def test_ledger_replay_matches_accounts_under_adversaries_and_faults():
    mix = AdversaryMix(policy="misreport", fraction=0.34, seed=11)
    cluster = SimCluster(6, seed=11, engine_mode="analytic",
                         adversary_mix=mix, fail_prob=0.2)
    router = make_router(cluster, RouterConfig(
        solver="dense", n_hubs=2, warm_start=True, audit_ledger=True))
    spec = WorkloadSpec("coqa_like", n_dialogues=8, seed=12)
    run_workload(cluster, router, generate(spec), max_new_tokens=4)
    balances = router.settlement.audit(router.accounts)
    assert balances["faults"] > 0
    assert balances["payments"] == router.accounts["payments"]
    assert balances["surplus"] == router.accounts["surplus"]
    # per-agent revenue recomputed from the chain covers every settled payee
    rev = router.settlement.revenue_by_agent()
    assert sum(rev.values()) == pytest.approx(balances["payments"])


def test_audit_rejects_diverged_accounts():
    led = SettlementLedger()
    led.append(kind="settle", request_id="r1", agent_id="a1", payment=2.0,
               cost=1.0, reported_quality=1.0, audited_quality=1.0,
               true_value=2.5, reputation_before=1.0, reputation_after=1.0)
    good = {"payments": 2.0, "agent_costs": 1.0, "surplus": 1.0,
            "welfare_realized": 1.5}
    assert led.audit(good)["settled"] == 1
    with pytest.raises(ValueError, match="payments"):
        led.audit({**good, "payments": 2.5})
