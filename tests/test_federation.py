"""Hubs-of-hubs federation: oracle parity, exactly-once, determinism.

The load-bearing contracts:

* S=1 `FederatedSimulator` is a bit-exact oracle for `EventSimulator` —
  same decisions, same accounts, same settlement-ledger head — in both
  the closed-loop lockstep regime and the open-loop Poisson regime.
* S>1 runs settle every dialogue exactly once under faults AND forced
  cross-super-hub migration (hash-chained per-shard ledgers + disjoint
  request-id prefixes + migration conservation).
* Results are bit-deterministic under ANY shard-advance schedule (the
  fold_in-style per-shard seed split shares no mutable state).
* Gossip staleness consumed by spill is bounded by one epoch when
  digests refresh every boundary.
* A process-parallel run is bit-identical to the inline run (same
  `InlineShard.from_spec` factory on both sides of the pipe).
"""
import numpy as np
import pytest

from repro.core import IEMASRouter
from repro.core.hub import cluster_super_hubs, route_to_super_hub
from repro.serving import (EventSimulator, SimCluster, SyncArrivals,
                           build_federation)
from repro.serving.workload import PoissonArrivals, WorkloadSpec, generate

ROUTER_KW = dict(solver="dense", warm_start=True, audit_ledger=True)


def _sig(cluster):
    """Bit-comparable per-record signature, in completion order."""
    return [(r.request.request_id, r.request.dialogue_id, r.request.turn,
             r.agent_id, r.n_prompt, r.n_hit, r.payment, r.latency,
             r.dispatched_at) for r in cluster.records]


def _single_heap(dlg, *, fail=0.0, **loop_kw):
    cluster = SimCluster(n_agents=4, seed=0, max_new_tokens=3,
                         engine_mode="analytic", fail_prob=fail)
    router = IEMASRouter(cluster.agent_infos(), n_hubs=2, **ROUTER_KW)
    out = EventSimulator(cluster, router, dlg, max_new_tokens=3,
                         **loop_kw).run()
    return cluster, router, out


def _federated_s1(dlg, *, fail=0.0, **loop_kw):
    fed = build_federation(
        dlg, n_agents=4, super_hubs=1,
        arrivals=loop_kw.pop("arrivals", None), seed=0,
        router_kwargs=dict(ROUTER_KW, n_hubs=2),
        loop_kwargs=dict(loop_kw, max_new_tokens=3),
        cluster_kwargs=dict(max_new_tokens=3, fail_prob=fail))
    out = fed.run()
    return fed.shards[0].cluster, fed.shards[0].router, out


# ---------------------------------------------------- S=1 oracle parity --
@pytest.mark.parametrize("fail", [0.0, 0.2])
def test_s1_bit_parity_lockstep(fail):
    """S=1 federation reproduces EventSimulator bit-for-bit in the
    quantized closed-loop regime — decisions, accounts, ledger head —
    including the fault path (same rng draw order)."""
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=7, seed=3))
    c1, r1, m1 = _single_heap(dlg, fail=fail, arrivals=SyncArrivals(),
                              batch_cap=4, quantize=0.05)
    c2, r2, m2 = _federated_s1(dlg, fail=fail, arrivals=SyncArrivals(),
                               batch_cap=4, quantize=0.05)
    assert _sig(c1) == _sig(c2)
    assert r1.accounts == r2.accounts
    assert r1.settlement.head == r2.settlement.head
    assert m1["n"] == m2["n"]
    assert m2["federation"]["exactly_once"]["ok"]


def test_s1_bit_parity_open_loop():
    """Same oracle contract under Poisson arrivals and a bounded
    admission window — the streaming regime, epoch pauses included."""
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=20, seed=5))
    c1, r1, m1 = _single_heap(
        dlg, arrivals=PoissonArrivals(rate=12.0, seed=2), batch_cap=8,
        batch_window=0.05, max_inflight=16)
    c2, r2, m2 = _federated_s1(
        dlg, arrivals=PoissonArrivals(rate=12.0, seed=2), batch_cap=8,
        batch_window=0.05, max_inflight=16)
    assert _sig(c1) == _sig(c2)
    assert r1.accounts == r2.accounts
    assert r1.settlement.head == r2.settlement.head


# -------------------------------------------- exactly-once + migration --
def _overloaded_federation(*, fail=0.0, shard_schedule=None, seed=0,
                           rate=300.0, parallel="inline"):
    """3 super-hubs with every dialogue forced into ONE domain: the home
    shard saturates, the other two idle — spill must migrate."""
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=150, seed=1))
    dom = sorted({d.domain for d in dlg})[0]
    dlg = [type(d)(d.dialogue_id, dom, d.turns, d.difficulty) for d in dlg]
    return build_federation(
        dlg, n_agents=12, super_hubs=3,
        arrivals=PoissonArrivals(rate=rate, seed=2), seed=seed,
        router_kwargs=dict(ROUTER_KW),
        loop_kwargs=dict(batch_cap=32, batch_window=0.05, max_new_tokens=4),
        cluster_kwargs=dict(max_new_tokens=4, fail_prob=fail),
        max_inflight=900, epoch=0.25, spill_min_wait=0.2,
        shard_schedule=shard_schedule, parallel=parallel)


def test_s3_exactly_once_under_faults_and_migration():
    """Every dialogue settles exactly once when the saturated shard spills
    across super-hubs AND agents fault mid-flight: per-shard ledger
    replays verify, request-id prefixes stay disjoint, migration hand-offs
    conserve dialogues, and nothing is lost or double-completed."""
    out = _overloaded_federation(fail=0.1).run()
    eo = out["federation"]["exactly_once"]
    assert out["federation"]["spill_migrated"] > 0   # migration exercised
    assert out["migrated_in"] == out["migrated_out"] > 0
    assert eo["ok"] and eo["ledger_replay_ok"]
    assert eo["lost_dialogues"] == 0
    assert eo["ledgers_attached"] == 3
    assert out["dialogues_arrived"] == 150
    assert out["dialogues_completed"] + out["unfinished_dialogues"] == 150
    assert not out["truncated"]


def test_spill_rescues_saturated_shard():
    """The spill round moves work onto idle remote capacity: migrated
    dialogues complete remotely (the destination shard books completions
    it never admitted as arrivals)."""
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=400, seed=1))
    dom = sorted({d.domain for d in dlg})[0]
    dlg = [type(d)(d.dialogue_id, dom, d.turns, d.difficulty) for d in dlg]
    out = build_federation(
        dlg, n_agents=12, super_hubs=3,
        arrivals=PoissonArrivals(rate=400.0, seed=2), seed=0,
        router_kwargs=dict(ROUTER_KW),
        loop_kwargs=dict(batch_cap=32, batch_window=0.05, max_new_tokens=4),
        cluster_kwargs=dict(max_new_tokens=4),
        max_inflight=1200, epoch=0.25, spill_min_wait=0.2).run()
    assert out["federation"]["spill_candidates"] > 0
    assert out["federation"]["spill_migrated"] > 0
    receivers = [s for s in out["shards"] if s["migrated_in"] > 0]
    assert receivers and all(s["n"] > 0 for s in receivers)
    assert out["dialogues_completed"] == 400


# ------------------------------------------------------- determinism --
def test_bit_determinism_under_shuffled_shard_schedule():
    """Shard advance order is irrelevant: the fold_in-style seed split
    gives every shard its own rng stream, so reversed / rotating epoch
    schedules replay identical ledger heads and accounts."""
    base = _overloaded_federation().run()

    def rotating(epoch_idx):
        order = [0, 1, 2]
        k = epoch_idx % 3
        return order[k:] + order[:k]

    for sched in ([2, 1, 0], rotating):
        out = _overloaded_federation(shard_schedule=sched).run()
        assert [s["ledger"]["head"] for s in out["shards"]] == \
            [s["ledger"]["head"] for s in base["shards"]]
        assert out["accounts"] == base["accounts"]
        assert out["federation"]["spill_migrated"] == \
            base["federation"]["spill_migrated"]


def test_shard_seed_split_is_stable_and_decorrelated():
    """`shard_seed` is a pure function of (base, super_id) with distinct
    outputs across shards — never scheduling-dependent."""
    from repro.distributed.federation import shard_seed
    seeds = [shard_seed(7, k) for k in range(16)]
    assert seeds == [shard_seed(7, k) for k in range(16)]  # reproducible
    assert len(set(seeds)) == 16                           # decorrelated
    assert shard_seed(8, 0) != shard_seed(7, 0)


# ------------------------------------------------------------ gossip --
def test_gossip_staleness_bounded_by_one_epoch():
    """With digests refreshed at every boundary, no spill valuation ever
    consumes a digest older than one epoch."""
    fed = _overloaded_federation()
    out = fed.run()
    g = out["federation"]["gossip"]
    assert g["digests"] == 3
    assert g["max_staleness_epochs"] <= 1


# ----------------------------------------------------- process workers --
def test_process_parallel_bit_identical_to_inline():
    """An S=2 run with each shard in its own OS process replays the
    inline run bit-for-bit (same `InlineShard.from_spec` on both sides)."""
    def run(parallel):
        dlg = generate(WorkloadSpec("coqa_like", n_dialogues=40, seed=1))
        fed = build_federation(
            dlg, n_agents=16, super_hubs=2,
            arrivals=PoissonArrivals(rate=30.0, seed=2), seed=0,
            router_kwargs=dict(ROUTER_KW),
            loop_kwargs=dict(batch_cap=16, batch_window=0.05,
                             max_new_tokens=4),
            cluster_kwargs=dict(max_new_tokens=4),
            max_inflight=128, epoch=0.25, parallel=parallel)
        out = fed.run()
        return out, [s["ledger"]["head"] for s in out["shards"]]

    o1, h1 = run("inline")
    o2, h2 = run("process")
    assert h1 == h2
    assert o1["accounts"] == o2["accounts"]
    assert o2["federation"]["exactly_once"]["ok"]


# ------------------------------------------------------- partitioning --
def test_cluster_super_hubs_positional_ids_and_coverage():
    """Super-hub ids are list positions (shard seeds / rid prefixes key on
    them) and the partition covers every agent exactly once."""
    rng = np.random.default_rng(0)
    doms = [("qa",), ("code",), ("math",), ("qa", "code")] * 8
    scales = list(rng.uniform(0.5, 2.0, len(doms)))
    supers = cluster_super_hubs(doms, scales, 3)
    assert [sh.hub_id for sh in supers] == list(range(len(supers)))
    seen = sorted(i for sh in supers for i in sh.agent_indices)
    assert seen == list(range(len(doms)))
    for d in ("qa", "code", "math"):
        k = route_to_super_hub(d, supers, doms)
        assert 0 <= k < len(supers)
