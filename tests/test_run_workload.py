"""run_workload regression suite: batch-collection fairness, truncation
reporting, and per-dialogue request attribution (ISSUE-5 satellites).

Uses analytic-engine clusters (deterministic virtual service times) so the
closed-loop oracle runs in milliseconds inside tier-1."""
import numpy as np
import pytest

from repro.core import IEMASRouter
from repro.core.mechanism import RouteDecision
from repro.serving import SimCluster, WorkloadSpec, generate, run_workload


class GreedyRouter:
    """Minimal deterministic router: matches every request round-robin over
    the cluster's agents — isolates the serving loop's queueing discipline
    from auction behavior."""

    def __init__(self, infos):
        self.infos = list(infos)
        self._i = 0

    def route_batch(self, batch, telemetry, free_slots=None):
        out = []
        for req in batch:
            agent = self.infos[self._i % len(self.infos)]
            self._i += 1
            out.append(RouteDecision(req, agent.agent_id, 0.0, None, 1.0, 0))
        return out

    def on_complete(self, request_id, obs):
        pass


def _cluster(n_agents=3, seed=0, **kw):
    return SimCluster(n_agents=n_agents, seed=seed, max_new_tokens=2,
                      engine_mode="analytic", **kw)


# ------------------------------------------------------------ fairness --
def test_batch_collection_is_fifo_fair():
    """No dialogue is starved by the batch cap: with N ready dialogues and
    cap K, every dialogue's FIRST dispatch happens within ceil(N/K) rounds
    (round-robin bound) — the seed's dict-order scan re-served the first K
    dialogues' later turns first, starving the tail indefinitely."""
    n, cap, dt = 12, 4, 0.05
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=n, seed=2))
    cluster = _cluster()
    router = GreedyRouter(cluster.agent_infos())
    out = run_workload(cluster, router, dlg, batch_per_round=cap,
                       round_dt=dt, max_new_tokens=2, max_rounds=4000)
    assert not out["truncated"]
    first_dispatch = {}
    for rec in cluster.records:
        did = rec.request.dialogue_id
        first_dispatch.setdefault(did, rec.dispatched_at)
    assert len(first_dispatch) == n
    rounds_bound = -(-n // cap)  # ceil: pure round-robin over the backlog
    for k, d in enumerate(dlg):
        first_round = round(first_dispatch[d.dialogue_id] / dt) + 1
        # "no dialogue waits more than one extra round vs round-robin"
        assert first_round <= k // cap + 1 + 1, \
            f"dialogue {k} first served in round {first_round}"
        assert first_round <= rounds_bound + 1


def test_unmatched_requests_keep_queue_priority():
    """Requests the router leaves unmatched go back to the FRONT of the
    ready queue in order, not to the back."""

    class RejectFirstRounds(GreedyRouter):
        """Rejects everything for 2 rounds, then greedy round-robin."""

        def __init__(self, infos):
            super().__init__(infos)
            self.calls = 0

        def route_batch(self, batch, telemetry, free_slots=None):
            self.calls += 1
            if self.calls <= 2:
                return [RouteDecision(r, None, 0.0, None, 0.0, -1)
                        for r in batch]
            return super().route_batch(batch, telemetry, free_slots)

    n, cap = 6, 4
    dlg = generate(WorkloadSpec("hotpot_like", n_dialogues=n, seed=5))
    cluster = _cluster()
    router = RejectFirstRounds(cluster.agent_infos())
    run_workload(cluster, router, dlg, batch_per_round=cap,
                 max_new_tokens=2, max_rounds=4000)
    # dialogues 0..3 were rejected twice but must still be dispatched
    # before 4..5 ever are (they kept their place at the head); request ids
    # are assigned in batch-build order, i.e. queue order
    order = []
    for rec in sorted(cluster.records,
                      key=lambda r: int(r.request.request_id[1:])):
        if rec.request.dialogue_id not in order:
            order.append(rec.request.dialogue_id)
    ids = [d.dialogue_id for d in dlg]
    assert order[:cap] == ids[:cap]


# ---------------------------------------------------------- truncation --
def test_truncation_is_reported_not_silent():
    """Exhausting max_rounds reports unfinished dialogues + warns instead
    of returning partial metrics that look like a completed run."""
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=5, seed=3))
    cluster = _cluster()
    router = IEMASRouter(cluster.agent_infos(), solver="dense")
    with pytest.warns(RuntimeWarning, match="round budget"):
        out = run_workload(cluster, router, dlg, max_rounds=4,
                           max_new_tokens=2, batch_per_round=2)
    assert out["truncated"]
    assert 0 < out["unfinished_dialogues"] <= 5
    total_turns = sum(len(d.turns) for d in dlg)
    assert out["completed_turns"] < total_turns
    assert out["rounds"] == 4


def test_completed_run_reports_clean():
    """A run that finishes reports zero unfinished dialogues, full turn
    counts and no warning."""
    import warnings

    dlg = generate(WorkloadSpec("quac_like", n_dialogues=4, seed=1))
    cluster = _cluster()
    router = IEMASRouter(cluster.agent_infos(), solver="dense")
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        out = run_workload(cluster, router, dlg, max_new_tokens=2)
    assert not out["truncated"]
    assert out["unfinished_dialogues"] == 0
    assert out["completed_turns"] == sum(len(d.turns) for d in dlg)
    assert out["n"] == out["completed_turns"]


# ------------------------------------------------- request attribution --
def test_dispatch_attribution_per_dialogue():
    """record_of is wired into the result: dispatched_requests and the
    per-dialogue stats count every dispatch, including fault retries."""
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=4, seed=6))
    cluster = _cluster(seed=4, fail_prob=0.25)
    router = IEMASRouter(cluster.agent_infos(), solver="dense")
    out = run_workload(cluster, router, dlg, max_new_tokens=2,
                       max_rounds=4000)
    assert not out["truncated"]
    total_turns = sum(len(d.turns) for d in dlg)
    # failures force re-dispatches: attribution counts them, metrics don't
    assert out["dispatched_requests"] > total_turns
    assert out["n"] == total_turns
    assert out["requests_per_dialogue_mean"] == pytest.approx(
        out["dispatched_requests"] / len(dlg))
    assert out["requests_per_dialogue_max"] >= max(len(d.turns) for d in dlg)


def test_dead_dispatch_target_is_quarantined_not_livelocked():
    """An agent removed from the cluster but not the router must not be
    re-matched forever: the dead dispatch reports as a failure, the router
    quarantines it, and the workload completes."""
    # 20 first turns vs one live agent's 12 free slots: the auction MUST
    # overflow onto the dead (removed-from-cluster) agent in round 1
    # (coqa difficulty keeps every dialogue profitable for the survivor,
    # so the run can actually finish once the dead agent is quarantined)
    dlg = generate(WorkloadSpec("coqa_like", n_dialogues=20, seed=7))
    cluster = _cluster(n_agents=2)
    router = IEMASRouter(cluster.agent_infos(), solver="dense")
    victim = list(cluster.agents)[1]
    cluster.remove_agent(victim, router=None)  # router left unaware
    out = run_workload(cluster, router, dlg, max_new_tokens=2,
                       batch_per_round=20, max_rounds=2000)
    assert not out["truncated"]
    assert out["n"] == sum(len(d.turns) for d in dlg)
    assert victim in router.quarantined
    assert not router._pending  # no leaked entries from dead dispatches
