"""End-to-end behaviour: full IEMAS stack vs the paper's headline claims,
on a reduced workload (quantitative versions live in benchmarks/)."""
import numpy as np

from repro.core import IEMASRouter
from repro.core.baselines import LeastLoadedRouter
from repro.serving import SimCluster, WorkloadSpec, generate, run_workload


def _run(router_fn, workload="coqa_like", n_dialogues=5, seed=0):
    cluster = SimCluster(n_agents=4, seed=seed, max_new_tokens=3)
    router = router_fn(cluster.agent_infos())
    dialogues = generate(WorkloadSpec(workload, n_dialogues=n_dialogues,
                                      seed=seed + 1))
    metrics = run_workload(cluster, router, dialogues, max_rounds=1500)
    metrics["router"] = router
    return metrics


def test_iemas_dominates_load_balancing_on_multiturn():
    """P1 claim: naive load balancing destroys cache locality. (Baselines
    still get partial-prefix hits — the paper's Table 1 shows 26-53% — so
    the margins are on both hit rate and realized cost.)"""
    m_ie = _run(lambda a: IEMASRouter(a))
    m_ll = _run(lambda a: LeastLoadedRouter(a))
    assert m_ie["kv_hit_rate"] > m_ll["kv_hit_rate"] + 0.08
    assert m_ie["cost_mean"] < 0.75 * m_ll["cost_mean"]


def test_market_accounts_consistent():
    """Payments cover agent costs (weak budget balance, realized)."""
    m = _run(lambda a: IEMASRouter(a))
    acc = m["router"].accounts
    assert acc["matched"] > 0
    assert acc["payments"] >= acc["agent_costs"] - 1e-6
    assert acc["surplus"] >= -1e-6


def test_predictions_converge_to_observations():
    """NMAE of the latency/cost predictors drops as feedback accumulates
    (Fig. 3 behaviour). cache_slots sized so sessions fit: chronic LRU
    thrash makes the proxy's cache model diverge from the backend's true
    LRU order, which is a capacity problem, not a learning one."""
    cluster = SimCluster(n_agents=3, seed=2, max_new_tokens=3,
                         cache_slots=12)
    router = IEMASRouter(cluster.agent_infos(), predictor_kw={"warm_n": 4})
    dialogues = generate(WorkloadSpec("coqa_like", n_dialogues=8, seed=3))
    errs = []

    orig = router.on_complete

    def tracked(request_id, obs):
        entry = router._pending.get(request_id)
        if entry is not None and not obs.failed:
            x, agent, req, payment, pred_cost = entry
            est = router.pool[agent.agent_id].predict(x)
            from repro.core.pricing import observed_cost
            cost = observed_cost(agent.prices, obs.n_prompt, obs.n_hit,
                                 obs.n_gen)
            errs.append(abs(est.cost - cost) / max(cost, 1e-6))
        return orig(request_id, obs)

    router.on_complete = tracked
    run_workload(cluster, router, dialogues, max_rounds=1500)
    assert len(errs) > 30
    # medians: the tail has unavoidable one-off eviction surprises (the
    # proxy's LRU model can lag the backend's true LRU by one request)
    early = np.median(errs[: len(errs) // 3])
    late = np.median(errs[-len(errs) // 3:])
    assert late < early  # predictor improves online
