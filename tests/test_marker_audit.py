"""Slow-marker audit: tier-1 (`pytest -x -q`, which filters `-m "not slow"`)
must stay under ~5 minutes, so every test module must make an explicit
choice — carry a module-level ``pytestmark = pytest.mark.slow`` or be listed
in ``TIER1_MODULES`` below. A new module that does neither fails here,
forcing the author to budget it deliberately instead of silently growing
the tier-1 wall clock."""
import re
from pathlib import Path

TESTS_DIR = Path(__file__).parent

# modules vetted to run in tier-1 (keep the combined suite < ~5 min)
TIER1_MODULES = {
    "test_adversary",
    "test_affinity",
    "test_auction",
    "test_auction_dense",
    "test_auction_pallas",
    "test_churn_storm",
    "test_column_market",
    "test_dag_workload",
    "test_docs",
    "test_exploration",
    "test_federation",
    "test_hoeffding",
    "test_hoeffding_batch",
    "test_hub_sharding",
    "test_marker_audit",
    "test_mcmf",
    "test_mechanism",
    "test_models",
    "test_predictor_batch",
    "test_reputation_identity",
    "test_routing_fused",
    "test_run_workload",
    "test_sharding",
    "test_simulator",
    "test_system",
    "test_truthfulness",
}

SLOW_RE = re.compile(r"^pytestmark\s*=.*pytest\.mark\.slow", re.MULTILINE)


def test_every_module_is_budgeted():
    unbudgeted = []
    for path in sorted(TESTS_DIR.glob("test_*.py")):
        name = path.stem
        if name in TIER1_MODULES:
            continue
        if SLOW_RE.search(path.read_text()):
            continue
        unbudgeted.append(name)
    assert not unbudgeted, (
        f"modules {unbudgeted} are neither slow-marked nor vetted for "
        f"tier-1; add `pytestmark = pytest.mark.slow` or (if genuinely "
        f"fast) list them in TIER1_MODULES")


def test_vetted_list_is_current():
    """No stale entries: every vetted module still exists."""
    existing = {p.stem for p in TESTS_DIR.glob("test_*.py")}
    stale = TIER1_MODULES - existing
    assert not stale, f"TIER1_MODULES lists removed modules: {stale}"
