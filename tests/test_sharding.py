"""Logical-axis rule resolver: divisibility fallback, axis-reuse guard,
param/act rule layering, HLO collective parser."""
from types import SimpleNamespace

import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DEFAULT_RULES, TRAIN_PARAM_RULES,
                                        TRAIN_RULES, ShardingPolicy)
from repro.utils.hlo import collective_wire_bytes, parse_collectives


def _policy(shape=None, acts=None, params=None):
    mesh = SimpleNamespace(shape=shape or {"data": 16, "model": 16})
    return ShardingPolicy(mesh, acts=acts or dict(TRAIN_RULES),
                          params=params or dict(TRAIN_PARAM_RULES))


def test_divisibility_fallback():
    p = _policy()
    # 8 KV heads cannot divide the 16-way model axis -> replicated
    spec = p.act_spec(("batch", "seq", "kv_heads", "head_dim"),
                      (256, 4096, 8, 128))
    assert spec == P(("pod", "data"), "model") or spec == P("data", "model")
    # 64 heads can
    spec = p.act_spec(("batch", "seq", "heads", "head_dim"),
                      (256, 4096, 64, 128))
    assert spec[1] == "model" or spec[2] == "model"


def test_no_axis_reuse_within_tensor():
    p = _policy()
    spec = p.param_spec(("embed", "ff"), (8192, 29568))
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else [s])
    assert len(flat) == len(set(flat))


def test_param_rules_override_act_rules():
    p = _policy()
    # activations: embed replicated; params: embed -> data (FSDP)
    a = p.act_spec(("batch", "seq", "embed"), (256, 4096, 8192))
    assert len(a) < 3 or a[2] is None
    w = p.param_spec(("embed", "ff"), (8192, 29568))
    assert w[0] == "data" and w[1] == "model"


def test_missing_mesh_axis_dropped():
    p = _policy(shape={"data": 4})  # no model axis at all
    spec = p.act_spec(("batch", "seq", "heads", "head_dim"), (8, 128, 64, 64))
    flat = [s for s in spec if s is not None]
    assert "model" not in str(flat)


def test_pod_axis_tuple():
    p = _policy(shape={"pod": 2, "data": 16, "model": 16})
    spec = p.act_spec(("batch", "seq"), (256, 4096))
    assert spec[0] == ("pod", "data")
    # batch=1 cannot shard 32 ways -> fully dropped
    spec = p.act_spec(("batch", "seq"), (1, 4096))
    assert len(spec) == 0 or spec[0] is None


HLO_SAMPLE = """
ENTRY %main (p0: bf16[16,256,8192]) -> bf16[16,256,8192] {
  %p0 = bf16[16,256,8192]{2,1,0} parameter(0)
  %ag = bf16[16,4096,8192]{2,1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={1}
  %ar = f32[512,512]{1,0} all-reduce(%conv), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = bf16[16,256,8192]{2,1,0} reduce-scatter(%ag), replica_groups=[16,16]<=[256], dimensions={1}
  %cp = bf16[128]{0} collective-permute(%p0), source_target_pairs={{0,1}}
}
"""


def test_hlo_collective_parser():
    ops = parse_collectives(HLO_SAMPLE)
    kinds = sorted(c.op for c in ops)
    assert kinds == ["all-gather", "all-reduce", "collective-permute",
                     "reduce-scatter"]
    by = {c.op: c for c in ops}
    assert by["all-gather"].group_size == 16
    assert by["all-reduce"].group_size == 4
    ag = by["all-gather"]
    assert ag.result_bytes == 16 * 4096 * 8192 * 2
    # reduce-scatter wire bytes use the OPERAND (gathered) size
    rs = by["reduce-scatter"]
    assert rs.operand_bytes == ag.result_bytes
    totals = collective_wire_bytes(HLO_SAMPLE)
    assert totals["count"] == 4
    assert totals["total"] > 0


def test_workload_determinism():
    from repro.serving.workload import WorkloadSpec, generate

    a = generate(WorkloadSpec("coqa_like", n_dialogues=4, seed=7))
    b = generate(WorkloadSpec("coqa_like", n_dialogues=4, seed=7))
    assert len(a) == len(b)
    for da, db in zip(a, b):
        assert da.domain == db.domain and len(da.turns) == len(db.turns)
        for ta, tb in zip(da.turns, db.turns):
            assert (ta == tb).all()


def test_elastic_remesh_factorization():
    from repro.distributed.elastic import remesh
    import jax

    mesh = remesh(1)
    assert mesh.shape["data"] * mesh.shape["model"] == 1
