"""Dense ε-scaling auction solver: welfare parity with the MCMF oracle and
brute force, certified gap, batched Clarke-pivot payment correctness, DSIC
under the dense payment rule, and jax-variant parity."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.auction import client_utilities, run_auction, solve_allocation
from repro.core.auction_dense import (dense_clarke_payments,
                                      solve_dense_auction)
from repro.core.mcmf import brute_force_matching

ATOL = 1e-6


def _instance(rng, n_max=32, m_max=32):
    """Random market with varying size, caps and sparsity."""
    n = int(rng.integers(1, n_max + 1))
    m = int(rng.integers(1, m_max + 1))
    sparsity = rng.uniform(0.0, 0.7)
    values = rng.uniform(0, 6, (n, m)) * (rng.random((n, m)) > sparsity)
    costs = rng.uniform(0, 3, (n, m))
    caps = rng.integers(1, 5, m).tolist()
    return values, costs, caps


# ---------------------------------------------------------------- welfare --
@settings(max_examples=100, deadline=None)
@given(st.integers(0, 10**6))
def test_dense_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 7))
    m = int(rng.integers(1, 5))
    w = np.round(rng.uniform(-1, 3, (n, m)), 3)
    wp = np.where(w > 0, w, 0.0)
    caps = rng.integers(1, 3, m).tolist()
    bf_w, _ = brute_force_matching(wp.tolist(), caps)
    res = solve_dense_auction(wp, caps)
    assert res.welfare == pytest.approx(bf_w, abs=ATOL)
    assert res.gap_bound < ATOL
    # feasibility
    used = {}
    for j, i in enumerate(res.assignment):
        if i >= 0:
            assert wp[j, i] > 0
            used[i] = used.get(i, 0) + 1
    for i, c in used.items():
        assert c <= caps[i]


def test_dense_matches_mcmf_on_200_instances():
    """Acceptance: welfare parity with the exact MCMF within 1e-6 on >=200
    random instances with n, m <= 32 (sizes, caps and sparsity varying)."""
    rng = np.random.default_rng(1234)
    checked = 0
    for _ in range(200):
        values, costs, caps = _instance(rng)
        w = np.maximum(values - costs, 0.0)
        _, mcmf_w, _ = solve_allocation(w, caps)
        res = solve_dense_auction(w, caps)
        assert res.welfare == pytest.approx(mcmf_w, abs=ATOL), \
            f"instance {checked}: dense {res.welfare} vs mcmf {mcmf_w}"
        checked += 1
    assert checked >= 200


def test_run_auction_dense_solver_full_result():
    rng = np.random.default_rng(5)
    values, costs, caps = _instance(rng, 16, 8)
    r_m = run_auction(values, costs, caps)
    r_d = run_auction(values, costs, caps, solver="dense")
    assert r_d.welfare == pytest.approx(r_m.welfare, abs=ATOL)
    assert r_d.solver_stats["solver"] == "dense"
    assert r_d.solver_stats["gap_bound"] < ATOL
    # unmatched requests pay nothing
    for j, i in enumerate(r_d.assignment):
        if i < 0:
            assert r_d.payments[j] == 0.0


def test_unknown_solver_rejected():
    with pytest.raises(ValueError):
        run_auction(np.ones((2, 2)), np.zeros((2, 2)), [1, 1], solver="nope")


# ---------------------------------------------------------------- payments --
@settings(max_examples=120, deadline=None)
@given(st.integers(0, 10**6))
def test_dense_payments_match_vcg_when_assignments_agree(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 9))
    m = int(rng.integers(1, 5))
    values = np.round(rng.uniform(0, 5, (n, m)), 3)
    costs = np.round(rng.uniform(0, 3, (n, m)), 3)
    caps = rng.integers(1, 3, m).tolist()
    r_naive = run_auction(values, costs, caps, payment_mode="naive")
    r_dense = run_auction(values, costs, caps, solver="dense")
    assert r_dense.welfare == pytest.approx(r_naive.welfare, abs=ATOL)
    if r_dense.assignment == r_naive.assignment:
        assert np.allclose(r_dense.payments, r_naive.payments, atol=ATOL)


@settings(max_examples=80, deadline=None)
@given(st.integers(0, 10**6), st.floats(-2, 2))
def test_dense_truthfulness_dominant_strategy(seed, deviation):
    """Acceptance: misreporting never raises utility under dense payments."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 8))
    m = int(rng.integers(1, 5))
    values = np.round(rng.uniform(0, 5, (n, m)), 3)
    costs = np.round(rng.uniform(0, 3, (n, m)), 3)
    caps = rng.integers(1, 3, m).tolist()
    j = int(rng.integers(0, n))
    honest = run_auction(values, costs, caps, solver="dense")
    u_honest = client_utilities(honest, values)[j]
    lied = values.copy()
    lied[j] = np.maximum(lied[j] + deviation, 0.0)
    strategic = run_auction(lied, costs, caps, solver="dense")
    u_lied = client_utilities(strategic, values)[j]
    assert u_lied <= u_honest + ATOL


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10**6))
def test_dense_weak_budget_balance_and_ir(seed):
    rng = np.random.default_rng(seed)
    values, costs, caps = _instance(rng, 16, 8)
    r = run_auction(values, costs, caps, solver="dense")
    total_pay = sum(r.payments)
    total_cost = sum(costs[j, i] for j, i in enumerate(r.assignment) if i >= 0)
    assert total_pay >= total_cost - ATOL            # Theorem 4.3
    u = client_utilities(r, values)
    assert (u >= -ATOL).all()                        # IR when truthful
    for j, i in enumerate(r.assignment):
        if i >= 0:
            assert r.payments[j] >= costs[j, i] - ATOL


def test_dense_payment_equals_externality_simple():
    # two clients compete for one slot: winner pays the displaced welfare
    values = np.array([[10.0], [7.0]])
    costs = np.array([[1.0], [1.0]])
    r = run_auction(values, costs, [1], solver="dense")
    assert r.assignment == [0, -1]
    assert r.payments[0] == pytest.approx(7.0, abs=ATOL)


def test_dense_clarke_payments_standalone():
    w = np.array([[3.0, 1.0], [2.0, 2.0]])
    costs = np.zeros((2, 2))
    res = solve_dense_auction(w, [1, 1])
    pays = dense_clarke_payments(w, costs, [1, 1], res.assignment)
    r_naive = run_auction(w, costs, [1, 1], payment_mode="naive")
    assert res.assignment == r_naive.assignment
    assert np.allclose(pays, r_naive.payments, atol=ATOL)


# ------------------------------------------------------------- edge cases --
def test_dense_empty_and_degenerate():
    res = solve_dense_auction(np.zeros((3, 2)), [1, 1])
    assert res.assignment == [-1, -1, -1] and res.welfare == 0.0
    res = solve_dense_auction(np.ones((2, 2)), [0, 0])    # no capacity
    assert res.assignment == [-1, -1]
    res = solve_dense_auction(np.zeros((0, 2)).reshape(0, 2), [1, 1])
    assert res.assignment == [] and res.welfare == 0.0
    # caps larger than n are harmless (slots clamp to n)
    res = solve_dense_auction(np.array([[2.0]]), [50])
    assert res.assignment == [0] and res.welfare == 2.0


def test_dense_welfare_monotone_in_capacity():
    rng = np.random.default_rng(3)
    w = rng.uniform(0, 2, (8, 3))
    w1 = solve_dense_auction(w, [1, 1, 1]).welfare
    w2 = solve_dense_auction(w, [2, 2, 2]).welfare
    w3 = solve_dense_auction(w, [8, 8, 8]).welfare
    assert w1 <= w2 + 1e-9 <= w3 + 2e-9
    assert w3 == pytest.approx(np.maximum(w, 0).max(axis=1).sum())


def test_dense_ties_resolve_consistently():
    # identical requests fighting identical slots must settle fast and exactly
    w = np.full((6, 2), 2.5)
    res = solve_dense_auction(w, [2, 1])
    assert res.welfare == pytest.approx(7.5, abs=ATOL)
    assert sum(1 for a in res.assignment if a >= 0) == 3


# ------------------------------------------------------------- jax variant --
@pytest.mark.slow
def test_dense_jax_matches_numpy():
    from repro.core.auction_dense import solve_dense_auction_jax

    rng = np.random.default_rng(17)
    for _ in range(3):
        values, costs, caps = _instance(rng, 12, 6)
        w = np.maximum(values - costs, 0.0)
        r_np = solve_dense_auction(w, caps)
        r_jx = solve_dense_auction_jax(w, caps)
        # float32 path: certified gap is wider than the float64 reference
        tol = max(1e-6, r_jx.gap_bound + 1e-4)
        assert abs(r_np.welfare - r_jx.welfare) <= tol


@pytest.mark.slow
def test_dense_jax_payments_match_vcg_when_assignments_agree():
    rng = np.random.default_rng(23)
    agreed = 0
    for _ in range(10):
        n = int(rng.integers(1, 9))
        m = int(rng.integers(1, 5))
        values = np.round(rng.uniform(0, 5, (n, m)), 3)
        costs = np.round(rng.uniform(0, 3, (n, m)), 3)
        caps = rng.integers(1, 3, m).tolist()
        r_naive = run_auction(values, costs, caps, payment_mode="naive")
        r_jax = run_auction(values, costs, caps, solver="dense-jax")
        if r_jax.assignment == r_naive.assignment:
            agreed += 1
            assert np.allclose(r_jax.payments, r_naive.payments, atol=1e-4)
    assert agreed >= 5  # ties aside, the float32 path finds the optimum


def test_dense_jax_raises_on_round_exhaustion():
    from repro.core.auction_dense import solve_dense_auction_jax

    rng = np.random.default_rng(3)
    w = rng.uniform(0, 5, (12, 6))
    with pytest.raises(RuntimeError, match="failed to converge"):
        solve_dense_auction_jax(w, [2] * 6, max_rounds=3)
