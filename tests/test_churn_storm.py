"""Churn-storm stress: membership/capacity/quarantine flapping under the
event-driven simulator — price-book cold-start correctness, exactly-once
settlement, global request-id uniqueness, and the orphan-completion guard."""
import numpy as np

from repro.configs.iemas_cluster import RouterConfig
from repro.core.adversary import AdversaryMix, ChurnStormPolicy
from repro.core.mechanism import CompletionObs, Request
from repro.serving import (EventSimulator, SimCluster, iter_dialogues,
                           make_arrivals, make_router, run_workload)
from repro.serving.workload import WorkloadSpec, generate


def _storm_mix(seed=13, fraction=0.5, period=2):
    return AdversaryMix(policy="churn", fraction=fraction, theta=0.4,
                        seed=seed, churn_period=period)


def _event_run(mix=None, *, n_agents=8, n_dialogues=12, seed=13,
               fail_prob=0.0, incremental=False):
    cluster = SimCluster(n_agents, seed=seed, engine_mode="analytic",
                         fail_prob=fail_prob, adversary_mix=mix)
    router = make_router(cluster, RouterConfig(
        solver="dense", n_hubs=2, warm_start=True, audit_ledger=True))
    spec = WorkloadSpec("coqa_like", n_dialogues=n_dialogues, seed=seed + 1)
    sim = EventSimulator(cluster, router, iter_dialogues(spec),
                         arrivals=make_arrivals("poisson", rate=40.0,
                                                seed=seed + 2),
                         batch_cap=8, incremental=incremental,
                         max_inflight=64, lean=True)
    metrics = sim.run()
    return cluster, router, metrics


def test_churn_storm_run_completes_with_exactly_once_settlement():
    """A flapping fleet (join/leave/quarantine/capacity every other tick on
    half the agents) must still drain the workload, and every request must
    appear in the settlement ledger at most once."""
    cluster, router, metrics = _event_run(_storm_mix(), fail_prob=0.1)
    assert cluster.records  # work actually flowed through the storm
    led = router.settlement
    assert led.verify_chain()
    balances = led.audit(router.accounts)  # replay == books, bit-exact
    # exactly-once: no request id is ever settled or faulted twice (retries
    # burn fresh ids; orphans are skipped, never double-booked)
    ids = [e.request_id for e in led.entries]
    assert len(ids) == len(set(ids))
    settled_ids = {e.request_id for e in led.entries if e.kind == "settle"}
    fault_ids = {e.request_id for e in led.entries if e.kind == "fault"}
    assert not settled_ids & fault_ids
    # completions never exceed matched dispatches (orphans may skip some)
    assert balances["settled"] + balances["faults"] <= \
        router.accounts["matched"]


def test_churn_flips_cold_start_the_price_book():
    """Every membership/capacity flip invalidates the warm-start key, so a
    storm run must cold-start the SlotPriceBook strictly more often than
    the identical honest run."""
    def cold_starts(mix):
        cluster = SimCluster(8, seed=21, engine_mode="analytic",
                             adversary_mix=mix)
        router = make_router(cluster, RouterConfig(
            solver="dense", n_hubs=2, warm_start=True))
        spec = WorkloadSpec("coqa_like", n_dialogues=12, seed=22)
        run_workload(cluster, router, generate(spec), max_new_tokens=4)
        return router.price_book.stats()

    honest = cold_starts(None)
    storm = cold_starts(_storm_mix(seed=21))
    assert honest["warm_hits"] > 0  # the steady state actually warm-starts
    assert storm["cold_starts"] > honest["cold_starts"]


def test_request_ids_globally_unique_under_incremental_and_retry():
    """Ids burn monotonically: across batch routing, incremental offers,
    fault retries and churn, no dispatched request id is ever reused."""
    cluster, router, _ = _event_run(_storm_mix(), fail_prob=0.15,
                                    incremental=True)
    ids = [r.request.request_id for r in cluster.records]
    assert ids
    assert len(ids) == len(set(ids))
    led_ids = [e.request_id for e in router.settlement.entries]
    assert len(led_ids) == len(set(led_ids))


def test_churn_tick_actions_cover_the_policy_space():
    """Driven directly, a storm policy eventually exercises all three
    actions (capacity flap, leave+rejoin, quarantine) and always returns
    from quarantine one cycle later."""
    cluster = SimCluster(6, seed=31, engine_mode="analytic")
    router = make_router(cluster, RouterConfig(solver="dense", n_hubs=2))
    aid = cluster.agent_infos()[0].agent_id
    pol = ChurnStormPolicy(theta=0.4, period=1, seed=2)
    n_before = len(router.agents)
    was_quarantined = False
    for _ in range(40):
        pol.tick(cluster, router, aid)
        if aid in router.quarantined:
            was_quarantined = True
        assert len(router.agents) == n_before  # leave+rejoin nets to zero
        assert aid in cluster.agents
    assert was_quarantined
    assert aid not in router.quarantined or pol._quarantined


def test_orphan_completion_is_skipped_not_crashed():
    """An agent that leaves between dispatch and completion: the router
    must drop the orphan completion without touching accounts or ledger."""
    cluster = SimCluster(4, seed=41, engine_mode="analytic")
    router = make_router(cluster, RouterConfig(
        solver="dense", n_hubs=1, audit_ledger=True))
    req = Request(request_id="r-orphan", dialogue_id="d0",
                  tokens=np.arange(12, dtype=np.int32), turn=0,
                  domain=cluster.agent_infos()[0].domains[0],
                  max_new_tokens=4, meta={"difficulty": 0.2})
    telem = cluster.telemetry.snapshot(cluster.now)
    dec = router.route_batch([req], telem,
                             free_slots=cluster.free_slots())[0]
    assert dec.agent_id is not None
    router.remove_agent(dec.agent_id)  # agent leaves mid-flight
    before = dict(router.accounts)
    n_entries = len(router.settlement.entries)
    router.on_complete("r-orphan", CompletionObs(
        latency=0.1, n_prompt=12, n_hit=0, n_gen=4, quality=1.0))
    assert router.accounts == before
    assert len(router.settlement.entries) == n_entries
