"""Pallas auction backend: bidding-round kernel bit-parity vs the jnp
oracle, full-solve parity vs the NumPy reference backend (including
degenerate shapes), warm starts, the sharded/spill paths, and the solver
registry protocol contract."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.auction import (SPILL_HUB, run_auction, run_sharded_auction)
from repro.core.solvers import (SolverBackend, available_solvers, get_solver,
                                register_solver, solve_dense_auction,
                                solve_dense_auction_pallas)

ATOL = 1e-6


def _instance(rng, n_max=24, m_max=12):
    n = int(rng.integers(1, n_max + 1))
    m = int(rng.integers(1, m_max + 1))
    sparsity = rng.uniform(0.0, 0.7)
    values = rng.uniform(0, 6, (n, m)) * (rng.random((n, m)) > sparsity)
    costs = rng.uniform(0, 3, (n, m))
    caps = rng.integers(1, 5, m).tolist()
    return values, costs, caps


# ------------------------------------------------------ kernel bit parity --
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6))
def test_bid_kernel_bit_parity_with_oracle(seed):
    """Interpret-mode kernel == pure-jnp oracle, bit for bit.

    The column-market round quotes each AGENT's cheapest (ask) and
    second-cheapest (ask2) unit price; some agents quote ask2 = +big
    (single-unit agents) — the kernel must reproduce the oracle across
    that whole quote range.
    """
    from repro.kernels.ops import auction_bid_op
    from repro.kernels.ref import auction_bid_ref

    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 48))
    m = int(rng.integers(1, 72))
    W = np.maximum(rng.uniform(-1, 4, (n, m)), 0.0).astype(np.float32)
    ask = rng.uniform(0, 3, m).astype(np.float32)
    ask2 = (ask + rng.uniform(0, 2, m)).astype(np.float32)
    big = np.float32(np.finfo(np.float32).max / 4)
    ask2 = np.where(rng.random(m) < 0.2, big, ask2)  # single-unit agents
    active = rng.random(n) > rng.uniform(0, 1)
    eps = np.float32(rng.uniform(1e-4, 0.5))
    got = auction_bid_op(W, ask, ask2, active, eps)
    want = auction_bid_ref(W, ask, ask2, active, eps)
    for g, w, name in zip(got, want, ("best", "winner", "wants")):
        assert np.array_equal(np.asarray(g), np.asarray(w)), \
            f"{name} mismatch (n={n}, m={m})"


def test_bid_kernel_parity_degenerate_inputs():
    """Single request / single agent / nobody active / all-zero weights."""
    from repro.kernels.ops import auction_bid_op
    from repro.kernels.ref import auction_bid_ref

    big = np.float32(np.finfo(np.float32).max / 4)
    cases = [
        (np.ones((1, 1), np.float32), np.zeros(1, np.float32),
         np.full(1, big, np.float32), np.ones(1, bool)),
        (np.zeros((4, 3), np.float32), np.zeros(3, np.float32),
         np.zeros(3, np.float32), np.ones(4, bool)),
        (np.ones((5, 2), np.float32), np.ones(2, np.float32),
         np.ones(2, np.float32), np.zeros(5, bool)),
        (np.full((3, 7), 2.5, np.float32), np.zeros(7, np.float32),
         np.zeros(7, np.float32), np.ones(3, bool)),   # total ties
    ]
    for W, ask, ask2, active in cases:
        got = auction_bid_op(W, ask, ask2, active, np.float32(0.1))
        want = auction_bid_ref(W, ask, ask2, active, np.float32(0.1))
        for g, w in zip(got, want):
            assert np.array_equal(np.asarray(g), np.asarray(w))


# ------------------------------------------------------- full-solve parity --
def test_pallas_solver_matches_numpy_backend():
    """Assignments, Clarke payments and certificates track the float64
    NumPy backend within the float32 staged tolerances."""
    rng = np.random.default_rng(11)
    agreed = 0
    for _ in range(6):
        values, costs, caps = _instance(rng)
        r_np = run_auction(values, costs, caps, solver="dense")
        r_pl = run_auction(values, costs, caps, solver="pallas")
        tol = max(ATOL, r_pl.solver_stats["gap_bound"] + 1e-4)
        assert abs(r_np.welfare - r_pl.welfare) <= tol
        assert r_pl.solver_stats["gap_bound"] == pytest.approx(
            2.0 * values.shape[0] * r_pl.solver_stats["eps"])
        if r_pl.assignment == r_np.assignment:
            agreed += 1
            assert np.allclose(r_pl.payments, r_np.payments, atol=1e-4)
    assert agreed >= 3  # ties aside, the float32 path finds the optimum


def test_pallas_solver_degenerate_shapes():
    from repro.core.solvers.dense_common import DenseAuctionResult

    # n=1, one agent
    res = solve_dense_auction_pallas(np.array([[2.0]]), [1])
    assert res.assignment == [0] and res.welfare == pytest.approx(2.0, abs=1e-4)
    # all-zero weights: nobody matches
    res = solve_dense_auction_pallas(np.zeros((3, 2)), [1, 1])
    assert res.assignment == [-1, -1, -1] and res.welfare == 0.0
    # zero capacity
    res = solve_dense_auction_pallas(np.ones((2, 2)), [0, 0])
    assert res.assignment == [-1, -1]
    # capacity > n clamps to n slots
    res = solve_dense_auction_pallas(np.array([[2.0]]), [50])
    assert isinstance(res, DenseAuctionResult)
    assert res.assignment == [0] and res.welfare == pytest.approx(2.0, abs=1e-4)
    # empty request set
    res = solve_dense_auction_pallas(np.zeros((0, 2)), [1, 1])
    assert res.assignment == [] and res.welfare == 0.0


def test_pallas_warm_start_roundtrip():
    rng = np.random.default_rng(5)
    values, costs, caps = _instance(rng, 16, 6)
    w = np.maximum(values - costs, 0.0)
    cold = solve_dense_auction_pallas(w, caps)
    warm = solve_dense_auction_pallas(w, caps, start_prices=cold.flat_prices)
    assert warm.warm_started and not warm.fallback
    assert warm.welfare == pytest.approx(cold.welfare, abs=1e-4)
    bad = np.ones(len(cold.flat_prices) + 3)
    with pytest.raises(ValueError, match="column layout"):
        solve_dense_auction_pallas(w, caps, start_prices=bad)


def test_pallas_run_auction_full_result():
    rng = np.random.default_rng(7)
    values, costs, caps = _instance(rng, 16, 8)
    r = run_auction(values, costs, caps, solver="pallas")
    assert r.solver_stats["solver"] == "pallas"
    for j, i in enumerate(r.assignment):
        if i < 0:
            assert r.payments[j] == 0.0
        else:
            assert r.payments[j] >= costs[j, i] - 1e-4


@pytest.mark.slow
def test_pallas_sharded_batch_matches_per_block():
    """The vmapped bucket batch path equals solo pallas solves per block."""
    rng = np.random.default_rng(13)
    values = rng.uniform(0, 5, (24, 8))
    costs = rng.uniform(0, 2, (24, 8))
    caps = rng.integers(1, 4, 8).tolist()
    blocks = {0: (list(range(12)), [0, 1, 2, 3]),
              1: (list(range(12, 24)), [4, 5, 6, 7])}
    sharded = run_sharded_auction(values, costs, caps, blocks, solver="pallas")
    for h, (r_idx, a_idx) in blocks.items():
        solo = run_auction(values[np.ix_(r_idx, a_idx)],
                           costs[np.ix_(r_idx, a_idx)],
                           [caps[i] for i in a_idx], solver="pallas")
        tol = max(ATOL, sharded[h].solver_stats["gap_bound"] + 1e-4)
        assert abs(sharded[h].welfare - solo.welfare) <= tol


# ------------------------------------------------------------------ spill --
def test_cross_hub_spill_rescues_unmatched():
    """A saturated hub's losers re-auction over another hub's slack."""
    # hub 0: 4 requests, 1 slot; hub 1: 0 requests, 3 slots of slack
    values = np.full((4, 4), 4.0)
    costs = np.full((4, 4), 1.0)
    caps = [1, 1, 1, 1]
    blocks = {0: ([0, 1, 2, 3], [0]), 1: ([], [1, 2, 3])}
    for solver in ("dense", "mcmf", "pallas"):
        plain = run_sharded_auction(values, costs, caps, blocks, solver=solver)
        spilled = run_sharded_auction(values, costs, caps, blocks,
                                      solver=solver, spill=True)
        # first-round results untouched (splice parity preserved)
        for h in plain:
            assert spilled[h].assignment == plain[h].assignment
        sp = spilled[SPILL_HUB]
        info = sp.solver_stats["spill"]
        assert info["candidates"] == 3 and info["rescued"] == 3
        assert info["a_idx"] == [1, 2, 3]
        w_plain = sum(r.welfare for r in plain.values())
        w_spill = sum(r.welfare for h, r in spilled.items())
        assert w_spill == pytest.approx(w_plain + 3 * 3.0, abs=1e-3)


def test_spill_noop_when_no_residual_or_no_losers():
    values = np.full((2, 2), 4.0)
    costs = np.full((2, 2), 1.0)
    # everyone matches in round 1 -> no candidates
    res = run_sharded_auction(values, costs, [1, 1],
                              {0: ([0], [0]), 1: ([1], [1])},
                              solver="dense", spill=True)
    assert SPILL_HUB not in res
    # losers exist but zero residual capacity -> no spill round
    res = run_sharded_auction(values, costs, [1, 1],
                              {0: ([0, 1], [0, 1])}, solver="dense",
                              spill=True)
    assert SPILL_HUB not in res


def test_router_spill_rescues_and_accounts():
    from repro.core import AgentInfo, IEMASRouter, Request, TokenPrices

    def agents():
        # two single-capacity "code" agents, two idle "math" agents
        return [AgentInfo(f"c{i}", TokenPrices(0.001, 0.0001, 0.003), 1,
                          ("code",)) for i in range(2)] + \
               [AgentInfo(f"m{i}", TokenPrices(0.001, 0.0001, 0.003), 1,
                          ("math",)) for i in range(2)]

    def reqs(k):
        return [Request(f"r{j}", f"d{j}", np.arange(40, dtype=np.int32), 0,
                        domain="code") for j in range(k)]

    on = IEMASRouter(agents(), n_hubs=2, solver="dense", spill=True,
                     predictor_kw={"warm_n": 99})
    off = IEMASRouter(agents(), n_hubs=2, solver="dense", spill=False,
                      predictor_kw={"warm_n": 99})
    d_on = on.route_batch(reqs(4), {})
    d_off = off.route_batch(reqs(4), {})
    assert sum(1 for d in d_on if d.agent_id) > \
        sum(1 for d in d_off if d.agent_id)
    assert on.accounts["spill_rescued"] > 0
    assert on.accounts["matched"] - on.accounts["unmatched"] >= \
        off.accounts["matched"] - off.accounts["unmatched"]
    # spill winners must route to real agents with per-agent capacity kept
    used = {}
    for d in d_on:
        if d.agent_id:
            used[d.agent_id] = used.get(d.agent_id, 0) + 1
    assert all(v <= 1 for v in used.values())


def test_router_spill_rescues_from_dead_hub():
    """A hub whose live agents are all quarantined still spills its pinned
    requests onto other hubs' residual capacity (empty round-1 block)."""
    from repro.core import AgentInfo, IEMASRouter, Request, TokenPrices

    agents = [AgentInfo(f"c{i}", TokenPrices(0.001, 0.0001, 0.003), 1,
                        ("code",)) for i in range(2)] + \
             [AgentInfo(f"m{i}", TokenPrices(0.001, 0.0001, 0.003), 2,
                        ("math",)) for i in range(2)]
    router = IEMASRouter(agents, n_hubs=2, solver="dense", spill=True,
                         predictor_kw={"warm_n": 99})
    router.quarantine("c0")
    router.quarantine("c1")
    reqs = [Request(f"r{j}", f"d{j}", np.arange(30, dtype=np.int32), 0,
                    domain="code") for j in range(2)]
    decisions = router.route_batch(reqs, {})
    assert all(d.agent_id in ("m0", "m1") for d in decisions)
    assert router.accounts["spill_rescued"] == 2
    assert router.accounts["matched"] == 2
    assert router.accounts["unmatched"] == 0


# --------------------------------------------------------------- registry --
def test_every_registered_backend_satisfies_protocol():
    for name in available_solvers():
        backend = get_solver(name)
        assert isinstance(backend, SolverBackend), name
        assert backend.name == name
        assert isinstance(backend.supports_warm_start, bool)
        assert isinstance(backend.supports_batch, bool)


def test_registry_rejects_unknown_and_malformed():
    with pytest.raises(ValueError, match="unknown solver"):
        get_solver("nope")

    class NotABackend:
        name = "broken"

    with pytest.raises(TypeError):
        register_solver(NotABackend())


def test_backend_certificates():
    rng = np.random.default_rng(3)
    values, costs, caps = _instance(rng, 10, 5)
    for name in available_solvers():
        backend = get_solver(name)
        r = run_auction(values, costs, caps, solver=name)
        cert = backend.certificate(r)
        assert cert >= 0.0
        if name == "mcmf":
            assert cert == 0.0
        else:
            assert cert == r.solver_stats["gap_bound"]


def test_auction_module_has_no_per_solver_branching():
    """The acceptance criterion, enforced: core/auction.py resolves every
    solver through the registry — no conditionals on the solver name."""
    import inspect
    import re

    import repro.core.auction as auction

    src = inspect.getsource(auction)
    assert not re.search(r"solver\s*(==|!=|\bin\b\s*\()", src), \
        "core/auction.py still branches on the solver name"
    assert "get_solver" in src
