"""Identity-persistent reputation: the churn-laundering hole is closed.

Before this layer, an agent whose reported quality had been audited down
could `remove_agent` + `add_agent` itself back and rejoin at the honest
1.0 reputation — leave/rejoin was a full pardon.  Reputation is now
parked under a stable identity fingerprint (agent id + exact posted
prices) when an agent departs, and restored when the same market identity
rejoins; only a genuinely different identity (changed prices = a new
posted offer) starts fresh.
"""
import numpy as np

from repro.core import IEMASRouter
from repro.core.mechanism import AgentInfo, CompletionObs, Request
from repro.core.predictor import PredictorPool, identity_fingerprint
from repro.core.pricing import TokenPrices

P = TokenPrices(0.01, 0.002, 0.03)


def _decay(pool, aid, k=6, residual=0.4):
    for _ in range(k):
        pool.note_residual(aid, residual)
    return pool[aid].reputation


def test_fingerprint_binds_id_and_prices():
    """Same id + same prices = same identity; any price change (or id
    change) is a different identity."""
    assert identity_fingerprint("a", P) == identity_fingerprint(
        "a", TokenPrices(0.01, 0.002, 0.03))
    assert identity_fingerprint("a", P) != identity_fingerprint("b", P)
    assert identity_fingerprint("a", P) != identity_fingerprint(
        "a", TokenPrices(0.0100001, 0.002, 0.03))


def test_rejoin_inherits_decayed_reputation():
    """The laundering path: decay -> leave -> rejoin must NOT reset."""
    pool = PredictorPool({"adv": P})
    rep = _decay(pool, "adv")
    assert rep < 0.9
    pool.remove_agent("adv")
    pool.add_agent("adv", P)
    assert pool["adv"].reputation == rep      # inherited, not pardoned


def test_new_identity_starts_fresh():
    """A different posted-price vector is a different market identity and
    rightfully starts at the honest 1.0 (entry is not punished)."""
    pool = PredictorPool({"adv": P})
    _decay(pool, "adv")
    pool.remove_agent("adv")
    pool.add_agent("adv", TokenPrices(0.02, 0.002, 0.03))
    assert pool["adv"].reputation == 1.0


def test_honest_agents_unaffected_by_churn():
    """An agent that never drew a residual churns in and out at exactly
    1.0 — the honest fixed point is bit-preserved."""
    pool = PredictorPool({"h": P})
    pool.remove_agent("h")
    pool.add_agent("h", P)
    assert pool["h"].reputation == 1.0


def test_launderer_no_longer_recovers_honest_tier_weight():
    """Router-level regression: after audits crush a misreporter's
    reputation, leave/rejoin no longer restores honest-tier w-blend
    weight — its reputation-scaled quality (and hence bid values) stays
    at the decayed tier."""
    agents = [
        AgentInfo("hon", P, capacity=4, domains=("qa",)),
        AgentInfo("adv", P, capacity=4, domains=("qa",)),
    ]
    router = IEMASRouter(agents, solver="dense", n_hubs=1, warm_start=True)
    telem = {"router_inflight": 0, "router_rps": 0.0,
             "agent_inflight": {}, "agent_rps": {}}
    rng = np.random.default_rng(0)
    # the adversary inflates its reports; the audit channel exposes it
    # (free_slots pins each probe onto the adversary so the decay runs
    # through the real Phase-4 settlement path)
    for t in range(8):
        req = Request(f"r{t}", "d0", rng.integers(1, 255, 24, np.int32), t,
                      domain="qa")
        [dec] = router.route_batch([req], telem,
                                   free_slots={"hon": 0, "adv": 4})
        assert dec.agent_id == "adv"
        router.on_complete(req.request_id, CompletionObs(
            latency=0.05, n_prompt=24, n_hit=0, n_gen=4,
            quality=0.95, audit_quality=0.45))
    rep_before = router.pool["adv"].reputation
    assert rep_before < 0.9
    router.remove_agent("adv")
    router.add_agent(AgentInfo("adv", P, capacity=4, domains=("qa",)))
    assert router.pool["adv"].reputation == rep_before
    # and the w-blend weight it bids with reflects the decayed tier: the
    # rejoined adversary's cold-start quality is its prior scaled by the
    # inherited reputation, strictly below the honest agent's
    q_adv = router.pool["adv"].predict(_x()).quality
    q_hon = router.pool["hon"].predict(_x()).quality
    assert q_adv < q_hon


def _x():
    from repro.core.predictor import PredictorInput
    return PredictorInput(prompt_len=24, turn=0, affinity=0.0,
                          router_inflight=0, router_rps=0.0,
                          agent_inflight=0, agent_rps=0.0, capacity=4,
                          utilization=0.0, domain_match=1.0)
