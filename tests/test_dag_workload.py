"""Workflow-DAG serving: family generation, precedence scheduling, handoff
prefix threading, precedence-aware affinity credit, and the id/precedence
property suite (ISSUE-7 tentpole + satellite 4).

Everything runs on ``engine_mode="analytic"`` clusters (deterministic
virtual service times), same as tests/test_simulator.py."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import IEMASRouter
from repro.core.affinity import PrefixLedger
from repro.core.baselines import GraphSchedulerRouter
from repro.serving import (DAG_WORKLOADS, EventSimulator, PoissonArrivals,
                           SimCluster, WorkloadSpec, generate, iter_dialogues,
                           run_workload)
from repro.serving.analytic import AnalyticEngine
from repro.serving.workload import (DOMAINS, DagScript, DagStep,
                                    validate_dag)


def _fresh(seed=0, n_agents=4, fail=0.0, **cluster_kw):
    cluster = SimCluster(n_agents=n_agents, seed=seed, max_new_tokens=3,
                         engine_mode="analytic", fail_prob=fail, **cluster_kw)
    router = IEMASRouter(cluster.agent_infos(), solver="dense", n_hubs=2,
                         warm_start=True)
    return cluster, router


def _tok(rng, n):
    return rng.integers(1, 255, size=n, dtype=np.int32)


# ------------------------------------------------- family generation --
@pytest.mark.parametrize("family", DAG_WORKLOADS)
def test_dag_families_generate_valid_graphs(family):
    """Both topology families draw well-formed DAGs: contiguous ids,
    topological edges, at least one root, and the advertised shapes."""
    scripts = generate(WorkloadSpec(family, n_dialogues=20, seed=3))
    assert len(scripts) == 20
    for s in scripts:
        assert isinstance(s, DagScript)
        validate_dag(s)  # raises on malformed graphs
        assert all(st_.domain in DOMAINS for st_ in s.steps)
        roles = [st_.role for st_ in s.steps]
        if family == "dag_orchestrator":
            # plan -> W parallel workers -> fan-in aggregation
            assert roles[0] == "orchestrator" and roles[-1] == "aggregator"
            workers = [st_ for st_ in s.steps if st_.role == "worker"]
            assert 2 <= len(workers) <= 4
            assert all(st_.parents == (0,) for st_ in workers)
            assert s.steps[-1].parents == tuple(w.step_id for w in workers)
        else:
            # handoff chain; optional side branch merged by an aggregator
            chain = [st_ for st_ in s.steps if st_.role == "handoff"]
            assert len(chain) >= 3
            assert all(st_.parents == (st_.step_id - 1,)
                       for st_ in chain[1:])
            if "aggregator" in roles:
                assert roles[-1] == "aggregator" \
                    and len(s.steps[-1].parents) == 2
    # cross-agent handoffs exist: at least one script changes domain
    assert any(len({st_.domain for st_ in s.steps}) > 1 for s in scripts)


def test_validate_dag_rejects_malformed_graphs():
    """Non-contiguous ids, forward/self edges and empty graphs all raise."""
    rng = np.random.default_rng(0)
    ok = DagStep(0, (), "orchestrator", "reasoning", _tok(rng, 8))
    with pytest.raises(ValueError, match="step_ids must be 0..n-1"):
        validate_dag(DagScript("d", "reasoning", [
            ok, DagStep(2, (0,), "worker", "code", _tok(rng, 4))], 0.5))
    with pytest.raises(ValueError, match="non-topological"):
        validate_dag(DagScript("d", "reasoning", [
            ok, DagStep(1, (1,), "worker", "code", _tok(rng, 4))], 0.5))
    with pytest.raises(ValueError, match="non-topological"):
        validate_dag(DagScript("d", "reasoning", [
            DagStep(0, (0,), "orchestrator", "reasoning", _tok(rng, 8))],
            0.5))
    with pytest.raises(ValueError, match="no root step"):
        validate_dag(DagScript("d", "reasoning", [], 0.5))


# ------------------------------------------- precedence-aware affinity --
def test_parent_credit_raises_handoff_affinity():
    """An agent that served a PARENT step gets credit for the child's
    prompt prefix even though the child runs under a fresh session key."""
    rng = np.random.default_rng(1)
    ledger = PrefixLedger()
    parent_ctx = _tok(rng, 40)
    ledger.update("a0", "d#s0", parent_ctx)
    child = np.concatenate([parent_ctx, _tok(rng, 10)])
    # fresh child session: own-session affinity is zero for everyone
    o = ledger.affinity_matrix([child], ["d#s1"], ["a0", "a1"])
    assert o[0, 0] == 0.0 and o[0, 1] == 0.0
    o = ledger.parent_credit(o, [child], [("d#s0",)], ["a0", "a1"])
    assert o[0, 0] == pytest.approx(40 / 50)   # a0 holds the parent prefix
    assert o[0, 1] == 0.0                      # a1 never served the parent
    # linear rows (no parents) are untouched
    o2 = np.full((2, 2), 0.25)
    out = ledger.parent_credit(o2, [child, child], [(), ()], ["a0", "a1"])
    assert np.array_equal(out, np.full((2, 2), 0.25))


def test_parent_credit_respects_lru_and_arch_masks():
    """Parent entries are LRU-masked like own-session affinity, and
    recurrent agents only get exact-extension credit."""
    rng = np.random.default_rng(2)
    ledger = PrefixLedger()
    parent_ctx = _tok(rng, 30)
    ledger.update("a0", "d#s0", parent_ctx)
    ledger.update("a0", "other", _tok(rng, 12))   # newer session
    child = np.concatenate([parent_ctx, _tok(rng, 6)])
    # 1 cache slot: only "other" is presumed resident -> no parent credit
    o = ledger.parent_credit(np.zeros((1, 1)), [child], [("d#s0",)], ["a0"],
                             cache_slots=[1])
    assert o[0, 0] == 0.0
    # 2 slots: the parent entry is back in the window
    o = ledger.parent_credit(np.zeros((1, 1)), [child], [("d#s0",)], ["a0"],
                             cache_slots=[2])
    assert o[0, 0] == pytest.approx(30 / 36)
    # recurrent mask: the parent ctx IS an exact prefix -> extension credit
    o = ledger.parent_credit(np.zeros((1, 1)), [child], [("d#s0",)], ["a0"],
                             extension_only_mask=[True])
    assert o[0, 0] == pytest.approx(30 / 36)
    # ...but a diverging child prompt gets nothing under extension-only
    diverged = np.concatenate([parent_ctx[:10], _tok(rng, 20)])
    o = ledger.parent_credit(np.zeros((1, 1)), [diverged], [("d#s0",)],
                             ["a0"], extension_only_mask=[True])
    assert o[0, 0] == 0.0


def test_engine_parent_fork_reuses_handoff_prefix():
    """The engine forks a parent step's cache when the child's prompt
    extends the parent context — and stays cold without the parent hint."""
    rng = np.random.default_rng(3)
    eng = AnalyticEngine("qwen-4b", seed=0, cache_slots=8, max_new_tokens=4)
    parent_prompt = _tok(rng, 40)
    rp = eng.serve("d#s0", parent_prompt, now=0.0)
    parent_ctx = np.concatenate([parent_prompt, rp.output_tokens])
    child_prompt = np.concatenate([parent_ctx, _tok(rng, 10)]).astype(np.int32)
    rc = eng.serve("d#s1", child_prompt, now=1.0, parents=("d#s0",))
    assert rc.n_hit == len(parent_ctx)          # the whole handoff is warm
    assert "d#s1" in eng.sessions and "d#s0" in eng.sessions
    # same handoff WITHOUT the parent hint: cold prefill
    eng2 = AnalyticEngine("qwen-4b", seed=0, cache_slots=8, max_new_tokens=4)
    r2 = eng2.serve("d#s0", parent_prompt, now=0.0)
    child2 = np.concatenate([parent_prompt, r2.output_tokens,
                             _tok(rng, 10)]).astype(np.int32)
    assert eng2.serve("d#s1", child2, now=1.0).n_hit == 0


# --------------------------------------------- end-to-end precedence --
@pytest.mark.parametrize("family", DAG_WORKLOADS)
def test_dag_end_to_end_precedence_and_prefixes(family):
    """The simulator never dispatches a step before all its parents
    completed, every step prompt begins with the concatenated parent
    contexts, and handoffs produce real KV hits."""
    cluster, router = _fresh(seed=2)
    spec = WorkloadSpec(family, n_dialogues=8, seed=4)
    sim = EventSimulator(cluster, router, iter_dialogues(spec),
                         arrivals=PoissonArrivals(rate=10.0, seed=5),
                         batch_cap=8, batch_window=0.02, max_new_tokens=3)
    orig_execute = cluster.execute

    def checked(dec, rtr):
        step = dec.request.meta.get("step_id")
        if step is not None:
            dst = sim.states[dec.request.dialogue_id]
            s = dst.script.steps[step]
            assert all(p in dst.step_ctx for p in s.parents), \
                f"step {step} dispatched before parents {s.parents}"
            if s.parents:
                prefix = np.concatenate([dst.step_ctx[p]
                                         for p in sorted(s.parents)])
                assert np.array_equal(dec.request.tokens[:len(prefix)],
                                      prefix)
        return orig_execute(dec, rtr)

    cluster.execute = checked
    out = sim.run()
    assert out["dialogues_completed"] == 8 and not out["truncated"]
    assert out["kv_hit_rate"] > 0          # handoff prefixes were reused
    # every record carries the step session scheme
    for rec in cluster.records:
        meta = rec.request.meta
        did = rec.request.dialogue_id
        assert meta["session"] == f"{did}#s{meta['step_id']}"
        assert all(ps.startswith(f"{did}#s")
                   for ps in meta["parent_sessions"])


def test_dag_beats_affinity_blind_on_handoff_hits():
    """Sanity companion to benchmarks/dag_routing.py: on the same workload
    the precedence-aware router reuses strictly more handoff prefix than
    the affinity-blind graph scheduler."""
    def kv(router_for):
        cluster = SimCluster(n_agents=8, seed=0, max_new_tokens=3,
                             engine_mode="analytic")
        router = router_for(cluster)
        spec = WorkloadSpec("dag_handoff", n_dialogues=12, seed=6)
        out = EventSimulator(cluster, router, iter_dialogues(spec),
                             arrivals=PoissonArrivals(rate=10.0, seed=7),
                             batch_cap=8, batch_window=0.02,
                             max_new_tokens=3).run()
        assert out["dialogues_completed"] == 12
        return out["kv_hit_rate"]

    kv_iemas = kv(lambda c: IEMASRouter(c.agent_infos(), solver="dense",
                                        n_hubs=2, warm_start=True))
    kv_blind = kv(lambda c: GraphSchedulerRouter(c.agent_infos(), seed=0))
    assert kv_iemas > kv_blind


def test_run_workload_rejects_dag_scripts():
    """The closed-loop round loop has no precedence scheduler; handing it
    a DAG script must fail loudly, pointing at the event simulator."""
    cluster, router = _fresh(seed=0)
    dlg = generate(WorkloadSpec("dag_orchestrator", n_dialogues=2, seed=1))
    with pytest.raises(TypeError, match="EventSimulator"):
        run_workload(cluster, router, dlg, max_new_tokens=3)


# ---------------------------------------------- property suite (sat 4) --
@st.composite
def _dag_cases(draw):
    """Random topology + fault/incremental regime for one property run."""
    n_steps = draw(st.integers(min_value=1, max_value=6))
    parents = [()]
    for k in range(1, n_steps):
        n_par = draw(st.integers(min_value=1, max_value=min(k, 2)))
        ps = {draw(st.integers(min_value=0, max_value=k - 1))
              for _ in range(n_par)}
        parents.append(tuple(sorted(ps)))
    fail = draw(st.integers(min_value=0, max_value=1)) * 0.25
    incremental = bool(draw(st.integers(min_value=0, max_value=1)))
    seed = draw(st.integers(min_value=0, max_value=10))
    return tuple(parents), fail, incremental, seed


@settings(max_examples=12, deadline=None)
@given(_dag_cases())
def test_dag_property_unique_ids_and_precedence(case):
    """Over random DAG shapes, fault rates and incremental on/off: the
    batch and incremental paths together never emit a duplicate
    request_id, never dispatch a step before all its parents completed,
    and every workflow drains."""
    parents, fail, incremental, seed = case
    rng = np.random.default_rng(seed)
    scripts = []
    for d in range(2):
        steps = [DagStep(k, ps, "worker" if ps else "orchestrator",
                         DOMAINS[int(rng.integers(len(DOMAINS)))],
                         _tok(rng, int(rng.integers(6, 30))))
                 for k, ps in enumerate(parents)]
        script = DagScript(f"prop-{d}", steps[0].domain, steps,
                           float(rng.uniform(0.2, 0.8)))
        validate_dag(script)
        scripts.append(script)

    cluster, router = _fresh(seed=seed, n_agents=3, fail=fail,
                             quarantine_cooldown=1.0)
    sim = EventSimulator(cluster, router, scripts,
                         arrivals=PoissonArrivals(rate=20.0, seed=seed),
                         batch_cap=6, batch_window=0.01,
                         incremental=incremental, max_new_tokens=3)
    seen_rids = []
    orig_batch, orig_inc = router.route_batch, router.route_incremental

    def batch(reqs, telem, free_slots=None):
        seen_rids.extend(r.request_id for r in reqs)
        return orig_batch(reqs, telem, free_slots=free_slots)

    def inc(reqs, telem, free_slots=None):
        seen_rids.extend(r.request_id for r in reqs)
        return orig_inc(reqs, telem, free_slots=free_slots)

    router.route_batch, router.route_incremental = batch, inc
    orig_execute = cluster.execute

    def checked(dec, rtr):
        step = dec.request.meta.get("step_id")
        if step is not None:
            dst = sim.states[dec.request.dialogue_id]
            assert all(p in dst.step_ctx
                       for p in dst.script.steps[step].parents)
        return orig_execute(dec, rtr)

    cluster.execute = checked
    out = sim.run()
    assert out["dialogues_completed"] == 2 and not out["truncated"]
    assert len(seen_rids) == len(set(seen_rids)), "request_id re-issued"
