"""`hypothesis` facade for the tier-1 suite.

When the real package is installed (see requirements-dev.txt / CI) it is
re-exported untouched.  When it is absent — the pinned repro container does
not ship it — a minimal deterministic fallback provides the subset the test
suite uses (`given`, `settings`, `strategies.integers/floats/lists/composite`)
backed by seeded random sampling, so `pytest -x -q` always collects and runs.

The fallback is NOT a property-testing engine: no shrinking, no edge-case
database — just `max_examples` seeded samples per test (seed derived from the
test name, so failures reproduce).  It intentionally biases a slice of draws
toward interval endpoints to keep some boundary coverage.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import random as _random
    import zlib as _zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 50

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rnd: "_random.Random"):
            return self._sample(rnd)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            def sample(rnd):
                if rnd.random() < 0.08:
                    return rnd.choice((min_value, max_value))
                return rnd.randint(min_value, max_value)
            return _Strategy(sample)

        @staticmethod
        def floats(min_value: float, max_value: float,
                   allow_nan: bool = True,
                   allow_infinity: bool | None = None) -> _Strategy:
            def sample(rnd):
                if rnd.random() < 0.08:
                    return rnd.choice((float(min_value), float(max_value)))
                return rnd.uniform(min_value, max_value)
            return _Strategy(sample)

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def sample(rnd):
                size = rnd.randint(min_size, max_size)
                return [elements.example(rnd) for _ in range(size)]
            return _Strategy(sample)

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                def sample(rnd):
                    return fn(lambda strat: strat.example(rnd),
                              *args, **kwargs)
                return _Strategy(sample)
            return build

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        def deco(fn):
            # applied above @given (the repo convention): fn is the runner
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            examples = getattr(fn, "_compat_max_examples", None)

            def runner():
                n = (runner._compat_max_examples if examples is None
                     else examples)
                rnd = _random.Random(
                    _zlib.crc32(fn.__qualname__.encode("utf-8")))
                for _ in range(n):
                    fn(*[s.example(rnd) for s in strats])

            # zero-arg wrapper on purpose: pytest must not mistake strategy
            # parameters for fixtures (functools.wraps would leak them)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner._compat_max_examples = _DEFAULT_MAX_EXAMPLES
            return runner
        return deco
