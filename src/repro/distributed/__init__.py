from repro.distributed.sharding import (
    ShardingPolicy,
    apply_policy,
    current_policy,
    shard,
    DEFAULT_RULES,
    TRAIN_RULES,
    DECODE_RULES,
)
