"""Distribution layer: logical-axis sharding rules (`sharding`) and the
process-parallel super-hub shard workers of the hubs-of-hubs federation
(`federation`)."""
from repro.distributed.sharding import (
    ShardingPolicy,
    apply_policy,
    current_policy,
    shard,
    DEFAULT_RULES,
    TRAIN_RULES,
    DECODE_RULES,
)
