"""Elastic scaling: re-mesh + reshard state when the device pool changes.

Checkpoints store full (unsharded) arrays, so elasticity is: rebuild the
mesh at the new size, re-derive shardings from the same logical-axis rules
(divisibility fallback handles non-power-of-two survivors), and device_put
the restored state. Serving-side elasticity (agents joining/leaving the
market) lives in core.mechanism.add_agent/remove_agent, which stamp every
membership change with an :class:`AgentSetVersion` — the version gates
cross-round warm-start state (hub slot prices) so nothing learned about one
agent set is replayed against another.

jax is imported lazily: the membership-versioning side of this module is
consumed by the (numpy-only) routing core.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class AgentSetVersion:
    """Monotonic stamp for the serving market's agent membership.

    The router bumps it on every agent join/leave/hub-rebuild; consumers of
    per-agent-set caches (e.g. `repro.core.hub.SlotPriceBook`) key their
    entries by the version at store time and treat any mismatch as a cold
    start.  ``fingerprint`` additionally binds an exact agent-id tuple, for
    caches that must also invalidate on *subset* changes (quarantine flips
    the live set without changing membership, so a version alone is not
    enough).
    """

    version: int = 0

    def bump(self) -> int:
        """Advance to (and return) the next version."""
        self.version += 1
        return self.version

    def fingerprint(self, agent_ids) -> tuple[int, tuple[str, ...]]:
        """(version, exact id tuple) — the full warm-start cache key."""
        return self.version, tuple(agent_ids)


def remesh(n_devices: int, *, data_model_ratio: float = 1.0,
           devices=None):
    """Largest (data, model) mesh fitting n_devices, preferring square-ish
    factorizations scaled by ``data_model_ratio`` (= data/model)."""
    import jax

    devices = list(devices or jax.devices())[:n_devices]
    n = len(devices)
    best = (1, n)
    best_score = -1.0
    for d in range(1, n + 1):
        if n % d:
            continue
        m = n // d
        ratio = d / m
        score = -abs(np.log(ratio / data_model_ratio))
        if score > best_score:
            best, best_score = (d, m), score
    d, m = best
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # pre-0.5 jax: meshes are implicitly Auto
        return jax.make_mesh((d, m), ("data", "model"), devices=devices)
    return jax.make_mesh((d, m), ("data", "model"), devices=devices,
                         axis_types=(axis_type.Auto,) * 2)


def reshard_state(state, param_axes, mesh, rules_acts: dict,
                  rules_params: dict):
    """device_put a restored pytree onto a new mesh using logical rules."""
    import jax

    from repro.distributed.sharding import ShardingPolicy, param_shardings

    policy = ShardingPolicy(mesh, acts=rules_acts, params=rules_params)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype), state)
    shardings = param_shardings(policy, abstract, param_axes)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
