"""Elastic scaling: re-mesh + reshard state when the device pool changes.

Checkpoints store full (unsharded) arrays, so elasticity is: rebuild the
mesh at the new size, re-derive shardings from the same logical-axis rules
(divisibility fallback handles non-power-of-two survivors), and device_put
the restored state. Serving-side elasticity (agents joining/leaving the
market) lives in core.mechanism.add_agent/remove_agent.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import ShardingPolicy, param_shardings


def remesh(n_devices: int, *, data_model_ratio: float = 1.0,
           devices=None) -> Mesh:
    """Largest (data, model) mesh fitting n_devices, preferring square-ish
    factorizations scaled by ``data_model_ratio`` (= data/model)."""
    devices = list(devices or jax.devices())[:n_devices]
    n = len(devices)
    best = (1, n)
    best_score = -1.0
    for d in range(1, n + 1):
        if n % d:
            continue
        m = n // d
        ratio = d / m
        score = -abs(np.log(ratio / data_model_ratio))
        if score > best_score:
            best, best_score = (d, m), score
    d, m = best
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # pre-0.5 jax: meshes are implicitly Auto
        return jax.make_mesh((d, m), ("data", "model"), devices=devices)
    return jax.make_mesh((d, m), ("data", "model"), devices=devices,
                         axis_types=(axis_type.Auto,) * 2)


def reshard_state(state, param_axes, mesh: Mesh, rules_acts: dict,
                  rules_params: dict):
    """device_put a restored pytree onto a new mesh using logical rules."""
    policy = ShardingPolicy(mesh, acts=rules_acts, params=rules_params)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype), state)
    shardings = param_shardings(policy, abstract, param_axes)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
