"""Logical-axis sharding rules (t5x/MaxText style) for the whole framework.

Models annotate activations with *logical* axis names via ``shard(x, ...)``
and parameters via per-leaf logical-axes pytrees. A ``ShardingPolicy`` maps
logical names to mesh axes, with automatic divisibility fallback (e.g. 8 KV
heads on a 16-way ``model`` axis fall back to replication), and never assigns
one mesh axis to two dims of the same tensor.

Two rule sets live in one policy:
  * ``acts``   — activation shardings (used by ``shard`` constraints)
  * ``params`` — parameter shardings (FSDP/ZeRO assignments live here)

When no policy is active (unit tests, single-device smoke runs) ``shard`` is
a no-op, so model code runs unchanged on 1 CPU device.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Rules map a logical axis name to a mesh axis name, a tuple of mesh axes,
# or None (replicated). Order matters only through the tensor's own axes.
DEFAULT_RULES: dict = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qk_dim": None,
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "expert_capacity": None,
    "kv_lora": None,
    "state": None,
    "inner": "model",   # SSM inner projections (rwkv/mamba d_inner)
    "conv_k": None,
    "lora_rank": None,
    "attn_seq": None,     # q seq in the chunked path (heads carry `model`)
    "attn_kv_seq": None,  # gathered key/value seq
    "attn_head": None,    # head dims in the dense path (seq carries `model`)
    "logit_seq": None,    # LM-head seq dim (vocab carries `model`)
    "cache_seq": None,
    "src_seq": None,
    "patches": None,
    # parameters (stacked layer dim never sharded)
    "layers": None,
    "groups": None,
}

# Training: sequence-parallel residual stream + FSDP parameters over `data`.
TRAIN_RULES = dict(DEFAULT_RULES)
TRAIN_RULES.update({"seq": "model"})
TRAIN_PARAM_RULES = {
    # FSDP: shard the long dim of weight matrices over `data` as well
    "embed": "data",
    "ff": "model",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "expert": "model",
}

# Decode/prefill: weights sharded over model only (no FSDP gather per step);
# batch over data, KV cache heads over model with seq fallback.
DECODE_RULES = dict(DEFAULT_RULES)
DECODE_RULES.update({"seq": None, "cache_seq": None})
DECODE_PARAM_RULES = {
    # ZeRO-style 2D weight sharding for serving: embed dim over `data`,
    # heads/ff/vocab over `model` => 256-way shards; contractions produce
    # small per-token partial-sum all-reduces instead of replicating e.g.
    # mixtral's 282 GB of expert weights per data replica.
    "embed": "data",
    "ff": "model",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "expert": "model",
    "inner": "model",
}


@dataclass(frozen=True)
class ShardingPolicy:
    """Maps logical axis names to mesh axes for activations and params.

    ``acts`` holds the activation rules consulted by `shard`; ``params``
    overlays parameter-specific rules (FSDP/ZeRO assignments) on top of
    them.  Resolution applies divisibility fallback and never assigns one
    mesh axis to two dims of the same tensor."""
    mesh: Mesh
    acts: dict = field(default_factory=lambda: dict(DEFAULT_RULES))
    params: dict = field(default_factory=dict)

    def _axis_size(self, name) -> int:
        return self.mesh.shape.get(name, 1)

    def _resolve(self, logical_axes, dims, rules) -> P:
        """Logical names -> PartitionSpec with divisibility + reuse fallback."""
        used: set = set()
        spec = []
        for i, name in enumerate(logical_axes):
            rule = rules.get(name, None)
            if rule is None:
                spec.append(None)
                continue
            axes = rule if isinstance(rule, tuple) else (rule,)
            # drop axes missing from the mesh or already used by this tensor
            axes = tuple(a for a in axes if a in self.mesh.shape and a not in used)
            if not axes:
                spec.append(None)
                continue
            total = 1
            for a in axes:
                total *= self._axis_size(a)
            if dims is not None and dims[i] % total != 0:
                # divisibility fallback: try shrinking the axis tuple
                while axes and (dims[i] % _prod(self._axis_size(a) for a in axes) != 0):
                    axes = axes[:-1]
                if not axes:
                    spec.append(None)
                    continue
            used.update(axes)
            spec.append(axes if len(axes) > 1 else axes[0])
        while spec and spec[-1] is None:
            spec.pop()
        return P(*spec)

    def act_spec(self, logical_axes, dims=None) -> P:
        """PartitionSpec for an activation under the ``acts`` rules."""
        rules = dict(self.acts)
        return self._resolve(logical_axes, dims, rules)

    def param_spec(self, logical_axes, dims=None) -> P:
        """PartitionSpec for a parameter (``params`` overlaid on ``acts``)."""
        rules = dict(self.acts)
        rules.update(self.params)
        return self._resolve(logical_axes, dims, rules)

    def act_sharding(self, logical_axes, dims=None) -> NamedSharding:
        """`act_spec` bound to this policy's mesh as a NamedSharding."""
        return NamedSharding(self.mesh, self.act_spec(logical_axes, dims))

    def param_sharding(self, logical_axes, dims=None) -> NamedSharding:
        """`param_spec` bound to this policy's mesh as a NamedSharding."""
        return NamedSharding(self.mesh, self.param_spec(logical_axes, dims))

    def with_rules(self, acts=None, params=None) -> "ShardingPolicy":
        """A copy of this policy with rule overrides merged in."""
        new_acts = dict(self.acts)
        new_acts.update(acts or {})
        new_params = dict(self.params)
        new_params.update(params or {})
        return replace(self, acts=new_acts, params=new_params)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out


_state = threading.local()


def current_policy() -> ShardingPolicy | None:
    """The thread-local active policy (None outside `apply_policy`)."""
    return getattr(_state, "policy", None)


@contextlib.contextmanager
def apply_policy(policy: ShardingPolicy | None):
    """Make ``policy`` the thread-local active policy for the block."""
    prev = current_policy()
    _state.policy = policy
    try:
        yield policy
    finally:
        _state.policy = prev


def shard(x, *logical_axes):
    """Annotate an activation with logical axes; no-op without a policy."""
    policy = current_policy()
    if policy is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard(): {len(logical_axes)} logical axes for rank-{x.ndim} tensor"
        )
    spec = policy.act_spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(policy.mesh, spec))


def param_shardings(policy: ShardingPolicy, abstract_params, param_axes):
    """Pytree of NamedShardings for a params pytree given its logical axes.

    ``param_axes`` mirrors the params pytree with space-separated logical-axis
    strings as leaves, e.g. ``"layers embed ff"``.
    """
    def one(leaf, axes_str):
        axes = tuple(a if a != "." else None for a in axes_str.split())
        if len(axes) != len(leaf.shape):
            raise ValueError(f"axes {axes_str!r} vs shape {leaf.shape}")
        return policy.param_sharding(axes, leaf.shape)

    return jax.tree.map(one, abstract_params, param_axes)
