"""Process-parallel substrate for the hubs-of-hubs federation.

The federation's shards (`repro.serving.federation.InlineShard`) are
analytically-engined, numpy-only event loops, so they parallelize
cleanly across OS processes: this module provides the deterministic
per-shard seed split, the picklable `ShardSpec` a worker needs to build
its shard from scratch, and `ProcessShardHandle` — a pipe-RPC proxy
exposing the exact `InlineShard` surface, so
`repro.serving.federation.FederatedSimulator` drives inline and remote
shards through one interface.

Seed splitting (`shard_seed`) is `jax.random.fold_in`-style: the base
seed and the super-hub id are folded through a specified, platform-stable
mix (`numpy.random.SeedSequence`), so every shard owns an independent RNG
stream derived ONLY from ``(base_seed, super_id)`` — never from
scheduling order.  Since shards share no mutable random state (each
`SimCluster` carries its own generator) a federated run is bit-
deterministic under ANY shard-advance interleave, which is what lets the
process pool below overlap shard execution freely between epochs
(tests/test_federation.py shuffles the advance schedule to prove it).

Placement note: `launch/mesh.py` pins device meshes for the JAX training/
kernel stack; the federation's shard workers are CPU-bound numpy loops,
so `worker_slots` just bounds process fan-out by visible cores rather
than claiming mesh devices.
"""
from __future__ import annotations

import multiprocessing as mp
import os
from dataclasses import dataclass, field

import numpy as np


def shard_seed(base_seed: int, super_id: int) -> int:
    """Fold a super-hub id into the base seed (`fold_in`-style).

    `numpy.random.SeedSequence` entropy mixing is specified and
    platform-stable, so the same ``(base_seed, super_id)`` pair yields
    the same 31-bit seed on every machine — and distinct pairs are
    decorrelated far beyond what ``base_seed + super_id`` would give.
    """
    ss = np.random.SeedSequence((int(base_seed), int(super_id)))
    return int(ss.generate_state(1, np.uint32)[0] % (2**31))


def worker_slots(requested: int | None = None) -> int:
    """Bound process fan-out by visible CPU cores (at least one)."""
    cores = os.cpu_count() or 1
    return max(1, min(requested or cores, cores))


@dataclass
class ShardSpec:
    """Everything a worker process needs to build one federation shard.

    Pure data (profiles are frozen dataclasses of scalars/tuples), so the
    spec pickles across a spawn boundary; the worker materializes the
    `SimCluster`/`IEMASRouter`/`ShardEventLoop` triple itself via
    `repro.serving.federation.InlineShard.from_spec` — the SAME factory
    the inline path uses, which is what keeps process-parallel runs
    bit-identical to inline runs.
    """

    super_id: int
    profiles: list                      # this shard's slice of the fleet
    seed: int                           # shard_seed(base_seed, super_id)
    router_kwargs: dict = field(default_factory=dict)
    loop_kwargs: dict = field(default_factory=dict)
    cluster_kwargs: dict = field(default_factory=dict)


def _shard_worker(conn, spec: ShardSpec) -> None:
    """Worker main: build the shard, then serve pipe-RPC until ``close``.

    Imports the serving stack lazily (inside the process) so the module
    itself stays importable without touching jax; the RPC protocol is
    ``(method_name, args tuple)`` in, ``("ok", result)`` /
    ``("err", repr)`` out.
    """
    try:
        from repro.serving.federation import InlineShard

        shard = InlineShard.from_spec(spec)
        conn.send(("ok", None))
    except Exception as e:          # pragma: no cover - startup failure path
        conn.send(("err", repr(e)))
        return
    while True:
        try:
            msg = conn.recv()
        except EOFError:            # parent died: exit quietly
            return
        if msg is None:
            return
        name, args = msg
        try:
            conn.send(("ok", getattr(shard, name)(*args)))
        except Exception as e:
            conn.send(("err", repr(e)))


class ProcessShardHandle:
    """One federation shard living in its own OS process (pipe-RPC proxy).

    Exposes the `InlineShard` driver surface (``start``, ``inject``,
    ``advance``, ``digest``, ``residuals``, ``extract``, ``admit``,
    ``close_arrivals``, ``finalize``) by forwarding each call over a
    duplex pipe.  Calls are synchronous by default; ``advance`` can be
    split into `advance_async` + `wait` so the parent overlaps all
    shards' epoch work — the actual concurrency win.  Uses the spawn
    start method: the parent has jax initialized, and forking a process
    with live jax threadpools is not safe.
    """

    def __init__(self, spec: ShardSpec, *, ctx: str = "spawn"):
        self.super_id = spec.super_id
        context = mp.get_context(ctx)
        self._conn, child = context.Pipe()
        self._proc = context.Process(target=_shard_worker,
                                     args=(child, spec), daemon=True)
        self._proc.start()
        child.close()
        self._pending = False
        status, payload = self._conn.recv()     # startup ack
        if status != "ok":
            raise RuntimeError(f"shard {spec.super_id} worker failed to "
                               f"start: {payload}")

    def _call(self, name: str, *args):
        self._conn.send((name, args))
        status, payload = self._conn.recv()
        if status != "ok":
            raise RuntimeError(f"shard {self.super_id}.{name}: {payload}")
        return payload

    def advance_async(self, t_end: float | None) -> None:
        """Kick off one epoch's advance without waiting for the result."""
        self._conn.send(("advance", (t_end,)))
        self._pending = True

    def wait(self):
        """Collect the result of the outstanding `advance_async`."""
        if not self._pending:
            raise RuntimeError("wait() without a pending advance_async()")
        self._pending = False
        status, payload = self._conn.recv()
        if status != "ok":
            raise RuntimeError(f"shard {self.super_id}.advance: {payload}")
        return payload

    def close(self) -> None:
        """Shut the worker down (idempotent)."""
        if self._proc.is_alive():
            try:
                self._conn.send(None)
            except (BrokenPipeError, OSError):
                pass
            self._proc.join(timeout=10)
            if self._proc.is_alive():   # pragma: no cover - hung worker
                self._proc.terminate()
        self._conn.close()

    def __getattr__(self, name):
        # proxy the remaining InlineShard surface verbatim
        if name.startswith("_"):
            raise AttributeError(name)

        def method(*args):
            return self._call(name, *args)

        return method
