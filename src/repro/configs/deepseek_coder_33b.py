"""deepseek-coder-33b — dense llama-architecture code model.

[arXiv:2401.14196; hf] 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    attn_kind="gqa",
    rope_theta=1e5,
    source="arXiv:2401.14196; hf",
)
