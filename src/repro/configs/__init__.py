"""Architecture registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_supported,
    model_flops,
    param_counts,
)

from repro.configs import (  # noqa: E402
    rwkv6_3b,
    mixtral_8x22b,
    deepseek_v2_lite_16b,
    seamless_m4t_medium,
    deepseek_coder_33b,
    qwen2_72b,
    qwen3_8b,
    qwen2_5_32b,
    llava_next_34b,
    zamba2_7b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        rwkv6_3b,
        mixtral_8x22b,
        deepseek_v2_lite_16b,
        seamless_m4t_medium,
        deepseek_coder_33b,
        qwen2_72b,
        qwen3_8b,
        qwen2_5_32b,
        llava_next_34b,
        zamba2_7b,
    )
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def list_archs() -> list[str]:
    return sorted(ARCHS)


__all__ = [
    "ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
    "list_archs", "cell_supported", "model_flops", "param_counts",
]
