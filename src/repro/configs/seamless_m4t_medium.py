"""seamless-m4t-medium — encoder-decoder multimodal (audio) backbone.

[arXiv:2308.11596; hf] 12L d_model=1024 16H d_ff=4096 vocab=256206.
The speech frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings [B, src_len, d_model]. Convention (DESIGN.md):
``seq_len`` refers to the decoder; encoder source length is 1024 frames.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,       # decoder layers
    enc_layers=12,     # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    attn_kind="gqa",
    src_len=1024,
    source="arXiv:2308.11596; hf",
)
