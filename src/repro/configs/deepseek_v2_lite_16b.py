"""deepseek-v2-lite-16b — MLA (kv_lora=512) + fine-grained MoE.

[arXiv:2405.04434; hf] 27L d_model=2048 16H d_ff=1408(moe) vocab=102400,
MoE 64 routed experts top-6 + 2 shared, first layer dense (d_ff=10944).
Cache stores the compressed latent (kv_lora_rank + qk_rope_dim = 576/token).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    attn_kind="mla",
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    dense_d_ff=10944,
    source="arXiv:2405.04434; hf",
)
