"""zamba2-7b — hybrid: Mamba2 backbone + shared full-attention blocks.

[arXiv:2411.15242; unverified] 81L d_model=3584 32H (GQA kv=32, i.e. MHA-width
KV for the shared block) d_ff=14336 vocab=32000, ssm_state=64.
A single shared transformer block (attention + MLP, with per-invocation LoRA
deltas) is applied after every 6th Mamba2 layer -> 13 applications.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    attn_kind="gqa",
    ssm_kind="mamba2",
    ssm_state=64,
    ssm_heads=112,  # d_inner = 2*d_model, mamba2 head_dim 64
    attn_every=6,
    source="arXiv:2411.15242; unverified",
)
