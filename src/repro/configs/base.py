"""Config schema for models and workload shapes.

Every assigned architecture is a frozen ``ModelConfig``; the four assigned
input shapes are ``ShapeConfig`` entries in ``SHAPES``. The dry-run iterates
the cross product (with documented skips, see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention
    attn_kind: str = "gqa"  # gqa | mla | none
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0  # 0 = full attention
    rope_theta: float = 10000.0

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # expert hidden size when different from d_ff
    first_dense_layers: int = 0
    dense_d_ff: int = 0  # FFN size of the leading dense layers (0 -> d_ff)
    capacity_factor: float = 1.25  # MoE dispatch capacity factor

    # SSM / hybrid
    ssm_kind: str = ""  # rwkv6 | mamba2
    ssm_state: int = 0
    ssm_heads: int = 0
    attn_every: int = 0  # zamba2: shared attention applied after every k ssm layers

    # encoder-decoder (seamless-m4t)
    enc_layers: int = 0
    src_len: int = 0  # encoder source length convention (audio frames)

    # vlm
    n_patches: int = 0  # anyres patch embeddings prepended to the prompt

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""  # provenance tag from the assignment table

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch hold a 500k-token context (see DESIGN.md §4)?"""
        return self.ssm_kind != "" or (self.sliding_window > 0 and self.attn_kind != "none")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-capable (enc-dec included)

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            name=self.name + "-smoke",
        )
        if self.is_moe:
            base.update(n_experts=4, top_k=2, moe_d_ff=64,
                        n_shared_experts=min(self.n_shared_experts, 1),
                        first_dense_layers=min(self.first_dense_layers, 1))
        if self.attn_kind == "mla":
            base.update(kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16)
        if self.ssm_kind:
            base.update(ssm_state=16, ssm_heads=4)
        if self.attn_every:
            base.update(n_layers=4, attn_every=2)
        if self.is_encdec:
            base.update(enc_layers=2, src_len=32)
        if self.n_patches:
            base.update(n_patches=8)
        if self.sliding_window:
            base.update(sliding_window=32)
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell; reason when skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k-token decode context exceeds "
                       "per-chip HBM for the KV cache and is architecturally "
                       "out of scope (see DESIGN.md §4)")
    return True, ""


# ---------------- parameter / FLOP accounting (analytic) ----------------

def param_counts(cfg: ModelConfig) -> dict:
    """Analytic parameter counts: total and active-per-token (MoE-aware)."""
    d, hd = cfg.d_model, cfg.hd
    qkv_out = cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd
    if cfg.attn_kind == "mla":
        q_dim = cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
        attn = (d * q_dim                                  # W_q
                + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)  # W_dkv (+ rope key)
                + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                + cfg.n_heads * cfg.v_head_dim * d)         # W_o
    elif cfg.attn_kind == "none":
        attn = 0
    else:
        attn = d * qkv_out + cfg.n_heads * hd * d
        if cfg.qkv_bias:
            attn += qkv_out

    ffn_dense = 3 * d * cfg.d_ff
    if cfg.is_moe:
        e_ff = cfg.moe_d_ff or cfg.d_ff
        ffn_moe_total = cfg.n_experts * 3 * d * e_ff + cfg.n_shared_experts * 3 * d * e_ff
        ffn_moe_active = cfg.top_k * 3 * d * e_ff + cfg.n_shared_experts * 3 * d * e_ff
        router = d * cfg.n_experts
    else:
        ffn_moe_total = ffn_moe_active = router = 0

    if cfg.ssm_kind == "rwkv6":
        # r,k,v,g,w projections + output + time-mix loras (approx, matches models/ssm.py)
        tmix = 5 * d * d + d * d + 5 * (d * 32 + 32 * d) + 2 * d
        cmix = 2 * d * cfg.d_ff + d * d
        per_layer_total = per_layer_active = tmix + cmix
    elif cfg.ssm_kind == "mamba2" and cfg.family == "hybrid":
        d_inner = 2 * d
        mamba = d * (2 * d_inner + 2 * cfg.ssm_state + cfg.ssm_heads) + d_inner * d
        per_layer_total = per_layer_active = mamba
    else:
        dense_l = max(cfg.first_dense_layers, 0)
        moe_l = cfg.n_layers - dense_l if cfg.is_moe else 0
        n_dense = cfg.n_layers - moe_l
        per_layer_total = attn + (ffn_dense if not cfg.is_moe else 0)
        per_layer_active = per_layer_total
        total = (cfg.n_layers * attn + n_dense * ffn_dense
                 + moe_l * (ffn_moe_total + router))
        active = (cfg.n_layers * attn + n_dense * ffn_dense
                  + moe_l * (ffn_moe_active + router))
        emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
        if cfg.is_encdec:
            total += cfg.enc_layers * (attn + ffn_dense) + cfg.n_layers * (attn)  # cross-attn
            active = total
        return {"total": total + emb, "active": active + emb, "embedding": emb}

    # ssm / hybrid path
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total = cfg.n_layers * per_layer_total
    if cfg.attn_every:
        # one shared attention block (+ lora deltas folded in approx)
        total += attn + ffn_dense
    return {"total": total + emb, "active": total + emb, "embedding": emb}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS per step: 6*N*D for training, 2*N_active*D forward-only.

    N excludes embeddings-as-lookup but includes the LM head matmul via the
    embedding term when tied (standard 6ND convention keeps it simple).
    """
    counts = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * counts["active"] * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * counts["active"] * tokens
    # decode: one new token per sequence
    tokens = shape.global_batch
    return 2.0 * counts["active"] * tokens
