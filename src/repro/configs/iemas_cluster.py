"""The paper's own experimental setup (§5.1) as a cluster config.

The paper profiles vLLM on RTX 4090 / RTX 6000 nodes serving LLaMA-3-7B,
Qwen-4B and Qwen-8B, with a concurrent batch buffer of 12 and constrained
GPU memory (frequent cache evictions). Here the same *population structure*
is expressed as agent profiles for the simulated cluster; the engines run
reduced JAX models so latency/cost are measured, not scripted.

``agent_profiles(n_agents)`` tiles the three model classes across agents with
heterogeneous domains, capacities and token pricing.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class AgentProfile:
    agent_id: str
    model_class: str      # which reduced model config the engine runs
    scale: float          # S_i, relative model scale (paper: parameter size)
    domains: tuple[str, ...]  # K_i, specialization tags
    capacity: int         # B_i, max concurrent tasks (paper buffer: 12)
    price_miss: float     # pi_miss per uncached prompt token
    price_hit: float      # pi_hit per cached prompt token
    price_out: float      # pi_out per generated token
    cache_slots: int = 12  # cached sessions ~ the paper's concurrent buffer (12)
    speed: float = 1.0    # relative hardware speed (4090 vs 6000 heterogeneity)


@dataclass(frozen=True)
class RouterConfig:
    """Mechanism-side knobs plumbed from configs/CLI into IEMASRouter.

    ``solver`` names a backend in the ``repro.core.solvers`` registry:
    ``"mcmf"`` is the exact pure-Python oracle, ``"dense"`` the vectorized
    ε-scaling auction (hot path at scale), ``"dense-jax"`` its
    jax.jit-staged variant and ``"pallas"`` the staged variant with the
    Pallas bidding kernel (interpret mode off-TPU).

    ``n_hubs`` shards Phase 2 across proxy hubs (§4.4): agents are clustered
    by ``hub_scheme`` and each batch's welfare matrix is auctioned per hub
    block (batch-capable solvers run uneven blocks through one vmapped
    program per shape bucket).  ``warm_start=True`` reuses each hub's final
    slot prices as the next round's ε-scaling seed (backends with
    ``supports_warm_start`` only; the router cold-starts any hub whose live
    agent set changed).  ``spill=True`` re-auctions requests a saturated
    hub left unmatched over every hub's residual capacity (one cross-hub
    second round per batch; payments are Clarke pivots within each round's
    market, so strict-DSIC deployments at ``n_hubs > 1`` should disable
    it — see `repro.core.mechanism`).

    ``batched`` picks the Phase-1 QoS path: True (default) scores the full
    (n, m, F) feature tensor through the compiled Hoeffding forests in one
    vectorized pass; False keeps the per-pair scalar loop (the semantic
    oracle — identical decisions, ~an order of magnitude slower).
    ``predictor_backend`` is ``"numpy"`` (bit-exact vs scalar; the serving
    default) or ``"jax"`` (jit-staged descent, float32; retraces whenever
    the batch shape or a split-grown node pool changes shape, so it only
    pays off under shape-stable batches — benchmark steady state).

    ``reputation`` enables the reputation-weighted priors (exactly neutral
    without an audit channel, so leaving it on costs honest runs nothing);
    ``audit_ledger`` attaches the append-only hash-chained settlement
    ledger (`repro.core.ledger`) for replay audits.

    ``fused=True`` runs the whole per-batch routing step — ledger gather,
    Eq.-4 affinity, Eq.-5 prediction, Eq.-1 values and the column auction —
    as ONE device-resident jitted program (`repro.core.routing_fused`);
    requires ``n_hubs == 1`` and a staged-family solver (``dense-jax`` or
    ``pallas``), enforced at router construction.

    ``explore_bonus`` is the predictor optimism knob against affinity
    entrenchment (tests/test_exploration.py): predicted quality is lifted
    by ``explore_bonus / sqrt(1 + n_obs)`` so a never-sampled in-domain
    specialist can outbid a cache-warm mismatched incumbent.  The default
    0.0 is an exact no-op."""
    solver: str = "mcmf"
    payment_mode: str = "warmstart"
    n_hubs: int = 1
    hub_scheme: str = "domain"
    warm_start: bool = False
    spill: bool = True
    use_kernel_affinity: bool = False
    batched: bool = True
    predictor_backend: str = "numpy"
    reputation: bool = True
    audit_ledger: bool = False
    fused: bool = False
    explore_bonus: float = 0.0

    def router_kwargs(self) -> dict:
        import dataclasses

        kw = dataclasses.asdict(self)
        # IEMASRouter takes the predictor knob via predictor_kw
        explore = kw.pop("explore_bonus")
        if explore:
            kw["predictor_kw"] = {"explore": explore}
        return kw


DEFAULT_ROUTER = RouterConfig()


@dataclass(frozen=True)
class ClusterScaleConfig:
    """Preset for open-loop scale runs (`repro.serving.simulator`).

    Bundles the population size with the serving-loop knobs a scale run
    needs to be meaningful: an analytic engine mode (real JAX engines at
    128 agents would swamp the sweep in reduced-model matmuls), an
    open-loop Poisson arrival rate (scaled per agent so every fleet size
    runs a comparable virtual-time window), the streaming-admission
    window, the micro-batch cap/window, and a hub-sharded warm-started
    dense router.  This is the configuration `benchmarks/serving_scale.py`
    sweeps (``run_cell`` consumes these fields at varying ``n_agents``).
    """

    n_agents: int = 128
    n_dialogues: int = 10_000
    engine_mode: str = "analytic"
    rate_per_agent: float = 0.75   # Poisson dialogues/s per agent
    max_inflight: int = 256        # streaming admission window
    batch_cap: int = 64            # micro-batch size per router invocation
    batch_window: float = 0.05     # batching delay, seconds
    max_new_tokens: int = 6
    agents_per_hub: int = 16       # n_hubs = max(1, n_agents // this)
    solver: str = "dense"
    warm_start: bool = True
    # hubs-of-hubs federation (repro.serving.federation): number of
    # independently-advancing super-hub shards and the virtual seconds
    # between price-book-gossip / cross-super-hub-spill boundaries.
    # super_hubs=1 is the single-heap EventSimulator (bit-exact oracle).
    super_hubs: int = 1
    epoch: float = 0.25

    def arrival_rate(self, n_agents: int | None = None) -> float:
        """Open-loop arrival rate (dialogues/s) for a given fleet size."""
        return self.rate_per_agent * (n_agents or self.n_agents)

    def n_hubs(self, n_agents: int | None = None) -> int:
        """Hub count for a given fleet size (inner hubs per shard when
        federated: each super-hub recuts its slice by ``agents_per_hub``)."""
        return max(1, (n_agents or self.n_agents) // self.agents_per_hub)

    def router_config(self, n_agents: int | None = None) -> RouterConfig:
        """The matching mechanism-side RouterConfig."""
        return RouterConfig(solver=self.solver, n_hubs=self.n_hubs(n_agents),
                            warm_start=self.warm_start)


#: the 128-agent / 10k-dialogue headline scale preset
SCALE_128 = ClusterScaleConfig()

#: the federation scale preset: a 1024-agent fleet serving 100k dialogues
#: across 8 super-hub shards — the regime one event heap cannot sustain
#: (the routing benchmark's overhead crossover) and the headline row of
#: `benchmarks/serving_scale.py --federation`
SCALE_1K = ClusterScaleConfig(n_agents=1024, n_dialogues=100_000,
                              max_inflight=2048, super_hubs=8, epoch=0.5)

MODEL_CLASSES = {
    # name: (n_layers, d_model, n_heads, d_ff, relative scale)
    # sized so CPU prefill compute dominates dispatch noise, preserving the
    # GPU-regime latency structure (prefill >> queueing) the paper relies on
    "llama3-7b": (6, 256, 4, 768, 7.0),
    "qwen-8b": (6, 288, 4, 864, 8.0),
    "qwen-4b": (4, 192, 4, 576, 4.0),
}

DOMAINS = ("dialogue", "longctx", "reasoning", "code", "math")


def agent_profiles(n_agents: int = 9, seed: int = 0) -> list[AgentProfile]:
    import random

    rng = random.Random(seed)
    classes = list(MODEL_CLASSES.items())
    profiles = []
    for i in range(n_agents):
        cname, (_, _, _, _, scale) = classes[i % len(classes)]
        doms = tuple(rng.sample(DOMAINS, k=2))
        # larger models cost more per token; cached tokens ~10x cheaper
        base = 0.002 * scale
        profiles.append(
            AgentProfile(
                agent_id=f"agent-{i}",
                model_class=cname,
                scale=scale,
                domains=doms,
                capacity=12,
                price_miss=base,
                price_hit=base * 0.1,
                price_out=base * 3.0,
                cache_slots=12,
                speed=rng.choice([0.8, 1.0, 1.25]),
            )
        )
    return profiles
