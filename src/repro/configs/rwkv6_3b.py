"""rwkv6-3b — Finch, data-dependent decay, attention-free.

[arXiv:2404.05892; hf] 32L d_model=2560 d_ff=8960 vocab=65536.
WKV6 head size 64 -> 40 heads. No KV cache; O(1) recurrent state.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attn_kind="none",
    ssm_kind="rwkv6",
    ssm_state=64,   # per-head state is [head_dim x head_dim]
    ssm_heads=40,
    source="arXiv:2404.05892; hf",
)
