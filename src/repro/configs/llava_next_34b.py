"""llava-next-34b — VLM; dense LM backbone with anyres patch embeddings.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed anyres patch embeddings [B, n_patches=2880, d_model] that are
prepended to the text token embeddings (5 tiles x 576 patches).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    attn_kind="gqa",
    rope_theta=5e6,
    n_patches=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
