"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch, shape) cell on the single-pod mesh, three per-step time bounds
from the per-layer-corrected dry-run costs (all numbers are PER DEVICE; the
SPMD HLO is per-partition):

    t_compute    = flops_dev / PEAK_FLOPS          (197 TFLOP/s bf16, v5e)
    t_memory     = bytes_dev / HBM_BW              (819 GB/s)
    t_collective = wire_bytes_dev / ICI_BW         (~50 GB/s/link)

Dominant term = max -> the bottleneck. "roofline fraction" = useful model
flops / (chips * PEAK * t_dominant): the fraction of peak the step would
reach if it ran exactly at the dominant roofline bound.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9       # bytes/s / chip
ICI_BW = 50e9        # bytes/s / link

CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


def analyze(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return None
    chips = CHIPS.get(rec["mesh"], 256)
    full = {"flops": rec["full"]["flops"], "bytes": rec["full"]["bytes"],
            "coll": rec["full"]["collectives"]["total"]}
    src = rec.get("corrected") or full
    # the full-depth module counts each scan body ONCE, so it is a lower
    # bound on the true cost: clamp extrapolation noise against it
    flops = max(src["flops"], full["flops"], 0.0)
    hbytes = max(src["bytes"], full["bytes"], 0.0)
    coll = max(src["coll"], full["coll"], 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = hbytes / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    t_dom = terms[dominant]
    model_fl = rec.get("model_flops", 0.0)
    useful_ratio = model_fl / (flops * chips) if flops else 0.0
    mfu_at_roofline = (model_fl / (chips * PEAK_FLOPS * t_dom)) if t_dom else 0.0
    mem = rec["full"]["memory"]
    resident = mem["argument"] + mem["temp"] + mem["output"] - mem["alias"]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops": model_fl,
        "hlo_flops_total": flops * chips,
        "useful_flops_ratio": useful_ratio,
        "mfu_at_roofline": mfu_at_roofline,
        "mem_resident_gb": resident / 1e9,
        "fits_hbm16": resident <= 16e9,
    }


def load_all(art_dir: str, mesh: str = "pod16x16") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("supported", True):
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "skip": rec["skip_reason"]})
            continue
        row = analyze(rec)
        if row:
            out.append(row)
        else:
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"],
                        "fail": rec.get("error", "?")})
    return out


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dom':>5s} {'useful':>7s} {'MFU@roof':>8s} "
           f"{'mem GB':>7s} fit")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "skip" in r:
            lines.append(f"{r['arch']:22s} {r['shape']:12s} SKIP: {r['skip'][:70]}")
            continue
        if "fail" in r:
            lines.append(f"{r['arch']:22s} {r['shape']:12s} FAIL: {r['fail'][:70]}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:9.2e} "
            f"{r['t_memory_s']:9.2e} {r['t_collective_s']:9.2e} "
            f"{r['dominant'][:4]:>5s} {r['useful_flops_ratio']:7.3f} "
            f"{r['mfu_at_roofline']:8.3f} {r['mem_resident_gb']:7.1f} "
            f"{'y' if r['fits_hbm16'] else 'N'}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="?", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()
    rows = load_all(args.artifacts, args.mesh)
    print(format_table(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
