"""Synthetic multi-turn workloads mirroring the paper's three benchmarks.

The datasets themselves (CoQA/QuAC/HotpotQA) are not available offline, so
we generate deterministic token-id dialogues with the same *structural*
properties the paper exploits:

  * coqa_like   — many turns (6-14), short follow-up questions on a growing
                  shared context: high prefix-reuse opportunity.
  * quac_like   — long initial context (200-360 tokens) + medium turns:
                  long-context reuse.
  * hotpot_like — mostly 1-2 turns, long unique prompts: scarce reuse
                  (the paper's low-KV regime, Table 1 rightmost block).

Turn t's prompt = full conversation so far (client appends the engine's
actual generated answer, preserving conversational causality like the
paper's client, Appendix C.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import zlib

DOMAINS = ("dialogue", "longctx", "reasoning", "code", "math")


@dataclass
class DialogueScript:
    dialogue_id: str
    domain: str
    turns: list          # list of user-turn token arrays
    difficulty: float    # [0,1], drives simulated quality


@dataclass
class WorkloadSpec:
    name: str
    n_dialogues: int = 24
    vocab: int = 255     # token ids 1..vocab (0 reserved)
    seed: int = 0


def _tok(rng, n, vocab):
    return rng.integers(1, vocab, size=n, dtype=np.int32)


def generate(spec: WorkloadSpec) -> list[DialogueScript]:
    rng = np.random.default_rng(spec.seed + zlib.crc32(spec.name.encode()) % 100000)
    out = []
    for d in range(spec.n_dialogues):
        if spec.name == "coqa_like":
            domain = "dialogue"
            n_turns = int(rng.integers(6, 15))
            turns = [_tok(rng, int(rng.integers(24, 48)), spec.vocab)]
            turns += [_tok(rng, int(rng.integers(6, 14)), spec.vocab)
                      for _ in range(n_turns - 1)]
            difficulty = float(rng.uniform(0.1, 0.5))
        elif spec.name == "quac_like":
            domain = "longctx"
            n_turns = int(rng.integers(3, 7))
            turns = [_tok(rng, int(rng.integers(200, 360)), spec.vocab)]
            turns += [_tok(rng, int(rng.integers(8, 20)), spec.vocab)
                      for _ in range(n_turns - 1)]
            difficulty = float(rng.uniform(0.3, 0.7))
        elif spec.name == "hotpot_like":
            domain = "reasoning"
            n_turns = int(rng.integers(1, 3))
            turns = [_tok(rng, int(rng.integers(90, 200)), spec.vocab)
                     for _ in range(n_turns)]
            difficulty = float(rng.uniform(0.5, 0.9))
        else:
            raise KeyError(spec.name)
        out.append(DialogueScript(f"{spec.name}-{d}", domain, turns, difficulty))
    return out


WORKLOADS = ("coqa_like", "quac_like", "hotpot_like")
