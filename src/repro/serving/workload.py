"""Synthetic multi-turn workloads mirroring the paper's three benchmarks.

The datasets themselves (CoQA/QuAC/HotpotQA) are not available offline, so
we generate deterministic token-id dialogues with the same *structural*
properties the paper exploits:

  * coqa_like   — many turns (6-14), short follow-up questions on a growing
                  shared context: high prefix-reuse opportunity.
  * quac_like   — long initial context (200-360 tokens) + medium turns:
                  long-context reuse.
  * hotpot_like — mostly 1-2 turns, long unique prompts: scarce reuse
                  (the paper's low-KV regime, Table 1 rightmost block).

Turn t's prompt = full conversation so far (client appends the engine's
actual generated answer, preserving conversational causality like the
paper's client, Appendix C.1).

Beyond the paper's linear benchmarks, two **workflow/DAG families** model
real agentic traffic as task graphs with handoffs between *different*
agents (the MasRouter routing problem; topology shapes follow the
orchestrator-worker and handoff-swarm patterns in SNIPPETS.md):

  * dag_orchestrator — a root planning step fans out to 2-4 specialist
                       worker steps (distinct domains), joined by a fan-in
                       aggregation step: the OpenMAS
                       ``patterns.orchestrator`` delegate/aggregate shape.
  * dag_handoff      — a 3-6 step chain whose domain changes step to step
                       (each specialist hands the task off to the next),
                       with an optional side branch merged by a final
                       fan-in join: the AWorld ``Swarm(HANDOFF)`` shape.

A DAG step becomes runnable only once every parent step completed, and its
prompt is the concatenation of its parents' full contexts (parent prompt +
generated answer) followed by its own instruction tokens — the producer's
output IS the consumer's prompt prefix, so a router that co-places chained
steps keeps KV-prefix affinity alive across the handoff.

Scale runs (`repro.serving.simulator`) consume the same scripts lazily via
``iter_dialogues`` — 10k dialogues stream through the simulator's bounded
admission window instead of being pre-materialized — and pace them with an
:class:`ArrivalProcess` (open-loop Poisson, synchronous closed-loop, or an
explicit trace), the standard methodology in serving-system evaluations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator

import numpy as np
import zlib

DOMAINS = ("dialogue", "longctx", "reasoning", "code", "math")


@dataclass
class DialogueScript:
    """One scripted multi-turn dialogue (user turns only; answers are live)."""

    dialogue_id: str
    domain: str
    turns: list          # list of user-turn token arrays
    difficulty: float    # [0,1], drives simulated quality


@dataclass
class DagStep:
    """One node of a workflow DAG: instruction tokens + precedence edges.

    ``parents`` index earlier steps of the same script (a step is runnable
    only when all of them completed); ``role`` tags the step's function in
    the topology (orchestrator / worker / aggregator / handoff) and
    ``domain`` is the specialist skill it needs — steps of one dialogue may
    target different domains, which is exactly the cross-agent handoff the
    precedence-aware router has to keep cache-affine.
    """

    step_id: int
    parents: tuple          # step_ids that must complete first (all < step_id)
    role: str               # orchestrator | worker | aggregator | handoff
    domain: str
    tokens: np.ndarray      # the step's own instruction tokens


@dataclass
class DagScript:
    """One scripted workflow DAG (steps + edges; answers are live).

    The simulator derives each step's prompt at readiness time: the
    concatenated parent contexts (their prompt + the engine's actual
    answer, in ascending ``step_id`` order) followed by the step's own
    ``tokens``.  ``domain`` is the root/coordination domain used where a
    single per-dialogue tag is needed.
    """

    dialogue_id: str
    domain: str
    steps: list             # list[DagStep], topologically ordered by step_id
    difficulty: float       # [0,1], drives simulated quality


def validate_dag(script: DagScript) -> None:
    """Raise ValueError unless ``script`` is a well-formed workflow DAG:
    contiguous step_ids, all edges pointing to earlier steps (acyclic by
    construction), and at least one root step."""
    ids = [s.step_id for s in script.steps]
    if ids != list(range(len(ids))):
        raise ValueError(f"{script.dialogue_id}: step_ids must be 0..n-1, "
                         f"got {ids}")
    roots = 0
    for s in script.steps:
        if any(p >= s.step_id or p < 0 for p in s.parents):
            raise ValueError(f"{script.dialogue_id}: step {s.step_id} has "
                             f"non-topological parents {s.parents}")
        roots += not s.parents
    if roots == 0:
        raise ValueError(f"{script.dialogue_id}: no root step")


@dataclass
class WorkloadSpec:
    """Parameters of one synthetic workload family draw."""

    name: str
    n_dialogues: int = 24
    vocab: int = 255     # token ids 1..vocab (0 reserved)
    seed: int = 0


def _tok(rng, n, vocab):
    return rng.integers(1, vocab, size=n, dtype=np.int32)


def iter_dialogues(spec: WorkloadSpec) -> Iterator[DialogueScript]:
    """Yield ``spec.n_dialogues`` scripts lazily, in ``generate`` order.

    Bit-identical to ``generate(spec)`` element by element (one shared rng
    consumed in dialogue order), but streams: the 10k-dialogue scale runs
    hold only the simulator's bounded in-flight window in memory.
    """
    rng = np.random.default_rng(spec.seed + zlib.crc32(spec.name.encode()) % 100000)
    for d in range(spec.n_dialogues):
        if spec.name == "coqa_like":
            domain = "dialogue"
            n_turns = int(rng.integers(6, 15))
            turns = [_tok(rng, int(rng.integers(24, 48)), spec.vocab)]
            turns += [_tok(rng, int(rng.integers(6, 14)), spec.vocab)
                      for _ in range(n_turns - 1)]
            difficulty = float(rng.uniform(0.1, 0.5))
        elif spec.name == "quac_like":
            domain = "longctx"
            n_turns = int(rng.integers(3, 7))
            turns = [_tok(rng, int(rng.integers(200, 360)), spec.vocab)]
            turns += [_tok(rng, int(rng.integers(8, 20)), spec.vocab)
                      for _ in range(n_turns - 1)]
            difficulty = float(rng.uniform(0.3, 0.7))
        elif spec.name == "hotpot_like":
            domain = "reasoning"
            n_turns = int(rng.integers(1, 3))
            turns = [_tok(rng, int(rng.integers(90, 200)), spec.vocab)
                     for _ in range(n_turns)]
            difficulty = float(rng.uniform(0.5, 0.9))
        elif spec.name in DAG_WORKLOADS:
            yield _dag_script(spec, d, rng)
            continue
        else:
            raise KeyError(spec.name)
        yield DialogueScript(f"{spec.name}-{d}", domain, turns, difficulty)


def _dag_script(spec: WorkloadSpec, d: int, rng) -> DagScript:
    """Draw one workflow DAG of the ``spec.name`` topology family."""
    if spec.name == "dag_orchestrator":
        # orchestrator-worker delegation: plan -> W parallel specialists ->
        # fan-in aggregation (OpenMAS patterns.orchestrator shape)
        root_dom = "reasoning"
        n_workers = int(rng.integers(2, 5))
        steps = [DagStep(0, (), "orchestrator", root_dom,
                         _tok(rng, int(rng.integers(40, 90)), spec.vocab))]
        for w in range(n_workers):
            dom = DOMAINS[int(rng.integers(len(DOMAINS)))]
            steps.append(DagStep(1 + w, (0,), "worker", dom,
                                 _tok(rng, int(rng.integers(10, 28)),
                                      spec.vocab)))
        steps.append(DagStep(1 + n_workers, tuple(range(1, 1 + n_workers)),
                             "aggregator", root_dom,
                             _tok(rng, int(rng.integers(8, 18)), spec.vocab)))
        difficulty = float(rng.uniform(0.3, 0.8))
    elif spec.name == "dag_handoff":
        # handoff swarm: a chain through changing specialist domains, with
        # an optional side branch merged by a fan-in join (AWorld
        # Swarm(build_type=HANDOFF) shape)
        root_dom = DOMAINS[int(rng.integers(len(DOMAINS)))]
        n_chain = int(rng.integers(3, 7))
        steps = [DagStep(0, (), "handoff", root_dom,
                         _tok(rng, int(rng.integers(30, 70)), spec.vocab))]
        for k in range(1, n_chain):
            dom = DOMAINS[int(rng.integers(len(DOMAINS)))]
            steps.append(DagStep(k, (k - 1,), "handoff", dom,
                                 _tok(rng, int(rng.integers(8, 26)),
                                      spec.vocab)))
        if n_chain >= 3 and rng.random() < 0.5:
            src = int(rng.integers(1, n_chain - 1))
            dom = DOMAINS[int(rng.integers(len(DOMAINS)))]
            steps.append(DagStep(n_chain, (src,), "worker", dom,
                                 _tok(rng, int(rng.integers(8, 22)),
                                      spec.vocab)))
            steps.append(DagStep(n_chain + 1, (n_chain - 1, n_chain),
                                 "aggregator", root_dom,
                                 _tok(rng, int(rng.integers(6, 14)),
                                      spec.vocab)))
        difficulty = float(rng.uniform(0.2, 0.7))
    else:  # pragma: no cover - guarded by the caller's membership test
        raise KeyError(spec.name)
    script = DagScript(f"{spec.name}-{d}", root_dom, steps, difficulty)
    validate_dag(script)
    return script


def generate(spec: WorkloadSpec) -> list[DialogueScript]:
    """Materialize the whole workload (small closed-loop runs and tests)."""
    return list(iter_dialogues(spec))


WORKLOADS = ("coqa_like", "quac_like", "hotpot_like")
DAG_WORKLOADS = ("dag_orchestrator", "dag_handoff")


# --------------------------------------------------------------------------
# Arrival processes (open-loop load generation for the event simulator)
# --------------------------------------------------------------------------
class ArrivalProcess:
    """Dialogue arrival-time source for `repro.serving.simulator`.

    ``times()`` yields absolute arrival timestamps (virtual seconds,
    non-decreasing), one per dialogue, until the dialogue stream runs dry —
    implementations may be infinite generators; the simulator zips them
    against the dialogue iterator.
    """

    def times(self) -> Iterator[float]:
        """Yield non-decreasing absolute arrival timestamps."""
        raise NotImplementedError


@dataclass
class SyncArrivals(ArrivalProcess):
    """Closed-loop arrivals: every dialogue present at ``at`` (default t=0).

    This is the `run_workload` regime — the whole population arrives up
    front — and the arrival process the closed-loop parity suite uses.
    """

    at: float = 0.0

    def times(self) -> Iterator[float]:
        """Constant stream of ``at``."""
        while True:
            yield self.at


@dataclass
class PoissonArrivals(ArrivalProcess):
    """Open-loop memoryless arrivals at ``rate`` dialogues per virtual second.

    The standard serving-evaluation load model: inter-arrival gaps are
    iid Exp(rate), independent of system state, so queueing pressure is
    sustained rather than self-throttling.
    """

    rate: float
    seed: int = 0
    start: float = 0.0

    def times(self) -> Iterator[float]:
        """Exponential-gap timestamps from a dedicated seeded rng."""
        if self.rate <= 0:
            raise ValueError(f"arrival rate must be > 0, got {self.rate}")
        rng = np.random.default_rng(self.seed)
        t = self.start
        while True:
            t += float(rng.exponential(1.0 / self.rate))
            yield t


@dataclass
class TraceArrivals(ArrivalProcess):
    """Replay an explicit (sorted) timestamp trace, e.g. from a log."""

    timestamps: tuple

    def times(self) -> Iterator[float]:
        """Yield the recorded timestamps in order."""
        prev = -np.inf
        for t in self.timestamps:
            t = float(t)
            if t < prev:
                raise ValueError("trace timestamps must be non-decreasing")
            prev = t
            yield t


def load_trace(path) -> tuple:
    """Load an arrival trace file: one float timestamp per line (blank
    lines and ``#`` comments ignored).  Ordering is validated lazily by
    `TraceArrivals.times` when the simulator consumes the trace."""
    out = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.split("#", 1)[0].strip()
            if not line:
                continue
            try:
                out.append(float(line))
            except ValueError:
                raise ValueError(
                    f"{path}:{lineno}: not a timestamp: {line!r}") from None
    if not out:
        raise ValueError(f"{path}: empty arrival trace")
    return tuple(out)


def make_arrivals(name: str, *, rate: float = 8.0, seed: int = 0,
                  trace=None) -> ArrivalProcess:
    """CLI helper: ``"sync"``, ``"poisson"`` (with ``rate``) or ``"trace"``
    (with ``trace`` timestamps, e.g. from `load_trace`) by name."""
    if name == "sync":
        return SyncArrivals()
    if name == "poisson":
        return PoissonArrivals(rate=rate, seed=seed)
    if name == "trace":
        if trace is None:
            raise ValueError("trace arrivals need timestamps: pass trace=... "
                             "(CLI: --trace-file)")
        return TraceArrivals(tuple(float(t) for t in trace))
    raise KeyError(f"unknown arrival process {name!r} (sync|poisson|trace)")
