"""Synthetic multi-turn workloads mirroring the paper's three benchmarks.

The datasets themselves (CoQA/QuAC/HotpotQA) are not available offline, so
we generate deterministic token-id dialogues with the same *structural*
properties the paper exploits:

  * coqa_like   — many turns (6-14), short follow-up questions on a growing
                  shared context: high prefix-reuse opportunity.
  * quac_like   — long initial context (200-360 tokens) + medium turns:
                  long-context reuse.
  * hotpot_like — mostly 1-2 turns, long unique prompts: scarce reuse
                  (the paper's low-KV regime, Table 1 rightmost block).

Turn t's prompt = full conversation so far (client appends the engine's
actual generated answer, preserving conversational causality like the
paper's client, Appendix C.1).

Scale runs (`repro.serving.simulator`) consume the same scripts lazily via
``iter_dialogues`` — 10k dialogues stream through the simulator's bounded
admission window instead of being pre-materialized — and pace them with an
:class:`ArrivalProcess` (open-loop Poisson, synchronous closed-loop, or an
explicit trace), the standard methodology in serving-system evaluations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator

import numpy as np
import zlib

DOMAINS = ("dialogue", "longctx", "reasoning", "code", "math")


@dataclass
class DialogueScript:
    """One scripted multi-turn dialogue (user turns only; answers are live)."""

    dialogue_id: str
    domain: str
    turns: list          # list of user-turn token arrays
    difficulty: float    # [0,1], drives simulated quality


@dataclass
class WorkloadSpec:
    """Parameters of one synthetic workload family draw."""

    name: str
    n_dialogues: int = 24
    vocab: int = 255     # token ids 1..vocab (0 reserved)
    seed: int = 0


def _tok(rng, n, vocab):
    return rng.integers(1, vocab, size=n, dtype=np.int32)


def iter_dialogues(spec: WorkloadSpec) -> Iterator[DialogueScript]:
    """Yield ``spec.n_dialogues`` scripts lazily, in ``generate`` order.

    Bit-identical to ``generate(spec)`` element by element (one shared rng
    consumed in dialogue order), but streams: the 10k-dialogue scale runs
    hold only the simulator's bounded in-flight window in memory.
    """
    rng = np.random.default_rng(spec.seed + zlib.crc32(spec.name.encode()) % 100000)
    for d in range(spec.n_dialogues):
        if spec.name == "coqa_like":
            domain = "dialogue"
            n_turns = int(rng.integers(6, 15))
            turns = [_tok(rng, int(rng.integers(24, 48)), spec.vocab)]
            turns += [_tok(rng, int(rng.integers(6, 14)), spec.vocab)
                      for _ in range(n_turns - 1)]
            difficulty = float(rng.uniform(0.1, 0.5))
        elif spec.name == "quac_like":
            domain = "longctx"
            n_turns = int(rng.integers(3, 7))
            turns = [_tok(rng, int(rng.integers(200, 360)), spec.vocab)]
            turns += [_tok(rng, int(rng.integers(8, 20)), spec.vocab)
                      for _ in range(n_turns - 1)]
            difficulty = float(rng.uniform(0.3, 0.7))
        elif spec.name == "hotpot_like":
            domain = "reasoning"
            n_turns = int(rng.integers(1, 3))
            turns = [_tok(rng, int(rng.integers(90, 200)), spec.vocab)
                     for _ in range(n_turns)]
            difficulty = float(rng.uniform(0.5, 0.9))
        else:
            raise KeyError(spec.name)
        yield DialogueScript(f"{spec.name}-{d}", domain, turns, difficulty)


def generate(spec: WorkloadSpec) -> list[DialogueScript]:
    """Materialize the whole workload (small closed-loop runs and tests)."""
    return list(iter_dialogues(spec))


WORKLOADS = ("coqa_like", "quac_like", "hotpot_like")


# --------------------------------------------------------------------------
# Arrival processes (open-loop load generation for the event simulator)
# --------------------------------------------------------------------------
class ArrivalProcess:
    """Dialogue arrival-time source for `repro.serving.simulator`.

    ``times()`` yields absolute arrival timestamps (virtual seconds,
    non-decreasing), one per dialogue, until the dialogue stream runs dry —
    implementations may be infinite generators; the simulator zips them
    against the dialogue iterator.
    """

    def times(self) -> Iterator[float]:
        """Yield non-decreasing absolute arrival timestamps."""
        raise NotImplementedError


@dataclass
class SyncArrivals(ArrivalProcess):
    """Closed-loop arrivals: every dialogue present at ``at`` (default t=0).

    This is the `run_workload` regime — the whole population arrives up
    front — and the arrival process the closed-loop parity suite uses.
    """

    at: float = 0.0

    def times(self) -> Iterator[float]:
        """Constant stream of ``at``."""
        while True:
            yield self.at


@dataclass
class PoissonArrivals(ArrivalProcess):
    """Open-loop memoryless arrivals at ``rate`` dialogues per virtual second.

    The standard serving-evaluation load model: inter-arrival gaps are
    iid Exp(rate), independent of system state, so queueing pressure is
    sustained rather than self-throttling.
    """

    rate: float
    seed: int = 0
    start: float = 0.0

    def times(self) -> Iterator[float]:
        """Exponential-gap timestamps from a dedicated seeded rng."""
        if self.rate <= 0:
            raise ValueError(f"arrival rate must be > 0, got {self.rate}")
        rng = np.random.default_rng(self.seed)
        t = self.start
        while True:
            t += float(rng.exponential(1.0 / self.rate))
            yield t


@dataclass
class TraceArrivals(ArrivalProcess):
    """Replay an explicit (sorted) timestamp trace, e.g. from a log."""

    timestamps: tuple

    def times(self) -> Iterator[float]:
        """Yield the recorded timestamps in order."""
        prev = -np.inf
        for t in self.timestamps:
            t = float(t)
            if t < prev:
                raise ValueError("trace timestamps must be non-decreasing")
            prev = t
            yield t


def make_arrivals(name: str, *, rate: float = 8.0, seed: int = 0
                  ) -> ArrivalProcess:
    """CLI helper: ``"sync"`` or ``"poisson"`` (with ``rate``) by name."""
    if name == "sync":
        return SyncArrivals()
    if name == "poisson":
        return PoissonArrivals(rate=rate, seed=seed)
    raise KeyError(f"unknown arrival process {name!r} (sync|poisson)")
