"""Router/agent telemetry (Eq. 5 load features): inflight, RPS EWMAs, TTFT.

Also accumulates per-agent busy seconds (virtual engine time, reported by
the cluster on dispatch) so the event simulator can compute fleet
utilization and the profiler's engine-compute denominator from the same
source the router's load features come from.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field


@dataclass
class TelemetryTracker:
    """Decaying per-agent load state the proxy layer exposes to the router."""

    rps_halflife: float = 5.0  # seconds of virtual time
    router_inflight: int = 0
    agent_inflight: dict = field(default_factory=lambda: defaultdict(int))
    agent_busy: dict = field(default_factory=lambda: defaultdict(float))
    _router_rps: float = 0.0
    _agent_rps: dict = field(default_factory=lambda: defaultdict(float))
    _last_t: float = 0.0

    def _decay(self, now: float):
        dt = max(0.0, now - self._last_t)
        if dt > 0:
            f = 0.5 ** (dt / self.rps_halflife)
            self._router_rps *= f
            for k in self._agent_rps:
                self._agent_rps[k] *= f
            self._last_t = now

    def on_dispatch(self, agent_id: str, now: float):
        """Record one request entering an agent's queue at virtual ``now``."""
        self._decay(now)
        self.router_inflight += 1
        self.agent_inflight[agent_id] += 1
        self._router_rps += 1.0 / self.rps_halflife
        self._agent_rps[agent_id] += 1.0 / self.rps_halflife

    def on_busy(self, agent_id: str, seconds: float):
        """Accumulate one dispatch's virtual engine-busy seconds."""
        self.agent_busy[agent_id] += float(seconds)

    def on_complete(self, agent_id: str, now: float):
        """Record one request leaving an agent at virtual ``now``."""
        self._decay(now)
        self.router_inflight = max(0, self.router_inflight - 1)
        self.agent_inflight[agent_id] = max(0, self.agent_inflight[agent_id] - 1)

    def busy_seconds(self) -> float:
        """Total virtual engine-busy seconds across the fleet."""
        return float(sum(self.agent_busy.values()))

    def snapshot(self, now: float) -> dict:
        """The per-round telemetry dict Phase 1 consumes (Eq. 5 features)."""
        self._decay(now)
        return {
            "router_inflight": self.router_inflight,
            "router_rps": self._router_rps,
            "agent_inflight": dict(self.agent_inflight),
            "agent_rps": dict(self._agent_rps),
        }
