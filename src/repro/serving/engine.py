"""Per-agent inference engine: REAL JAX prefill/extend/decode with KV reuse.

Each serving agent runs a reduced JAX model (configs/iemas_cluster.py). The
engine keeps per-dialogue caches (LRU over ``cache_slots`` sessions — the
paper's constrained-memory / frequent-eviction regime) and measures:

  * TTFT       — wall-clock seconds of the prefill/extend path (real compute,
                 scaled by the agent's hardware ``speed``),
  * n_hit      — exactly how many prompt tokens were served from cache
                 (whole-prefix reuse for attention archs with truncation to
                 the LCP; exact-extension for recurrent archs),
  * n_gen      — generated tokens.

This gives the paper's causal chain *physically*: routing with affinity ->
more cached tokens -> less prefill compute -> lower TTFT and cost.

Prompt lengths are bucketed (powers of two) so jit caches stay small.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.affinity import lcp_length
from repro.models import build_model


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


@dataclass
class SessionCache:
    """One dialogue's cached model state + the prompt it encodes."""

    cache: object             # model cache pytree (B=1)
    prompt: np.ndarray        # tokens whose state the cache encodes
    last_used: float = 0.0


@dataclass
class ServeResult:
    """Measured outcome of one request: tokens, timings, cache accounting."""

    output_tokens: np.ndarray
    ttft: float               # seconds (scaled by agent speed)
    total_time: float
    n_prompt: int
    n_hit: int
    n_gen: int


# Engines of the same model class share one Model + jit cache: params are
# same-shaped arguments, so XLA compiles each shape bucket ONCE per class
# across the whole cluster (keeps CPU compile time out of TTFT measurements).
_SHARED: dict = {}


def _shared_fns(cfg: ModelConfig, max_len: int):
    key = (cfg, max_len)
    if key not in _SHARED:
        model = build_model(cfg)
        _SHARED[key] = {
            "model": model,
            "prefill": jax.jit(
                lambda p, b: model.prefill(p, {**b, "max_len": max_len})),
            "decode": jax.jit(model.decode_step),
            "extend": jax.jit(model.extend),
        }
    return _SHARED[key]


class AgentEngine:
    """One agent's inference engine: real JAX prefill/extend/decode with
    per-dialogue KV/state reuse (see module docstring)."""

    def __init__(self, cfg: ModelConfig, *, seed: int = 0, speed: float = 1.0,
                 cache_slots: int = 6, max_len: int = 1024,
                 max_new_tokens: int = 8):
        self.cfg = cfg
        shared = _shared_fns(cfg, max_len)
        self.model = shared["model"]
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.speed = speed
        self.cache_slots = cache_slots
        self.max_len = max_len
        self.max_new = max_new_tokens
        self.sessions: dict[str, SessionCache] = {}
        self.recurrent = self.model.family in ("rwkv", "zamba")
        self._prefill_j = shared["prefill"]
        self._decode_j = shared["decode"]
        self._extend_j = shared["extend"]
        self.evictions = 0

    def warmup(self, prefill_buckets=(32, 64, 128, 256, 512),
               extend_buckets=(16, 32, 64)) -> None:
        """Pre-compile the shape buckets so TTFT excludes XLA compile time."""
        for b in prefill_buckets:
            if b > self.max_len:
                continue
            r = self.serve("__warm__", np.arange(1, b + 1, dtype=np.int32) %
                           (self.cfg.vocab_size - 1) + 1, max_new_tokens=1)
        for b in extend_buckets:
            ext = np.arange(1, b, dtype=np.int32) % (self.cfg.vocab_size - 1) + 1
            prev = self.sessions.get("__warm__")
            if prev is None:
                continue
            self.serve("__warm__", np.concatenate([prev.prompt, ext]),
                       max_new_tokens=1)
        self.drop_session("__warm__")

    # ---------------- cache management ----------------
    def _evict_lru(self, now: float):
        while len(self.sessions) > self.cache_slots:
            victim = min(self.sessions, key=lambda k: self.sessions[k].last_used)
            del self.sessions[victim]
            self.evictions += 1

    def _truncate_attn_cache(self, cache, keep: int):
        """Invalidate cached positions >= keep (attention archs only)."""
        new = dict(cache)
        sp = cache["slot_pos"]
        new["slot_pos"] = jnp.where(sp < keep, sp, -1)
        new["pos"] = jnp.full_like(cache["pos"], keep)
        return new

    def _session_hit(self, prompt: np.ndarray, sess: SessionCache) -> int:
        """Cached prompt tokens this session would grant (arch rules):
        attention reuses any common prefix; recurrent state only an exact
        extension of the session's full prompt."""
        l = lcp_length(prompt, sess.prompt)
        if self.recurrent:
            return l if (l == len(sess.prompt) and l <= len(prompt)) else 0
        return l

    def _pick_session(self, dialogue_id: str, prompt: np.ndarray, parents):
        """Best cache candidate among the session's own entry and its DAG
        parent-step sessions (handoff fork: a child step's prompt starts
        with its parents' contexts, so a parent's cache is a warm prefix).
        Forking is safe — cache pytrees are immutable and extend/truncate
        return fresh dicts, so the parent's entry is never mutated."""
        sess = self.sessions.get(dialogue_id)
        if not parents:
            return sess
        best = self._session_hit(prompt, sess) if sess is not None else 0
        for pid in parents:
            ps = self.sessions.get(pid)
            if ps is not None and self._session_hit(prompt, ps) > best:
                best, sess = self._session_hit(prompt, ps), ps
        return sess

    # ---------------- serving ----------------
    def serve(self, dialogue_id: str, prompt: np.ndarray, now: float = 0.0,
              max_new_tokens: int | None = None,
              parents: tuple = ()) -> ServeResult:
        """Serve one request: cache-aware prefill/extend + greedy decode,
        measuring TTFT/total wall-clock (scaled by agent speed) and exact
        cached-token counts.  ``parents`` names sibling session keys whose
        cached state may be forked (DAG handoffs); the result is stored
        under ``dialogue_id`` regardless."""
        prompt = np.asarray(prompt, dtype=np.int32)
        n_prompt = len(prompt)
        max_new = max_new_tokens or self.max_new
        sess = self._pick_session(dialogue_id, prompt, parents)

        n_hit = 0
        mode = "fresh"
        if sess is not None:
            l = lcp_length(prompt, sess.prompt)
            if self.recurrent:
                if l == len(sess.prompt) and l <= n_prompt:
                    n_hit, mode = l, "extend"
            else:
                if l == n_prompt and l == len(sess.prompt):
                    n_hit, mode = l, "identical"
                elif l > 0:
                    n_hit, mode = l, "extend"

        t0 = time.perf_counter()
        if mode == "identical":
            # nothing to prefill; just decode from current state
            cache = sess.cache
            last_tok = jnp.asarray(prompt[-1:][None])  # placeholder
            logits, _ = self._decode_noop(cache)
            jax.block_until_ready(logits)
            t_first = time.perf_counter()
        elif mode == "extend" and n_hit < n_prompt:
            suffix = prompt[n_hit:]
            if self.recurrent:
                # recurrent state cannot mask padding: exact-length extend
                # (jit specializes per suffix length; lengths are few)
                pad, eff = suffix, len(suffix)
            else:
                b = _bucket(len(suffix))
                pad = np.zeros(b, np.int32)
                pad[: len(suffix)] = suffix
                eff = len(suffix)
            cache = sess.cache
            if not self.recurrent:
                cache = self._truncate_attn_cache(cache, n_hit)
            logits, cache = self._extend_j(
                self.params, cache, jnp.asarray(pad[None]),
                jnp.asarray([eff], jnp.int32))
            jax.block_until_ready(logits)
            t_first = time.perf_counter()
        elif mode == "extend":
            cache = sess.cache
            if not self.recurrent:
                cache = self._truncate_attn_cache(cache, n_hit)
            logits, _ = self._decode_noop(cache)
            jax.block_until_ready(logits)
            t_first = time.perf_counter()
        else:
            if self.recurrent:
                pad, eff = prompt, n_prompt
            else:
                b = _bucket(n_prompt)
                pad = np.zeros(b, np.int32)
                pad[:n_prompt] = prompt
                eff = n_prompt
            batch = {"tokens": jnp.asarray(pad[None]),
                     "lens": jnp.asarray([eff], jnp.int32)}
            if self.cfg.is_encdec:
                batch["frames"] = jnp.zeros((1, self.cfg.src_len,
                                             self.cfg.d_model), jnp.float32)
            logits, cache = self._prefill_j(self.params, batch)
            jax.block_until_ready(logits)
            t_first = time.perf_counter()
            n_hit = 0

        # greedy decode
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(max_new):
            out.append(int(tok[0]))
            logits, cache = self._decode_j(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(logits)
        t_end = time.perf_counter()

        gen = np.array(out, dtype=np.int32)
        # store the state covering prompt + generated answer (next turn will
        # extend past it, mirroring vLLM prefix caching)
        full = np.concatenate([prompt, gen])
        self.sessions[dialogue_id] = SessionCache(cache, full, last_used=now)
        self._evict_lru(now)

        ttft = (t_first - t0) / self.speed
        total = (t_end - t0) / self.speed
        return ServeResult(gen, ttft, total, n_prompt, min(n_hit, n_prompt),
                           len(gen))

    def _decode_noop(self, cache):
        """Cheap logits for the 'everything cached' path: one decode step on
        the BOS-free cache without committing its state."""
        tok = jnp.zeros((cache["pos"].shape[0],), jnp.int32)
        return self._decode_j(self.params, cache, tok)

    def drop_session(self, dialogue_id: str) -> None:
        """Forget one dialogue's cached state."""
        self.sessions.pop(dialogue_id, None)
