"""Event-driven, open-loop serving simulator for 100+-agent scale runs.

`repro.serving.cluster.run_workload` is a closed-loop, fixed-population
round loop: the whole dialogue population is pre-materialized into one
``state`` dict and the clock ticks in fixed ``round_dt`` steps whether or
not anything happens.  That is the right *oracle* for small bit-comparable
runs, but it cannot express the paper's system-level regime — sustained
many-to-many load at 100+ agents and 10k dialogues, where arrivals are an
open-loop process and routing overhead must be attributed against engine
compute.  This module replaces it for scale runs:

  * **event queue** — a single heap carries dialogue ARRIVAL events (from a
    Poisson/trace `repro.serving.workload.ArrivalProcess`) and ROUTE
    (router-invocation) events; engine completions stay in the cluster's
    own completion heap and the simulator jumps the virtual clock straight
    to the next of the three (``SimCluster.next_completion_time`` /
    ``advance_to`` hooks) — no empty rounds are ever spun.
  * **streaming admission** — dialogue scripts are pulled lazily from an
    iterator (`repro.serving.workload.iter_dialogues`) one arrival at a
    time, and at most ``max_inflight`` dialogues hold state concurrently;
    the rest wait in an admission backlog.  10k dialogues flow through a
    bounded window instead of one pre-built dict.
  * **`RoutingProfiler`** — attributes real wall-clock per routing phase
    (Phase-1 predict, Phase-2 solve per backend, the cross-hub spill round,
    price-book ops, Phase-4 feedback) against *simulated engine compute*
    (the virtual busy-seconds the engines report), so
    `benchmarks/serving_scale.py` can report where routing overhead crosses
    10% of engine compute as n_agents and batch size grow.

Workflow DAGs: alongside linear `DialogueScript` turns, the simulator
drives `repro.serving.workload.DagScript` task graphs — a step becomes
ready only when ALL its parent steps have completed, its prompt is the
concatenation of its parents' contexts (their prompt + generated output,
ascending step order) followed by its own instruction tokens, and sibling
steps dispatch concurrently.  Each step routes under its own session key
(``meta["session"] = "<dialogue>#s<step>"``) with its parents' session
keys in ``meta["parent_sessions"]``, which is what lets the router's
precedence-aware affinity and the engines' cache fork reuse the producer's
KV prefix across the handoff.

Closed-loop parity: with ``quantize=round_dt`` the ROUTE events fall on the
exact round boundaries of ``run_workload`` and completions are delivered at
those boundaries only — under `SyncArrivals` the simulator then reproduces
``run_workload``'s decisions bit-for-bit (tests/test_simulator.py), which
keeps the old loop useful as the oracle while this one owns the scale runs.
"""
from __future__ import annotations

import heapq
import math
import time
import warnings
from collections import Counter, deque
from contextlib import contextmanager
from dataclasses import dataclass, field

import numpy as np

from repro.core.mechanism import CompletionObs, Request
from repro.serving.workload import (ArrivalProcess, DagScript, DialogueScript,
                                    SyncArrivals)
from repro.utils.timing import phase_scope

# heap-event kinds; completions live in the cluster's heap.  ARRIVAL <
# MIGRATE < ROUTE so same-instant arrivals and migration hand-offs are
# admitted before the batch is formed.
_ARRIVAL, _MIGRATE, _ROUTE = 0, 1, 2
_EMPTY = np.zeros(0, np.int32)


class RoutingProfiler:
    """Wall-clock-per-phase accounting against simulated engine compute.

    The router and cluster wrap their sections in ``phase(name)`` (no-ops
    until a profiler is attached): ``route_batch`` is the umbrella around
    one router invocation, inside which the IEMAS router nests
    ``phase1_predict``, ``price_book``, ``phase2_solve[<backend>]`` and
    ``phase2_spill``; ``phase4_feedback`` wraps completion feedback.  The
    cluster reports each dispatch's virtual engine seconds through
    ``add_engine_compute``.  ``report()`` divides the top-level routing
    wall-clock (``route_batch`` + ``phase4_feedback`` — nested phases are
    *inside* the umbrella and not double-counted) by the engine compute to
    give the routing-overhead fraction the scale benchmark tables.
    """

    #: top-level (non-nested) phases whose sum is "routing overhead"
    TOP_PHASES = ("route_batch", "phase4_feedback")

    def __init__(self):
        self.phases: dict[str, float] = {}
        self.calls: dict[str, int] = {}
        self.engine_compute = 0.0   # virtual engine busy seconds
        self.route_requests = 0     # requests seen across route_batch calls
        self.empty_route_calls = 0  # route_batch invocations with 0 requests
        # fused routing step counters (core/routing_fused.py): device->host
        # materialization boundaries, syncs that fired BEFORE decisions
        # materialized (must stay 0 — the no-mid-sync contract), and fused
        # jit-cache growth (the pow-2 retrace bound)
        self.fused_host_transfers = 0
        self.fused_mid_syncs = 0
        self.fused_retraces = 0

    @contextmanager
    def phase(self, name: str):
        """Time one section under ``name`` (re-entrant safe, additive)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.phases[name] = self.phases.get(name, 0.0) + dt
            self.calls[name] = self.calls.get(name, 0) + 1

    def add_engine_compute(self, seconds: float) -> None:
        """Accumulate one dispatch's simulated engine seconds."""
        self.engine_compute += float(seconds)

    def note_route_batch(self, n_requests: int) -> None:
        """Record one router invocation's batch size (called by the router).

        ``n_requests == 0`` flags a wasted invocation — the event loop is
        expected to never fire the router without work (EventSimulator's
        empty-round guard), so ``empty_route_calls`` staying at 0 is a
        regression-tested invariant.
        """
        self.route_requests += int(n_requests)
        if n_requests == 0:
            self.empty_route_calls += 1

    def note_fused_step(self, host_transfers: int = 0, mid_syncs: int = 0,
                        retraces: int = 0) -> None:
        """Record one fused routing step's host-boundary accounting.

        Called by `repro.core.routing_fused.FusedRoutingStep` after its
        single materialization: ``host_transfers`` counts device->host
        boundaries (exactly one per fused batch), ``mid_syncs`` counts any
        sync performed before RouteDecisions materialized (zero by
        construction — a nonzero value means the fused program was split),
        and ``retraces`` is the fused jit-cache growth since the last step
        (bounded by the pow-2 shape buckets).
        """
        self.fused_host_transfers += int(host_transfers)
        self.fused_mid_syncs += int(mid_syncs)
        self.fused_retraces += int(retraces)

    def attach(self, cluster, router) -> "RoutingProfiler":
        """Hook this profiler into a cluster + router pair; returns self."""
        cluster.profiler = self
        router.profiler = self
        return self

    def routing_wall(self) -> float:
        """Total top-level routing wall-clock seconds."""
        return sum(self.phases.get(p, 0.0) for p in self.TOP_PHASES)

    def report(self) -> dict:
        """JSON-friendly attribution table (fractions of engine compute).

        With zero engine compute (e.g. every dispatch failed) the fractions
        are undefined and reported as ``None`` — strict-JSON safe, unlike
        ``inf``.
        """
        ec = self.engine_compute
        routing = self.routing_wall()
        return {
            "engine_compute_s": ec,
            "routing_wall_s": routing,
            "overhead_frac": (routing / ec) if ec > 0 else None,
            "route_requests": self.route_requests,
            "empty_route_calls": self.empty_route_calls,
            "fused": {
                "host_transfers": self.fused_host_transfers,
                "mid_pipeline_syncs": self.fused_mid_syncs,
                "retraces": self.fused_retraces,
            },
            "phases": {
                name: {
                    "wall_s": wall,
                    "calls": self.calls.get(name, 0),
                    "frac_of_engine": (wall / ec) if ec > 0 else None,
                }
                for name, wall in sorted(self.phases.items())
            },
        }


@dataclass
class _Dialogue:
    """In-flight dialogue state (exists only between admission and finish).

    Linear scripts use ``turn``/``history``/``pending``/``busy``; DAG
    scripts (`DagScript`) instead track per-step state: a step's prompt is
    built the moment its last parent completes (concatenated parent
    contexts + the step's own tokens), ``waiting`` counts incomplete
    parents per step, ``inflight`` holds dispatched step ids (several may
    run concurrently), and the dialogue finishes when ``remaining`` hits 0.
    """

    script: DialogueScript | DagScript
    arrived_at: float
    turn: int = 0
    history: np.ndarray = field(default_factory=lambda: _EMPTY)
    pending: np.ndarray | None = None   # next user turn awaiting dispatch
    busy: bool = False
    ready_since: float = 0.0
    # ---- DAG-mode fields (unused for linear scripts) ----
    step_prompt: dict = field(default_factory=dict)   # step -> prompt tokens
    step_ctx: dict = field(default_factory=dict)      # step -> prompt+output
    step_ready_since: dict = field(default_factory=dict)
    waiting: dict = field(default_factory=dict)       # step -> open parents
    children: dict = field(default_factory=dict)      # step -> child steps
    inflight: set = field(default_factory=set)        # dispatched step ids
    remaining: int = 0                                # steps not yet done
    migrations: int = 0   # cross-super-hub hand-offs this dialogue survived


class ShardEventLoop:
    """Open-loop event-driven serving driver (see module docstring).

    This class is the reusable *shard* event loop: one heap, one clock,
    one ready deque, one admission window over ONE ``(cluster, router)``
    pair.  `EventSimulator` (the public single-heap simulator) is a thin
    subclass that treats the whole fleet as a single shard;
    `repro.serving.federation.FederatedSimulator` composes S of these —
    one per super-hub — and advances them independently between
    synchronization epochs via `advance_until`.

    Parameters
    ----------
    cluster, router : the `SimCluster` + router pair to drive.
    dialogues : iterable of `DialogueScript` / `DagScript` — consumed
        lazily, one script per arrival (pass
        `repro.serving.workload.iter_dialogues` output for streaming scale
        runs); DAG scripts run their steps under precedence constraints.
    arrivals : `ArrivalProcess` pacing dialogue arrivals (default: all at
        t=0, the closed-loop population).
    batch_cap : max requests per router invocation (micro-batch size).
    batch_window : seconds a ROUTE event waits after work appears, letting
        a micro-batch accumulate (also the retry pacing for unmatched
        requests).  Ignored when ``quantize`` is set.
    quantize : when set, ROUTE events tick on exact multiples of this
        round length and completions are delivered only at those
        boundaries — the bit-comparable ``run_workload`` lockstep regime.
    incremental : when True, a dialogue that becomes ready (arrival or
        next turn) is first offered to ``router.route_incremental`` — a
        greedy posted-price bid against the standing warm-start duals —
        and dispatched IMMEDIATELY on success instead of waiting out the
        batch window; the next batch auction re-equilibrates the
        provisional routes (see `repro.core.mechanism.IEMASRouter`).
        Dialogues the posted-price pass declines fall back to the normal
        batch path unchanged.  Requires a router exposing
        ``route_incremental`` (and warm starts for any effect).
    max_inflight : admission-window bound on concurrently-active dialogues
        (None = unbounded, required for closed-loop parity).
    max_new_tokens : generation budget per request.
    profiler : optional `RoutingProfiler`; attached to cluster + router.
    max_rounds : router-invocation budget (mirrors ``run_workload``'s
        ``max_rounds``); exceeding it truncates the run with a warning.
    max_events : hard safety cap on processed events.
    horizon : optional virtual-time cap; reaching it truncates the run.
    lean : drop per-request token arrays once a completion is fully
        processed (bounds memory on 10k-dialogue runs; decisions are
        unaffected — the ledger/engines hold their own copies).
    on_round : optional callback ``(n_rounds, cluster)`` after each ROUTE.
    rid_prefix : prepended to every request id (``"s3:r17"``); federated
        shards pass ``"s{k}:"`` so ids stay globally unique across shard
        ledgers.  The default ``""`` keeps the historical ``r{N}`` ids
        (and thereby ledger-head parity) for single-heap runs.
    external_arrivals : when True the loop never pulls from ``dialogues``
        or ``arrivals`` itself — a parent driver feeds arrivals through
        `inject_arrival` and signals end-of-stream via `close_arrivals`
        (the `FederatedSimulator` S>1 partitioning mode).
    """

    def __init__(self, cluster, router, dialogues, *,
                 arrivals: ArrivalProcess | None = None,
                 batch_cap: int = 16, batch_window: float = 0.02,
                 quantize: float | None = None,
                 incremental: bool = False,
                 max_inflight: int | None = None,
                 max_new_tokens: int = 6,
                 profiler: RoutingProfiler | None = None,
                 max_rounds: int = 100_000,
                 max_events: int = 5_000_000,
                 horizon: float | None = None,
                 lean: bool = False,
                 on_round=None,
                 rid_prefix: str = "",
                 external_arrivals: bool = False):
        self.cluster = cluster
        self.router = router
        self.arrivals = arrivals if arrivals is not None else SyncArrivals()
        self.batch_cap = int(batch_cap)
        self.batch_window = float(batch_window)
        self.quantize = quantize
        self.incremental = bool(incremental) and \
            hasattr(router, "route_incremental")
        self.n_incremental = 0
        self.max_inflight = max_inflight
        self.max_new_tokens = max_new_tokens
        self.profiler = profiler
        if profiler is not None:
            profiler.attach(cluster, router)
        self.max_rounds = max_rounds
        self.max_events = max_events
        self.horizon = horizon
        self.lean = lean
        self.on_round = on_round
        self.rid_prefix = str(rid_prefix)
        self._external = bool(external_arrivals)

        if max_inflight is not None and max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.states: dict[str, _Dialogue] = {}
        # FIFO of ready work units: (dialogue_id, step_id) — step_id is None
        # for linear-dialogue turns, a DAG step id otherwise
        self.ready: deque[tuple] = deque()
        self.backlog: deque[DialogueScript] = deque()
        # per-dialogue dispatch attribution (includes fault-path retries)
        self.dispatch_count: Counter[str] = Counter()
        self.n_dispatched = 0
        self._events: list = []               # (time, kind, seq, payload)
        self._seq = 0
        self._rid = 0
        self._rounds = 0
        self._n_processed = 0
        self._route_at: float | None = None
        self._dialogue_iter = iter(dialogues)
        self._arrival_times = self.arrivals.times()
        self._arrivals_open = True
        self._truncated_reason: str | None = None
        self._started = False
        self._stopped = False
        self._wall0 = 0.0
        # aggregates (bounded memory — no per-dialogue lists)
        self.n_arrived = 0
        self.peak_inflight = 0
        self.n_completed_dialogues = 0
        self.migrated_in = 0
        self.migrated_out = 0
        self._dlg_latency_sum = 0.0
        self._wait_sum = 0.0
        self._wait_n = 0

    # ---------------- event scheduling ----------------
    def _push(self, t: float, kind: int, payload=None) -> None:
        heapq.heappush(self._events, (t, kind, self._seq, payload))
        self._seq += 1

    def _schedule_next_arrival(self) -> None:
        if self._external or not self._arrivals_open:
            return      # federation mode: the parent feeds inject_arrival
        script = next(self._dialogue_iter, None)
        if script is None:
            self._arrivals_open = False
            return
        t = next(self._arrival_times, None)
        if t is None:
            # zip semantics (see ArrivalProcess): a finite trace shorter
            # than the dialogue stream ends the arrivals — but loudly
            self._arrivals_open = False
            self._truncated_reason = "arrival process exhausted before " \
                "the dialogue stream"
            return
        t = max(float(t), 0.0)
        if self.quantize is not None:
            # lockstep contract: everything happens on round boundaries
            q = self.quantize
            t = math.ceil(t / q - 1e-9) * q
        self._push(t, _ARRIVAL, script)

    def _schedule_route(self, t: float) -> None:
        if self._route_at is None or t < self._route_at:
            self._push(t, _ROUTE)
            self._route_at = t

    def _next_time(self) -> float | None:
        cand = []
        if self._events:
            cand.append(self._events[0][0])
        if self.quantize is None:
            tc = self.cluster.next_completion_time()
            if tc is not None:
                cand.append(max(tc, self.cluster.now))
        return min(cand) if cand else None

    def _work_remains(self) -> bool:
        return bool(self._arrivals_open or self.backlog or self.ready
                    or self.states)

    # ---------------- federation hooks (external arrivals + migration) ----
    def inject_arrival(self, t: float, script) -> None:
        """Driver-fed arrival (``external_arrivals`` mode): push one ARRIVAL.

        Mirrors `_schedule_next_arrival`'s normalization (clamp to >= 0,
        quantize rounds up to the next boundary) so a parent driver
        partitioning one global arrival stream across shards preserves
        single-heap arrival semantics: same-time arrivals keep stream
        order (heap seq), and ARRIVAL still sorts before same-instant
        ROUTE ticks.
        """
        t = max(float(t), 0.0)
        if self.quantize is not None:
            q = self.quantize
            t = math.ceil(t / q - 1e-9) * q
        self._push(t, _ARRIVAL, script)

    def close_arrivals(self) -> None:
        """Signal end of the parent's global dialogue stream (federation).

        Already-injected ARRIVAL events still process; this only lets the
        loop's termination/truncation logic know no further work will be
        fed, exactly like the internal iterator drying up.
        """
        self._arrivals_open = False

    def residual_units(self, now: float, min_wait: float,
                       max_migrations: int = 2) -> list[dict]:
        """Dialogues stuck in this shard's ready queue >= ``min_wait``.

        A dialogue qualifies when it has NO in-flight engine work (the
        migration precondition — a completion racing the hand-off would
        settle twice) and its longest-waiting ready unit has queued at
        least ``min_wait`` virtual seconds.  Returns one summary row per
        dialogue (domain, difficulty, queued-unit count, max wait, and
        the stuck unit's prompt length — the cost driver for a remote
        bid); the federation prices these rows against gossiped remote
        capacity.  ``max_migrations`` stops spill ping-pong: a dialogue
        that already migrated that many times stays put.
        """
        agg: dict[str, dict] = {}
        for did, step in self.ready:
            st = self.states.get(did)
            if st is None or st.busy or st.inflight or \
                    st.migrations >= max_migrations:
                continue
            if step is None:
                since = st.ready_since
                plen = len(st.history) + len(st.pending)
            else:
                since = st.step_ready_since[step]
                plen = len(st.step_prompt[step])
            waited = now - since
            row = agg.setdefault(did, {
                "dialogue_id": did, "domain": st.script.domain,
                "difficulty": st.script.difficulty, "units": 0,
                "waited": waited, "prompt_len": plen})
            row["units"] += 1
            if waited > row["waited"]:
                row["waited"], row["prompt_len"] = waited, plen
        return [r for r in agg.values() if r["waited"] >= min_wait]

    def extract_dialogue(self, did: str) -> _Dialogue:
        """Surrender one dialogue's session state for migration.

        Only dialogues with no in-flight work may leave (enforced);
        every queued ready unit is withdrawn with it.  The arrived count
        and dispatch attribution stay on this shard — exactly-once
        accounting counts an arrival where it was admitted and a
        completion wherever the dialogue finishes.  Vacating the window
        slot admits from the backlog, same as a local finish.
        """
        st = self.states.pop(did)
        if st.busy or st.inflight:
            self.states[did] = st       # restore before failing loudly
            raise RuntimeError(f"cannot migrate {did!r}: in-flight work")
        self.ready = deque(k for k in self.ready if k[0] != did)
        self.migrated_out += 1
        st.migrations += 1
        if self.backlog:
            self._admit(self.backlog.popleft())
        return st

    def admit_migrant(self, st: _Dialogue, t: float) -> None:
        """Schedule adoption of a migrated dialogue at virtual time ``t``.

        Queued as a MIGRATE event (admitted before any same-instant ROUTE
        tick) so shard clocks are never touched at the hand-off — epoch
        boundaries stay pure pauses and S=1 federation parity holds.
        """
        self._push(max(float(t), 0.0), _MIGRATE, st)

    def _admit_migrant(self, st: _Dialogue) -> None:
        """Adopt a migrated dialogue's state (cross-super-hub hand-off).

        The dialogue was counted as arrived on its home shard, so
        ``n_arrived`` is untouched; its ready units re-enter this queue
        with fresh wait clocks (remote placement starts a new queueing
        episode) and bid incrementally like any local admission.
        Migrants bypass the ``max_inflight`` window — they were admitted
        globally on their home shard, and parking them in the local
        backlog could strand a dialogue behind a shard that never
        drains.
        """
        now = self.cluster.now
        self.migrated_in += 1
        did = st.script.dialogue_id
        self.states[did] = st
        self.peak_inflight = max(self.peak_inflight, len(self.states))
        if isinstance(st.script, DagScript):
            # ready = prompt built, not completed (migration precondition
            # already guarantees nothing is in flight)
            for sid in sorted(st.step_prompt):
                if sid in st.step_ctx:
                    continue
                st.step_ready_since[sid] = now
                self.ready.append((did, sid))
                self._try_incremental()
            return
        st.ready_since = now
        self.ready.append((did, None))
        self._try_incremental()

    # ---------------- dialogue lifecycle ----------------
    def _admit(self, script) -> None:
        now = self.cluster.now
        if isinstance(script, DagScript):
            st = _Dialogue(script, arrived_at=now,
                           remaining=len(script.steps))
            for s in script.steps:
                st.waiting[s.step_id] = len(s.parents)
                for p in s.parents:
                    st.children.setdefault(p, []).append(s.step_id)
            self.states[script.dialogue_id] = st
            self.peak_inflight = max(self.peak_inflight, len(self.states))
            # roots have no parents: ready (and bidding) immediately
            for s in script.steps:
                if not s.parents:
                    st.step_prompt[s.step_id] = s.tokens.astype(np.int32)
                    st.step_ready_since[s.step_id] = now
                    self.ready.append((script.dialogue_id, s.step_id))
                    self._try_incremental()
            return
        self.states[script.dialogue_id] = _Dialogue(
            script, arrived_at=now, pending=script.turns[0], ready_since=now)
        self.peak_inflight = max(self.peak_inflight, len(self.states))
        self.ready.append((script.dialogue_id, None))
        self._try_incremental()

    def _on_arrival(self, script: DialogueScript) -> None:
        self.n_arrived += 1
        if self.max_inflight is not None and \
                len(self.states) >= self.max_inflight:
            self.backlog.append(script)     # admission window full: wait
        else:
            self._admit(script)

    def _finish_dialogue(self, did: str, now: float) -> None:
        """Release a finished dialogue's state and admit from the backlog."""
        st = self.states[did]
        self.n_completed_dialogues += 1
        self._dlg_latency_sum += now - st.arrived_at
        del self.states[did]
        if self.backlog:
            self._admit(self.backlog.popleft())

    def _handle_completions(self, t: float) -> None:
        done = self.cluster.advance_to(t, self.router)
        now = self.cluster.now
        for rec in done:
            did = rec.request.dialogue_id
            st = self.states[did]
            step = rec.request.meta.get("step_id")
            if step is not None:
                self._complete_step(st, did, step, rec, now)
                continue
            st.busy = False
            if rec.failed:
                # retry keeps the ORIGINAL ready time: the turn has been
                # waiting since it first became ready, and resetting the
                # clock here under-reported queueing wait across retries
                self.ready.append((did, None))  # re-issue the same turn
                self._try_incremental()
                continue
            st.history = np.concatenate(
                [st.history, st.pending, rec.output_tokens]).astype(np.int32)
            st.turn += 1
            if self.lean:
                rec.request.tokens = _EMPTY
                rec.output_tokens = _EMPTY
            if st.turn < len(st.script.turns):
                st.pending = st.script.turns[st.turn]
                st.ready_since = now
                self.ready.append((did, None))
                self._try_incremental()
            else:
                self._finish_dialogue(did, now)

    def _complete_step(self, st: _Dialogue, did: str, step: int, rec,
                       now: float) -> None:
        """One DAG step finished (or failed): update precedence state.

        On success the step's context (prompt + generated output) is
        recorded; every child whose last open parent this was gets its
        prompt built — concatenated parent contexts in ascending step order,
        then the child's own tokens — and becomes ready.  On failure the
        step re-queues with its original ready time (same wait-clock
        contract as linear retries).
        """
        st.inflight.discard(step)
        if rec.failed:
            self.ready.append((did, step))
            self._try_incremental()
            return
        st.step_ctx[step] = np.concatenate(
            [st.step_prompt[step], rec.output_tokens]).astype(np.int32)
        st.remaining -= 1
        if self.lean:
            rec.request.tokens = _EMPTY
            rec.output_tokens = _EMPTY
        for c in st.children.get(step, ()):
            st.waiting[c] -= 1
            if st.waiting[c] == 0:
                s = st.script.steps[c]
                st.step_prompt[c] = np.concatenate(
                    [st.step_ctx[p] for p in sorted(s.parents)]
                    + [s.tokens]).astype(np.int32)
                st.step_ready_since[c] = now
                self.ready.append((did, c))
                self._try_incremental()
        if st.remaining == 0:
            self._finish_dialogue(did, now)

    # ---------------- routing ----------------
    def _build_request(self, key: tuple) -> Request:
        """Materialize the Request for one ready unit ``(did, step)``,
        consuming a fresh request id.

        Id contract: every built request burns its ``r{N}`` id — including
        incremental offers that end up deferred or dead-dispatched — so a
        dispatched id is NEVER re-issued to a different request and
        router/profiler state keyed by request_id cannot collide.  DAG
        steps carry their handoff metadata here: ``session`` (the step's
        own ledger/engine key), ``parent_sessions`` (precedence-aware
        affinity + engine cache fork), ``step_id`` and ``role``.
        """
        did, step = key
        st = self.states[did]
        if step is None:
            prompt = np.concatenate([st.history, st.pending])
            turn, domain = st.turn, st.script.domain
            meta = {"difficulty": st.script.difficulty}
        else:
            s = st.script.steps[step]
            prompt = st.step_prompt[step]
            turn, domain = step, s.domain
            meta = {"difficulty": st.script.difficulty,
                    "session": f"{did}#s{step}",
                    "parent_sessions": tuple(f"{did}#s{p}"
                                             for p in sorted(s.parents)),
                    "step_id": step, "role": s.role}
        req = Request(
            request_id=f"{self.rid_prefix}r{self._rid}", dialogue_id=did,
            tokens=prompt.astype(np.int32), turn=turn, domain=domain,
            max_new_tokens=self.max_new_tokens, meta=meta)
        self._rid += 1
        return req

    def _note_dispatch(self, st: _Dialogue, did: str, step) -> None:
        """Shared dispatch bookkeeping: busy/inflight + wait accounting."""
        if step is None:
            st.busy = True
            since = st.ready_since
        else:
            st.inflight.add(step)
            since = st.step_ready_since[step]
        self.dispatch_count[did] += 1
        self.n_dispatched += 1
        self._wait_sum += self.cluster.now - since
        self._wait_n += 1

    def _try_incremental(self) -> None:
        """Offer the just-readied work unit a provisional posted-price route.

        Called right after a unit is appended to ``ready``; on success the
        request dispatches immediately (its batch-window wait collapses
        to zero) and the unit is removed from the queue — the next
        batch auction re-equilibrates it as a shadow participant.  On any
        miss (stale/absent duals, no profitable unit, dead dispatch target)
        the unit simply stays queued for the batch path; its request id is
        burned, not recycled (see `_build_request`).
        """
        if not self.incremental or not self.ready:
            return
        cluster, router = self.cluster, self.router
        did, step = key = self.ready[-1]
        st = self.states[did]
        req = self._build_request(key)
        telem = cluster.telemetry.snapshot(cluster.now)
        free = cluster.free_slots()
        with phase_scope(self.profiler, "route_incremental"):
            dec = router.route_incremental([req], telem, free_slots=free)[0]
        if dec.agent_id is None:
            return                      # deferred to the next batch auction
        if cluster.execute(dec, router) is None:
            # dead dispatch target: fault-path feedback (quarantine +
            # pending/provisional cleanup); the unit stays queued
            router.on_complete(dec.request.request_id, CompletionObs(
                0.0, len(dec.request.tokens), 0, 0, 0.0, failed=True))
            return
        self.ready.pop()
        self._note_dispatch(st, did, step)
        self.n_incremental += 1

    def _route_step(self) -> None:
        cluster, router = self.cluster, self.router
        batch = []
        while self.ready and len(batch) < self.batch_cap:
            batch.append(self._build_request(self.ready.popleft()))
        if not batch:
            return
        telem = cluster.telemetry.snapshot(cluster.now)
        free = cluster.free_slots()
        with phase_scope(self.profiler, "route_batch"):
            decisions = router.route_batch(batch, telem, free_slots=free)
        unmatched = []
        for dec in decisions:
            did = dec.request.dialogue_id
            step = dec.request.meta.get("step_id")
            if dec.agent_id is None:
                unmatched.append((did, step))
                continue
            if cluster.execute(dec, router) is None:
                # dead dispatch target: fault-path feedback (quarantine +
                # pending cleanup) so the router stops matching it — same
                # handling as run_workload (parity contract)
                router.on_complete(dec.request.request_id, CompletionObs(
                    0.0, len(dec.request.tokens), 0, 0, 0.0, failed=True))
                unmatched.append((did, step))
                continue
            self._note_dispatch(self.states[did], did, step)
        # unmatched requests keep their queue priority, in order
        self.ready.extendleft(reversed(unmatched))

    # ---------------- main loop ----------------
    def start(self) -> None:
        """Idempotent initial scheduling (first arrival + quantize tick 0)."""
        if self._started:
            return
        self._started = True
        self._wall0 = time.perf_counter()
        self._schedule_next_arrival()
        if self.quantize is not None:
            self._schedule_route(0.0)

    def _truncate(self, reason: str) -> None:
        """Record a truncation and stop the loop for good (sticky)."""
        self._truncated_reason = reason
        self._stopped = True

    def advance_until(self, t_end: float | None) -> None:
        """Process every event at virtual time ``<= t_end``, then pause.

        The workhorse behind both `run` (``t_end=None``: run to
        completion/truncation) and `FederatedSimulator` epochs.  Pausing
        is pure — no clock is touched, no event reordered — so advancing
        in epoch segments replays the exact event sequence of one
        continuous run (the S=1 federation bit-parity contract).  Once a
        truncation fires the loop is stopped for good; further calls
        return immediately.
        """
        self.start()
        while not self._stopped:
            if self._n_processed >= self.max_events:
                self._truncate(f"max_events ({self.max_events})")
                break
            t = self._next_time()
            if t is None:
                if self._external and self._arrivals_open:
                    break       # idle shard: awaiting injected arrivals
                if self._work_remains():
                    # e.g. an admission window far smaller than the stream:
                    # arrivals drained with the backlog still populated —
                    # never exit silently with work on the floor
                    self._truncate("event queue drained with work remaining")
                break
            if t_end is not None and t > t_end:
                break           # next event lies beyond this epoch
            if self.horizon is not None and t > self.horizon:
                self._truncate(f"horizon ({self.horizon}s)")
                break
            self._handle_completions(t)
            run_route = False
            while self._events and self._events[0][0] <= t:
                _, kind, _, payload = heapq.heappop(self._events)
                self._n_processed += 1
                if kind == _ARRIVAL:
                    self._on_arrival(payload)
                    self._schedule_next_arrival()
                elif kind == _MIGRATE:
                    self._admit_migrant(payload)
                else:
                    self._route_at = None
                    run_route = True
            if run_route and self.ready:
                # ready-gated: a ROUTE tick with every dialogue busy (the
                # quantize regime fires one per round boundary regardless)
                # must not invoke the router on an empty batch, burn a
                # max_rounds unit, or fire on_round — empty rounds would
                # skew the rounds/overhead accounting and the profiler's
                # empty_route_calls invariant
                self._rounds += 1
                self._route_step()
                # strategic-agent round hook (repro.core.adversary): churn
                # policies flap membership here; a no-op without a mix, so
                # honest runs keep bit-exact lockstep parity vs run_workload
                tick = getattr(self.cluster, "adversary_tick", None)
                if tick is not None:
                    tick(self.router)
                if self.on_round is not None:
                    self.on_round(self._rounds, self.cluster)
                if self._rounds >= self.max_rounds:
                    self._truncate(f"max_rounds ({self.max_rounds})")
                    break
            # keep exactly one ROUTE event pending whenever work remains
            if self.quantize is not None:
                if self._route_at is None and self._work_remains():
                    self._schedule_route(self.cluster.now + self.quantize)
            elif self.ready and self._route_at is None:
                self._schedule_route(self.cluster.now + self.batch_window)

    def run(self) -> dict:
        """Run to completion (or truncation) and return the metrics dict."""
        self.start()
        self.advance_until(None)
        return self._finalize(time.perf_counter() - self._wall0)

    def _finalize(self, wall_s: float) -> dict:
        out = self.cluster.metrics()
        now = self.cluster.now
        out.update({
            "rounds": self._rounds,
            "events": self._n_processed,
            "sim_time_s": now,
            "wall_time_s": wall_s,
            "dialogues_arrived": self.n_arrived,
            "dialogues_completed": self.n_completed_dialogues,
            "peak_inflight": self.peak_inflight,
            "unfinished_dialogues": len(self.states) + len(self.backlog),
            "truncated": self._truncated_reason is not None,
            "dispatched_requests": self.n_dispatched,
            "incremental_dispatched": self.n_incremental,
            "migrated_in": self.migrated_in,
            "migrated_out": self.migrated_out,
        })
        # turns completed = completed request records (retries excluded)
        out["completed_turns"] = out.get("n", 0)
        if self.dispatch_count:
            out["requests_per_dialogue_mean"] = (
                self.n_dispatched / len(self.dispatch_count))
            out["requests_per_dialogue_max"] = max(self.dispatch_count.values())
        if self.n_completed_dialogues:
            out["dialogue_latency_mean_s"] = (
                self._dlg_latency_sum / self.n_completed_dialogues)
        if self._wait_n:
            out["queue_wait_mean_s"] = self._wait_sum / self._wait_n
        if now > 0:
            out["throughput_rps"] = out.get("n", 0) / now
            busy = self.cluster.telemetry.busy_seconds()
            out["utilization"] = busy / (now * max(1, len(self.cluster.agents)))
        if self._truncated_reason is not None:
            warnings.warn(
                f"{type(self).__name__}: truncated by "
                f"{self._truncated_reason} with "
                f"{out['unfinished_dialogues']} admitted/backlogged dialogues "
                f"unfinished (arrivals "
                f"{'still open' if self._arrivals_open else 'drained'}); "
                f"metrics cover completed requests only",
                RuntimeWarning, stacklevel=2)
        book = getattr(self.router, "price_book", None)
        if book is not None and getattr(self.router, "warm_start", False):
            out["warm_start"] = book.stats()
        if self.profiler is not None:
            out["routing"] = self.profiler.report()
        return out


class EventSimulator(ShardEventLoop):
    """The public single-heap simulator: the whole fleet as ONE shard.

    Pure façade — every knob and behavior lives in `ShardEventLoop`; this
    name is what launchers, benchmarks and the parity suite construct for
    non-federated runs, and what `FederatedSimulator(S=1)` must reproduce
    bit-for-bit.
    """


def simulate_workload(cluster, router, dialogues, *, profile: bool = True,
                      **kwargs) -> dict:
    """One-call convenience wrapper: build, (optionally) profile, run.

    ``kwargs`` pass through to `EventSimulator`; a fresh `RoutingProfiler`
    is attached unless ``profile=False`` or one was passed explicitly.
    """
    if profile and "profiler" not in kwargs:
        kwargs["profiler"] = RoutingProfiler()
    return EventSimulator(cluster, router, dialogues, **kwargs).run()
