from repro.serving.cluster import SimCluster, make_router, run_workload
from repro.serving.engine import AgentEngine, ServeResult
from repro.serving.evaluator import SimulatedSkillEvaluator, TokenSpanEvaluator
from repro.serving.telemetry import TelemetryTracker
from repro.serving.workload import WORKLOADS, DialogueScript, WorkloadSpec, generate
