"""Serving layer: engines, cluster, workloads, and the serving loops."""
from repro.serving.analytic import AnalyticEngine
from repro.serving.cluster import SimCluster, make_router, run_workload
from repro.serving.engine import AgentEngine, ServeResult
from repro.serving.evaluator import SimulatedSkillEvaluator, TokenSpanEvaluator
from repro.serving.federation import (FederatedSimulator, InlineShard,
                                      build_federation)
from repro.serving.simulator import (EventSimulator, RoutingProfiler,
                                     ShardEventLoop, simulate_workload)
from repro.serving.telemetry import TelemetryTracker
from repro.serving.workload import (DAG_WORKLOADS, WORKLOADS, ArrivalProcess,
                                    DagScript, DagStep, DialogueScript,
                                    PoissonArrivals, SyncArrivals,
                                    TraceArrivals, WorkloadSpec, generate,
                                    iter_dialogues, load_trace, make_arrivals,
                                    validate_dag)
