"""Analytic (virtual-time) serving engine for 100+-agent scale runs.

`repro.serving.engine.AgentEngine` runs REAL JAX compute per request —
physically honest, but a 128-agent / 10k-dialogue sweep would spend hours
of CPU inside reduced-model prefills and hold ~2 GB of per-engine params.
`AnalyticEngine` keeps the *semantics* the mechanism consumes — exact
per-dialogue prefix-cache accounting (identical / extend / fresh modes,
LRU eviction over ``cache_slots`` sessions, the same arch rules as the real
engine) — while service times come from a calibrated roofline model instead
of executing the matmuls:

    ttft          = (F0·layers + miss_tokens · f/R_prefill) / speed
    decode/token  = (D0 + f/R_decode) / speed

with ``f`` the per-token forward FLOPs of the agent's model class.  The
constants are calibrated against the real reduced engines on CPU (measured
2026-07: llama3-7b class ≈ 32 ms TTFT at 64 uncached tokens, ≈ 35 ms per
decoded token; qwen-4b ≈ 12 ms / 15 ms), so the "simulated engine compute"
the `RoutingProfiler` divides routing overhead by is on the same scale the
closed-loop oracle actually measures.

Determinism: times are pure functions of (prompt, cache state, speed) and
generated tokens are a hash of (dialogue, prompt length, position) — an
analytic cluster replays bit-identically under a fixed seed regardless of
wall-clock, which is what the simulator's event-ordering determinism suite
relies on.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np

from repro.configs.iemas_cluster import MODEL_CLASSES
from repro.core.affinity import lcp_length
from repro.serving.engine import ServeResult

# calibration constants (see module docstring): per-layer fixed prefill cost,
# per-step decode dispatch cost, effective prefill / decode FLOP rates
F0_PER_LAYER = 1.5e-3     # s of fixed prefill cost per layer
D0_DECODE = 2.0e-3        # s of fixed cost per decode step
R_PREFILL = 17.0e9        # FLOP/s during batched prefill
R_DECODE = 0.24e9         # FLOP/s during single-token decode


def class_flops_per_token(model_class: str) -> float:
    """Per-token forward FLOPs of one reduced model class (attn + MLP)."""
    n_layers, d_model, _n_heads, d_ff, _scale = MODEL_CLASSES[model_class]
    return float(n_layers * (8 * d_model**2 + 4 * d_model * d_ff))


@dataclass
class _Session:
    """Cached conversation state: the token sequence the cache encodes."""

    prompt: np.ndarray
    last_used: float = 0.0


class AnalyticEngine:
    """Drop-in `AgentEngine` stand-in with modeled (virtual) service times.

    Mirrors the real engine's public surface (``serve`` / ``warmup`` /
    ``drop_session`` / ``sessions`` / ``cache_slots`` / ``recurrent``) so
    `SimCluster` can swap it in via ``engine_mode="analytic"`` without the
    router or the serving loops noticing.
    """

    def __init__(self, model_class: str, *, vocab: int = 255, seed: int = 0,
                 speed: float = 1.0, cache_slots: int = 12,
                 max_new_tokens: int = 8):
        self.model_class = model_class
        self.vocab = vocab
        self.seed = seed
        self.speed = speed
        self.cache_slots = cache_slots
        self.max_new = max_new_tokens
        self.recurrent = False        # all scale-config classes are attention
        self.sessions: dict[str, _Session] = {}
        self.evictions = 0
        n_layers = MODEL_CLASSES[model_class][0]
        self._f = class_flops_per_token(model_class)
        self._t_fixed = F0_PER_LAYER * n_layers
        self._t_prefill_tok = self._f / R_PREFILL
        self._t_decode_tok = D0_DECODE + self._f / R_DECODE

    def warmup(self, *args, **kwargs) -> None:
        """No-op: the analytic engine has no jit caches to pre-compile."""

    def _evict_lru(self, now: float) -> None:
        while len(self.sessions) > self.cache_slots:
            victim = min(self.sessions, key=lambda k: self.sessions[k].last_used)
            del self.sessions[victim]
            self.evictions += 1

    def _gen_token(self, dialogue_id: str, n_prompt: int, k: int) -> int:
        """Deterministic pseudo-token: hash of (dialogue, prompt len, pos)."""
        h = zlib.crc32(f"{self.seed}:{dialogue_id}:{n_prompt}:{k}".encode())
        return int(h % self.vocab) + 1

    def serve(self, dialogue_id: str, prompt: np.ndarray, now: float = 0.0,
              max_new_tokens: int | None = None,
              parents: tuple = ()) -> ServeResult:
        """Modeled serve: real cache accounting, roofline service times.
        ``parents`` names DAG parent-step session keys whose cached prefix
        may be forked, mirroring the real engine's handoff path."""
        prompt = np.asarray(prompt, dtype=np.int32)
        n_prompt = len(prompt)
        max_new = max_new_tokens or self.max_new
        sess = self.sessions.get(dialogue_id)
        if parents:
            # fork the warmest candidate (attention: longest common prefix)
            best = lcp_length(prompt, sess.prompt) if sess is not None else 0
            for pid in parents:
                ps = self.sessions.get(pid)
                if ps is not None and lcp_length(prompt, ps.prompt) > best:
                    best, sess = lcp_length(prompt, ps.prompt), ps

        # cache semantics — identical to AgentEngine's attention path
        n_hit = 0
        if sess is not None:
            l = lcp_length(prompt, sess.prompt)
            if l == n_prompt and l == len(sess.prompt):
                n_hit = l                      # identical: nothing to prefill
            elif l > 0:
                n_hit = l                      # extend past the common prefix

        miss = n_prompt - n_hit
        if miss > 0:
            ttft = self._t_fixed + miss * self._t_prefill_tok
        else:
            ttft = self._t_decode_tok          # one probe step, like the oracle
        total = ttft + max_new * self._t_decode_tok

        gen = np.array([self._gen_token(dialogue_id, n_prompt, k)
                        for k in range(max_new)], dtype=np.int32)
        full = np.concatenate([prompt, gen])
        self.sessions[dialogue_id] = _Session(full, last_used=now)
        self._evict_lru(now)
        return ServeResult(gen, ttft / self.speed, total / self.speed,
                           n_prompt, min(n_hit, n_prompt), len(gen))

    def drop_session(self, dialogue_id: str) -> None:
        """Forget one dialogue's cached state (mirror of the real engine)."""
        self.sessions.pop(dialogue_id, None)
