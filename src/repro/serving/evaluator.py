"""Response-quality evaluation (ground truth for the performance predictor).

Two evaluators, mirroring Appendix C.2.5:
  * TokenSpanEvaluator — deterministic: does the gold token span appear as a
    contiguous subsequence of the output? (exact reproduction of the paper's
    TokenSpanCoqaEvaluator at token level).
  * SimulatedSkillEvaluator — the reduced CPU models generate noise, so the
    benchmark quality signal is drawn from a (domain x agent-scale) skill
    matrix modulated by request difficulty. This preserves the statistical
    structure the predictor must learn (documented in DESIGN.md §8).
"""
from __future__ import annotations

import numpy as np


class TokenSpanEvaluator:
    """Deterministic span-match evaluator (paper's TokenSpanCoqaEvaluator)."""

    def score(self, output_tokens, gold_tokens) -> float:
        """1.0 iff the gold span occurs contiguously in the output."""
        o = np.asarray(output_tokens)
        g = np.asarray(gold_tokens)
        if len(g) == 0 or len(o) < len(g):
            return 0.0
        for s in range(len(o) - len(g) + 1):
            if np.array_equal(o[s : s + len(g)], g):
                return 1.0
        return 0.0


class SimulatedSkillEvaluator:
    """P(correct) = sigmoid(a*scale + b*domain_match - c*difficulty)."""

    def __init__(self, seed: int = 0, a=0.18, b=1.2, c=2.2, bias=0.2):
        self.rng = np.random.default_rng(seed)
        self.a, self.b, self.c, self.bias = a, b, c, bias

    def prob_correct(self, agent_scale: float, domain_match: bool,
                     difficulty: float) -> float:
        """Correctness probability from the (scale, domain, difficulty) skill model."""
        z = (self.a * agent_scale + self.b * float(domain_match)
             - self.c * difficulty + self.bias)
        return float(1.0 / (1.0 + np.exp(-z)))

    def score(self, agent_scale: float, domain_match: bool,
              difficulty: float) -> float:
        """One Bernoulli quality draw at ``prob_correct``."""
        return float(self.rng.random()
                     < self.prob_correct(agent_scale, domain_match, difficulty))
