"""Hubs-of-hubs federation: S super-hub shards + epoch-synchronized markets.

One level above `EventSimulator`: the fleet is partitioned into S
super-hubs (`repro.core.hub.cluster_super_hubs`), each owning its own
`IEMASRouter` (with its own inner proxy hubs and `SlotPriceBook`), its
own `SimCluster` shard of the agent fleet, and its own independently-
advancing `ShardEventLoop` event heap.  `FederatedSimulator` drives the
shards through synchronization **epochs**:

  1. **advance** — every shard processes its own events up to the epoch
     boundary, with no cross-shard communication (this is what the
     process-parallel path overlaps across cores —
     `repro.distributed.federation.ProcessShardHandle`);
  2. **gossip** — each shard cuts a `GossipDigest` (per-agent free
     slack + standing `SlotPriceBook` asks, epoch-stamped so staleness
     is measurable; cold books gossip price-0 asks — the same
     capacity-keyed cold-start rule the book applies locally);
  3. **spill** — dialogues stuck in a shard's ready queue at least
     ``spill_min_wait`` re-auction against the gossiped REMOTE slack:
     one `run_auction` over (residuals x remote agents), valued by the
     structural cold-start prior alone (affinity 0 remotely, a domain-
     mismatch discount on prior quality) minus a flat dispatch penalty
     — `run_sharded_auction(spill=True)` generalized one level up, with
     the penalty keeping KV-affinity anchored to the home shard;
  4. **migrate** — winners hand their session state to the destination
     shard exactly once (`ShardEventLoop.extract_dialogue` /
     `admit_migrant`: only dialogues with zero in-flight work move, the
     arrival stays counted at home, the completion settles wherever the
     dialogue finishes, and per-shard request-id prefixes keep the
     settlement ledgers globally collision-free).

Bit-exact oracle: at S=1 the single shard runs with INTERNAL arrivals —
the same lazy pull path as `EventSimulator` — and epoch boundaries are
pure pauses (`advance_until` never touches a clock), so the federated
run replays the exact event sequence, decisions, accounts and ledger
head of today's single-heap simulator (tests/test_federation.py).
"""
from __future__ import annotations

import math
import time
import warnings
from collections import defaultdict
from contextlib import contextmanager

import numpy as np

from repro.core.hub import (AgentAsk, GossipBook, GossipDigest, SuperHub,
                            cluster_super_hubs, route_to_super_hub)
from repro.core.auction import run_auction
from repro.core.valuation import ValuationConfig, client_value
from repro.serving.simulator import RoutingProfiler, ShardEventLoop
from repro.serving.workload import SyncArrivals

__all__ = ["InlineShard", "FederatedSimulator", "build_federation"]

#: structural-prior constants shared with `AgentPredictor` defaults — the
#: federation prices remote bids from gossiped metadata only, so it uses
#: the same cold-start latency model the shard predictors would
PRIOR_LPT, PRIOR_LB, PRIOR_Q = 1e-3, 0.02, 0.6


class InlineShard:
    """One federation shard in-process: (cluster, router, loop) + driver API.

    The driver surface (`start`/`inject`/`advance`/`digest`/`residuals`/
    `extract`/`admit`/`close_arrivals`/`finalize`) is exactly what
    `repro.distributed.federation.ProcessShardHandle` proxies over a
    pipe, so `FederatedSimulator` treats inline and process shards
    identically.
    """

    def __init__(self, super_id: int, cluster, router,
                 loop: ShardEventLoop):
        self.super_id = int(super_id)
        self.cluster = cluster
        self.router = router
        self.loop = loop

    @classmethod
    def from_spec(cls, spec, dialogues=(), arrivals=None,
                  external: bool = True) -> "InlineShard":
        """Materialize a shard from a picklable `ShardSpec`.

        Both the inline and the worker-process paths build through here,
        which is what keeps them bit-identical.  ``external=False`` (the
        S=1 oracle) hands the loop the global ``dialogues``/``arrivals``
        stream directly — the exact `EventSimulator` pull path — and
        drops the request-id prefix for ledger-head parity.
        """
        from repro.core import IEMASRouter
        from repro.serving.cluster import SimCluster

        cluster = SimCluster(profiles=spec.profiles, seed=spec.seed,
                             **spec.cluster_kwargs)
        router = IEMASRouter(cluster.agent_infos(), **spec.router_kwargs)
        lkw = dict(spec.loop_kwargs)
        profiler = RoutingProfiler() if lkw.pop("profile", True) else None
        loop = ShardEventLoop(
            cluster, router, dialogues, arrivals=arrivals,
            profiler=profiler,
            rid_prefix=f"s{spec.super_id}:" if external else "",
            external_arrivals=external, **lkw)
        return cls(spec.super_id, cluster, router, loop)

    # ---------------- driver surface ----------------
    def start(self) -> None:
        """Idempotent initial scheduling (delegates to the loop)."""
        self.loop.start()

    def is_external(self) -> bool:
        """True when this shard is fed by the parent (`inject`)."""
        return self.loop._external

    def inject(self, items: list[tuple[float, object]]) -> None:
        """Feed this epoch's home-routed arrivals: ``[(t, script), ...]``."""
        for t, script in items:
            self.loop.inject_arrival(t, script)

    def close_arrivals(self) -> None:
        """Parent signal: the global dialogue stream is exhausted."""
        self.loop.close_arrivals()

    def advance(self, t_end: float | None) -> dict:
        """Advance the shard's event loop to the epoch boundary."""
        before = self.loop._n_processed
        self.loop.advance_until(t_end)
        return {"work": self.loop._work_remains(),
                "stopped": self.loop._stopped,
                "truncated": self.loop._truncated_reason,
                "processed": self.loop._n_processed - before,
                "now": self.cluster.now}

    def residuals(self, now: float, min_wait: float,
                  max_migrations: int = 2) -> list[dict]:
        """Spill candidates (delegates to `ShardEventLoop.residual_units`)."""
        return self.loop.residual_units(now, min_wait,
                                        max_migrations=max_migrations)

    def extract(self, dialogue_ids: list[str]) -> list:
        """Surrender the listed dialogues' state for migration."""
        return [self.loop.extract_dialogue(d) for d in dialogue_ids]

    def admit(self, migrants: list, t: float) -> None:
        """Adopt migrated dialogues at virtual time ``t``."""
        for st in migrants:
            self.loop.admit_migrant(st, t)

    def digest(self, epoch: int) -> GossipDigest:
        """Cut this shard's epoch-stamped gossip payload.

        Standing asks come out of the shard's `SlotPriceBook` under the
        SAME staleness contract `route_incremental` applies locally
        (agent-set version + exact live-id tuple + published
        capacities); hubs whose entry is stale or cold contribute empty
        ask vectors — the price-0 free-unit boundary.
        """
        cluster, router = self.cluster, self.router
        free = cluster.free_slots()
        telem = cluster.telemetry.snapshot(cluster.now)
        inflight = telem.get("agent_inflight", {})
        asks_map: dict[str, np.ndarray] = {}
        book = getattr(router, "price_book", None)
        if book is not None and getattr(router, "warm_start", False):
            live_ids = {a.agent_id for a in router.agents
                        if a.agent_id not in router.quarantined}
            for h, hub in enumerate(router.hubs):
                hub_live = [router.agents[gi] for gi in hub.agent_indices
                            if router.agents[gi].agent_id in live_ids]
                if not hub_live:
                    continue
                version, ids = router.agent_set_version.fingerprint(
                    a.agent_id for a in hub_live)
                asks = book.posted_asks(h, version, ids,
                                        [a.capacity for a in hub_live])
                if asks:
                    for aid, vec in asks.items():
                        asks_map[aid] = np.asarray(vec, dtype=np.float64)
        entries = []
        for a in router.agents:
            aid = a.agent_id
            if aid in router.quarantined:
                continue
            pred = router.pool[aid] if aid in router.pool else None
            entries.append(AgentAsk(
                agent_id=aid, free=int(free.get(aid, a.capacity)),
                capacity=int(a.capacity),
                price_miss=float(a.prices.miss),
                price_hit=float(a.prices.hit),
                price_out=float(a.prices.out),
                scale=float(a.scale), domains=tuple(a.domains),
                utilization=float(inflight.get(aid, 0.0))
                / max(1.0, float(a.capacity)),
                ewma_gen=(float(pred.ewma_gen) if pred is not None
                          else 32.0),
                asks=asks_map.get(aid, np.zeros(0))))
        return GossipDigest(super_id=self.super_id, epoch=int(epoch),
                            asks=entries)

    def finalize(self) -> dict:
        """Shard metrics + accounts + (optional) settlement-ledger audit."""
        out = self.loop._finalize(time.perf_counter() - self.loop._wall0)
        out["super_id"] = self.super_id
        out["n_agents"] = len(self.cluster.agents)
        out["rid_prefix"] = self.loop.rid_prefix
        if hasattr(self.router, "accounts"):
            out["accounts"] = dict(self.router.accounts)
        settlement = getattr(self.router, "settlement", None)
        if settlement is not None:
            ledger = {"head": settlement.head,
                      "entries": len(settlement.entries)}
            try:
                settlement.audit(self.router.accounts)
                ledger["ok"] = True
            except ValueError as e:     # replay divergence / broken chain
                ledger["ok"] = False
                ledger["error"] = str(e)
            out["ledger"] = ledger
        return out


class FederatedSimulator:
    """Advance S shard event loops between synchronization epochs.

    Parameters
    ----------
    shards : list of `InlineShard` / ``ProcessShardHandle``, positionally
        aligned with ``super_hubs``.
    super_hubs : the `SuperHub` partition (home-shard routing metadata).
    agent_domains : GLOBAL per-agent domain tuples (home-shard scoring).
    dialogues, arrivals : the global dialogue stream + arrival process;
        consumed by the parent and partitioned to external shards by
        `route_to_super_hub`.  Ignored when every shard feeds itself
        (the S=1 internal-arrivals oracle).
    epoch : virtual seconds between synchronization boundaries.
    spill / spill_penalty / spill_min_wait / mismatch_discount /
    max_migrations : cross-super-hub spill knobs — the flat dispatch
        penalty keeps KV-affinity anchored at home, the quality discount
        prices domain mismatch, ``spill_min_wait`` (default: one epoch)
        is how long a dialogue must starve before it may emigrate.
    gossip_every : epochs between digest refreshes (1 = every boundary,
        which bounds consumed staleness at one epoch).
    shard_schedule : optional permutation (or callable ``epoch ->
        permutation``) of shard indices fixing the advance order —
        results are bit-identical under ANY schedule (seed-split RNGs,
        tests/test_federation.py), so this exists to PROVE it, not to
        tune it.
    quantize : forwarded epoch alignment for lockstep shards (the
        boundary itself never needs alignment — pauses are pure).
    """

    def __init__(self, shards: list, super_hubs: list[SuperHub],
                 agent_domains: list[tuple[str, ...]], dialogues=None, *,
                 arrivals=None, epoch: float = 0.25,
                 spill: bool = True, spill_penalty: float = 0.5,
                 spill_min_wait: float | None = None,
                 mismatch_discount: float = 0.5, max_migrations: int = 2,
                 gossip_every: int = 1,
                 valuation: ValuationConfig | None = None,
                 payment_mode: str = "warmstart",
                 shard_schedule=None, max_epochs: int = 1_000_000):
        if len(shards) != len(super_hubs):
            raise ValueError(f"{len(shards)} shards vs {len(super_hubs)} "
                             "super-hubs")
        self.shards = shards
        self.super_hubs = super_hubs
        self._agent_domains = list(agent_domains)
        self.epoch = float(epoch)
        if self.epoch <= 0:
            raise ValueError(f"epoch must be > 0, got {epoch}")
        self.spill = bool(spill) and len(shards) > 1
        self.spill_penalty = float(spill_penalty)
        self.spill_min_wait = (float(spill_min_wait)
                               if spill_min_wait is not None else self.epoch)
        self.mismatch_discount = float(mismatch_discount)
        self.max_migrations = int(max_migrations)
        self.gossip_every = max(1, int(gossip_every))
        self.valuation = valuation or ValuationConfig()
        self.payment_mode = payment_mode
        self.max_epochs = int(max_epochs)
        self._schedule = shard_schedule
        self.gossip = GossipBook()

        self._external = [h.is_external() for h in shards]
        self._stream_open = any(self._external)
        self._buffered: tuple[float, object] | None = None
        self._dialogue_iter = iter(dialogues if dialogues is not None else ())
        self._arrivals = arrivals if arrivals is not None else SyncArrivals()
        self._arrival_times = self._arrivals.times()
        self._truncated_reason: str | None = None
        self.n_fed = 0
        self.epochs = 0
        self.spill_candidates = 0
        self.spill_migrated = 0
        self._fed_phases: dict[str, list] = {}  # name -> [wall_s, calls]

    # ---------------- internals ----------------
    @contextmanager
    def _phase(self, name: str):
        """Accumulate federation-level wall-clock (gossip/spill/migrate)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            slot = self._fed_phases.setdefault(name, [0.0, 0])
            slot[0] += time.perf_counter() - t0
            slot[1] += 1

    def _order(self, epoch_idx: int) -> list[int]:
        """Shard advance order this epoch (any order is bit-equivalent)."""
        if self._schedule is None:
            return list(range(len(self.shards)))
        sched = (self._schedule(epoch_idx) if callable(self._schedule)
                 else self._schedule)
        order = [int(k) for k in sched]
        if sorted(order) != list(range(len(self.shards))):
            raise ValueError(f"shard_schedule {order} is not a permutation "
                             f"of range({len(self.shards)})")
        return order

    def _feed_arrivals(self, t_end: float) -> None:
        """Partition global arrivals with ``t <= t_end`` to home shards."""
        if not self._stream_open:
            return
        batches: dict[int, list] = defaultdict(list)
        while True:
            if self._buffered is None:
                script = next(self._dialogue_iter, None)
                if script is None:
                    self._close_stream()
                    break
                t = next(self._arrival_times, None)
                if t is None:
                    # zip semantics, same loud truncation as the loop's
                    # internal pull path
                    self._truncated_reason = ("arrival process exhausted "
                                              "before the dialogue stream")
                    self._close_stream()
                    break
                self._buffered = (max(float(t), 0.0), script)
            t, script = self._buffered
            if t > t_end:
                break                   # held for a later epoch
            self._buffered = None
            k = route_to_super_hub(script.domain, self.super_hubs,
                                   self._agent_domains)
            batches[k].append((t, script))
            self.n_fed += 1
        for k in sorted(batches):
            self.shards[k].inject(batches[k])

    def _close_stream(self) -> None:
        self._stream_open = False
        for h, ext in zip(self.shards, self._external):
            if ext:
                h.close_arrivals()

    def _advance_all(self, order: list[int], t_end: float) -> dict:
        """One epoch of shard advances; process shards overlap for real."""
        statuses: dict[int, dict] = {}
        for k in order:
            h = self.shards[k]
            if hasattr(h, "advance_async"):
                h.advance_async(t_end)
        for k in order:
            h = self.shards[k]
            statuses[k] = h.wait() if hasattr(h, "advance_async") \
                else h.advance(t_end)
        return statuses

    def _spill_round(self, epoch_idx: int, t_end: float) -> list:
        """Re-auction stuck residuals against gossiped remote capacity.

        Returns migration moves ``(src_shard, dialogue_id, dst_shard)``.
        One `run_auction` prices every residual against every remote
        agent with free slack: value = Eq.-1 on the structural prior
        (affinity 0, domain-mismatch discount on prior quality) minus
        the flat dispatch penalty; cost = the Eq.-6 prior from gossiped
        prices; the warm seed replays each agent's gossiped ascending
        asks (price-0-padded — the cold-start boundary).
        """
        residuals = []                  # (src shard idx, summary row)
        for k, h in enumerate(self.shards):
            for row in h.residuals(t_end, self.spill_min_wait,
                                   self.max_migrations):
                residuals.append((k, row))
        if not residuals:
            return []
        self.spill_candidates += len(residuals)
        # one global remote-capacity column set from the consumed digests
        consumed: dict[int, GossipDigest] = {}
        for k in sorted({src for src, _ in residuals}):
            for d in self.gossip.fresh(k, epoch_idx):
                consumed.setdefault(d.super_id, d)
        cols: list[tuple[int, AgentAsk]] = []
        pos_of: dict[int, int] = {}     # super_id -> shard list position
        for pos, sh in enumerate(self.super_hubs):
            pos_of[sh.hub_id] = pos
        for sid in sorted(consumed):
            for ask in consumed[sid].asks:
                if ask.free > 0:
                    cols.append((pos_of[sid], ask))
        if not cols:
            return []
        n, m = len(residuals), len(cols)
        values = np.zeros((n, m))
        costs = np.zeros((n, m))
        for j, (src, row) in enumerate(residuals):
            pl = float(row["prompt_len"])
            for i, (owner, ask) in enumerate(cols):
                if owner == src:
                    continue            # home market owns its own agents
                prior_lat = (PRIOR_LB + PRIOR_LPT * pl) \
                    * (1.0 + ask.utilization)
                prior_cst = ask.price_miss * pl \
                    + ask.price_out * ask.ewma_gen
                q = PRIOR_Q if row["domain"] in ask.domains \
                    else PRIOR_Q * self.mismatch_discount
                values[j, i] = client_value(q, prior_lat, self.valuation) \
                    - self.spill_penalty
                costs[j, i] = prior_cst
        caps = [min(int(ask.free), n) for _, ask in cols]
        seed = np.concatenate([
            np.pad(np.asarray(ask.asks[:c], dtype=np.float64),
                   (0, c - min(len(ask.asks), c)))
            for (_, ask), c in zip(cols, caps)]) if cols else None
        result = run_auction(values, costs, caps,
                             payment_mode=self.payment_mode,
                             solver="dense", start_prices=seed)
        moves = []
        for j, i in enumerate(result.assignment):
            if i >= 0 and result.weights[j, i] > 0.0:
                moves.append((residuals[j][0], residuals[j][1]["dialogue_id"],
                              cols[i][0]))
        return moves

    def _boundary(self, epoch_idx: int, t_end: float) -> list:
        """Epoch synchronization: gossip, spill, migrate."""
        if epoch_idx % self.gossip_every == 0:
            with self._phase("gossip"):
                for pos, h in enumerate(self.shards):
                    d = h.digest(epoch_idx)
                    self.gossip.publish(d)
                    # refresh the published free-capacity tie-breaker the
                    # home-shard classifier reads (route_to_hub contract)
                    self.super_hubs[pos].published["free_capacity"] = \
                        d.total_slack()
        if not self.spill:
            return []
        with self._phase("spill"):
            moves = self._spill_round(epoch_idx, t_end)
        if moves:
            with self._phase("migrate"):
                by_src: dict[int, list[str]] = defaultdict(list)
                dst_of: dict[str, int] = {}
                for src, did, dst in moves:
                    by_src[src].append(did)
                    dst_of[did] = dst
                for src in sorted(by_src):
                    migrants = self.shards[src].extract(by_src[src])
                    by_dst: dict[int, list] = defaultdict(list)
                    for st in migrants:
                        by_dst[dst_of[st.script.dialogue_id]].append(st)
                    for dst in sorted(by_dst):
                        self.shards[dst].admit(by_dst[dst], t_end)
            self.spill_migrated += len(moves)
        return moves

    # ---------------- main loop ----------------
    def run(self) -> dict:
        """Run the federation to completion and return merged metrics."""
        wall0 = time.perf_counter()
        for h in self.shards:
            h.start()
        epoch_idx = 0
        t_end = self.epoch
        while True:
            self._feed_arrivals(t_end)
            statuses = self._advance_all(self._order(epoch_idx), t_end)
            stopped = [k for k, s in statuses.items() if s["stopped"]]
            if stopped:
                k = stopped[0]
                self._truncated_reason = (
                    f"shard {k}: {statuses[k].get('truncated')}")
                break
            work = any(s["work"] for s in statuses.values()) \
                or self._buffered is not None or self._stream_open
            if not work:
                break
            if epoch_idx >= self.max_epochs:
                self._truncated_reason = f"max_epochs ({self.max_epochs})"
                break
            moves = self._boundary(epoch_idx, t_end)
            epoch_idx += 1
            idle = all(s["processed"] == 0 for s in statuses.values())
            if idle and not moves and self._buffered is not None \
                    and self._buffered[0] > t_end + self.epoch:
                # every shard is drained until the next global arrival:
                # jump the boundary there instead of spinning empty epochs
                t_end = self._buffered[0]
            else:
                t_end += self.epoch
        self.epochs = epoch_idx
        return self._finalize(time.perf_counter() - wall0)

    # ---------------- reporting ----------------
    def _finalize(self, wall_s: float) -> dict:
        shard_outs = [h.finalize() for h in self.shards]
        for h in self.shards:
            if hasattr(h, "close"):
                h.close()
        out = self._merge_metrics(shard_outs, wall_s)
        if self._truncated_reason is not None:
            out["truncated"] = True
            warnings.warn(
                f"FederatedSimulator: truncated by {self._truncated_reason}",
                RuntimeWarning, stacklevel=2)
        return out

    def _merge_metrics(self, shard_outs: list[dict], wall_s: float) -> dict:
        """Fold per-shard reports into one federation-level metrics dict."""
        out: dict = {"shards": shard_outs, "epochs": self.epochs,
                     "wall_time_s": wall_s}
        sums = ("n", "rounds", "events", "dialogues_arrived",
                "dialogues_completed", "unfinished_dialogues",
                "dispatched_requests", "incremental_dispatched",
                "migrated_in", "migrated_out", "completed_turns",
                "peak_inflight")
        for key in sums:
            out[key] = sum(s.get(key, 0) for s in shard_outs)
        weights = np.array([max(1, s.get("n", 0)) for s in shard_outs],
                           dtype=np.float64)
        for key in ("kv_hit_rate", "latency_ms_mean", "latency_ms_median",
                    "latency_ms_p95", "cost_mean", "quality_mean",
                    "dialogue_latency_mean_s", "queue_wait_mean_s"):
            vals = np.array([s.get(key, 0.0) or 0.0 for s in shard_outs])
            out[key] = float((vals * weights).sum() / weights.sum())
        now = max((s.get("sim_time_s", 0.0) for s in shard_outs),
                  default=0.0)
        out["sim_time_s"] = now
        out["truncated"] = any(s.get("truncated") for s in shard_outs)
        if now > 0:
            out["throughput_rps"] = out["n"] / now
            total_agents = sum(s.get("n_agents", 0) for s in shard_outs)
            busy = sum(s.get("utilization", 0.0) * s.get("sim_time_s", 0.0)
                       * s.get("n_agents", 0) for s in shard_outs)
            out["utilization"] = busy / (now * max(1, total_agents))
        accounts: dict[str, float] = defaultdict(float)
        for s in shard_outs:
            for k, v in (s.get("accounts") or {}).items():
                accounts[k] += v
        out["accounts"] = dict(accounts)
        out["routing"] = self._merge_routing(shard_outs)
        out["federation"] = {
            "super_hubs": len(self.shards),
            "epoch_s": self.epoch,
            "arrivals_fed": self.n_fed,
            "spill_candidates": self.spill_candidates,
            "spill_migrated": self.spill_migrated,
            "gossip": self.gossip.stats(),
            "exactly_once": self.exactly_once(shard_outs),
        }
        return out

    def _merge_routing(self, shard_outs: list[dict]) -> dict:
        """Sum shard profiler reports + fold in federation-level phases."""
        engine = sum((s.get("routing") or {}).get("engine_compute_s", 0.0)
                     for s in shard_outs)
        routing = sum((s.get("routing") or {}).get("routing_wall_s", 0.0)
                      for s in shard_outs)
        phases: dict[str, dict] = defaultdict(
            lambda: {"wall_s": 0.0, "calls": 0})
        for s in shard_outs:
            for name, ph in ((s.get("routing") or {}).get("phases")
                             or {}).items():
                phases[name]["wall_s"] += ph.get("wall_s", 0.0)
                phases[name]["calls"] += ph.get("calls", 0)
        fed_wall = 0.0
        for name, (w, c) in sorted(self._fed_phases.items()):
            phases[f"federation_{name}"] = {"wall_s": w, "calls": c}
            fed_wall += w
        total = routing + fed_wall
        for ph in phases.values():
            ph["frac_of_engine"] = (ph["wall_s"] / engine) if engine > 0 \
                else None
        return {
            "engine_compute_s": engine,
            "routing_wall_s": total,
            "shard_routing_wall_s": routing,
            "federation_wall_s": fed_wall,
            "overhead_frac": (total / engine) if engine > 0 else None,
            "phases": dict(sorted(phases.items())),
        }

    def exactly_once(self, shard_outs: list[dict]) -> dict:
        """Global exactly-once settlement audit.

        Per shard: the hash-chained ledger replay must reproduce the
        accounts (when a ledger is attached).  Globally: request-id
        prefixes must be pairwise distinct (so per-shard ledger
        uniqueness implies global uniqueness), migration hand-offs must
        conserve dialogues (in == out), and every arrived dialogue must
        be either completed or still accounted for — none lost, none
        double-completed.
        """
        prefixes = [s.get("rid_prefix", "") for s in shard_outs]
        ledgers = [s.get("ledger") for s in shard_outs]
        ledger_ok = all(lg is None or lg.get("ok", False) for lg in ledgers)
        arrived = sum(s.get("dialogues_arrived", 0) for s in shard_outs)
        completed = sum(s.get("dialogues_completed", 0) for s in shard_outs)
        unfinished = sum(s.get("unfinished_dialogues", 0)
                         for s in shard_outs)
        m_in = sum(s.get("migrated_in", 0) for s in shard_outs)
        m_out = sum(s.get("migrated_out", 0) for s in shard_outs)
        conserved = (arrived == completed + unfinished) and (m_in == m_out)
        return {
            "ledger_replay_ok": ledger_ok,
            "ledgers_attached": sum(1 for lg in ledgers if lg is not None),
            "rid_prefixes_distinct": len(set(prefixes)) == len(prefixes),
            "dialogues_conserved": conserved,
            "lost_dialogues": arrived - completed - unfinished,
            "migrations_balanced": m_in == m_out,
            "ok": ledger_ok and conserved
            and len(set(prefixes)) == len(prefixes),
        }


def build_federation(dialogues, *, n_agents: int, super_hubs: int,
                     arrivals=None, seed: int = 0,
                     engine_mode: str = "analytic",
                     hub_scheme: str = "domain", agents_per_hub: int = 16,
                     max_inflight: int | None = None,
                     router_kwargs: dict | None = None,
                     loop_kwargs: dict | None = None,
                     cluster_kwargs: dict | None = None,
                     parallel: str = "inline",
                     **fed_kwargs) -> FederatedSimulator:
    """Construct an S-shard federation over one global fleet + stream.

    The fleet is ``agent_profiles(n_agents, seed)`` — the SAME profile
    list a single-heap run would build — partitioned by
    `cluster_super_hubs`; each shard gets `shard_seed(seed, k)` (the
    fold_in-style split that makes runs independent of shard advance
    order) and ``max_inflight // S`` of the global admission window.
    ``parallel="process"`` puts each shard in its own OS process
    (`ProcessShardHandle`); at S=1 the single inline shard consumes
    ``dialogues``/``arrivals`` directly — the bit-exact
    `EventSimulator` oracle configuration.  ``fed_kwargs`` pass through
    to `FederatedSimulator` (epoch, spill knobs, shard_schedule, ...).
    """
    from repro.configs.iemas_cluster import agent_profiles
    from repro.distributed.federation import (ProcessShardHandle, ShardSpec,
                                              shard_seed)

    profiles = agent_profiles(n_agents, seed=seed)
    supers = cluster_super_hubs([p.domains for p in profiles],
                                [p.scale for p in profiles], super_hubs,
                                scheme=hub_scheme, seed=seed,
                                agents_per_hub=agents_per_hub)
    s = len(supers)
    quantize = (loop_kwargs or {}).get("quantize")
    shards = []
    for pos, sh in enumerate(supers):
        rkw = dict(router_kwargs or {})
        rkw.setdefault("n_hubs", sh.n_inner_hubs)
        lkw = dict(loop_kwargs or {})
        if max_inflight is not None:
            lkw["max_inflight"] = max(1, max_inflight // s)
        # S=1: the lone shard IS the global simulator — keep the base seed
        # (fault/evaluator rng parity with EventSimulator); S>1: fold_in
        spec = ShardSpec(super_id=sh.hub_id,
                         profiles=[profiles[i] for i in sh.agent_indices],
                         seed=seed if s == 1 else shard_seed(seed, sh.hub_id),
                         router_kwargs=rkw, loop_kwargs=lkw,
                         cluster_kwargs=dict(
                             cluster_kwargs or {},
                             engine_mode=engine_mode))
        if s == 1:
            shards.append(InlineShard.from_spec(
                spec, dialogues=dialogues, arrivals=arrivals,
                external=False))
        elif parallel == "process":
            shards.append(ProcessShardHandle(spec))
        else:
            shards.append(InlineShard.from_spec(spec))
    if quantize is not None:
        fed_kwargs.setdefault("epoch", max(
            quantize, math.ceil(fed_kwargs.get("epoch", 0.25) / quantize)
            * quantize))
    return FederatedSimulator(
        shards, supers, [p.domains for p in profiles],
        dialogues if s > 1 else None,
        arrivals=arrivals if s > 1 else None, **fed_kwargs)
