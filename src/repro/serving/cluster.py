"""Simulated heterogeneous serving cluster with a virtual clock.

Replaces the paper's asyncio/vLLM deployment (no network stack in this
container) while keeping every quantity the mechanism consumes MEASURED:
engines run real JAX compute; the cluster adds queueing, heterogeneous
hardware speeds, stragglers and failures on a deterministic virtual clock.

Fault tolerance (required at 1000+-node scale):
  * agent failure  -> request marked failed, agent quarantined, request
                      re-enqueued and re-auctioned next round;
  * recovery       -> quarantined agents reinstate after a cooldown;
  * stragglers     -> per-agent slowdown spikes; the router's latency
                      predictor learns them and prices them out (the paper's
                      own mechanism IS the mitigation — measured in tests);
  * elastic scale  -> add_agent/remove_agent rebuild hubs + predictor pool.

Engine modes: ``engine_mode="real"`` (default) runs the reduced JAX models
(`repro.serving.engine.AgentEngine` — measured compute); ``"analytic"``
swaps in `repro.serving.analytic.AnalyticEngine`, whose service times come
from a roofline model calibrated against the real engines, enabling the
128-agent / 10k-dialogue scale runs of `repro.serving.simulator`.

`run_workload` below is the closed-loop, fixed-population oracle loop; the
event-driven open-loop driver for scale runs lives in
`repro.serving.simulator.EventSimulator` and reproduces this loop's
decisions bit-for-bit under synchronous arrivals (tests/test_simulator.py).
"""
from __future__ import annotations

import heapq
import warnings
import zlib
from collections import Counter, deque
from dataclasses import dataclass, field, replace

import numpy as np

from repro.configs.iemas_cluster import (DEFAULT_ROUTER, MODEL_CLASSES,
                                         AgentProfile, RouterConfig,
                                         agent_profiles)
from repro.core.adversary import AdversaryMix, AdversaryPolicy
from repro.core.mechanism import AgentInfo, CompletionObs, IEMASRouter, Request
from repro.core.pricing import TokenPrices
from repro.serving.engine import AgentEngine
from repro.serving.evaluator import SimulatedSkillEvaluator
from repro.serving.telemetry import TelemetryTracker
from repro.serving.workload import DialogueScript
from repro.utils.timing import phase_scope


def _engine_config(model_class: str, vocab: int):
    import dataclasses

    from repro.configs import get_config

    n_layers, d_model, n_heads, d_ff, _scale = MODEL_CLASSES[model_class]
    base = get_config("qwen3-8b").scaled(dtype="float32")
    return dataclasses.replace(
        base, name=f"engine-{model_class}", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_heads, head_dim=d_model // n_heads,
        d_ff=d_ff, vocab_size=vocab + 1, qk_norm=False)


@dataclass
class RequestRecord:
    """Ledger entry for one dispatched request (metrics + turn threading)."""

    request: Request
    agent_id: str
    dispatched_at: float
    ttft: float
    latency: float            # reported TTFT incl. queue + straggler effects
    cost: float
    n_prompt: int
    n_hit: int
    n_gen: int
    quality: float
    payment: float
    welfare_weight: float
    failed: bool = False
    # the engine's generated ids; run_workload threads them into the next
    # turn's prompt (dialogue causality, Appendix C.1)
    output_tokens: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))


@dataclass
class AgentRuntime:
    """One live agent: published info + engine + fault-injection knobs."""

    info: AgentInfo
    profile: AgentProfile
    engine: AgentEngine
    fail_prob: float = 0.0
    straggle_prob: float = 0.0
    straggle_factor: float = 6.0
    down_until: float = -1.0


class SimCluster:
    """Heterogeneous simulated cluster: engines + queueing + faults on a
    deterministic virtual clock (see module docstring)."""

    def __init__(self, n_agents: int = 9, *, vocab: int = 255, seed: int = 0,
                 max_new_tokens: int = 6, fail_prob: float = 0.0,
                 straggle_prob: float = 0.0, cache_slots: int | None = None,
                 quarantine_cooldown: float = 30.0, warmup: bool = False,
                 engine_mode: str = "real",
                 adversary_mix: AdversaryMix | None = None,
                 profiles: list[AgentProfile] | None = None):
        if engine_mode not in ("real", "analytic"):
            raise ValueError(f"engine_mode must be real|analytic, "
                             f"got {engine_mode!r}")
        self.rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.engine_mode = engine_mode
        self.telemetry = TelemetryTracker()
        self.evaluator = SimulatedSkillEvaluator(seed=seed + 1)
        self.quarantine_cooldown = quarantine_cooldown
        # attached by serving-layer profilers (repro.serving.simulator):
        # receives add_engine_compute() per dispatch + phase() around Phase 4
        self.profiler = None
        self.agents: dict[str, AgentRuntime] = {}
        # ``profiles`` overrides the generated population: federated shards
        # pass their partition of the GLOBAL agent_profiles() list so ids,
        # prices and engine seeds match the single-heap fleet exactly
        for prof in (profiles if profiles is not None
                     else agent_profiles(n_agents, seed=seed)):
            self._add_runtime(prof, fail_prob, straggle_prob, cache_slots,
                              max_new_tokens)
        # strategic-agent injection (repro.core.adversary): policies keyed by
        # agent id mutate published profiles / Phase-4 reports / membership;
        # an empty dict (no mix, or fraction 0) is bit-identical honest play
        self.adversaries: dict[str, AdversaryPolicy] = (
            adversary_mix.assign([rt.info for rt in self.agents.values()])
            if adversary_mix is not None else {})
        if warmup:
            for rt in self.agents.values():
                rt.engine.warmup()
        self.records: list[RequestRecord] = []
        self.now = 0.0
        self._completions: list = []  # heap of (time, seq, record, router_obs)
        self._seq = 0

    def _add_runtime(self, prof: AgentProfile, fail_prob, straggle_prob,
                     cache_slots, max_new_tokens):
        eng_seed = zlib.crc32(prof.agent_id.encode()) % (2**31)
        if self.engine_mode == "analytic":
            from repro.serving.analytic import AnalyticEngine

            engine = AnalyticEngine(
                prof.model_class, vocab=self.vocab, seed=eng_seed,
                speed=prof.speed, cache_slots=cache_slots or prof.cache_slots,
                max_new_tokens=max_new_tokens)
        else:
            cfg = _engine_config(prof.model_class, self.vocab)
            engine = AgentEngine(
                cfg, seed=eng_seed, speed=prof.speed,
                cache_slots=cache_slots or prof.cache_slots,
                max_new_tokens=max_new_tokens)
        info = AgentInfo(
            agent_id=prof.agent_id,
            prices=TokenPrices(prof.price_miss, prof.price_hit, prof.price_out),
            capacity=prof.capacity, domains=prof.domains, scale=prof.scale,
            recurrent=engine.recurrent, cache_slots=engine.cache_slots)
        self.agents[prof.agent_id] = AgentRuntime(
            info, prof, engine, fail_prob=fail_prob,
            straggle_prob=straggle_prob)

    # ---------------- elastic membership ----------------
    def agent_infos(self) -> list[AgentInfo]:
        """Published AgentInfo profiles of every live runtime.

        Strategic agents publish through their policy (a mutated COPY —
        e.g. misreported prices); everyone else publishes their true
        ``rt.info`` object itself, preserving the seed behavior where the
        router and cluster share one AgentInfo instance."""
        out = []
        for aid, rt in self.agents.items():
            pol = self.adversaries.get(aid)
            out.append(pol.publish(rt.info) if pol is not None else rt.info)
        return out

    def add_agent(self, profile: AgentProfile, router=None) -> None:
        """Elastic scale-out: spin up a runtime (and tell the router)."""
        self._add_runtime(profile, 0.0, 0.0, None, 6)
        if router is not None and hasattr(router, "add_agent"):
            router.add_agent(self.agents[profile.agent_id].info)

    def remove_agent(self, agent_id: str, router=None) -> None:
        """Elastic scale-in: drop a runtime (and tell the router)."""
        self.agents.pop(agent_id, None)
        if router is not None and hasattr(router, "remove_agent"):
            router.remove_agent(agent_id)

    def adversary_tick(self, router) -> None:
        """Give every strategic agent its per-round action hook (churn
        policies flap membership/capacity/quarantine here).  A no-op when
        no adversaries are assigned, so honest serving loops keep their
        bit-exact lockstep parity."""
        if not self.adversaries:
            return
        for aid, pol in list(self.adversaries.items()):
            pol.tick(self, router, aid)

    # ---------------- serving rounds ----------------
    def free_slots(self) -> dict:
        """Per-agent free concurrency slots (capacity minus inflight)."""
        inflight = self.telemetry.agent_inflight
        return {aid: max(0, rt.info.capacity - inflight.get(aid, 0))
                for aid, rt in self.agents.items()}

    def execute(self, decision, router) -> RequestRecord | None:
        """Dispatch one routed request to its agent and schedule completion."""
        req = decision.request
        if decision.agent_id is None or decision.agent_id not in self.agents:
            return None
        rt = self.agents[decision.agent_id]
        self.telemetry.on_dispatch(rt.info.agent_id, self.now)

        # failure injection
        if rt.down_until > self.now or self.rng.random() < rt.fail_prob:
            rt.down_until = max(rt.down_until, self.now + self.quarantine_cooldown)
            rec = RequestRecord(req, rt.info.agent_id, self.now, 0.0, 0.0, 0.0,
                                len(req.tokens), 0, 0, 0.0, 0.0,
                                decision.welfare_weight, failed=True)
            obs = CompletionObs(0.0, len(req.tokens), 0, 0, 0.0, failed=True)
            heapq.heappush(self._completions,
                           (self.now + 0.05, self._seq, rec, obs))
            self._seq += 1
            return rec

        # DAG steps serve under their own session key with parent-session
        # fork candidates (handoff prefix reuse); linear requests carry no
        # such meta and serve under the dialogue id exactly as before.
        session = req.meta.get("session", req.dialogue_id)
        result = rt.engine.serve(session, req.tokens, now=self.now,
                                 max_new_tokens=req.max_new_tokens,
                                 parents=req.meta.get("parent_sessions", ()))
        queue = self.telemetry.agent_inflight.get(rt.info.agent_id, 1) - 1
        straggle = (rt.straggle_factor
                    if self.rng.random() < rt.straggle_prob else 1.0)
        latency = result.ttft * straggle + 0.001 * max(0, queue)
        total = result.total_time * straggle + 0.001 * max(0, queue)

        dom_match = req.domain in rt.info.domains
        difficulty = float(req.meta.get("difficulty", 0.5))
        quality = self.evaluator.score(rt.info.scale, dom_match, difficulty)

        cost = (rt.info.prices.miss * (result.n_prompt - result.n_hit)
                + rt.info.prices.hit * result.n_hit
                + rt.info.prices.out * result.n_gen)
        rec = RequestRecord(req, rt.info.agent_id, self.now, result.ttft,
                            latency, cost, result.n_prompt, result.n_hit,
                            result.n_gen, quality, decision.payment,
                            decision.welfare_weight,
                            output_tokens=result.output_tokens)
        obs = CompletionObs(latency, result.n_prompt, result.n_hit,
                            result.n_gen, quality)
        if self.adversaries:
            # adversarial run: every Phase-4 report flows through a policy
            # (strategic agents may lie; honest ones attach the audit truth,
            # whose zero residual is reputation-neutral by construction)
            pol = self.adversaries.get(rt.info.agent_id)
            obs = (pol.report(obs, quality) if pol is not None
                   else replace(obs, audit_quality=quality))
        self.telemetry.on_busy(rt.info.agent_id, total)
        if self.profiler is not None:
            # virtual engine seconds — the overhead-attribution denominator
            self.profiler.add_engine_compute(total)
        heapq.heappush(self._completions, (self.now + total, self._seq, rec, obs))
        self._seq += 1
        return rec

    def next_completion_time(self) -> float | None:
        """Virtual time of the earliest scheduled completion (event hook)."""
        return self._completions[0][0] if self._completions else None

    def advance(self, dt: float, router) -> list[RequestRecord]:
        """Advance the virtual clock by ``dt``, delivering completions."""
        return self.advance_to(self.now + dt, router)

    def advance_to(self, t: float, router) -> list[RequestRecord]:
        """Advance the clock to absolute virtual time ``t`` (>= now),
        delivering every completion due by then to the router.

        The event simulator jumps straight to the next event with this hook
        (setting ``now`` exactly, no float drift against heap timestamps);
        the closed-loop ``advance`` above is a thin wrapper.
        """
        self.now = max(self.now, float(t))
        done = []
        while self._completions and self._completions[0][0] <= self.now:
            _, _, rec, obs = heapq.heappop(self._completions)
            self.telemetry.on_complete(rec.agent_id, self.now)
            with phase_scope(self.profiler, "phase4_feedback"):
                router.on_complete(rec.request.request_id, obs)
            if not rec.failed:
                self.records.append(rec)
            done.append(rec)
        # reinstate recovered agents
        if hasattr(router, "reinstate"):
            for aid, rt in self.agents.items():
                if 0 <= rt.down_until <= self.now:
                    router.reinstate(aid)
                    rt.down_until = -1.0
        return done

    # ---------------- metrics ----------------
    def metrics(self) -> dict:
        """Aggregate request-level metrics over completed (non-failed)
        records: KV hit rate, latency, cost, quality."""
        if not self.records:
            return {"n": 0}
        hits = np.array([r.n_hit / max(1, r.n_prompt) for r in self.records])
        lat = np.array([r.latency for r in self.records])
        cost = np.array([r.cost for r in self.records])
        qual = np.array([r.quality for r in self.records])
        return {
            "n": len(self.records),
            "kv_hit_rate": float(hits.mean()),
            "latency_ms_median": float(np.median(lat) * 1e3),
            "latency_ms_mean": float(lat.mean() * 1e3),
            "latency_ms_p95": float(np.percentile(lat, 95) * 1e3),
            "cost_mean": float(cost.mean()),
            "quality_mean": float(qual.mean()),
        }


def make_router(cluster: SimCluster, config: RouterConfig | None = None,
                **overrides) -> IEMASRouter:
    """Build the IEMAS router for a cluster from a RouterConfig.

    ``overrides`` land on top of the config and are passed straight to
    IEMASRouter (e.g. ``solver="dense"``, ``predictor_kw={...}``), so the
    Phase-2 solver choice threads from configs/CLI down to run_auction."""
    kwargs = (config or DEFAULT_ROUTER).router_kwargs()
    kwargs.update(overrides)
    return IEMASRouter(cluster.agent_infos(), **kwargs)


def run_workload(cluster: SimCluster, router, dialogues: list[DialogueScript],
                 *, round_dt: float = 0.05, max_rounds: int = 4000,
                 batch_per_round: int = 16, max_new_tokens: int = 6,
                 on_round=None) -> dict:
    """Drive multi-turn dialogues through router+cluster to completion.

    Dialogue causality: turn t+1 is issued only after turn t completes, with
    the engine's actual answer appended to the conversation (Appendix C.1).

    Fairness: ready dialogues queue through a FIFO deque ordered by when
    their turn became ready — a request skipped by the ``batch_per_round``
    cap keeps its place at the head next round.  (The seed scanned the
    ``state`` dict in insertion order every round and broke at the cap, so
    late-inserted dialogues were starved whenever the ready count exceeded
    it.)  Requests the auction leaves unmatched return to the *front* of
    the queue in order; failed requests re-enter at the back when their
    failure is delivered, like any other newly-ready turn.

    Truncation: exhausting ``max_rounds`` is no longer silent — the result
    carries ``unfinished_dialogues`` / ``completed_turns`` / ``truncated``
    and a ``RuntimeWarning`` fires, so scaled runs cannot quietly drop the
    tail of the latency distribution.  ``dispatched_requests`` and the
    ``requests_per_dialogue_*`` stats attribute dispatch counts (including
    fault-path retries) per dialogue.

    This loop is the closed-loop oracle: `repro.serving.simulator` must
    reproduce its decisions bit-for-bit under synchronous arrivals.
    """
    for d in dialogues:
        if not isinstance(d, DialogueScript):
            raise TypeError(
                f"run_workload drives linear DialogueScripts only; got "
                f"{type(d).__name__} for {getattr(d, 'dialogue_id', '?')!r} — "
                f"DAG workloads need repro.serving.simulator.EventSimulator")
    state = {d.dialogue_id: {"script": d, "turn": 0, "history": np.zeros(0, np.int32),
                             "busy": False} for d in dialogues}
    pending_next: dict[str, np.ndarray] = {
        d.dialogue_id: d.turns[0] for d in dialogues}
    ready: deque[str] = deque(d.dialogue_id for d in dialogues)
    rid = 0
    rounds = 0
    # per-dialogue dispatch attribution (includes fault-path retries); this
    # replaces the seed's write-only record_of dict
    dispatch_count: Counter = Counter()
    dispatched = 0
    while rounds < max_rounds:
        rounds += 1
        # collect up to batch_per_round ready requests (micro-batching,
        # C.2.1), FIFO by readiness time
        batch = []
        while ready and len(batch) < batch_per_round:
            did = ready.popleft()
            st = state[did]
            script = st["script"]
            prompt = np.concatenate([st["history"], pending_next[did]])
            batch.append(Request(request_id=f"r{rid}", dialogue_id=did,
                                 tokens=prompt.astype(np.int32), turn=st["turn"],
                                 domain=script.domain,
                                 max_new_tokens=max_new_tokens,
                                 meta={"difficulty": script.difficulty}))
            rid += 1
        if batch:
            telem = cluster.telemetry.snapshot(cluster.now)
            decisions = router.route_batch(batch, telem,
                                           free_slots=cluster.free_slots())
            unmatched = []
            for dec in decisions:
                did = dec.request.dialogue_id
                if dec.agent_id is None:
                    unmatched.append(did)  # retry, keeping queue priority
                    continue
                if cluster.execute(dec, router) is None:
                    # dead dispatch target (agent removed from the cluster
                    # but not the router): report it as a failure so the
                    # router quarantines it and clears its pending entry,
                    # instead of re-matching the same dead agent forever
                    router.on_complete(dec.request.request_id, CompletionObs(
                        0.0, len(dec.request.tokens), 0, 0, 0.0, failed=True))
                    unmatched.append(did)
                    continue
                state[did]["busy"] = True
                dispatch_count[did] += 1
                dispatched += 1
            ready.extendleft(reversed(unmatched))
        done = cluster.advance(round_dt, router)
        for rec in done:
            did = rec.request.dialogue_id
            st = state[did]
            st["busy"] = False
            if rec.failed:
                ready.append(did)  # re-issue the same turn next round
                continue
            new_user = pending_next.pop(did)
            st["history"] = np.concatenate(
                [st["history"], new_user, rec.output_tokens]).astype(np.int32)
            st["turn"] += 1
            script = st["script"]
            if st["turn"] < len(script.turns):
                pending_next[did] = script.turns[st["turn"]]
                ready.append(did)
        # strategic-agent round hook (no-op without an adversary mix)
        cluster.adversary_tick(router)
        if not pending_next and not any(st["busy"] for st in state.values()):
            break
        if on_round is not None:
            on_round(rounds, cluster)
    out = cluster.metrics()
    out["rounds"] = rounds
    out["completed_turns"] = sum(st["turn"] for st in state.values())
    # a dialogue is unfinished iff a turn of it is still pending (waiting,
    # in the ready queue, or in flight when the round budget ran out)
    out["unfinished_dialogues"] = len(pending_next)
    out["truncated"] = bool(pending_next)
    out["dispatched_requests"] = dispatched
    if dispatch_count:
        # same definition as EventSimulator: mean over dialogues that were
        # actually dispatched (identical when nothing truncated)
        out["requests_per_dialogue_mean"] = dispatched / len(dispatch_count)
        out["requests_per_dialogue_max"] = max(dispatch_count.values())
    if pending_next:
        warnings.warn(
            f"run_workload: round budget ({max_rounds}) exhausted with "
            f"{len(pending_next)}/{len(state)} dialogues unfinished "
            f"({out['completed_turns']} turns completed); metrics cover "
            f"completed requests only", RuntimeWarning, stacklevel=2)
    # warm-start effectiveness (IEMASRouter only): how often a hub's auction
    # was seeded from the previous round's slot prices vs cold-started
    book = getattr(router, "price_book", None)
    if book is not None and getattr(router, "warm_start", False):
        out["warm_start"] = book.stats()
    return out
