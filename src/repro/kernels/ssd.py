"""Pallas SSD kernel: chunkwise-parallel Mamba2 recurrence (scalar decay).

Same structure as wkv6.py but with a SCALAR decay per (head, step), so the
intra-chunk decay matrix is [C, C] (not [C, C, dk]) and B/C projections are
shared across heads. State [hd, ds] lives in VMEM scratch across the chunk
grid dimension. All decay exponents relative (<= 0) — overflow-free.

Grid: (B * H, S / C).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 16


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, la_ref, dskip_ref, y_ref,
                sT_ref, s_ref, *, chunk):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)       # [C, hd]
    bm = b_ref[0].astype(jnp.float32)      # [C, ds]
    cm = c_ref[0].astype(jnp.float32)      # [C, ds]
    dt = dt_ref[0].astype(jnp.float32)     # [C, 1] -> [C]
    la = la_ref[0].astype(jnp.float32)     # [C, 1]
    dskip = dskip_ref[0, 0, 0]
    dt = dt[:, 0]
    la = la[:, 0]

    c = chunk
    p = jnp.cumsum(la)                     # [C] inclusive
    state = s_ref[...]                     # [hd, ds]

    # intra: M[t,s] = exp(p_t - p_s) * (C_t . B_s) * dt_s, s <= t
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # [C, C]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    dec = jnp.exp(jnp.where(si <= ti, p[:, None] - p[None, :], -jnp.inf))
    m = cb * dec * dt[None, :]
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())))     # [C, hd]

    # inter: y_t += exp(p_t) * (S_in @ C_t)
    y = y + jnp.exp(p)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())))

    y = y + dskip * x
    y_ref[0] = y.astype(y_ref.dtype)

    # state: S_out = exp(p_last) S_in + sum_s exp(p_last - p_s) dt_s x_s (x) B_s
    w = jnp.exp(p[-1] - p) * dt                                  # [C]
    s_ref[...] = state * jnp.exp(p[-1]) + jax.lax.dot_general(
        x * w[:, None], bm, (((0,), (0,)), ((), ())))

    @pl.when(ic == nc - 1)
    def _emit():
        sT_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, bmat, cmat, dt, a_log, d_skip, *, chunk: int = CHUNK,
        interpret: bool = True):
    """x: [B,S,H,hd]; bmat,cmat: [B,S,ds]; dt: [B,S,H] (post-softplus);
    a_log, d_skip: [H]. Zero initial state. Returns (y, sT [B,H,hd,ds])."""
    b, s, h, hd = x.shape
    ds = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    ss = s + pad
    nc = ss // chunk

    la = -jnp.exp(a_log.astype(jnp.float32))[None, None, :] * dt  # [B,S',H]
    xx = x.transpose(0, 2, 1, 3).reshape(b * h, ss, hd)
    bb = jnp.broadcast_to(bmat[:, None], (b, h, ss, ds)).reshape(b * h, ss, ds)
    cc = jnp.broadcast_to(cmat[:, None], (b, h, ss, ds)).reshape(b * h, ss, ds)
    dtt = dt.transpose(0, 2, 1).reshape(b * h, ss, 1)
    laa = la.transpose(0, 2, 1).reshape(b * h, ss, 1)
    dsk = jnp.broadcast_to(d_skip.astype(jnp.float32)[None], (b, h)
                           ).reshape(b * h, 1, 1)

    y, sT = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1, 1), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, hd, ds), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, ss, hd), x.dtype),
            jax.ShapeDtypeStruct((b * h, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xx, bb, cc, dtt, laa, dsk)
    y = y.reshape(b, h, ss, hd).transpose(0, 2, 1, 3)
    return y[:, :s], sT.reshape(b, h, hd, ds)
