"""Pallas kernel: batched longest-common-prefix (the router's affinity hot loop).

The IEMAS proxy computes an N x M LCP matrix per micro-batch (every request
against every agent's prefix ledger, Eq. 4). On TPU there are no divergent
branches for early exit, so the kernel uses the cumulative-product-of-equality
trick: LCP(a, b) = sum_t prod_{u<=t} [a_u == b_u] — one VPU pass, no control
flow (DESIGN.md §3).

Tiling: grid over (N/bn, M/bm); each program holds a [bn, L] prompt tile and
a [bn, bm, L] ledger tile in VMEM. With bn=8, bm=8, L=1024 int32 that is
8*1024*4 + 8*8*1024*4 = 288 KiB — comfortably within a v5e core's VMEM.

``interpret`` follows the `auction_bid` tile-plan convention: the default
(None) resolves backend-aware — compiled Pallas on TPU, interpret mode
everywhere else — and the padding plan depends on the resolved mode (the
token axis is padded to the LANE width only off-interpret, where the VPU
needs 128-multiple lanes; interpret mode keeps the caller's width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN, BM = 8, 8
LANE = 128      # token-axis padding multiple on real hardware


def _lcp_kernel(p_ref, l_ref, o_ref):
    p = p_ref[...]            # [bn, L]
    led = l_ref[...]          # [bn, bm, L]
    eq = (p[:, None, :] == led).astype(jnp.int32)
    prefix = jnp.cumprod(eq, axis=-1)
    o_ref[...] = prefix.sum(axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lcp_affinity(prompts, ledgers, *, interpret: bool | None = None):
    """prompts: [N, L] int32; ledgers: [N, M, L] int32 -> lcp [N, M] int32.

    N and M are padded to the block sizes internally (and L to the lane
    width when running compiled). ``interpret=None`` resolves backend-aware:
    compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, l = prompts.shape
    m = ledgers.shape[1]
    pn = (-n) % BN
    pm = (-m) % BM
    pl_tok = 0 if interpret else (-l) % LANE
    if pn:
        prompts = jnp.pad(prompts, ((0, pn), (0, 0)), constant_values=-1)
        ledgers = jnp.pad(ledgers, ((0, pn), (0, 0), (0, 0)), constant_values=-2)
    if pm:
        ledgers = jnp.pad(ledgers, ((0, 0), (0, pm), (0, 0)), constant_values=-2)
    if pl_tok:
        # pad tokens diverge (-1 vs -2), so the cumprod chain cannot extend
        # past the real width
        prompts = jnp.pad(prompts, ((0, 0), (0, pl_tok)), constant_values=-1)
        ledgers = jnp.pad(ledgers, ((0, 0), (0, 0), (0, pl_tok)),
                          constant_values=-2)
    nn, mm = prompts.shape[0], ledgers.shape[1]
    l = prompts.shape[1]

    out = pl.pallas_call(
        _lcp_kernel,
        grid=(nn // BN, mm // BM),
        in_specs=[
            pl.BlockSpec((BN, l), lambda i, j: (i, 0)),
            pl.BlockSpec((BN, BM, l), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((BN, BM), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nn, mm), jnp.int32),
        interpret=interpret,
    )(prompts, ledgers)
    return out[:n, :m]
