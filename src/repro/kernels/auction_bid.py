"""Pallas kernel: one Jacobi forward-bidding round of the dense auction.

The Phase-2 ε-scaling auction (`repro.core.solvers`) spends almost all of
its time in the forward bidding round: every unassigned request scans the
full slot row for its top-2 profits, then the winning bids are scattered
into the per-slot price vector as a segment max (ties to the lowest request
index).  This kernel computes one such round for a (n × K) slot-level
weight matrix:

    P[j, k]  = B[j, k] - prices[k]            (only active rows compete)
    v1, k1   = top profit and its slot        (per request)
    v2       = second profit, floored at the outside option 0
    bid[j]   = prices[k1] + (v1 - v2) + ε     (only if v1 > 0, else park)
    best[k]  = max over bidders with k1 = k of bid[j]   (segment max)
    winner[k]= min j among bidders at best[k]           (deterministic ties)

Tiling
------
Grid over request tiles: ``(n / bn,)`` programs, each holding a [bn, K]
weight tile, the full [1, K] price row and a [bn, 1] active mask in VMEM
(slots are NOT tiled — K is the per-hub slot count, a few thousand floats).
The per-request outputs (``wants``) block-map one tile per program; the
per-slot outputs (``best``, ``winner``) map every program onto the SAME
[1, K] block, exploiting the sequential grid execution on a TPU core: each
program folds its tile's segment max into the accumulator (max for prices,
three-way merge for the tie-broken winner), with ``pl.when(i == 0)``
initialization.  With bn = 8 and K = 4096 float32 the working set is
8·4096·4 B ≈ 128 KiB — comfortably inside a v5e core's VMEM, and the
scatter never leaves the tile (the one-hot trick: a segment max over k1 is
a masked row-max, no gather/scatter primitives needed).

The caller pads n to the tile size and K to the lane width; padded rows
are inactive and padded slots carry weight 0 at price +big, so neither can
attract or place a bid.  ``kernels/ref.py::auction_bid_ref`` is the pure
jnp oracle; the interpret-mode kernel is bit-identical to it (same op
order; max/argmax reductions are order-independent, the one-hot price
gather adds exact zeros).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 8          # request rows per tile
LANE = 128      # slot-dimension padding multiple on real hardware


def _bid_kernel(b_ref, p_ref, a_ref, e_ref, best_ref, win_ref, wants_ref,
                *, n_total: int, bn: int):
    i = pl.program_id(0)
    B = b_ref[...]                       # [bn, K] slot-level weights
    prices = p_ref[...]                  # [1, K]
    act = a_ref[...] != 0                # [bn, 1]
    eps = e_ref[0, 0]
    K = B.shape[1]
    big = jnp.asarray(jnp.finfo(B.dtype).max / 4, B.dtype)

    P = jnp.where(act, B - prices, -big)                     # [bn, K]
    v1 = P.max(axis=1)
    k1 = P.argmax(axis=1)
    onehot = jax.lax.broadcasted_iota(jnp.int32, (bn, K), 1) == k1[:, None]
    v2 = jnp.maximum(jnp.where(onehot, -big, P).max(axis=1), 0.0)
    wants = act[:, 0] & (v1 > 0.0)
    # prices[k1] as a masked sum: exactly one nonzero term, so bit-exact
    p_k1 = jnp.where(onehot, prices, 0.0).sum(axis=1)
    bid = p_k1 + (v1 - v2) + eps

    # segment max of bids into slots, entirely within the tile
    contrib = jnp.where(onehot & wants[:, None], bid[:, None], -big)
    tile_best = contrib.max(axis=0)                          # [K]
    rowid = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, K), 0)
    cand = jnp.where((contrib == tile_best[None, :]) & (contrib > -big),
                     rowid, n_total)
    tile_win = cand.min(axis=0).astype(jnp.int32)            # [K]

    wants_ref[...] = wants[:, None].astype(jnp.int32)

    @pl.when(i == 0)
    def _init():
        best_ref[...] = tile_best[None, :]
        win_ref[...] = tile_win[None, :]

    @pl.when(i > 0)
    def _fold():
        prev_best = best_ref[0, :]
        prev_win = win_ref[0, :]
        # ties to the lowest request index; earlier tiles hold lower rows,
        # so equality keeps the accumulated winner via min
        best_ref[...] = jnp.maximum(prev_best, tile_best)[None, :]
        win_ref[...] = jnp.where(
            tile_best > prev_best, tile_win,
            jnp.where(tile_best < prev_best, prev_win,
                      jnp.minimum(prev_win, tile_win)))[None, :]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def auction_bid(B, prices, active, eps, *, bn: int = BN,
                interpret: bool = True):
    """One Jacobi forward-bidding round over slot-level weights.

    ``B``: [n, K] non-negative weights; ``prices``: [K]; ``active``: [n]
    bool (unassigned, not parked); ``eps`` scalar.  Returns
    ``(best, winner, wants)``: the per-slot segment-max bid [K] (−big where
    no bid), the winning request per slot [K] int32 (n where none), and the
    per-request wants-to-bid mask [n] bool (active rows with positive top
    profit; active rows with ``~wants`` park on the outside option).

    n is padded to the tile size (and K to the lane width off-interpret)
    internally; callers that pre-pad to power-of-two shape buckets hit a
    single trace across batch-size wobble.
    """
    B = jnp.asarray(B)
    n, K = B.shape
    pn = (-n) % bn
    pk = 0 if interpret else (-K) % LANE
    big = jnp.asarray(jnp.finfo(B.dtype).max / 4, B.dtype)
    if pn:
        B = jnp.pad(B, ((0, pn), (0, 0)))
        active = jnp.pad(jnp.asarray(active), (0, pn))
    if pk:
        # padded slots: weight 0 at price +big -> profit is hugely negative,
        # so they can never be a request's top-2 nor receive a bid
        B = jnp.pad(B, ((0, 0), (0, pk)))
        prices = jnp.pad(jnp.asarray(prices), (0, pk), constant_values=big)
    nn, kk = B.shape

    best, winner, wants = pl.pallas_call(
        functools.partial(_bid_kernel, n_total=nn, bn=bn),
        grid=(nn // bn,),
        in_specs=[
            pl.BlockSpec((bn, kk), lambda i: (i, 0)),
            pl.BlockSpec((1, kk), lambda i: (0, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kk), lambda i: (0, 0)),
            pl.BlockSpec((1, kk), lambda i: (0, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, kk), B.dtype),
            jax.ShapeDtypeStruct((1, kk), jnp.int32),
            jax.ShapeDtypeStruct((nn, 1), jnp.int32),
        ],
        interpret=interpret,
    )(B,
      jnp.asarray(prices, B.dtype).reshape(1, kk),
      jnp.asarray(active, jnp.int32).reshape(nn, 1),
      jnp.asarray(eps, B.dtype).reshape(1, 1))
    # padded rows never bid, so any no-winner sentinel folds back to n
    return (best[0, :K], jnp.minimum(winner[0, :K], n),
            wants[:n, 0].astype(bool))
