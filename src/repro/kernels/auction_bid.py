"""Pallas kernel: one Jacobi forward-bidding round of the column auction.

The Phase-2 ε-scaling auction (`repro.core.solvers`) spends almost all of
its time in the forward bidding round.  Since PR 6 the market holds ONE
capacitated column per agent (m columns) instead of one column per unit
slot (K = Σ min(b_i, n) columns): the solver keeps an (m × cmax) unit-price
grid and hands this kernel the two cheapest unit prices per agent — the
segment-min ``ask`` and the second-cheapest ``ask2``.  The kernel computes
one bidding round for an (n × m) agent-level weight matrix:

    P[j, i]  = W[j, i] - ask[i]               (only active rows compete)
    v1, k1   = top profit and its agent       (per request)
    v2       = runner-up profit with the favourite agent's own ask2
               substituted at k1, floored at the outside option 0
    bid[j]   = ask[k1] + (v1 - v2) + ε        (only if v1 > 0, else park)
    best[i]  = max over bidders with k1 = i of bid[j]   (segment max)
    winner[i]= min j among bidders at best[i]           (deterministic ties)

The ask2 substitution is what makes the aggregated column equivalent to a
slot-expanded market: a request whose top TWO profits both sit at the same
agent would, under slot expansion, see that agent's two cheapest slots as
two distinct columns — here the second one re-enters through ask2.

Tiling
------
Grid over request tiles: ``(n / bn,)`` programs, each holding a [bn, m]
weight tile, the full [1, m] ask/ask2 rows and a [bn, 1] active mask in
VMEM (agents are NOT tiled — m is the per-hub agent count, far below the
old K slot count in the slack regime).  The per-request outputs
(``wants``) block-map one tile per program; the per-agent outputs
(``best``, ``winner``) map every program onto the SAME [1, m] block,
exploiting the sequential grid execution on a TPU core: each program folds
its tile's segment max into the accumulator (max for bids, three-way merge
for the tie-broken winner), with ``pl.when(i == 0)`` initialization.  With
bn = 8 and m = 4096 float32 the working set is 8·4096·4 B ≈ 128 KiB —
comfortably inside a v5e core's VMEM, and the scatter never leaves the
tile (the one-hot trick: a segment max over k1 is a masked row-max, no
gather/scatter primitives needed).

The caller pads n to the tile size and m to the lane width; padded rows
are inactive and padded agents carry weight 0 at ask = ask2 = +big (an
agent with no units quotes an infinite ask), so neither can attract or
place a bid.  ``kernels/ref.py::auction_bid_ref`` is the pure jnp oracle;
the interpret-mode kernel is bit-identical to it (same op order; max/argmax
reductions are order-independent, the one-hot ask gathers add exact zeros).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 8          # request rows per tile
LANE = 128      # agent-dimension padding multiple on real hardware


def _bid_kernel(w_ref, a1_ref, a2_ref, act_ref, e_ref,
                best_ref, win_ref, wants_ref, *, n_total: int, bn: int):
    i = pl.program_id(0)
    W = w_ref[...]                       # [bn, m] agent-level weights
    ask = a1_ref[...]                    # [1, m] cheapest unit per agent
    ask2 = a2_ref[...]                   # [1, m] second-cheapest unit
    act = act_ref[...] != 0              # [bn, 1]
    eps = e_ref[0, 0]
    m = W.shape[1]
    big = jnp.asarray(jnp.finfo(W.dtype).max / 4, W.dtype)

    P = jnp.where(act, W - ask, -big)                        # [bn, m]
    v1 = P.max(axis=1)
    k1 = P.argmax(axis=1)
    onehot = jax.lax.broadcasted_iota(jnp.int32, (bn, m), 1) == k1[:, None]
    # the favourite agent's column re-enters the runner-up scan at its own
    # second-cheapest unit — the collapsed image of the next slot
    alt = jnp.where(onehot & act, W - ask2, P)
    v2 = jnp.maximum(alt.max(axis=1), 0.0)
    wants = act[:, 0] & (v1 > 0.0)
    # ask[k1] as a masked sum: exactly one nonzero term, so bit-exact
    a_k1 = jnp.where(onehot, ask, 0.0).sum(axis=1)
    bid = a_k1 + (v1 - v2) + eps

    # segment max of bids into agent columns, entirely within the tile
    contrib = jnp.where(onehot & wants[:, None], bid[:, None], -big)
    tile_best = contrib.max(axis=0)                          # [m]
    rowid = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, m), 0)
    cand = jnp.where((contrib == tile_best[None, :]) & (contrib > -big),
                     rowid, n_total)
    tile_win = cand.min(axis=0).astype(jnp.int32)            # [m]

    wants_ref[...] = wants[:, None].astype(jnp.int32)

    @pl.when(i == 0)
    def _init():
        best_ref[...] = tile_best[None, :]
        win_ref[...] = tile_win[None, :]

    @pl.when(i > 0)
    def _fold():
        prev_best = best_ref[0, :]
        prev_win = win_ref[0, :]
        # ties to the lowest request index; earlier tiles hold lower rows,
        # so equality keeps the accumulated winner via min
        best_ref[...] = jnp.maximum(prev_best, tile_best)[None, :]
        win_ref[...] = jnp.where(
            tile_best > prev_best, tile_win,
            jnp.where(tile_best < prev_best, prev_win,
                      jnp.minimum(prev_win, tile_win)))[None, :]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def auction_bid(W, ask, ask2, active, eps, *, bn: int = BN,
                interpret: bool = True):
    """One Jacobi forward-bidding round over agent-level weights.

    ``W``: [n, m] non-negative weights; ``ask``/``ask2``: [m] cheapest and
    second-cheapest unit price per agent (+big where the agent has fewer
    than one/two free-or-filled units); ``active``: [n] bool (unassigned,
    not parked); ``eps`` scalar.  Returns ``(best, winner, wants)``: the
    per-agent segment-max bid [m] (−big where no bid), the winning request
    per agent [m] int32 (n where none), and the per-request wants-to-bid
    mask [n] bool (active rows with positive top profit; active rows with
    ``~wants`` park on the outside option).

    n is padded to the tile size (and m to the lane width off-interpret)
    internally; callers that pre-pad to power-of-two shape buckets hit a
    single trace across batch-size wobble.
    """
    W = jnp.asarray(W)
    n, m = W.shape
    pn = (-n) % bn
    pm = 0 if interpret else (-m) % LANE
    big = jnp.asarray(jnp.finfo(W.dtype).max / 4, W.dtype)
    if pn:
        W = jnp.pad(W, ((0, pn), (0, 0)))
        active = jnp.pad(jnp.asarray(active), (0, pn))
    if pm:
        # padded agents: weight 0 at ask/ask2 +big -> profit is hugely
        # negative, so they can never be a request's top-2 nor take a bid
        W = jnp.pad(W, ((0, 0), (0, pm)))
        ask = jnp.pad(jnp.asarray(ask), (0, pm), constant_values=big)
        ask2 = jnp.pad(jnp.asarray(ask2), (0, pm), constant_values=big)
    nn, mm = W.shape

    best, winner, wants = pl.pallas_call(
        functools.partial(_bid_kernel, n_total=nn, bn=bn),
        grid=(nn // bn,),
        in_specs=[
            pl.BlockSpec((bn, mm), lambda i: (i, 0)),
            pl.BlockSpec((1, mm), lambda i: (0, 0)),
            pl.BlockSpec((1, mm), lambda i: (0, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, mm), lambda i: (0, 0)),
            pl.BlockSpec((1, mm), lambda i: (0, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, mm), W.dtype),
            jax.ShapeDtypeStruct((1, mm), jnp.int32),
            jax.ShapeDtypeStruct((nn, 1), jnp.int32),
        ],
        interpret=interpret,
    )(W,
      jnp.asarray(ask, W.dtype).reshape(1, mm),
      jnp.asarray(ask2, W.dtype).reshape(1, mm),
      jnp.asarray(active, jnp.int32).reshape(nn, 1),
      jnp.asarray(eps, W.dtype).reshape(1, 1))
    # padded rows never bid, so any no-winner sentinel folds back to n
    return (best[0, :m], jnp.minimum(winner[0, :m], n),
            wants[:n, 0].astype(bool))
