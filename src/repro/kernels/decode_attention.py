"""Pallas flash-decode: one-token attention against a long KV cache.

TPU adaptation of paged/flash-decoding (DESIGN.md §3): pass 1 (the kernel)
splits the cache length M into blocks and emits per-block partial
(max, sum-exp, weighted-V) triples; pass 2 is a tiny jnp log-sum-exp combine.
There is no pointer-chased page table — caches are contiguous slabs and
validity comes from the slot_pos array, which is what the serving layer
maintains anyway.

Grid: (B * Hkv, M / bk). Each program holds the [G, d] query group and one
[bk, d] cache block in VMEM (G = H / Hkv query heads per KV head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, m_ref, l_ref, acc_ref,
                   *, scale):
    q = q_ref[0].astype(jnp.float32)        # [G, d]
    k = k_ref[0].astype(jnp.float32)        # [bk, d]
    v = v_ref[0].astype(jnp.float32)        # [bk, d]
    valid = valid_ref[0]                    # [bk] int32 (1 = valid)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [G, bk]
    s = jnp.where(valid[None, :] > 0, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)                     # [G, 1]
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    acc = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))  # [G, d]
    m_ref[0, 0] = m
    l_ref[0, 0] = l
    acc_ref[0, 0] = acc


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k_cache, v_cache, valid, *, bk: int = 256,
                     interpret: bool = True):
    """q: [B, H, d]; caches: [B, M, Hkv, d]; valid: [B, M] bool -> [B, H, d]."""
    b, h, d = q.shape
    m_len, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / (d ** 0.5)
    bk = min(bk, m_len)
    pm = (-m_len) % bk
    if pm:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pm), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pm), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pm)))
    mm = m_len + pm
    nk = mm // bk

    qg = q.reshape(b, hkv, g, d).reshape(b * hkv, g, d)
    kk = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, mm, d)
    vv = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, mm, d)
    val = jnp.broadcast_to(valid.astype(jnp.int32)[:, None, :],
                           (b, hkv, mm)).reshape(b * hkv, mm)

    m_p, l_p, acc_p = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=(b * hkv, nk),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, ik: (bh, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk), lambda bh, ik: (bh, ik)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, 1), lambda bh, ik: (bh, ik, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bh, ik: (bh, ik, 0, 0)),
            pl.BlockSpec((1, 1, g, d), lambda bh, ik: (bh, ik, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, nk, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, nk, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, nk, g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kk, vv, val)

    # pass 2: combine partials over the nk block axis (log-sum-exp)
    m_all = m_p[..., 0]                       # [BH, nk, G]
    m_star = m_all.max(axis=1, keepdims=True)
    w = jnp.exp(m_all - m_star)               # [BH, nk, G]
    l_tot = (l_p[..., 0] * w).sum(axis=1)     # [BH, G]
    acc = (acc_p * w[..., None]).sum(axis=1)  # [BH, G, d]
    out = acc / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(b, hkv, g, d).reshape(b, h, d).astype(q.dtype)