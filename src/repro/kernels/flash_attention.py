"""Pallas flash attention (prefill/training): tiled online-softmax, GQA, SWA.

Layout per program: one (batch*head, q-block) pair iterates over k-blocks in
the innermost grid dimension with fp32 running (m, l, acc) scratch in VMEM —
the canonical TPU flash pattern (no warp shuffles: the combine is a VMEM
reduction, DESIGN.md §3). Block sizes default to 128x128 (MXU-aligned).

GQA is handled in the k/v BlockSpec index maps: query head h reads kv head
h // (H / Hkv) — no repeat-materialization of K/V.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale, bq, bk, seq_k, causal, window):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # [bq, d]
    k = k_ref[0].astype(jnp.float32)          # [bk, d]
    v = v_ref[0].astype(jnp.float32)          # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]

    iq = pl.program_id(1)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                              "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: [B, Sq, H, d]; k, v: [B, Sk, Hkv, d] -> [B, Sq, H, d]."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    scale = 1.0 / (d ** 0.5)

    bq = min(bq, sq)
    bk = min(bk, sk)
    pq = (-sq) % bq
    pk = (-sk) % bk
    qq = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kk = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vv = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v

    # [B,S,H,d] -> [B*H, S, d] with kv-head folding handled by index maps
    qq = qq.transpose(0, 2, 1, 3).reshape(b * h, sq + pq, d)
    kk = kk.transpose(0, 2, 1, 3).reshape(b * hkv, sk + pk, d)
    vv = vv.transpose(0, 2, 1, 3).reshape(b * hkv, sk + pk, d)

    def kv_index(bh, iq, ik):
        batch = bh // h
        head = bh % h
        return (batch * hkv + head // group, ik, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk, seq_k=sk,
                          causal=causal, window=window),
        grid=(b * h, (sq + pq) // bq, (sk + pk) // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pq, d), q.dtype),
        scratch_shapes=[
            # fp32 running max / denom / accumulator in VMEM, persistent
            # across the k-block grid dimension
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qq, kk, vv)
    out = out.reshape(b, h, sq + pq, d).transpose(0, 2, 1, 3)
    return out[:, :sq]
