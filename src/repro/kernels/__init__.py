"""Pallas kernels for the framework's compute hot-spots.

Three-file pattern per op: ``<name>.py`` holds the `pl.pallas_call` kernel
(compiled on TPU, interpret mode elsewhere), ``ref.py`` the simplest-possible
pure-jnp oracle it is validated against, and ``ops.py`` the dispatch wrapper
callers import.  Current kernels: LCP affinity (router Phase 1), the dense
auction's forward-bidding round (router Phase 2), flash/decode attention,
WKV6 and SSD recurrences (serving engines).
"""
