"""Dispatch wrappers: Pallas on TPU, interpret-mode on CPU, oracles for tests.

Every op takes the same arguments as its kernel; ``interpret`` defaults to
True off-TPU so the whole framework runs (slowly but correctly) on CPU while
targeting compiled Pallas on real hardware.
"""
from __future__ import annotations

import jax

from repro.kernels.auction_bid import auction_bid
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lcp_affinity import lcp_affinity
from repro.kernels.ssd import ssd
from repro.kernels.wkv6 import wkv6


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def auction_bid_op(W, ask, ask2, active, eps, *, bn=8):
    """One forward-bidding round of the column market: W [n, m], ask/ask2
    [m] (cheapest/second-cheapest unit price per agent), active [n], eps
    scalar -> (best [m], winner [m], wants [n]); see kernels/auction_bid."""
    return auction_bid(W, ask, ask2, active, eps, bn=bn,
                       interpret=_interpret())


def lcp_affinity_op(prompts, ledgers):
    """prompts [N, L], ledgers [N, M, L] -> lcp [N, M]. Backend-aware:
    compiled Pallas on TPU, interpret mode elsewhere (the kernel's own
    ``interpret=None`` default resolves the same way)."""
    return lcp_affinity(prompts, ledgers, interpret=_interpret())


def flash_attention_op(q, k, v, *, causal=True, window=0, bq=128, bk=128):
    """Tiled flash attention over [B, S, H, d] q/k/v (GQA by head group)."""
    return flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                           bk=bk, interpret=_interpret())


def decode_attention_op(q, k_cache, v_cache, valid, *, bk=256):
    """Single-token decode attention against a masked [B, M, Hkv, d] cache."""
    return decode_attention(q, k_cache, v_cache, valid, bk=bk,
                            interpret=_interpret())


def wkv6_op(r, k, v, log_w, u, *, chunk=16):
    """Chunked WKV6 (RWKV-6) recurrence over [B, S, H, dk] inputs."""
    return wkv6(r, k, v, log_w, u, chunk=chunk, interpret=_interpret())


def ssd_op(x, bmat, cmat, dt, a_log, d_skip, *, chunk=16):
    """Chunked SSD (Mamba-2) state-space scan over [B, S, H, hd] inputs."""
    return ssd(x, bmat, cmat, dt, a_log, d_skip, chunk=chunk,
               interpret=_interpret())
