"""Pure-jnp/numpy oracles for every Pallas kernel (ground truth in tests).

These are intentionally the SIMPLEST possible implementations (stepwise
recurrences, dense masked attention, python-loop LCP) — slow but obviously
correct. Kernels and the models' optimized jnp paths are both validated
against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------- LCP ----------------

def lcp_ref(prompts: np.ndarray, ledgers: np.ndarray) -> np.ndarray:
    """prompts: [N, L] int32; ledgers: [N, M, L] int32 -> [N, M] int32."""
    n, l = prompts.shape
    m = ledgers.shape[1]
    out = np.zeros((n, m), np.int32)
    for j in range(n):
        for i in range(m):
            c = 0
            while c < l and prompts[j, c] == ledgers[j, i, c]:
                c += 1
            out[j, i] = c
    return out


# ---------------- auction bidding round ----------------

def auction_bid_ref(W, ask, ask2, active, eps):
    """One Jacobi forward-bidding round of the capacitated column market,
    pure jnp (the kernel's oracle).

    W: [n, m] agent-level weights; ask/ask2: [m] cheapest and
    second-cheapest unit price per agent (segment-min/-min2 over the
    agent's capacity counter, +big where the agent has fewer units);
    active: [n] bool; eps scalar.  Returns (best [m], winner [m] int32,
    wants [n] bool) — the segment-max bid per agent, the winning request
    per agent (ties to the lowest index, n where no bid), and which active
    requests bid at all (top profit > 0).

    The runner-up value v2 substitutes the favourite agent's own
    second-cheapest unit (ask2) at the k1 column — the column market's
    equivalent of masking out the single chosen slot in a slot-expanded
    round.
    """
    W = jnp.asarray(W)
    ask = jnp.asarray(ask, W.dtype)
    ask2 = jnp.asarray(ask2, W.dtype)
    active = jnp.asarray(active, bool)
    n, m = W.shape
    big = jnp.asarray(jnp.finfo(W.dtype).max / 4, W.dtype)
    P = jnp.where(active[:, None], W - ask[None, :], -big)
    v1 = P.max(axis=1)
    k1 = P.argmax(axis=1)
    onehot = jnp.arange(m)[None, :] == k1[:, None]
    alt = jnp.where(onehot & active[:, None], W - ask2[None, :], P)
    v2 = jnp.maximum(alt.max(axis=1), 0.0)
    wants = active & (v1 > 0.0)
    bid = ask[k1] + (v1 - v2) + eps
    best = jnp.full((m,), -big, W.dtype).at[
        jnp.where(wants, k1, m)].max(bid, mode="drop")
    at_best = wants & (bid == best[jnp.minimum(k1, m - 1)])
    winner = jnp.full((m,), n, jnp.int32).at[
        jnp.where(at_best, k1, m)].min(
            jnp.arange(n, dtype=jnp.int32), mode="drop")
    return best, winner, wants


# ---------------- attention ----------------

def attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: [B,Sq,H,d], k/v: [B,Sk,Hkv,d] (GQA by head grouping)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    s = s * (scale or 1.0 / np.sqrt(d))
    qpos = np.arange(sq)[:, None]
    kpos = np.arange(k.shape[1])[None, :]
    mask = np.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(jnp.asarray(mask)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, valid):
    """q: [B,H,d]; caches: [B,M,Hkv,d]; valid: [B,M] bool."""
    b, h, d = q.shape
    hkv = k_cache.shape[2]
    qg = q.reshape(b, hkv, h // hkv, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bmkd->bkgm", qg, k_cache.astype(jnp.float32))
    s = s / np.sqrt(d)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgm,bmkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)


# ---------------- WKV6 (stepwise recurrence) ----------------

def wkv6_ref(r, k, v, log_w, u, s0):
    """r,k,v,log_w: [B,S,H,dk] (dv == dk); u: [H,dk]; s0: [B,H,dk,dv].

    o_t = r_t @ (S_{t-1} + (u*k_t)^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    r = jnp.asarray(r, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    log_w = jnp.asarray(log_w, jnp.float32)
    u = jnp.asarray(u, jnp.float32)

    def step(s, inp):
        rt, kt, vt, lwt = inp
        kv = jnp.einsum("bhd,bhv->bhdv", kt, vt)
        o = jnp.einsum("bhd,bhdv->bhv", rt, s + u[None, :, :, None] * kv)
        s = s * jnp.exp(lwt)[..., None] + kv
        return s, o

    xs = tuple(x.swapaxes(0, 1) for x in (r, k, v, log_w))
    sT, o = jax.lax.scan(step, jnp.asarray(s0, jnp.float32), xs)
    return o.swapaxes(0, 1), sT


# ---------------- SSD / Mamba2 (stepwise recurrence) ----------------

def ssd_ref(x, bmat, cmat, dt, a_log, d_skip, s0):
    """x: [B,S,H,hd]; bmat,cmat: [B,S,ds]; dt: [B,S,H]; s0: [B,H,hd,ds].

    S_t = a_t S_{t-1} + dt_t (x_t outer B_t);  y_t = S_t @ C_t + D * x_t
    """
    x = jnp.asarray(x, jnp.float32)
    bmat = jnp.asarray(bmat, jnp.float32)
    cmat = jnp.asarray(cmat, jnp.float32)
    dt = jnp.asarray(dt, jnp.float32)
    a = lambda dtt: jnp.exp(-jnp.exp(jnp.asarray(a_log, jnp.float32))[None] * dtt)

    def step(s, inp):
        xt, bt, ct, dtt = inp
        s = s * a(dtt)[..., None, None] + jnp.einsum(
            "bh,bhd,bn->bhdn", dtt, xt, bt)
        y = jnp.einsum("bhdn,bn->bhd", s, ct)
        y = y + jnp.asarray(d_skip, jnp.float32)[None, :, None] * xt
        return s, y

    xs = (x.swapaxes(0, 1), bmat.swapaxes(0, 1), cmat.swapaxes(0, 1),
          dt.swapaxes(0, 1))
    sT, y = jax.lax.scan(step, jnp.asarray(s0, jnp.float32), xs)
    return y.swapaxes(0, 1), sT
