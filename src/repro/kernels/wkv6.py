"""Pallas WKV6 kernel: chunkwise-parallel RWKV6 recurrence.

Mirrors models/ssm.wkv6_chunked (same math, same chunk size), with the state
held in a VMEM fp32 scratch that persists across the chunk grid dimension —
the TPU-native replacement for the CUDA sequential-scan kernel (DESIGN.md §3).
All decay exponents are relative (<= 0): no overflow paths.

Grid: (B * H, S / C). Per program: r/k/v/log_w chunk tiles [C, dk] plus the
running state [dk, dv] — with C=16, dk=dv=64 that is ~4*16*64*4 + 64*64*4
= 32 KiB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 16


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sT_ref, s_ref,
                 *, chunk):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)     # [C, dk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)     # [C, dv]
    lw = lw_ref[0].astype(jnp.float32)   # [C, dk]
    u = u_ref[0].astype(jnp.float32)     # [1, dk] -> broadcast

    p = jnp.cumsum(lw, axis=0)           # inclusive
    p_shift = p - lw                     # exclusive
    state = s_ref[...]

    # inter-chunk
    r_dec = r * jnp.exp(p_shift)
    o = jax.lax.dot_general(r_dec, state, (((1,), (0,)), ((), ())))  # [C, dv]

    # intra-chunk: decay[t,s,d] = exp(p_shift[t,d] - p[s,d]) for s < t
    c = chunk
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    tri = (si < ti)[:, :, None]
    dec = jnp.exp(jnp.where(tri, p_shift[:, None, :] - p[None, :, :], -jnp.inf))
    a = jnp.einsum("td,sd,tsd->ts", r, k, dec,
                   preferred_element_type=jnp.float32)
    diag = (r * u * k).sum(axis=-1)      # bonus: r_t . (u * k_t)
    a = a + diag[:, None] * jnp.eye(c, dtype=jnp.float32)
    o = o + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())))
    o_ref[0] = o.astype(o_ref.dtype)

    # state update
    p_last = p[-1:, :]                   # [1, dk]
    k_dec = k * jnp.exp(p_last - p)      # [C, dk]
    s_ref[...] = state * jnp.exp(p_last)[0][:, None] + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())))

    @pl.when(ic == nc - 1)
    def _emit_state():
        sT_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, log_w, u, *, chunk: int = CHUNK, interpret: bool = True):
    """r,k,v,log_w: [B,S,H,dk] (dv == dk); u: [H,dk].

    Returns (o [B,S,H,dk], sT [B,H,dk,dk]); initial state is zero (callers
    with a nonzero state fold it in with one extra jnp chunk — the LM path
    uses models/ssm.wkv6_chunked for that case).
    """
    b, s, h, dk = r.shape
    pad = (-s) % chunk
    if pad:
        padfn = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, log_w = padfn(r), padfn(k), padfn(v), padfn(log_w)
    ss = s + pad
    nc = ss // chunk

    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, ss, dk)
    rr, kk, vv, lw = fold(r), fold(k), fold(v), fold(log_w)
    uu = jnp.broadcast_to(u[None], (b, h, dk)).reshape(b * h, 1, dk)

    o, sT = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1, dk), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dk), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, dk, dk), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, ss, dk), r.dtype),
            jax.ShapeDtypeStruct((b * h, dk, dk), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dk), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, lw, uu)
    o = o.reshape(b, h, ss, dk).transpose(0, 2, 1, 3)
    return o[:, :s], sT.reshape(b, h, dk, dk)
