"""Optimized-HLO text analysis: collective op inventory and wire-byte estimates.

``compiled.cost_analysis()`` does not report collective traffic, so the
roofline analyzer parses ``compiled.as_text()`` (post-SPMD-partitioning HLO)
and sums the bytes moved by every collective op.

Wire-byte model (ring algorithms over a group of k participants, per device):
    all-reduce        2 * S * (k-1)/k     (reduce-scatter + all-gather phases)
    all-gather        R * (k-1)/k         (R = gathered result bytes)
    reduce-scatter    S * (k-1)/k         (S = operand bytes)
    all-to-all        S * (k-1)/k
    collective-permute  R                 (point-to-point)

Notes:
  * cost_analysis / HLO text are PER-PARTITION under SPMD, so these are
    per-device wire bytes already.
  * A ``while`` (lax.scan) body appears once in the HLO regardless of trip
    count; callers that scan over layers account for that via the unrolled
    L=1/L=2 extrapolation in repro.roofline.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.5 = bf16[16,256,8192]{2,1,0} all-gather(%param.3), ...
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^=]*?\)|[\w\[\],{}\s]+?)\s+"
    r"(?P<op>[\w\-]+)\((?P<operands>[^)]*)\)"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every dtype[shape] group found in a type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups, group_size]<=[...]
        return int(m.group(2))
    return default


@dataclass
class CollectiveOp:
    op: str
    result_bytes: int
    operand_bytes: int
    group_size: int
    computation: str
    line: str = field(repr=False, default="")

    @property
    def wire_bytes(self) -> float:
        k = max(self.group_size, 1)
        ring = (k - 1) / k if k > 1 else 0.0
        if self.op == "all-reduce":
            return 2.0 * self.operand_bytes * ring
        if self.op == "all-gather":
            return self.result_bytes * ring
        if self.op == "reduce-scatter":
            return self.operand_bytes * ring
        if self.op == "all-to-all":
            return self.operand_bytes * ring
        if self.op == "collective-permute":
            return float(self.result_bytes)
        return float(self.result_bytes)


def parse_collectives(hlo_text: str, default_group: int = 1) -> list[CollectiveOp]:
    """Extract every collective op from optimized HLO text.

    Handles async pairs (``all-reduce-start``/``-done``) by counting only the
    ``-start`` op. Returns ops tagged with the computation they live in, so a
    caller can attribute while-body collectives separately if desired.
    """
    shapes: dict[str, int] = {}
    ops: list[CollectiveOp] = []
    computation = "<module>"
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers look like:  %body.42 (arg.1: ...) -> ... {   or  ENTRY %main ... {
        if stripped.endswith("{") and ("(" in stripped) and "=" not in stripped.split("(")[0]:
            header = stripped.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
            if header:
                computation = header
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group("name"), m.group("type"), m.group("op")
        shapes[name] = _shape_bytes(type_str)
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base not in _COLLECTIVES:
            continue
        operand_bytes = 0
        for operand in m.group("operands").split(","):
            oname = operand.strip().lstrip("%").split(" ")[0]
            operand_bytes += shapes.get(oname, 0)
        result_bytes = shapes[name]
        if operand_bytes == 0:
            operand_bytes = result_bytes
        ops.append(
            CollectiveOp(
                op=base,
                result_bytes=result_bytes,
                operand_bytes=operand_bytes,
                group_size=_group_size(line, default_group),
                computation=computation,
                line=stripped[:160],
            )
        )
    return ops


def collective_wire_bytes(hlo_text: str, default_group: int = 1) -> dict:
    """Per-collective-type wire bytes (per device) + total, from HLO text."""
    ops = parse_collectives(hlo_text, default_group)
    by_type: dict[str, float] = {}
    for c in ops:
        by_type[c.op] = by_type.get(c.op, 0.0) + c.wire_bytes
    by_type["total"] = sum(by_type.values())
    by_type["count"] = len(ops)
    return by_type
