"""Lightweight timing helpers for benchmarks (CPU wall-clock)."""
from __future__ import annotations

import time
from contextlib import nullcontext

import jax


def phase_scope(profiler, name: str):
    """``profiler.phase(name)`` or a no-op context when no profiler is set.

    The one shared implementation of the serving-layer profiling idiom:
    routers, the auction layer and the serving loops all call this instead
    of re-deriving the nullcontext dispatch (the profiler itself is
    duck-typed — see `repro.serving.simulator.RoutingProfiler`).
    """
    return profiler.phase(name) if profiler is not None else nullcontext()


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def lap(self) -> float:
        t = time.perf_counter()
        dt = t - self.t0
        self.t0 = t
        return dt


def bench_call(fn, *args, warmup: int = 2, iters: int = 5, block: bool = True) -> float:
    """Median wall-clock microseconds per call."""
    for _ in range(warmup):
        out = fn(*args)
        if block:
            jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        if block:
            jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
