from repro.utils.tree import (
    param_count,
    param_bytes,
    tree_cast,
    tree_zeros_like_f32,
    tree_global_norm,
)
from repro.utils.hlo import collective_wire_bytes, parse_collectives
from repro.utils.timing import Timer, bench_call
