"""Pytree utilities used across the framework (no flax/optax available)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    """Total number of parameters in a pytree (works on ShapeDtypeStructs too)."""
    return int(sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree)))


def param_bytes(tree) -> int:
    return int(
        sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize for x in jax.tree.leaves(tree))
    )


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_zeros_like_f32(tree):
    """f32 zeros with the same structure/shape — used for optimizer state."""
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def tree_global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)
