"""Serving launcher: IEMAS (or a baseline) routing over the simulated cluster.

``python -m repro.launch.serve --router iemas --workload coqa_like``
"""
from __future__ import annotations

import argparse
import json

from repro.core import IEMASRouter
from repro.core.baselines import BASELINES
from repro.core.solvers import available_solvers
from repro.serving import SimCluster, WorkloadSpec, generate, run_workload


def build_router(name: str, infos, *, n_hubs: int = 1, payment_mode="warmstart",
                 solver: str = "mcmf", warm_start: bool = False,
                 spill: bool = True, batched: bool = True,
                 predictor_backend: str = "numpy", seed: int = 0):
    if name == "iemas":
        return IEMASRouter(infos, n_hubs=n_hubs, payment_mode=payment_mode,
                           solver=solver, warm_start=warm_start, spill=spill,
                           batched=batched,
                           predictor_backend=predictor_backend)
    return BASELINES[name](infos, seed=seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--router", default="iemas",
                    choices=["iemas", *BASELINES])
    ap.add_argument("--workload", default="coqa_like")
    ap.add_argument("--agents", type=int, default=9)
    ap.add_argument("--dialogues", type=int, default=16)
    ap.add_argument("--hubs", type=int, default=1,
                    help="shard Phase 2 across K proxy hubs (§4.4); each "
                         "batch is auctioned per hub block")
    ap.add_argument("--solver", default="mcmf",
                    choices=available_solvers(),
                    help="Phase-2 backend from the core/solvers registry")
    ap.add_argument("--warm-start", action="store_true",
                    help="seed each hub's dense auction from the previous "
                         "round's slot prices (cold-starts on membership "
                         "changes; warm-start-capable solvers only)")
    ap.add_argument("--no-spill", action="store_true",
                    help="disable the cross-hub spill re-auction of "
                         "requests a saturated hub left unmatched")
    ap.add_argument("--payment-mode", default="warmstart",
                    choices=["warmstart", "naive"])
    ap.add_argument("--scalar-phase1", action="store_true",
                    help="per-pair scalar QoS loop (oracle) instead of the "
                         "batched Phase-1 tensor path")
    ap.add_argument("--predictor-backend", default="numpy",
                    choices=["numpy", "jax"])
    ap.add_argument("--fail-prob", type=float, default=0.0)
    ap.add_argument("--straggle-prob", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cluster = SimCluster(n_agents=args.agents, seed=args.seed,
                         fail_prob=args.fail_prob,
                         straggle_prob=args.straggle_prob,
                         warmup=not args.no_warmup)
    router = build_router(args.router, cluster.agent_infos(), n_hubs=args.hubs,
                          payment_mode=args.payment_mode, solver=args.solver,
                          warm_start=args.warm_start,
                          spill=not args.no_spill,
                          batched=not args.scalar_phase1,
                          predictor_backend=args.predictor_backend,
                          seed=args.seed)
    dialogues = generate(WorkloadSpec(args.workload, n_dialogues=args.dialogues,
                                      seed=args.seed + 1))
    metrics = run_workload(cluster, router, dialogues)
    if hasattr(router, "accounts"):
        metrics["accounts"] = dict(router.accounts)
    print(json.dumps(metrics, indent=2, default=float))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, default=float)


if __name__ == "__main__":
    main()
