"""Serving launcher: IEMAS (or a baseline) routing over the simulated cluster.

``python -m repro.launch.serve --router iemas --workload coqa_like``

Two serving loops:

  * ``--sim-mode closed`` (default) — the closed-loop `run_workload` round
    loop over real JAX engines: the bit-comparable small-run oracle.
  * ``--sim-mode event`` — the event-driven open-loop
    `repro.serving.simulator.EventSimulator`: Poisson arrivals at
    ``--arrival-rate``, streaming admission (``--max-inflight``), analytic
    engines by default, and a `RoutingProfiler` report attributing routing
    wall-clock per phase against simulated engine compute.  Scale example::

        python -m repro.launch.serve --sim-mode event --agents 128 \\
            --n-dialogues 10000 --arrival-rate 60 --hubs 8 --solver dense

    ``--super-hubs K`` (event mode) federates the simulator itself:
    K super-hub shards, each with its own router, price book and event
    heap, advance independently and synchronize every ``--epoch`` virtual
    seconds via price-book gossip, cross-super-hub spill and exactly-once
    dialogue migration (`repro.serving.federation`).  Federation scale
    example (the SCALE_1K preset's shape)::

        python -m repro.launch.serve --sim-mode event --agents 1024 \\
            --n-dialogues 100000 --arrival-rate 768 --solver dense \\
            --warm-start --super-hubs 8 --epoch 0.5 \\
            --federation-parallel process --max-inflight 2048
"""
from __future__ import annotations

import argparse
import json

from repro.core import IEMASRouter
from repro.core.adversary import POLICIES, AdversaryMix
from repro.core.baselines import BASELINES
from repro.core.solvers import available_solvers
from repro.serving import (DAG_WORKLOADS, EventSimulator, RoutingProfiler,
                           SimCluster, WorkloadSpec, build_federation,
                           generate, iter_dialogues, load_trace,
                           make_arrivals, run_workload)


def build_router(name: str, infos, *, n_hubs: int = 1, payment_mode="warmstart",
                 solver: str = "mcmf", warm_start: bool = False,
                 spill: bool = True, batched: bool = True,
                 predictor_backend: str = "numpy", seed: int = 0,
                 reputation: bool = True, audit_ledger: bool = False,
                 fused: bool = False, explore_bonus: float = 0.0):
    """Build the IEMAS router (or a named baseline) over ``infos``."""
    if name == "iemas":
        kw = {}
        if explore_bonus:
            kw["predictor_kw"] = {"explore": explore_bonus}
        return IEMASRouter(infos, n_hubs=n_hubs, payment_mode=payment_mode,
                           solver=solver, warm_start=warm_start, spill=spill,
                           batched=batched,
                           predictor_backend=predictor_backend,
                           reputation=reputation, audit_ledger=audit_ledger,
                           fused=fused, **kw)
    return BASELINES[name](infos, seed=seed)


def main():
    """Parse CLI flags, build cluster+router, run one serving simulation."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--router", default="iemas",
                    choices=["iemas", *BASELINES])
    ap.add_argument("--workload", default="coqa_like")
    ap.add_argument("--agents", type=int, default=9)
    ap.add_argument("--dialogues", "--n-dialogues", dest="dialogues",
                    type=int, default=16)
    ap.add_argument("--sim-mode", default="closed",
                    choices=["closed", "event"],
                    help="closed: lockstep run_workload oracle loop; "
                         "event: open-loop event-driven simulator "
                         "(repro.serving.simulator) with per-phase routing "
                         "overhead attribution")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="event mode: Poisson dialogue arrivals per virtual "
                         "second (default: synchronous, all at t=0)")
    ap.add_argument("--trace-file", default=None,
                    help="event mode: replay arrival timestamps from a file "
                         "(one virtual-second float per line, # comments "
                         "allowed); overrides --arrival-rate")
    ap.add_argument("--max-inflight", type=int, default=256,
                    help="event mode: streaming-admission window (max "
                         "concurrently active dialogues)")
    ap.add_argument("--batch-cap", type=int, default=16,
                    help="event mode: micro-batch size per router call")
    ap.add_argument("--batch-window", type=float, default=0.02,
                    help="event mode: batching delay in virtual seconds")
    ap.add_argument("--fused", action="store_true",
                    help="run the whole routing step (affinity, prediction, "
                         "values, column auction) as one device-resident "
                         "jitted program (core/routing_fused); needs --hubs "
                         "1 and a staged solver (dense-jax or pallas)")
    ap.add_argument("--incremental", action="store_true",
                    help="event mode: newly ready work bids into the "
                         "standing per-agent duals and dispatches "
                         "provisionally instead of waiting out the "
                         "batch window (needs --warm-start)")
    ap.add_argument("--super-hubs", type=int, default=1,
                    help="event mode: shard the fleet into K super-hubs, "
                         "each with its own router/price-book/event heap "
                         "advancing independently between epochs "
                         "(repro.serving.federation); 1 = the single-heap "
                         "EventSimulator (bit-exact oracle)")
    ap.add_argument("--epoch", type=float, default=0.25,
                    help="federation: virtual seconds between "
                         "synchronization boundaries (price-book gossip, "
                         "cross-super-hub spill, dialogue migration)")
    ap.add_argument("--federation-parallel", default="inline",
                    choices=["inline", "process"],
                    help="federation: advance shards inline, or give each "
                         "super-hub its own OS process with the epoch "
                         "advances overlapped (bit-identical either way)")
    ap.add_argument("--explore-bonus", type=float, default=0.0,
                    help="optimism bonus on predicted quality, "
                         "explore/sqrt(1+n_obs): breaks KV-affinity "
                         "entrenchment of cold-start mismatches "
                         "(0.0 = exact no-op)")
    ap.add_argument("--engine-mode", default=None,
                    choices=["real", "analytic"],
                    help="engine backend (default: real in closed mode, "
                         "analytic in event mode)")
    ap.add_argument("--hubs", type=int, default=1,
                    help="shard Phase 2 across K proxy hubs (§4.4); each "
                         "batch is auctioned per hub block")
    ap.add_argument("--solver", default="mcmf",
                    choices=available_solvers(),
                    help="Phase-2 backend from the core/solvers registry")
    ap.add_argument("--warm-start", action="store_true",
                    help="seed each hub's dense auction from the previous "
                         "round's slot prices (cold-starts on membership "
                         "changes; warm-start-capable solvers only)")
    ap.add_argument("--no-spill", action="store_true",
                    help="disable the cross-hub spill re-auction of "
                         "requests a saturated hub left unmatched")
    ap.add_argument("--payment-mode", default="warmstart",
                    choices=["warmstart", "naive"])
    ap.add_argument("--scalar-phase1", action="store_true",
                    help="per-pair scalar QoS loop (oracle) instead of the "
                         "batched Phase-1 tensor path")
    ap.add_argument("--predictor-backend", default="numpy",
                    choices=["numpy", "jax"])
    ap.add_argument("--adversary", default="none",
                    choices=["none", *POLICIES],
                    help="inject a strategic-agent population "
                         "(repro.core.adversary): published-profile/QoS "
                         "misreports or membership churn, on a seeded "
                         "fraction of the fleet")
    ap.add_argument("--adversary-fraction", type=float, default=0.25,
                    help="fleet fraction assigned the adversary policy")
    ap.add_argument("--adversary-theta", type=float, default=0.4,
                    help="adversary intensity (price/quality misreport "
                         "magnitude)")
    ap.add_argument("--audit-ledger", action="store_true",
                    help="attach the append-only hash-chained settlement "
                         "ledger (repro.core.ledger); the report includes "
                         "verify_chain + the replay audit")
    ap.add_argument("--no-reputation", action="store_true",
                    help="disable reputation-weighted priors (the audit "
                         "residual no longer decays an inflating agent's "
                         "predicted QoS)")
    ap.add_argument("--fail-prob", type=float, default=0.0)
    ap.add_argument("--straggle-prob", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    if args.fused:
        from repro.core.routing_fused import FUSED_SOLVERS
        if args.router != "iemas":
            ap.error("--fused is an IEMAS routing path; baselines have no "
                     "fused step")
        if args.hubs != 1 or args.solver not in FUSED_SOLVERS:
            ap.error("--fused runs one global device-resident column market; "
                     "pass --hubs 1 with a staged solver "
                     f"({', '.join(FUSED_SOLVERS)})")
        if args.incremental:
            ap.error("--fused batches whole rounds through one program and "
                     "cannot dispatch provisionally; drop --incremental")
    if args.super_hubs > 1:
        if args.sim_mode != "event":
            ap.error("--super-hubs federates the event-driven simulator; "
                     "pass --sim-mode event")
        if args.router != "iemas":
            ap.error("federation shards the IEMAS router's price books; "
                     "baselines run single-heap only")
        if args.fused:
            ap.error("--fused runs one global device-resident market and "
                     "cannot be sharded across super-hub event heaps; "
                     "drop one of the two")
        if args.adversary != "none":
            ap.error("--adversary seeds its population over one global "
                     "cluster; strategic-agent studies run single-heap "
                     "(benchmarks/adversarial.py)")
    if args.incremental:
        from repro.core.solvers import get_solver
        if args.sim_mode != "event":
            ap.error("--incremental requires --sim-mode event")
        if not (args.warm_start
                and get_solver(args.solver).supports_warm_start):
            ap.error("--incremental bids into the standing per-agent duals "
                     "and would silently route nothing without them; pass "
                     "--warm-start with a warm-capable solver "
                     "(e.g. --solver dense)")

    engine_mode = args.engine_mode or (
        "analytic" if args.sim_mode == "event" else "real")
    spec = WorkloadSpec(args.workload, n_dialogues=args.dialogues,
                        seed=args.seed + 1)
    if args.workload in DAG_WORKLOADS and args.sim_mode != "event":
        ap.error(f"workload {args.workload!r} is a workflow DAG; precedence "
                 f"scheduling needs --sim-mode event")
    arrivals = None
    if args.sim_mode == "event":
        if args.trace_file:
            arrivals = make_arrivals("trace",
                                     trace=load_trace(args.trace_file))
        else:
            arrivals = make_arrivals(
                "poisson" if args.arrival_rate else "sync",
                rate=args.arrival_rate or 8.0, seed=args.seed + 2)

    if args.super_hubs > 1:
        # hubs-of-hubs: the federation builds its own per-shard
        # cluster/router/loop triples (repro.serving.federation)
        rkw = dict(payment_mode=args.payment_mode, solver=args.solver,
                   warm_start=args.warm_start, spill=not args.no_spill,
                   batched=not args.scalar_phase1,
                   predictor_backend=args.predictor_backend,
                   reputation=not args.no_reputation,
                   audit_ledger=args.audit_ledger)
        if args.hubs != 1:      # default: recut each shard by agents_per_hub
            rkw["n_hubs"] = args.hubs
        if args.explore_bonus:
            rkw["predictor_kw"] = {"explore": args.explore_bonus}
        fed = build_federation(
            iter_dialogues(spec), n_agents=args.agents,
            super_hubs=args.super_hubs, arrivals=arrivals, seed=args.seed,
            engine_mode=engine_mode, max_inflight=args.max_inflight,
            router_kwargs=rkw,
            loop_kwargs=dict(batch_cap=args.batch_cap,
                             batch_window=args.batch_window,
                             incremental=args.incremental, lean=True),
            cluster_kwargs=dict(fail_prob=args.fail_prob,
                                straggle_prob=args.straggle_prob),
            epoch=args.epoch, parallel=args.federation_parallel)
        metrics = fed.run()
        print(json.dumps(metrics, indent=2, default=float))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(metrics, f, indent=2, default=float)
        return

    mix = None
    if args.adversary != "none":
        mix = AdversaryMix(policy=args.adversary,
                           fraction=args.adversary_fraction,
                           theta=args.adversary_theta, seed=args.seed + 3)
    cluster = SimCluster(n_agents=args.agents, seed=args.seed,
                         fail_prob=args.fail_prob,
                         straggle_prob=args.straggle_prob,
                         warmup=not args.no_warmup and engine_mode == "real",
                         engine_mode=engine_mode,
                         adversary_mix=mix)
    router = build_router(args.router, cluster.agent_infos(), n_hubs=args.hubs,
                          payment_mode=args.payment_mode, solver=args.solver,
                          warm_start=args.warm_start,
                          spill=not args.no_spill,
                          batched=not args.scalar_phase1,
                          predictor_backend=args.predictor_backend,
                          seed=args.seed,
                          reputation=not args.no_reputation,
                          audit_ledger=args.audit_ledger,
                          fused=args.fused,
                          explore_bonus=args.explore_bonus)
    if args.sim_mode == "event":
        sim = EventSimulator(cluster, router, iter_dialogues(spec),
                             arrivals=arrivals, batch_cap=args.batch_cap,
                             batch_window=args.batch_window,
                             incremental=args.incremental,
                             max_inflight=args.max_inflight,
                             profiler=RoutingProfiler(), lean=True)
        metrics = sim.run()
    else:
        metrics = run_workload(cluster, router, generate(spec))
    if hasattr(router, "accounts"):
        metrics["accounts"] = dict(router.accounts)
    if mix is not None:
        metrics["adversaries"] = sorted(cluster.adversaries)
        if hasattr(router, "pool"):
            metrics["reputation"] = router.pool.reputations()
    if getattr(router, "settlement", None) is not None:
        metrics["ledger"] = router.settlement.audit(router.accounts)
        metrics["ledger"]["head"] = router.settlement.head
    print(json.dumps(metrics, indent=2, default=float))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, default=float)


if __name__ == "__main__":
    main()
