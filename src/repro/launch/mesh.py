"""Production mesh builders.

Single pod: (16, 16) = 256 TPU v5e chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
carries pure data parallelism whose gradient all-reduce crosses DCI.

Functions, not module constants: importing this module must never touch jax
device state (smoke tests see 1 device; only dryrun forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(the dry-run launcher forces XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax)")
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(shape))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model],
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
