"""Training launcher: ``python -m repro.launch.train --arch qwen3-8b ...``.

Runs on whatever devices exist: full configs train on the production mesh
(real TPUs); ``--smoke`` trains the reduced config of the same family on CPU
(used by examples/train_small.py for the ~100M-scale demonstration run).
Fault tolerance: atomic checkpoints + resume-from-latest (``--ckpt-dir``).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.distributed.sharding import (TRAIN_PARAM_RULES, TRAIN_RULES,
                                        ShardingPolicy, apply_policy)
from repro.models import build_model
from repro.training.compress import CompressionConfig
from repro.training.data import SyntheticLM
from repro.training.loop import train_loop
from repro.training.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the same family (CPU-runnable)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="width multiplier on the reduced config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        s = args.scale
        cfg = cfg.scaled(dtype="float32",
                         d_model=int(64 * s), d_ff=int(128 * s),
                         head_dim=int(16 * s))
    model = build_model(cfg)
    data = SyntheticLM(cfg.vocab_size, args.seq_len, args.batch,
                       seed=args.seed)
    comp = CompressionConfig(enabled=args.compress)
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps)

    n_dev = len(jax.devices())
    policy = None
    if n_dev > 1:
        from repro.distributed.elastic import remesh
        policy = ShardingPolicy(remesh(n_dev), acts=TRAIN_RULES,
                                params=TRAIN_PARAM_RULES)

    ctx = apply_policy(policy) if policy else apply_policy(None)
    with ctx:
        out = train_loop(model, data, steps=args.steps, opt_cfg=opt,
                         compression=comp, accum_steps=args.accum,
                         ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         seed=args.seed)
    for step, loss in out["losses"]:
        print(f"step {step:5d}  loss {loss:.4f}")
    print(f"done: {args.steps} steps in {out['wall_s']:.1f}s "
          f"({args.steps * args.batch * args.seq_len / out['wall_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
