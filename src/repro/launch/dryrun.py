"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

NOTE: the first two executable lines below MUST set XLA_FLAGS before any
other import — jax locks the device count on first initialization.

For each cell this produces (artifacts/dryrun/<arch>__<shape>__<mesh>.json):
  * proof of shardability: .lower().compile() success on the production mesh,
  * memory_analysis() per-device bytes (the "fits" check),
  * cost_analysis() flops/bytes + HLO collective wire bytes,
  * unrolled L=1/L=2 variant costs -> exact per-layer extrapolation
    (cost_analysis counts a lax.scan body once; see DESIGN.md §6),
  * analytic MODEL_FLOPS cross-check.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all                  # single-pod, variants
  python -m repro.launch.dryrun --all --multi-pod      # 512-chip pass
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (SHAPES, cell_supported, get_config, list_archs,
                           model_flops, param_counts)
from repro.distributed.sharding import (DECODE_PARAM_RULES, DECODE_RULES,
                                        TRAIN_PARAM_RULES, TRAIN_RULES,
                                        ShardingPolicy, apply_policy,
                                        param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.registry import cache_axes, decode_state_specs, input_specs
from repro.models.scan_config import unrolled
from repro.training.loop import make_train_step
from repro.training.optimizer import OptConfig
from repro.utils.hlo import collective_wire_bytes


def build_policy(mesh, kind: str, shape_name: str) -> ShardingPolicy:
    if kind == "train":
        acts, params = dict(TRAIN_RULES), dict(TRAIN_PARAM_RULES)
    else:
        acts, params = dict(DECODE_RULES), dict(DECODE_PARAM_RULES)
        if kind == "prefill":
            acts["seq"] = "model"  # sequence-parallel residual stream
        # long caches shard on sequence (8 KV heads can't divide 16)
        acts["cache_seq"] = "model"
        acts["kv_heads"] = None
    return ShardingPolicy(mesh, acts=acts, params=params)


def _with_shardings(specs, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs, shardings)


def _abstract_params(model, policy):
    abstract = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shardings = param_shardings(policy, abstract, model.param_axes())
    return _with_shardings(abstract, shardings)


def _batch_specs(cfg, shape, policy):
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        if name == "tokens":
            axes = ("batch", "seq")
        elif name == "patches":
            axes = ("batch", "patches", "embed")
        else:  # frames
            axes = ("batch", "src_seq", "embed")
        out[name] = jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=policy.act_sharding(axes, s.shape))
    return out


def _cache_specs(model, shape, policy):
    specs, tok = decode_state_specs(model, shape)
    axes = cache_axes(model)

    def attach(spec, ax):
        return jax.ShapeDtypeStruct(
            spec.shape, spec.dtype,
            sharding=policy.act_sharding(tuple(ax.split()), spec.shape))

    specs = jax.tree.map(attach, specs, axes)
    tok = jax.ShapeDtypeStruct(
        tok.shape, tok.dtype, sharding=policy.act_sharding(("batch",), tok.shape))
    return specs, tok


def _opt_specs(params_specs):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=s.sharding)
    adam = {
        "master": jax.tree.map(f32, params_specs),
        "m": jax.tree.map(f32, params_specs),
        "v": jax.tree.map(f32, params_specs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return {"adam": adam}


TRAIN_ACCUM = 4


def _lower_cell(cfg, shape, mesh, kind, accum: int = TRAIN_ACCUM):
    model = build_model(cfg)
    policy = build_policy(mesh, kind, shape.name)
    with apply_policy(policy):
        params = _abstract_params(model, policy)
        if kind == "train":
            # 4 sequential microbatches: bounds activation memory at the
            # same global batch (EXPERIMENTS.md §Perf iteration 3)
            step = make_train_step(model, OptConfig(), accum_steps=accum)
            opt = _opt_specs(params)
            batch = _batch_specs(cfg, shape, policy)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params, opt, batch)
        elif kind == "prefill":
            batch = _batch_specs(cfg, shape, policy)
            # constrain the produced KV cache's shardings (otherwise GSPMD
            # may replicate multi-GB caches per device; §Perf iteration 1)
            cache_sds, _ = _cache_specs(model, shape, policy)
            cache_out = jax.tree.map(lambda s: s.sharding, cache_sds)
            logits_out = policy.act_sharding(("batch", "vocab"),
                                             (shape.global_batch, cfg.vocab_size))
            lowered = jax.jit(
                lambda p, b: model.prefill(p, b),
                out_shardings=(logits_out, cache_out)).lower(params, batch)
        else:  # decode
            cache, tok = _cache_specs(model, shape, policy)
            lowered = jax.jit(model.decode_step,
                              donate_argnums=(1,)).lower(params, cache, tok)
    return lowered, model


def _cost_of(lowered):
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_wire_bytes(hlo)
    ma = compiled.memory_analysis()
    mem = dict(argument=ma.argument_size_in_bytes, output=ma.output_size_in_bytes,
               temp=ma.temp_size_in_bytes, alias=ma.alias_size_in_bytes)
    return compiled, {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": mem,
    }


def variant_plan(cfg) -> list[tuple[dict, float]]:
    """[(config overrides, coefficient)]; corrected = sum coeff * C(variant)."""
    if cfg.is_encdec:
        le, ld = cfg.enc_layers, cfg.n_layers
        return [({"enc_layers": 1, "n_layers": 1}, 1.0 - (le - 1) - (ld - 1)),
                ({"enc_layers": 2, "n_layers": 1}, float(le - 1)),
                ({"enc_layers": 1, "n_layers": 2}, float(ld - 1))]
    if cfg.attn_every:  # zamba: unit = group of attn_every mamba + shared attn
        g = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers - g * cfg.attn_every
        units = g + tail / cfg.attn_every  # tail ~ fractional group
        return [({"n_layers": cfg.attn_every}, 2.0 - units),
                ({"n_layers": 2 * cfg.attn_every}, units - 1.0)]
    lf = cfg.n_layers
    return [({"n_layers": 1}, 2.0 - lf), ({"n_layers": 2}, float(lf - 1))]


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             *, variants: bool = True, out_dir: str = "artifacts/dryrun",
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind, "supported": ok}
    if not ok:
        rec["skip_reason"] = reason
        _save(rec, out_dir)
        return rec
    counts = param_counts(cfg)
    rec["params_total"] = counts["total"]
    rec["params_active"] = counts["active"]
    rec["model_flops"] = model_flops(cfg, shape)
    try:
        t0 = time.time()
        lowered, _ = _lower_cell(cfg, shape, mesh, shape.kind)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        _, cost = _cost_of(lowered)
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["full"] = cost
        if variants:
            vcosts = []
            for overrides, coeff in variant_plan(cfg):
                vcfg = dataclasses.replace(cfg, **overrides)
                with unrolled():
                    vlow, _ = _lower_cell(vcfg, shape, mesh, shape.kind)
                    _, vc = _cost_of(vlow)
                vcosts.append({"overrides": overrides, "coeff": coeff,
                               "flops": vc["flops"], "bytes": vc["bytes"],
                               "coll": vc["collectives"]["total"]})
            rec["variants"] = vcosts
            rec["corrected"] = {
                "flops": sum(v["coeff"] * v["flops"] for v in vcosts),
                "bytes": sum(v["coeff"] * v["bytes"] for v in vcosts),
                "coll": sum(v["coeff"] * v["coll"] for v in vcosts),
            }
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _save(rec, out_dir)
    if verbose:
        if rec.get("ok"):
            mem = rec["full"]["memory"]
            tot = (mem["argument"] + mem["temp"] + mem["output"]) / 1e9
            print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:10s} OK "
                  f"flops/dev={rec['full']['flops']:.2e} mem/dev={tot:.1f}GB "
                  f"coll/dev={rec['full']['collectives']['total']/1e9:.2f}GB "
                  f"({rec.get('lower_s', 0)}+{rec.get('compile_s', 0)}s)",
                  flush=True)
        elif not rec["supported"]:
            print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:10s} SKIP "
                  f"({rec['skip_reason'][:60]}...)", flush=True)
        else:
            print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:10s} FAIL "
                  f"{rec['error'][:160]}", flush=True)
    return rec


def _save(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-variants", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    variants = not args.no_variants and not args.multi_pod

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    t0 = time.time()
    results = []
    for arch in archs:
        for shape_name in shapes:
            results.append(run_cell(arch, shape_name, mesh, mesh_name,
                                    variants=variants, out_dir=args.out))
    n_ok = sum(1 for r in results if r.get("ok"))
    n_skip = sum(1 for r in results if not r["supported"])
    n_fail = len(results) - n_ok - n_skip
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED "
          f"in {time.time() - t0:.0f}s")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
