"""Prefix ledger + cache-affinity scores o_ij (Eq. 4).

The proxy maintains, per (agent, dialogue-session), the token sequence of the
last prompt that agent executed. Affinity of a new prompt p_j to agent i is

    o_ij = LCP(p_j, ledger[i, d(j)]) / max(1, |p_j|)          (Eq. 4)

Arch-aware semantics (DESIGN.md §Arch-applicability): attention agents can
reuse ANY common prefix; recurrent agents (rwkv/zamba backbones) can only
reuse an EXACT extension of the previous prompt (the state cannot be rewound),
so their affinity is |prev| / |p_j| if p_j extends prev, else 0.

Entries live in a persistent padded token arena (`PaddedLedgerStore`): one
(S, L) int32 matrix whose rows are (agent, session) entries, updated in place
on ``update``/``evict`` instead of being re-materialized from Python dicts
every batch. ``affinity_matrix`` computes the full N x M request-agent matrix;
the padded batched form gathers rows straight out of the arena and is backed
by the Pallas LCP kernel (repro.kernels) when ``use_kernel=True``. The fused
routing step (`core/routing_fused.py`) mirrors the same arena on device and
performs the gather there.
"""
from __future__ import annotations

import heapq

import numpy as np

from .buckets import pow2_bucket

PAD_PROMPT = -1   # prompt padding token (never a real token)
PAD_LEDGER = -2   # ledger padding token (never matches PAD_PROMPT)


def lcp_length(a: np.ndarray, b: np.ndarray) -> int:
    """Length of the longest common prefix of two token arrays."""
    n = min(len(a), len(b))
    if n == 0:
        return 0
    neq = np.nonzero(a[:n] != b[:n])[0]
    return int(neq[0]) if len(neq) else n


class PaddedLedgerStore:
    """Persistent padded token arena behind `PrefixLedger`.

    One ``(S, L)`` int32 matrix holds every (agent, session) ledger entry as
    a row (padded with ``PAD_LEDGER``), plus a parallel ``lens`` vector. Rows
    are written in place on record and recycled on evict; both dimensions
    grow by pow-2 doubling (`core/buckets.pow2_bucket`) so the arena's shape
    — and therefore any jit program traced over it — changes O(log) times
    over a run, not per batch.

    Row 0 is a reserved all-pad sentinel with length 0: batch gathers map
    "no entry for this (agent, session)" to row 0, which scores affinity 0
    through the shared LCP post-processing without any masking.

    ``dirty_rows``/``consume_dirty`` expose the rows written since the last
    drain so a device mirror (the fused routing step) can scatter just the
    changed rows instead of re-uploading the arena; ``shape_version`` bumps
    on regrow, signalling the mirror to re-allocate.
    """

    def __init__(self, floor_rows: int = 8, floor_width: int = 8):
        self.tokens = np.full((floor_rows, floor_width), PAD_LEDGER, np.int32)
        self.lens = np.zeros((floor_rows,), np.int32)
        self.row_of: dict[tuple, int] = {}
        self._free: list[int] = []
        self._next = 1                       # row 0 = absent sentinel
        self._dirty: set[int] = set()
        self.version = 0                     # bumps on every write
        self.shape_version = 0               # bumps on regrow

    @property
    def width(self) -> int:
        """Current padded token width L of the arena."""
        return self.tokens.shape[1]

    def _regrow(self, rows: int, width: int) -> None:
        """Reallocate the arena to at least (rows, width), pow-2 bucketed."""
        s = pow2_bucket(max(rows, self.tokens.shape[0]))
        w = pow2_bucket(max(width, self.width))
        if (s, w) == self.tokens.shape:
            return
        grown = np.full((s, w), PAD_LEDGER, np.int32)
        grown[: self.tokens.shape[0], : self.width] = self.tokens
        self.tokens = grown
        self.lens = np.concatenate(
            [self.lens, np.zeros((s - len(self.lens),), np.int32)])
        self.shape_version += 1
        self.version += 1
        # every row moved to a fresh buffer: device mirrors must re-upload
        self._dirty = set(range(self._next))

    def put(self, key: tuple, toks: np.ndarray) -> int:
        """Write (or overwrite) the entry for ``key``; returns its row."""
        k = len(toks)
        row = self.row_of.get(key)
        if row is None:
            row = self._free.pop() if self._free else self._next
            if row == self._next:
                self._next += 1
            self.row_of[key] = row
        self._regrow(self._next, max(k, 1))
        self.tokens[row, :k] = toks
        self.tokens[row, k:] = PAD_LEDGER    # clear stale tail on row reuse
        self.lens[row] = k
        self._dirty.add(row)
        self.version += 1
        return row

    def drop(self, key: tuple) -> None:
        """Recycle the row for ``key`` (no-op if absent)."""
        row = self.row_of.pop(key, None)
        if row is None:
            return
        self.lens[row] = 0
        self.tokens[row, :] = PAD_LEDGER
        self._free.append(row)
        self._dirty.add(row)
        self.version += 1

    def get(self, key: tuple) -> np.ndarray | None:
        """The stored token row for ``key`` (a view), or None."""
        row = self.row_of.get(key)
        if row is None:
            return None
        return self.tokens[row, : self.lens[row]]

    def rows_for(self, sessions: list, agent_ids: list) -> np.ndarray:
        """(len(sessions), len(agent_ids)) row indices; 0 where absent."""
        out = np.zeros((len(sessions), len(agent_ids)), np.int32)
        get = self.row_of.get
        for i, a in enumerate(agent_ids):
            for j, d in enumerate(sessions):
                out[j, i] = get((a, d), 0)
        return out

    def consume_dirty(self) -> np.ndarray:
        """Rows written since the last drain (then clears the set)."""
        rows = np.fromiter(self._dirty, np.int32, len(self._dirty))
        self._dirty.clear()
        return rows


class PrefixLedger:
    """Per-(agent, dialogue) record of the last prompt each agent served.

    Entries are indexed per agent (``_by_agent``) so the hot-path queries —
    ``recent_sessions`` every batch, ``evict``/``sessions`` on membership
    events — cost O(sessions of that agent), not O(every ledger entry ever
    written): at 10k streamed dialogues the flat scan made Phase 1 grow
    quadratically over a serving run. Token payloads live in the persistent
    padded arena ``store`` (`PaddedLedgerStore`), updated incrementally on
    ``update``/``evict`` so batch paths gather rows instead of rebuilding
    padded tiles from dicts.

    ``max_sessions_per_agent`` (None = unbounded, the default) LRU-caps the
    tracked sessions per agent, bounding ledger memory on streamed runs.
    Setting it to at least the agent's published ``cache_slots`` is
    behavior-neutral on the router path: any session older than the
    ``cache_slots`` most recent is presumed backend-evicted and has its
    affinity zeroed by ``apply_lru`` anyway, so dropping its ledger entry
    changes nothing the auction sees (the router sizes the cap from the
    live agents' published cache capacities).
    """

    def __init__(self, max_sessions_per_agent: int | None = None):
        self.store = PaddedLedgerStore()
        # agent_id -> {dialogue_id: last-touch clock}, kept in sync with
        # the store (the per-agent LRU index; insertion order tracks recency
        # because every touch deletes + reinserts)
        self._by_agent: dict[str, dict[str, int]] = {}
        self.max_sessions_per_agent = max_sessions_per_agent
        self._clock = 0

    def update(self, agent_id: str, dialogue_id: str, prompt_tokens) -> None:
        """Record the prompt agent ``agent_id`` just executed (Phase 4)."""
        self._clock += 1
        self.store.put((agent_id, dialogue_id),
                       np.asarray(prompt_tokens, dtype=np.int32))
        touched = self._by_agent.setdefault(agent_id, {})
        touched.pop(dialogue_id, None)   # re-insert at the recent end
        touched[dialogue_id] = self._clock
        cap = self.max_sessions_per_agent
        if cap is not None and len(touched) > cap:
            victim = next(iter(touched))  # oldest (dict preserves order)
            del touched[victim]
            self.store.drop((agent_id, victim))

    def recent_sessions(self, agent_id: str, limit: int) -> set:
        """The ``limit`` most-recently-served sessions of an agent — a local
        LRU model of the backend's cache (the hub's 'compact cache-state
        summary', §4.4). Sessions beyond it are presumed evicted."""
        touched = self._by_agent.get(agent_id)
        if touched is None:
            return set()
        if len(touched) <= limit:
            return set(touched)
        return {d for d, _ in heapq.nlargest(limit, touched.items(),
                                             key=lambda kv: kv[1])}

    def keep_mask(self, dialogue_ids: list, agent_ids: list,
                  cache_slots: list) -> np.ndarray:
        """(n, m) bool: True where agent i still has session j resident
        under the LRU cache model (always True for unbounded agents)."""
        n, m = len(dialogue_ids), len(agent_ids)
        keep = np.ones((n, m), bool)
        for i, (aid, slots) in enumerate(zip(agent_ids, cache_slots)):
            if slots > 0:
                recent = self.recent_sessions(aid, slots)
                keep[:, i] = np.fromiter((d in recent for d in dialogue_ids),
                                         dtype=bool, count=n)
        return keep

    def apply_lru(self, o: np.ndarray, dialogue_ids: list,
                  agent_ids: list, cache_slots: list) -> np.ndarray:
        """LRU cache model (§4.4 published cache summaries): zero, in place,
        the affinity of sessions each agent has presumably evicted — only
        the ``cache_slots[i]`` most-recent sessions keep their score
        (``cache_slots[i] <= 0`` means unbounded). One column masking per
        agent instead of the per-(request, agent) Python loop."""
        keep = self.keep_mask(dialogue_ids, agent_ids, cache_slots)
        o[:] = np.where(keep, o, 0.0)
        return o

    def parent_credit(self, o: np.ndarray, prompts: list,
                      parent_sessions: list, agent_ids: list,
                      extension_only_mask=None,
                      cache_slots=None) -> np.ndarray:
        """Precedence-aware affinity (workflow-DAG handoffs): raise, in
        place, ``o[j, i]`` to the best affinity over request j's *parent
        step* sessions still resident on agent i.

        A DAG step's prompt begins with its parents' contexts, so an agent
        that served a parent step holds a usable KV prefix even though the
        child runs under a fresh session key — without this credit the
        auction sees a cold cache at every handoff and co-placement never
        pays.  ``parent_sessions[j]`` lists request j's parent session ids
        (empty for linear dialogues — their rows are untouched).  Parent
        entries are LRU-masked exactly like own-session affinity: with
        ``cache_slots[i] > 0`` only agent i's ``cache_slots[i]``
        most-recent sessions can contribute (§4.4 published cache
        summaries).

        Vectorized: all (row, parent) candidate pairs are flattened, their
        ledger rows gathered from the padded arena, the LCP matrix computed
        in one batched pass, and the per-row maximum folded into ``o`` with
        a masked segment-max (``np.maximum.at``). The retired per-pair
        Python loop survives as ``_parent_credit_scalar`` (test oracle).
        """
        cand = [(j, s) for j, ps in enumerate(parent_sessions) for s in ps]
        if not cand:
            return o
        cj = np.array([j for j, _ in cand], np.int64)
        sess = [s for _, s in cand]
        crows = self.store.rows_for(sess, agent_ids)          # (C, m)
        clen = self.store.lens[crows]
        plens = np.array([len(prompts[j]) for j in cj], np.int64)
        width = max(int(plens.max()), self.store.width)
        pmat = np.full((len(cand), width), PAD_PROMPT, np.int32)
        for r, j in enumerate(cj):
            pmat[r, : plens[r]] = prompts[j]
        ctoks = np.full((len(cand), len(agent_ids), width), PAD_LEDGER,
                        np.int32)
        ctoks[:, :, : self.store.width] = self.store.tokens[crows]
        raw = np.logical_and.accumulate(
            pmat[:, None, :] == ctoks, axis=-1).sum(-1)
        lcp = np.minimum(raw, np.minimum(plens[:, None], clen))
        cred = lcp / np.maximum(plens[:, None], 1)
        if extension_only_mask is not None:
            ext = np.asarray(extension_only_mask, bool)[None, :]
            full_prev = (lcp == clen) & (clen > 0)
            cred = np.where(
                ext, np.where(full_prev,
                              clen / np.maximum(plens[:, None], 1), 0.0),
                cred)
        if cache_slots is not None:
            slots = np.asarray(cache_slots)
            for i, aid in enumerate(agent_ids):
                if slots[i] > 0:
                    recent = self.recent_sessions(aid, int(slots[i]))
                    live = np.fromiter((s in recent for s in sess),
                                       dtype=bool, count=len(sess))
                    cred[:, i] = np.where(live, cred[:, i], 0.0)
        np.maximum.at(o, cj, cred)
        return o

    def _parent_credit_scalar(self, o: np.ndarray, prompts: list,
                              parent_sessions: list, agent_ids: list,
                              extension_only_mask=None,
                              cache_slots=None) -> np.ndarray:
        """Per-pair scalar `parent_credit` (the vectorized path's oracle)."""
        rows = [j for j, ps in enumerate(parent_sessions) if ps]
        if not rows:
            return o
        for i, aid in enumerate(agent_ids):
            ext = bool(extension_only_mask[i]) \
                if extension_only_mask is not None else False
            slots = int(cache_slots[i]) if cache_slots is not None else 0
            recent = self.recent_sessions(aid, slots) if slots > 0 else None
            for j in rows:
                best = o[j, i]
                for s in parent_sessions[j]:
                    if recent is not None and s not in recent:
                        continue
                    a = self.affinity(aid, s, prompts[j], extension_only=ext)
                    if a > best:
                        best = a
                o[j, i] = best
        return o

    def get(self, agent_id: str, dialogue_id: str):
        """The last recorded prompt for this (agent, dialogue), or None."""
        return self.store.get((agent_id, dialogue_id))

    def evict(self, agent_id: str, dialogue_id: str | None = None) -> None:
        """Drop ledger entries (agent cache eviction resync, Appx C.2.2)."""
        if dialogue_id is not None:
            self.store.drop((agent_id, dialogue_id))
            touched = self._by_agent.get(agent_id)
            if touched is not None:
                touched.pop(dialogue_id, None)
        else:
            for d in list(self._by_agent.get(agent_id, ())):
                self.store.drop((agent_id, d))
            self._by_agent.pop(agent_id, None)

    def sessions(self, agent_id: str) -> list[str]:
        """Dialogue ids with a live ledger entry for this agent."""
        return list(self._by_agent.get(agent_id, ()))

    def affinity(self, agent_id: str, dialogue_id: str, prompt_tokens,
                 *, extension_only: bool = False) -> float:
        """o_ij of one (agent, request) pair (Eq. 4; arch-aware)."""
        prev = self.get(agent_id, dialogue_id)
        p = np.asarray(prompt_tokens, dtype=np.int32)
        if prev is None or len(p) == 0:
            return 0.0
        if extension_only:
            if len(prev) <= len(p) and lcp_length(prev, p) == len(prev):
                return len(prev) / max(1, len(p))
            return 0.0
        return lcp_length(p, prev) / max(1, len(p))

    def affinity_matrix(self, prompts: list, dialogue_ids: list,
                        agent_ids: list, extension_only_mask=None,
                        use_kernel: bool = False) -> np.ndarray:
        """o[j, i] for every (request j, agent i)."""
        n, m = len(prompts), len(agent_ids)
        if use_kernel:
            return self._affinity_matrix_kernel(prompts, dialogue_ids,
                                                agent_ids, extension_only_mask)
        out = np.zeros((n, m))
        for j, (p, d) in enumerate(zip(prompts, dialogue_ids)):
            for i, a in enumerate(agent_ids):
                ext = bool(extension_only_mask[i]) if extension_only_mask is not None else False
                out[j, i] = self.affinity(a, d, p, extension_only=ext)
        return out

    def _affinity_matrix_kernel(self, prompts, dialogue_ids, agent_ids,
                                extension_only_mask):
        """Batched LCP via the Pallas kernel, gathering padded ledger rows
        straight from the persistent arena (no per-pair Python rebuild)."""
        from repro.kernels.ops import lcp_affinity_op

        n, m = len(prompts), len(agent_ids)
        max_p = max((len(p) for p in prompts), default=1)
        rows = self.store.rows_for(dialogue_ids, agent_ids)   # (n, m)
        llen = self.store.lens[rows]
        length = max(max_p, self.store.width, 8)
        pmat = np.full((n, length), PAD_PROMPT, np.int32)
        plen = np.zeros((n,), np.int32)
        for j, p in enumerate(prompts):
            pmat[j, : len(p)] = p
            plen[j] = len(p)
        lmat = np.full((n, m, length), PAD_LEDGER, np.int32)
        lmat[:, :, : self.store.width] = self.store.tokens[rows]
        lcp = np.asarray(lcp_affinity_op(pmat, lmat))  # [N, M]
        lcp = np.minimum(lcp, np.minimum(plen[:, None], llen))
        o = lcp / np.maximum(plen[:, None], 1)
        if extension_only_mask is not None:
            ext = np.asarray(extension_only_mask, bool)[None, :]
            full_prev = (lcp == llen) & (llen > 0)
            o = np.where(ext, np.where(full_prev, llen / np.maximum(plen[:, None], 1), 0.0), o)
        return o
