"""Prefix ledger + cache-affinity scores o_ij (Eq. 4).

The proxy maintains, per (agent, dialogue-session), the token sequence of the
last prompt that agent executed. Affinity of a new prompt p_j to agent i is

    o_ij = LCP(p_j, ledger[i, d(j)]) / max(1, |p_j|)          (Eq. 4)

Arch-aware semantics (DESIGN.md §Arch-applicability): attention agents can
reuse ANY common prefix; recurrent agents (rwkv/zamba backbones) can only
reuse an EXACT extension of the previous prompt (the state cannot be rewound),
so their affinity is |prev| / |p_j| if p_j extends prev, else 0.

``affinity_matrix`` computes the full N x M request-agent matrix; the padded
batched form is backed by the Pallas LCP kernel (repro.kernels) when
``use_kernel=True`` — the beyond-paper fast path benchmarked in §Perf.
"""
from __future__ import annotations

import heapq

import numpy as np


def lcp_length(a: np.ndarray, b: np.ndarray) -> int:
    """Length of the longest common prefix of two token arrays."""
    n = min(len(a), len(b))
    if n == 0:
        return 0
    neq = np.nonzero(a[:n] != b[:n])[0]
    return int(neq[0]) if len(neq) else n


class PrefixLedger:
    """Per-(agent, dialogue) record of the last prompt each agent served.

    Entries are indexed per agent (``_by_agent``) so the hot-path queries —
    ``recent_sessions`` every batch, ``evict``/``sessions`` on membership
    events — cost O(sessions of that agent), not O(every ledger entry ever
    written): at 10k streamed dialogues the flat scan made Phase 1 grow
    quadratically over a serving run.

    ``max_sessions_per_agent`` (None = unbounded, the default) LRU-caps the
    tracked sessions per agent, bounding ledger memory on streamed runs.
    Setting it to at least the agent's published ``cache_slots`` is
    behavior-neutral on the router path: any session older than the
    ``cache_slots`` most recent is presumed backend-evicted and has its
    affinity zeroed by ``apply_lru`` anyway, so dropping its ledger entry
    changes nothing the auction sees (the router sizes the cap from the
    live agents' published cache capacities).
    """

    def __init__(self, max_sessions_per_agent: int | None = None):
        self._store: dict[tuple, np.ndarray] = {}
        # agent_id -> {dialogue_id: last-touch clock}, kept in sync with
        # _store (the per-agent LRU index; insertion order tracks recency
        # because every touch deletes + reinserts)
        self._by_agent: dict[str, dict[str, int]] = {}
        self.max_sessions_per_agent = max_sessions_per_agent
        self._clock = 0

    def update(self, agent_id: str, dialogue_id: str, prompt_tokens) -> None:
        """Record the prompt agent ``agent_id`` just executed (Phase 4)."""
        self._clock += 1
        self._store[(agent_id, dialogue_id)] = np.asarray(prompt_tokens,
                                                          dtype=np.int32)
        touched = self._by_agent.setdefault(agent_id, {})
        touched.pop(dialogue_id, None)   # re-insert at the recent end
        touched[dialogue_id] = self._clock
        cap = self.max_sessions_per_agent
        if cap is not None and len(touched) > cap:
            victim = next(iter(touched))  # oldest (dict preserves order)
            del touched[victim]
            self._store.pop((agent_id, victim), None)

    def recent_sessions(self, agent_id: str, limit: int) -> set:
        """The ``limit`` most-recently-served sessions of an agent — a local
        LRU model of the backend's cache (the hub's 'compact cache-state
        summary', §4.4). Sessions beyond it are presumed evicted."""
        touched = self._by_agent.get(agent_id)
        if touched is None:
            return set()
        if len(touched) <= limit:
            return set(touched)
        return {d for d, _ in heapq.nlargest(limit, touched.items(),
                                             key=lambda kv: kv[1])}

    def apply_lru(self, o: np.ndarray, dialogue_ids: list,
                  agent_ids: list, cache_slots: list) -> np.ndarray:
        """LRU cache model (§4.4 published cache summaries): zero, in place,
        the affinity of sessions each agent has presumably evicted — only
        the ``cache_slots[i]`` most-recent sessions keep their score
        (``cache_slots[i] <= 0`` means unbounded). One column masking per
        agent instead of the per-(request, agent) Python loop."""
        for i, (aid, slots) in enumerate(zip(agent_ids, cache_slots)):
            if slots > 0:
                recent = self.recent_sessions(aid, slots)
                keep = np.fromiter((d in recent for d in dialogue_ids),
                                   dtype=bool, count=len(dialogue_ids))
                o[:, i] = np.where(keep, o[:, i], 0.0)
        return o

    def parent_credit(self, o: np.ndarray, prompts: list,
                      parent_sessions: list, agent_ids: list,
                      extension_only_mask=None,
                      cache_slots=None) -> np.ndarray:
        """Precedence-aware affinity (workflow-DAG handoffs): raise, in
        place, ``o[j, i]`` to the best affinity over request j's *parent
        step* sessions still resident on agent i.

        A DAG step's prompt begins with its parents' contexts, so an agent
        that served a parent step holds a usable KV prefix even though the
        child runs under a fresh session key — without this credit the
        auction sees a cold cache at every handoff and co-placement never
        pays.  ``parent_sessions[j]`` lists request j's parent session ids
        (empty for linear dialogues — their rows are untouched).  Parent
        entries are LRU-masked exactly like own-session affinity: with
        ``cache_slots[i] > 0`` only agent i's ``cache_slots[i]``
        most-recent sessions can contribute (§4.4 published cache
        summaries).
        """
        rows = [j for j, ps in enumerate(parent_sessions) if ps]
        if not rows:
            return o
        for i, aid in enumerate(agent_ids):
            ext = bool(extension_only_mask[i]) \
                if extension_only_mask is not None else False
            slots = int(cache_slots[i]) if cache_slots is not None else 0
            recent = self.recent_sessions(aid, slots) if slots > 0 else None
            for j in rows:
                best = o[j, i]
                for s in parent_sessions[j]:
                    if recent is not None and s not in recent:
                        continue
                    a = self.affinity(aid, s, prompts[j], extension_only=ext)
                    if a > best:
                        best = a
                o[j, i] = best
        return o

    def get(self, agent_id: str, dialogue_id: str):
        """The last recorded prompt for this (agent, dialogue), or None."""
        return self._store.get((agent_id, dialogue_id))

    def evict(self, agent_id: str, dialogue_id: str | None = None) -> None:
        """Drop ledger entries (agent cache eviction resync, Appx C.2.2)."""
        if dialogue_id is not None:
            self._store.pop((agent_id, dialogue_id), None)
            touched = self._by_agent.get(agent_id)
            if touched is not None:
                touched.pop(dialogue_id, None)
        else:
            for d in list(self._by_agent.get(agent_id, ())):
                self._store.pop((agent_id, d), None)
            self._by_agent.pop(agent_id, None)

    def sessions(self, agent_id: str) -> list[str]:
        """Dialogue ids with a live ledger entry for this agent."""
        return list(self._by_agent.get(agent_id, ()))

    def affinity(self, agent_id: str, dialogue_id: str, prompt_tokens,
                 *, extension_only: bool = False) -> float:
        """o_ij of one (agent, request) pair (Eq. 4; arch-aware)."""
        prev = self.get(agent_id, dialogue_id)
        p = np.asarray(prompt_tokens, dtype=np.int32)
        if prev is None or len(p) == 0:
            return 0.0
        if extension_only:
            if len(prev) <= len(p) and lcp_length(prev, p) == len(prev):
                return len(prev) / max(1, len(p))
            return 0.0
        return lcp_length(p, prev) / max(1, len(p))

    def affinity_matrix(self, prompts: list, dialogue_ids: list,
                        agent_ids: list, extension_only_mask=None,
                        use_kernel: bool = False) -> np.ndarray:
        """o[j, i] for every (request j, agent i)."""
        n, m = len(prompts), len(agent_ids)
        if use_kernel:
            return self._affinity_matrix_kernel(prompts, dialogue_ids,
                                                agent_ids, extension_only_mask)
        out = np.zeros((n, m))
        for j, (p, d) in enumerate(zip(prompts, dialogue_ids)):
            for i, a in enumerate(agent_ids):
                ext = bool(extension_only_mask[i]) if extension_only_mask is not None else False
                out[j, i] = self.affinity(a, d, p, extension_only=ext)
        return out

    def _affinity_matrix_kernel(self, prompts, dialogue_ids, agent_ids,
                                extension_only_mask):
        """Batched LCP via the Pallas kernel (padded token matrices)."""
        from repro.kernels.ops import lcp_affinity_op

        n, m = len(prompts), len(agent_ids)
        max_p = max((len(p) for p in prompts), default=1)
        ledgers = [[self.get(a, d) for a in agent_ids] for d in dialogue_ids]
        max_l = max((len(l) for row in ledgers for l in row if l is not None),
                    default=1)
        length = max(max_p, max_l, 8)
        pmat = np.full((n, length), -1, np.int32)
        plen = np.zeros((n,), np.int32)
        for j, p in enumerate(prompts):
            pmat[j, : len(p)] = p
            plen[j] = len(p)
        lmat = np.full((n, m, length), -2, np.int32)  # -2 never matches -1
        llen = np.zeros((n, m), np.int32)
        for j in range(n):
            for i in range(m):
                led = ledgers[j][i]
                if led is not None:
                    lmat[j, i, : len(led)] = led
                    llen[j, i] = len(led)
        lcp = np.asarray(lcp_affinity_op(pmat, lmat))  # [N, M]
        lcp = np.minimum(lcp, np.minimum(plen[:, None], llen))
        o = lcp / np.maximum(plen[:, None], 1)
        if extension_only_mask is not None:
            ext = np.asarray(extension_only_mask, bool)[None, :]
            full_prev = (lcp == llen) & (llen > 0)
            o = np.where(ext, np.where(full_prev, llen / np.maximum(plen[:, None], 1), 0.0), o)
        return o
