"""Baseline routers with the same interface & information set as IEMAS.

The paper compares against learned routers (GraphRouter, GMTRouter,
MFRouter, RouterDC) trained offline on logged preference data that is not
reproducible here; our stand-ins learn ONLINE from the same telemetry IEMAS
sees (documented in DESIGN.md §8). ``RandomRouter`` is exact per the paper.

All baselines respect agent capacity (skip full agents) and implement
``route_batch`` / ``on_complete`` so the cluster driver treats every policy
identically.
"""
from __future__ import annotations

import math
from collections import defaultdict

import numpy as np

from repro.core.affinity import PrefixLedger
from repro.core.mechanism import AgentInfo, CompletionObs, Request, RouteDecision
from repro.core.pricing import observed_cost


class _BaseRouter:
    name = "base"

    def __init__(self, agents: list[AgentInfo], seed: int = 0):
        self.agents = list(agents)
        self.rng = np.random.default_rng(seed)
        self._pending: dict[str, AgentInfo] = {}
        self.accounts = defaultdict(float)

    def _free_agents(self, free_slots):
        out = []
        for a in self.agents:
            if (free_slots or {}).get(a.agent_id, a.capacity) > 0:
                out.append(a)
        return out

    def _decide(self, requests, pick, free_slots):
        decisions = []
        remaining = {a.agent_id: (free_slots or {}).get(a.agent_id, a.capacity)
                     for a in self.agents}
        for r in requests:
            cands = [a for a in self.agents if remaining[a.agent_id] > 0]
            agent = pick(r, cands) if cands else None
            if agent is None:
                decisions.append(RouteDecision(r, None, 0.0, None, 0.0, 0))
                continue
            remaining[agent.agent_id] -= 1
            self._pending[r.request_id] = (agent, r)
            decisions.append(RouteDecision(r, agent.agent_id, 0.0, None, 0.0, 0))
        return decisions

    def on_complete(self, request_id: str, obs: CompletionObs) -> None:
        entry = self._pending.pop(request_id, None)
        if entry is None:
            return
        agent, req = entry
        cost = observed_cost(agent.prices, obs.n_prompt, obs.n_hit, obs.n_gen)
        self.accounts["agent_costs"] += cost
        self._learn(agent, req, obs, cost)

    def _learn(self, agent, req, obs, cost):
        pass


class RandomRouter(_BaseRouter):
    """Uniform random routing (paper's Random baseline)."""
    name = "random"

    def route_batch(self, requests, telemetry, free_slots=None):
        """Assign each request to a uniformly random non-full agent."""
        return self._decide(
            requests, lambda r, cands: cands[self.rng.integers(len(cands))],
            free_slots)


class RoundRobinRouter(_BaseRouter):
    """Cycle through agents in order, skipping full ones."""

    name = "roundrobin"

    def __init__(self, agents, seed=0):
        super().__init__(agents, seed)
        self._next = 0

    def route_batch(self, requests, telemetry, free_slots=None):
        """Assign requests round-robin over the non-full agents."""
        def pick(r, cands):
            a = cands[self._next % len(cands)]
            self._next += 1
            return a
        return self._decide(requests, pick, free_slots)


class LeastLoadedRouter(_BaseRouter):
    """Classic load balancing — the paper's 'naive load balancing destroys
    cache locality' strawman."""
    name = "leastloaded"

    def route_batch(self, requests, telemetry, free_slots=None):
        """Assign each request to the least-utilized agent."""
        inflight = telemetry.get("agent_inflight", {})

        def pick(r, cands):
            return min(cands, key=lambda a: (inflight.get(a.agent_id, 0)
                                             / max(1, a.capacity),
                                             a.agent_id))
        return self._decide(requests, pick, free_slots)


class GreedyAffinityRouter(_BaseRouter):
    """Cache-affinity-first routing WITHOUT the auction (mechanism ablation):
    session stickiness, ties broken by load."""
    name = "greedyaffinity"

    def __init__(self, agents, seed=0):
        super().__init__(agents, seed)
        self.ledger = PrefixLedger()

    def route_batch(self, requests, telemetry, free_slots=None):
        """Assign each request to its best (affinity, domain, load) score."""
        inflight = telemetry.get("agent_inflight", {})

        def pick(r, cands):
            scored = []
            for a in cands:
                o = self.ledger.affinity(a.agent_id, r.dialogue_id, r.tokens,
                                         extension_only=a.recurrent)
                load = inflight.get(a.agent_id, 0) / max(1, a.capacity)
                dom = 0.1 * (r.domain in a.domains)
                scored.append((o + dom - 0.05 * load, a))
            return max(scored, key=lambda t: t[0])[1]
        return self._decide(requests, pick, free_slots)

    def _learn(self, agent, req, obs, cost):
        self.ledger.update(agent.agent_id, req.dialogue_id, req.tokens)


class BanditRouter(_BaseRouter):
    """UCB1 over (domain, agent) reward = quality - lambda*cost - mu*latency.
    Stand-in for learned per-query routers (MFRouter/RouterDC class)."""
    name = "bandit"

    def __init__(self, agents, seed=0, lam=0.02, mu=0.5):
        super().__init__(agents, seed)
        self.lam, self.mu = lam, mu
        self.stats = defaultdict(lambda: [0, 0.0])  # (domain, agent) -> [n, sum]
        self.total = 0

    def route_batch(self, requests, telemetry, free_slots=None):
        """Assign each request to the UCB1-optimal (domain, agent) arm."""
        def pick(r, cands):
            best, best_u = None, -math.inf
            for a in cands:
                n, s = self.stats[(r.domain, a.agent_id)]
                if n == 0:
                    u = math.inf  # explore
                else:
                    u = s / n + math.sqrt(2 * math.log(max(2, self.total)) / n)
                if u > best_u:
                    best, best_u = a, u
            return best
        return self._decide(requests, pick, free_slots)

    def _learn(self, agent, req, obs, cost):
        reward = obs.quality - self.lam * cost - self.mu * obs.latency
        st = self.stats[(req.domain, agent.agent_id)]
        st[0] += 1
        st[1] += reward
        self.total += 1


class EwmaScoreRouter(_BaseRouter):
    """Softmax over EWMA utility scores per (domain, agent) — stand-in for
    embedding-similarity routers (GraphRouter/GMTRouter class)."""
    name = "ewmascore"

    def __init__(self, agents, seed=0, lam=0.02, mu=0.5, temp=0.15,
                 alpha=0.2):
        super().__init__(agents, seed)
        self.lam, self.mu, self.temp, self.alpha = lam, mu, temp, alpha
        self.score = defaultdict(float)

    def route_batch(self, requests, telemetry, free_slots=None):
        """Sample each request's agent from the softmaxed EWMA scores."""
        def pick(r, cands):
            s = np.array([self.score[(r.domain, a.agent_id)] for a in cands])
            p = np.exp((s - s.max()) / self.temp)
            p /= p.sum()
            return cands[self.rng.choice(len(cands), p=p)]
        return self._decide(requests, pick, free_slots)

    def _learn(self, agent, req, obs, cost):
        reward = obs.quality - self.lam * cost - self.mu * obs.latency
        key = (req.domain, agent.agent_id)
        self.score[key] = (1 - self.alpha) * self.score[key] + self.alpha * reward


class GraphSchedulerRouter(_BaseRouter):
    """Affinity-blind workflow-graph scheduler — the dag_routing baseline.

    What a classic DAG scheduler (HEFT-style list scheduling) does when
    dropped into an agent marketplace: it sees the precedence structure
    (the simulator only hands it ready steps) and places each one by
    skill match, then load, then hardware scale — but it is blind to KV
    prefix state, so a handoff step lands wherever the queue is shortest
    and the producer's cached context is re-prefilled from scratch.  The
    gap to IEMAS's precedence-aware affinity auction is exactly what
    `benchmarks/dag_routing.py` measures.
    """

    name = "graphsched"

    def route_batch(self, requests, telemetry, free_slots=None):
        """Assign each ready step by (domain match, load, -scale)."""
        inflight = telemetry.get("agent_inflight", {})

        def pick(r, cands):
            return min(cands, key=lambda a: (
                0 if r.domain in a.domains else 1,
                inflight.get(a.agent_id, 0) / max(1, a.capacity),
                -a.scale, a.agent_id))
        return self._decide(requests, pick, free_slots)


BASELINES = {
    c.name: c for c in (RandomRouter, RoundRobinRouter, LeastLoadedRouter,
                        GreedyAffinityRouter, BanditRouter, EwmaScoreRouter,
                        GraphSchedulerRouter)
}
