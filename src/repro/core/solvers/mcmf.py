"""``solver="mcmf"``: the exact MCMF welfare oracle + §4.3 VCG payments.

Max-weight b-matching via successive-shortest-paths min-cost max-flow
(`repro.core.mcmf`) — pure Python, exact (Theorem 4.1), the ground truth the
dense auction family is validated against.  Two payment computation modes
(§4.3):

  * ``naive``     — re-solve the MCMF from scratch for every matched request
                    (the textbook N+1-solve VCG).
  * ``warmstart`` — ONE residual-graph shortest path per matched request:
                    W(C\\{j}) = (W(C) - w_ij) + max(0, -SP_cost(G_f - j)).
                    This is the paper's Hershberger-Suri-style reoptimization
                    and is validated against ``naive`` in tests.

The oracle keeps no persistent duals, so it neither accepts warm-start
seeds nor batches (``supports_warm_start = supports_batch = False``); its
certificate is exactly 0.
"""
from __future__ import annotations

import numpy as np

from repro.core.mcmf import (FlowNetwork, residual_shortest_path,
                             solve_min_cost_flow)
from repro.core.solvers.base import (AuctionResult, sequential_solve_batch)

__all__ = ["solve_allocation", "McmfBackend"]


def _build_network(w: np.ndarray, caps):
    n, m = w.shape
    s, t = n + m, n + m + 1
    g = FlowNetwork(n + m + 2)
    req_edges = []
    for j in range(n):
        req_edges.append(g.add_edge(s, j, 1.0, 0.0))
    match_edges = {}
    for j in range(n):
        for i in range(m):
            if w[j, i] > 0:
                match_edges[(j, i)] = g.add_edge(j, n + i, 1.0, -float(w[j, i]))
    sink_edges = [g.add_edge(n + i, t, float(caps[i]), 0.0) for i in range(m)]
    g.match_edges = match_edges
    g.sink_edges = sink_edges
    return g, s, t, match_edges


def solve_allocation(w: np.ndarray, caps) -> tuple[list, float, FlowNetwork]:
    """Max-weight b-matching via MCMF. Returns (assignment, welfare, residual)."""
    n, m = w.shape
    g, s, t, match_edges = _build_network(w, caps)
    flow, cost, _pot = solve_min_cost_flow(g, s, t)
    assignment = [-1] * n
    for (j, i), eid in match_edges.items():
        if g.cap[eid] <= 1e-9:  # saturated forward edge = matched
            assignment[j] = i
    return assignment, -cost, g


def _welfare_without(w: np.ndarray, caps, j: int) -> float:
    w2 = np.delete(w, j, axis=0)
    _, wf, _ = solve_allocation(w2, caps)
    return wf


def _cancel_unit(g: FlowNetwork, s: int, j: int, agent_node: int, t: int):
    """Remove one unit of flow along s->j->agent->t in a residual network."""
    def _undo(u, v):
        for eid in g.adj[u]:
            if g.to[eid] == v and eid % 2 == 0 and g.cap[eid ^ 1] > 1e-12:
                g.cap[eid] += 1.0
                g.cap[eid ^ 1] -= 1.0
                return True
        return False

    assert _undo(s, j), "request j was not matched"
    assert _undo(j, agent_node), "no flow j->i"
    assert _undo(agent_node, t), "no flow i->t"


class McmfBackend:
    """The exact oracle backend (see module docstring)."""

    name = "mcmf"
    supports_warm_start = False
    supports_batch = False

    def solve(self, w, costs, caps, *, payment_mode: str = "warmstart",
              start_prices=None) -> AuctionResult:
        """Exact allocation + per-request VCG payments (Eq. 7 + Eq. 8)."""
        w = np.asarray(w, dtype=np.float64)
        costs = np.asarray(costs, dtype=np.float64)
        n, m = w.shape
        assignment, welfare, gf = solve_allocation(w, caps)

        payments = [0.0] * n
        n_resolves = 0
        for j, i in enumerate(assignment):
            if i < 0:
                continue
            w_ij = w[j, i]
            c_ij = float(costs[j, i])
            if payment_mode == "naive":
                w_without = _welfare_without(w, caps, j)
                n_resolves += 1
            else:
                # warmstart: cancel j's unit; the only NEW residual capacity
                # is one unit on (agent i -> t). The optimum without j
                # improves over (W - w_ij) by at most one augmenting walk
                # that consumes that unit: either a path s~>i->t (a displaced
                # request gets matched) or a cycle t~>i->t (an existing match
                # reroutes onto agent i).
                g2 = gf.clone()
                s, t = n + m, n + m + 1
                _cancel_unit(g2, s, j, n + i, t)
                # block the i->t arc itself (both directions): the improving
                # walk ends there conceptually; traversing it mid-walk would
                # re-use the single freed unit and creates negative cycles
                # for BF.
                sink_eid = gf.sink_edges[i]
                be = {sink_eid, sink_eid ^ 1}
                d_s, _ = residual_shortest_path(g2, s, n + i, blocked={j},
                                                blocked_edges=be)
                d_t, _ = residual_shortest_path(g2, t, n + i, blocked={j},
                                                blocked_edges=be)
                d = min(d_s, d_t)
                gain = max(0.0, -d) if d != float("inf") else 0.0
                w_without = (welfare - w_ij) + gain
            # Eq. 8: p_j = W(C\{j}) - (W(C) - w_ij) + c_ij
            payments[j] = w_without - (welfare - w_ij) + c_ij

        return AuctionResult(
            assignment=assignment, welfare=welfare, payments=payments,
            weights=w, costs=costs,
            solver_stats={"solver": "mcmf", "payment_mode": payment_mode,
                          "resolves": n_resolves},
        )

    def solve_batch(self, ws, costs_list, caps_list, *,
                    payment_mode: str = "warmstart", start_prices_list=None
                    ) -> list[AuctionResult]:
        """Sequential per-market solves (the oracle has no batched form)."""
        return sequential_solve_batch(
            self, ws, costs_list, caps_list, payment_mode=payment_mode,
            start_prices_list=start_prices_list)

    def certificate(self, result: AuctionResult) -> float:
        """The oracle is exact: certified gap 0."""
        return 0.0
