"""jax.jit-staged dense auction: identical algorithm to the NumPy reference,
bidding rounds inside `lax.while_loop` so the whole solve is one XLA program.

The forward bidding round is pluggable (``bid_round=``): the default is the
pure-jnp `repro.kernels.ref.auction_bid_ref` (the Pallas kernel's oracle,
so there is exactly one jnp transcription of the round), and the ``pallas``
backend (`repro.core.solvers.pallas_backend`) passes the kernel dispatcher
instead — everything else (ε schedules, eviction, reverse rounds, warm-start
budgets, the vmapped shape-bucket batch path) is shared through this module.

Hub sharding
------------
`solve_dense_auction_jax_batch` solves many independent hub blocks of
uneven (n_h, K_h) shape as ONE traced program per shape bucket: blocks are
padded to power-of-two (n, K) buckets with zero-weight rows/columns and the
bucket is solved by `jax.vmap` of the staged solver.  Zero padding is
behavior-neutral — a padded request's best profit is ≤ 0 so it parks on its
first bid, and a padded slot carries price 0 and weight 0 so it neither
attracts bids (bids require strictly positive profit) nor goes stale in
reverse rounds (stale needs price > 0).
"""
from __future__ import annotations

import numpy as np

from repro.core.solvers.base import AuctionResult
from repro.core.solvers.dense_common import (DenseAuctionResult, THETA,
                                             check_start_prices, expand_slots,
                                             jax_eps_final,
                                             materialize_staged, package_dense,
                                             warm_eps0, warm_round_budget)
from repro.core.solvers.dense_np import solve_dense_auction
from repro.core.buckets import pow2_bucket

__all__ = ["solve_dense_auction_jax", "solve_dense_auction_jax_batch",
           "DenseJaxBackend"]

_JAX_CACHE: dict = {}


def _build_jax_solver(max_rounds: int, bid_round=None):
    import jax  # noqa: F401  (kept for parity with the jit/vmap wrappers)
    import jax.numpy as jnp
    from jax import lax

    if bid_round is None:
        # the kernel oracle IS the staged default: one jnp source of truth
        # for the bidding round, so dense-jax, the Pallas kernel and its
        # bit-parity tests can never drift apart
        from repro.kernels.ref import auction_bid_ref as bid_round

    def solve(B, p0, eps0, eps_final, theta):
        n, K = B.shape
        rows = jnp.arange(n)
        tol = eps_final / 8.0

        def cs_state(prices, owner, slot_of, parked, eps):
            """(unpark-violators, evict-violators, any-stale) predicates."""
            v1 = (B - prices[None, :]).max(axis=1)
            assigned = slot_of >= 0
            prof = jnp.where(assigned,
                             B[rows, jnp.maximum(slot_of, 0)]
                             - prices[jnp.maximum(slot_of, 0)], 0.0)
            unpark = parked & (v1 > eps + tol)
            viol = assigned & (prof < jnp.maximum(v1, 0.0) - eps - tol)
            stale = (owner < 0) & (prices > 0.0)
            return unpark, viol, stale

        def evict(prices, owner, slot_of, parked, eps):
            # prices are KEPT: with unchanged prices the eviction pass is
            # idempotent, so a single sweep suffices (no cascade loop)
            unpark, viol, _ = cs_state(prices, owner, slot_of, parked, eps)
            parked = parked & ~unpark
            owner = owner.at[jnp.where(viol, slot_of, K)].set(
                -1, mode="drop")
            slot_of = jnp.where(viol, -1, slot_of)
            return owner, slot_of, parked

        def bid_until_settled(prices, owner, slot_of, parked, eps, rounds):
            def bid_cond(st):
                _prices, _owner, slot_of, parked, r = st
                return ((slot_of < 0) & ~parked).any() & (r < max_rounds)

            def bid_body(st):
                prices, owner, slot_of, parked, r = st
                active = (slot_of < 0) & ~parked
                best, winner, wants = bid_round(B, prices, active, eps)
                parked = parked | (active & ~wants)
                won = winner < n
                new_owner = jnp.where(won, winner, owner)
                # displaced: my slot is now owned by someone else
                displaced = (slot_of >= 0) & (
                    new_owner[jnp.maximum(slot_of, 0)] != rows)
                slot_of = jnp.where(displaced, -1, slot_of)
                slot_won = jnp.full((n,), -1, jnp.int32).at[
                    jnp.where(won, winner, n)].set(
                        jnp.arange(K, dtype=jnp.int32), mode="drop")
                slot_of = jnp.where(slot_won >= 0, slot_won, slot_of)
                prices = jnp.where(won, best, prices)
                return prices, new_owner, slot_of, parked, r + 1

            return lax.while_loop(
                bid_cond, bid_body, (prices, owner, slot_of, parked, rounds))

        def reverse_until_clean(prices, owner, slot_of, parked, eps, rounds):
            big = jnp.asarray(jnp.finfo(B.dtype).max / 4, B.dtype)

            def rev_cond(st):
                prices, owner, _slot_of, _parked, r = st
                return ((owner < 0) & (prices > 0.0)).any() & (r < max_rounds)

            def rev_body(st):
                prices, owner, slot_of, parked, r = st
                stale = (owner < 0) & (prices > 0.0)
                assigned = slot_of >= 0
                pi = jnp.where(assigned,
                               B[rows, jnp.maximum(slot_of, 0)]
                               - prices[jnp.maximum(slot_of, 0)], 0.0)
                V = jnp.where(stale[None, :], B - pi[:, None], -big)
                b1 = V.max(axis=0)
                j1 = V.argmax(axis=0).astype(jnp.int32)
                V2 = V.at[j1, jnp.arange(K)].set(-big)
                b2 = V2.max(axis=0)
                weak = stale & (b1 <= eps)
                prices = jnp.where(weak, 0.0, prices)
                strong = stale & ~weak
                newp = jnp.maximum(b2 - eps, 0.0)
                off = jnp.where(strong, B[j1, jnp.arange(K)] - newp, -big)
                # request-side conflicts: best offer wins, ties to lowest slot
                bestoff = jnp.full((n,), -big, B.dtype).at[
                    jnp.where(strong, j1, n)].max(off, mode="drop")
                at_best = strong & (off == bestoff[jnp.minimum(j1, n - 1)])
                take = jnp.full((n,), K, jnp.int32).at[
                    jnp.where(at_best, j1, n)].min(
                        jnp.arange(K, dtype=jnp.int32), mode="drop")
                sel = strong & (take[jnp.minimum(j1, n - 1)]
                                == jnp.arange(K))
                grab = jnp.full((n,), -1, jnp.int32).at[
                    jnp.where(sel, j1, n)].set(
                        jnp.arange(K, dtype=jnp.int32), mode="drop")
                grabbed = grab >= 0
                old = jnp.where(grabbed & (slot_of >= 0), slot_of, K)
                owner = owner.at[old].set(-1, mode="drop")
                owner = owner.at[jnp.where(sel, jnp.arange(K), K)].set(
                    jnp.where(sel, j1, -1), mode="drop")
                prices = jnp.where(sel, newp, prices)
                slot_of = jnp.where(grabbed, grab, slot_of)
                parked = parked & ~grabbed
                return prices, owner, slot_of, parked, r + 1

            return lax.while_loop(
                rev_cond, rev_body, (prices, owner, slot_of, parked, rounds))

        def settle(prices, owner, slot_of, parked, eps, rounds):
            """Alternate forward bidding and reverse rounds at this ε."""
            def alt_cond(st):
                prices, owner, slot_of, parked, r = st
                unpark, viol, stale = cs_state(
                    prices, owner, slot_of, parked, eps)
                active = (slot_of < 0) & ~parked
                return (unpark.any() | viol.any() | stale.any()
                        | active.any()) & (r < max_rounds)

            def alt_body(st):
                prices, owner, slot_of, parked, r = st
                owner, slot_of, parked = evict(
                    prices, owner, slot_of, parked, eps)
                prices, owner, slot_of, parked, r = bid_until_settled(
                    prices, owner, slot_of, parked, eps, r)
                return reverse_until_clean(
                    prices, owner, slot_of, parked, eps, r)

            return lax.while_loop(
                alt_cond, alt_body, (prices, owner, slot_of, parked, rounds))

        def phase(carry):
            prices, owner, slot_of, parked, eps, rounds = carry
            prices, owner, slot_of, parked, rounds = settle(
                prices, owner, slot_of, parked, eps, rounds)
            eps = jnp.maximum(eps / theta, eps_final)
            return prices, owner, slot_of, parked, eps, rounds

        def phase_cond(carry):
            _p, _o, _s, _pk, eps, rounds = carry
            return (eps > eps_final * 1.0000000001) & (rounds < max_rounds)

        init = (jnp.asarray(p0, B.dtype),
                jnp.full((K,), -1, jnp.int32),
                jnp.full((n,), -1, jnp.int32),
                jnp.zeros((n,), bool),
                jnp.asarray(eps0, B.dtype), jnp.asarray(0, jnp.int32))
        # one final settle at eps_final after the loop drives eps down
        carry = lax.while_loop(phase_cond, phase, init)
        prices, owner, slot_of, parked, rounds = settle(
            *carry[:4], jnp.asarray(eps_final, B.dtype), carry[5])
        return prices, owner, slot_of, rounds

    return solve


def _get_jax_solver(max_rounds: int, batched: bool, bid_round=None):
    """jit (and, for hub batches, vmap) wrappers around the staged solve.

    The vmapped variant maps over every argument — (H, n, K) weight blocks
    with per-hub (p0, ε₀, ε_final, θ) vectors — so hubs padded to one shape
    bucket share a single traced program; `lax.while_loop`'s batching rule
    freezes already-converged hubs while the stragglers keep bidding.
    ``bid_round`` swaps the forward-bidding implementation (keyed into the
    trace cache), which is how the Pallas backend rides this exact solver.
    """
    import jax

    key = (max_rounds, batched, bid_round)
    solver = _JAX_CACHE.get(key)
    if solver is None:
        solve = _build_jax_solver(max_rounds, bid_round)
        solver = jax.jit(jax.vmap(solve)) if batched else jax.jit(solve)
        _JAX_CACHE[key] = solver
    return solver


def solve_dense_auction_jax(w, caps, *, eps_final: float | None = None,
                            theta: float = THETA,
                            max_rounds: int = 200_000,
                            start_prices: np.ndarray | None = None,
                            bid_round=None, pad_shape=None, solver_name="jax"):
    """JAX variant. Returns a DenseAuctionResult (host-side numpy values).

    Runs in the input dtype (float32 under default JAX config), so the
    certified gap is wider than the NumPy/float64 path; the NumPy solver is
    the reference, this one is the accelerator-resident building block.
    ``start_prices`` seeds the duals exactly like the NumPy solver's warm
    path (skipped coarse phase, cold re-solve on round-budget exhaustion).
    ``bid_round`` swaps the staged forward-bidding round (Pallas backend);
    ``pad_shape=(n_pad, K_pad)`` zero-pads the slot market into a shape
    bucket before staging (behavior-neutral, see the module docstring) so
    wobbling market sizes reuse a handful of traced programs.
    """
    import jax.numpy as jnp

    w_np = np.asarray(w, dtype=np.float64)
    n, m = w_np.shape
    slot_agent = expand_slots(caps, n)
    K = len(slot_agent)
    if n == 0 or K == 0 or float(w_np.max(initial=0.0)) <= 0.0:
        return DenseAuctionResult([-1] * n, 0.0, np.zeros(K), slot_agent,
                                  np.zeros(n), 0.0, 0, 0, 0.0)
    B_np = np.maximum(w_np, 0.0)[:, slot_agent]
    wmax = float(w_np.max())
    warm = start_prices is not None
    if warm:
        p0_np = check_start_prices(start_prices, K)
    n_pad, K_pad = pad_shape or (n, K)
    if (n_pad, K_pad) != (n, K):
        B_np = np.pad(B_np, ((0, n_pad - n), (0, K_pad - K)))
    B = jnp.asarray(B_np.astype(np.float32) if B_np.dtype != np.float32
                    else B_np)
    if eps_final is None:
        eps_final = jax_eps_final(wmax, B.dtype)
    cold_eps0 = max(wmax / theta, eps_final)
    solver = _get_jax_solver(max_rounds, batched=False, bid_round=bid_round)

    if warm:
        p0 = np.zeros(K_pad, np.float64)
        p0[:K] = p0_np
        eps0 = min(warm_eps0(p0_np, wmax, eps_final, theta), cold_eps0)
        budget = warm_round_budget(n_pad, K_pad, max_rounds)
        warm_solver = _get_jax_solver(budget, batched=False,
                                      bid_round=bid_round)
        prices, owner, slot_of, rounds = warm_solver(
            B, jnp.asarray(p0.astype(B.dtype)), float(eps0),
            float(eps_final), float(theta))
        if int(rounds) < budget:
            return materialize_staged(
                w_np, slot_agent, np.asarray(prices)[:K],
                np.asarray(slot_of)[:n], rounds, eps_final, warm_started=True)
        # warm attempt tripped its budget -> cold re-solve below
    prices, owner, slot_of, rounds = solver(
        B, jnp.zeros((K_pad,), B.dtype), float(cold_eps0), float(eps_final),
        float(theta))
    if int(rounds) >= max_rounds:
        # the staged while_loops stop silently at the cap; surface it the
        # same way the NumPy solver does instead of returning a bad matching
        raise RuntimeError(
            f"dense auction ({solver_name}) failed to converge in "
            f"{max_rounds} rounds (n={n}, m={m}, eps_final={eps_final:g})")
    return materialize_staged(
        w_np, slot_agent, np.asarray(prices)[:K], np.asarray(slot_of)[:n],
        rounds, eps_final, warm_started=warm, fallback=warm)


def solve_dense_auction_jax_batch(ws, caps_list, *,
                                  eps_final: float | None = None,
                                  theta: float = THETA,
                                  max_rounds: int = 200_000,
                                  start_prices_list=None,
                                  bid_round=None
                                  ) -> list[DenseAuctionResult]:
    """Solve many independent hub blocks in one vmapped program per bucket.

    ``ws[h]`` is hub h's dense (n_h, m_h) weight block and ``caps_list[h]``
    its per-agent capacities.  Blocks are zero-padded to power-of-two
    (n, K) shape buckets (padding is behavior-neutral — see the module
    docstring) and every bucket is solved by ONE `jax.vmap`-of-`jit` call,
    so K hubs of uneven size cost one trace + one device dispatch per
    distinct bucket instead of K dispatches.  ``start_prices_list[h]``
    optionally warm-starts hub h (None entries cold-start); any block whose
    staged solve hits the round cap is transparently re-solved by the
    float64 NumPy reference solver (``result.fallback``).  ``bid_round``
    swaps the staged bidding round (the Pallas backend's batch path).
    """
    import jax.numpy as jnp

    H = len(ws)
    sp_list = start_prices_list or [None] * H
    results: list[DenseAuctionResult | None] = [None] * H
    prep = []                      # (h, w_np, slot_agent, B, p0, eps0, eps_f)
    for h, (w, caps) in enumerate(zip(ws, caps_list)):
        w_np = np.asarray(w, dtype=np.float64)
        n = w_np.shape[0]
        slot_agent = expand_slots(caps, n)
        K = len(slot_agent)
        if n == 0 or K == 0 or float(w_np.max(initial=0.0)) <= 0.0:
            results[h] = DenseAuctionResult(
                [-1] * n, 0.0, np.zeros(K), slot_agent, np.zeros(n),
                0.0, 0, 0, 0.0)
            continue
        B = np.maximum(w_np, 0.0)[:, slot_agent].astype(np.float32)
        wmax = float(B.max())
        eps_f = eps_final if eps_final is not None \
            else jax_eps_final(wmax, B.dtype)
        sp = sp_list[h]
        if sp is not None:
            p0 = check_start_prices(sp, K, block=h).astype(np.float32)
            eps0 = min(warm_eps0(p0, wmax, eps_f, theta),
                       max(wmax / theta, eps_f))
            warm = True
        else:
            p0 = np.zeros(K, np.float32)
            eps0 = max(wmax / theta, eps_f)
            warm = False
        prep.append((h, w_np, slot_agent, B, p0, eps0, eps_f, warm))

    # group by (shape bucket, warm?) so uneven hubs share one traced solve;
    # warm and cold hubs never share a group — warm groups run under the
    # warm round budget (a bad seed must not drag the group to the global
    # cap) and that budget must not apply to cold solves
    groups: dict[tuple[int, int, bool], list] = {}
    for item in prep:
        _, w_np, slot_agent, B, *_, warm = item
        bucket = (pow2_bucket(B.shape[0]), pow2_bucket(B.shape[1]), warm)
        groups.setdefault(bucket, []).append(item)

    for (bn, bK, warm_group), members in groups.items():
        G = len(members)
        cap = max_rounds
        if warm_group:
            cap = warm_round_budget(bn, bK, max_rounds)
        vsolver = _get_jax_solver(cap, batched=True, bid_round=bid_round)
        Bs = np.zeros((G, bn, bK), np.float32)
        p0s = np.zeros((G, bK), np.float32)
        eps0s = np.zeros(G, np.float32)
        eps_fs = np.zeros(G, np.float32)
        for g, (_h, _w, _sa, B, p0, eps0, eps_f, _warm) in enumerate(members):
            Bs[g, :B.shape[0], :B.shape[1]] = B
            p0s[g, :len(p0)] = p0
            eps0s[g] = eps0
            eps_fs[g] = eps_f
        thetas = np.full(G, theta, np.float32)
        prices, owner, slot_of, rounds = vsolver(
            jnp.asarray(Bs), jnp.asarray(p0s), jnp.asarray(eps0s),
            jnp.asarray(eps_fs), jnp.asarray(thetas))
        prices = np.asarray(prices)
        slot_of = np.asarray(slot_of)
        rounds = np.asarray(rounds)
        for g, (h, w_np, slot_agent, B, p0, eps0, eps_f, warm) in \
                enumerate(members):
            n, K = B.shape
            if int(rounds[g]) >= cap:
                # capped mid-solve: the float64 reference re-solves this hub
                results[h] = solve_dense_auction(w_np, caps_list[h])
                results[h].warm_started = warm
                results[h].fallback = True
                continue
            results[h] = materialize_staged(
                w_np, slot_agent, prices[g, :K], slot_of[g, :n], rounds[g],
                eps_f, warm_started=warm)
    return results


class DenseJaxBackend:
    """``solver="dense-jax"``: the jit-staged float32 auction (hot path)."""

    name = "dense-jax"
    supports_warm_start = True
    supports_batch = True

    def solve(self, w, costs, caps, *, payment_mode: str = "warmstart",
              start_prices=None) -> AuctionResult:
        """One market through the staged solver + batched Clarke payments."""
        res = solve_dense_auction_jax(w, caps, start_prices=start_prices)
        return package_dense(self.name, w, costs, caps, res)

    def solve_batch(self, ws, costs_list, caps_list, *,
                    payment_mode: str = "warmstart", start_prices_list=None
                    ) -> list[AuctionResult]:
        """All markets padded into pow-2 buckets, one vmapped solve each."""
        dres = solve_dense_auction_jax_batch(
            ws, caps_list, start_prices_list=start_prices_list)
        return [package_dense(self.name, w, c, caps, r)
                for w, c, caps, r in zip(ws, costs_list, caps_list, dres)]

    def certificate(self, result: AuctionResult) -> float:
        """2·n·ε_final at the float32 resolution-bounded ε schedule."""
        return float(result.solver_stats["gap_bound"])
