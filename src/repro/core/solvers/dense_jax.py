"""jax.jit-staged dense column auction: identical algorithm to the NumPy
reference, bidding rounds inside `lax.while_loop` so the whole solve is one
XLA program.

The market state lives on an (m × cmax) unit-price grid — one capacitated
column per agent, ``counts[i] = min(b_i, n)`` live units each — instead of
the old K = Σ counts flat slot vector, so a bidding round scans O(n·m)
agent-level profits plus an O(m·cmax) segment-min for the per-agent asks.
The forward bidding round is pluggable (``bid_round=``): the default is the
pure-jnp `repro.kernels.ref.auction_bid_ref` (the Pallas kernel's oracle,
so there is exactly one jnp transcription of the round), and the ``pallas``
backend (`repro.core.solvers.pallas_backend`) passes the kernel dispatcher
instead — everything else (ε schedules, eviction, reverse rounds, warm-start
budgets, the vmapped shape-bucket batch path) is shared through this module.

Hub sharding
------------
`solve_dense_auction_jax_batch` solves many independent hub blocks of
uneven (n_h, m_h, cmax_h) shape as ONE traced program per shape bucket:
blocks are padded to power-of-two buckets with zero-weight rows and
zero-count agent columns and the bucket is solved by `jax.vmap` of the
staged solver.  Padding is behavior-neutral — a padded request's best
profit is ≤ 0 so it parks on its first bid, and a padded agent carries
count 0, so its ask is +big (it neither attracts bids nor has valid units
that could go stale in reverse rounds).
"""
from __future__ import annotations

import numpy as np

from repro.core.solvers.base import AuctionResult
from repro.core.solvers.dense_common import (DenseAuctionResult, THETA,
                                             check_start_prices,
                                             column_counts, empty_result,
                                             jax_eps_final,
                                             materialize_staged, package_dense,
                                             warm_eps0, warm_round_budget)
from repro.core.solvers.dense_np import _price_grid, solve_dense_auction
from repro.core.buckets import pow2_bucket

__all__ = ["solve_dense_auction_jax", "solve_dense_auction_jax_batch",
           "DenseJaxBackend"]

_JAX_CACHE: dict = {}


def _build_jax_solver(max_rounds: int, bid_round=None):
    import jax
    import jax.numpy as jnp
    from jax import lax

    if bid_round is None:
        # the kernel oracle IS the staged default: one jnp source of truth
        # for the bidding round, so dense-jax, the Pallas kernel and its
        # bit-parity tests can never drift apart
        from repro.kernels.ref import auction_bid_ref as bid_round

    def solve(W, counts, p0, eps0, eps_final, theta):
        n, m = W.shape
        cmax = p0.shape[1]
        rows = jnp.arange(n)
        arange_m = jnp.arange(m, dtype=jnp.int32)
        tol = eps_final / 8.0
        big = jnp.asarray(jnp.finfo(W.dtype).max / 4, W.dtype)
        uiota = lax.broadcasted_iota(jnp.int32, (m, cmax), 1)
        valid = uiota < counts[:, None]

        def asks(unit_price):
            """Cheapest / second-cheapest unit price per agent (+big where
            the agent has fewer than one/two units), and the cheapest
            unit's index — the unit a winning bid fills."""
            priced = jnp.where(valid, unit_price, big)
            ask = priced.min(axis=1)
            ku = priced.argmin(axis=1).astype(jnp.int32)
            ask2 = jnp.where(uiota == ku[:, None], big, priced).min(axis=1)
            return ask, ask2, ku

        def cs_state(unit_price, unit_owner, agent_of, unit_of, parked, eps):
            """(unpark-violators, evict-violators, stale-unit grid)."""
            ask, _, _ = asks(unit_price)
            v1 = (W - ask[None, :]).max(axis=1)
            assigned = agent_of >= 0
            ai = jnp.maximum(agent_of, 0)
            ui = jnp.maximum(unit_of, 0)
            prof = jnp.where(assigned, W[rows, ai] - unit_price[ai, ui], 0.0)
            unpark = parked & (v1 > eps + tol)
            viol = assigned & (prof < jnp.maximum(v1, 0.0) - eps - tol)
            stale = (unit_owner < 0) & (unit_price > 0.0) & valid
            return unpark, viol, stale

        def evict(unit_price, unit_owner, agent_of, unit_of, parked, eps):
            # prices are KEPT: with unchanged prices the eviction pass is
            # idempotent, so a single sweep suffices (no cascade loop)
            unpark, viol, _ = cs_state(
                unit_price, unit_owner, agent_of, unit_of, parked, eps)
            parked = parked & ~unpark
            unit_owner = unit_owner.at[
                jnp.where(viol, agent_of, m),
                jnp.maximum(unit_of, 0)].set(-1, mode="drop")
            agent_of = jnp.where(viol, -1, agent_of)
            unit_of = jnp.where(viol, -1, unit_of)
            return unit_owner, agent_of, unit_of, parked

        def bid_until_settled(unit_price, unit_owner, agent_of, unit_of,
                              parked, eps, rounds):
            def bid_cond(st):
                _up, _uo, agent_of, _un, parked, r = st
                return ((agent_of < 0) & ~parked).any() & (r < max_rounds)

            def bid_body(st):
                unit_price, unit_owner, agent_of, unit_of, parked, r = st
                active = (agent_of < 0) & ~parked
                ask, ask2, ku = asks(unit_price)
                best, winner, wants = bid_round(W, ask, ask2, active, eps)
                parked = parked | (active & ~wants)
                won = winner < n
                # displaced: the won unit's old owner loses it (owners never
                # bid, so a displaced request is never also a winner)
                old = unit_owner[arange_m, ku]
                disp = jnp.where(won & (old >= 0), old, n)
                agent_of = agent_of.at[disp].set(-1, mode="drop")
                unit_of = unit_of.at[disp].set(-1, mode="drop")
                wix = jnp.where(won, winner, n)
                agent_of = agent_of.at[wix].set(arange_m, mode="drop")
                unit_of = unit_of.at[wix].set(ku, mode="drop")
                unit_owner = unit_owner.at[
                    jnp.where(won, arange_m, m), ku].set(winner, mode="drop")
                unit_price = unit_price.at[
                    jnp.where(won, arange_m, m), ku].set(best, mode="drop")
                return unit_price, unit_owner, agent_of, unit_of, parked, r + 1

            return lax.while_loop(
                bid_cond, bid_body,
                (unit_price, unit_owner, agent_of, unit_of, parked, rounds))

        def reverse_until_clean(unit_price, unit_owner, agent_of, unit_of,
                                parked, eps, rounds):
            niota = lax.broadcasted_iota(jnp.int32, (m, n), 1)

            def rev_cond(st):
                unit_price, unit_owner, *_rest, r = st
                stale = (unit_owner < 0) & (unit_price > 0.0) & valid
                return stale.any() & (r < max_rounds)

            def rev_body(st):
                unit_price, unit_owner, agent_of, unit_of, parked, r = st
                stale = (unit_owner < 0) & (unit_price > 0.0) & valid
                has_stale = stale.any(axis=1)
                assigned = agent_of >= 0
                ai = jnp.maximum(agent_of, 0)
                ui = jnp.maximum(unit_of, 0)
                pi = jnp.where(assigned,
                               W[rows, ai] - unit_price[ai, ui], 0.0)
                # per-agent best/second-best support over requests (only
                # agents with a stale unit participate this round)
                V = jnp.where(has_stale[:, None], W.T - pi[None, :], -big)
                b1 = V.max(axis=1)
                j1 = V.argmax(axis=1).astype(jnp.int32)
                b2 = jnp.where(niota == j1[:, None], -big, V).max(axis=1)
                weak = has_stale & (b1 <= eps)
                # a weak agent's stale units all re-anchor to 0 this round
                unit_price = jnp.where(weak[:, None] & stale, 0.0, unit_price)
                strong = has_stale & ~weak
                newp = jnp.maximum(b2 - eps, 0.0)
                # the agent's LOWEST-index stale unit takes the grab
                us = jnp.argmax(stale, axis=1).astype(jnp.int32)
                off = jnp.where(strong, W[j1, arange_m] - newp, -big)
                # request-side conflicts: best offer wins, ties to lowest
                # agent index
                bestoff = jnp.full((n,), -big, W.dtype).at[
                    jnp.where(strong, j1, n)].max(off, mode="drop")
                at_best = strong & (off == bestoff[jnp.minimum(j1, n - 1)])
                take = jnp.full((n,), m, jnp.int32).at[
                    jnp.where(at_best, j1, n)].min(arange_m, mode="drop")
                sel = strong & (take[jnp.minimum(j1, n - 1)] == arange_m)
                # free the grabbed request's old unit (its price is kept —
                # the freed unit goes stale and re-anchors next round)
                old_a = agent_of[j1]
                old_u = jnp.maximum(unit_of[j1], 0)
                free = sel & (old_a >= 0)
                unit_owner = unit_owner.at[
                    jnp.where(free, old_a, m), old_u].set(-1, mode="drop")
                srow = jnp.where(sel, arange_m, m)
                unit_price = unit_price.at[srow, us].set(newp, mode="drop")
                unit_owner = unit_owner.at[srow, us].set(j1, mode="drop")
                grab = jnp.where(sel, j1, n)
                agent_of = agent_of.at[grab].set(arange_m, mode="drop")
                unit_of = unit_of.at[grab].set(us, mode="drop")
                parked = parked.at[grab].set(False, mode="drop")
                return unit_price, unit_owner, agent_of, unit_of, parked, r + 1

            return lax.while_loop(
                rev_cond, rev_body,
                (unit_price, unit_owner, agent_of, unit_of, parked, rounds))

        def settle(unit_price, unit_owner, agent_of, unit_of, parked, eps,
                   rounds):
            """Alternate forward bidding and reverse rounds at this ε."""
            def alt_cond(st):
                unit_price, unit_owner, agent_of, unit_of, parked, r = st
                unpark, viol, stale = cs_state(
                    unit_price, unit_owner, agent_of, unit_of, parked, eps)
                active = (agent_of < 0) & ~parked
                return (unpark.any() | viol.any() | stale.any()
                        | active.any()) & (r < max_rounds)

            def alt_body(st):
                unit_price, unit_owner, agent_of, unit_of, parked, r = st
                unit_owner, agent_of, unit_of, parked = evict(
                    unit_price, unit_owner, agent_of, unit_of, parked, eps)
                (unit_price, unit_owner, agent_of, unit_of, parked,
                 r) = bid_until_settled(
                    unit_price, unit_owner, agent_of, unit_of, parked, eps, r)
                return reverse_until_clean(
                    unit_price, unit_owner, agent_of, unit_of, parked, eps, r)

            return lax.while_loop(
                alt_cond, alt_body,
                (unit_price, unit_owner, agent_of, unit_of, parked, rounds))

        def phase(carry):
            unit_price, unit_owner, agent_of, unit_of, parked, eps, r = carry
            unit_price, unit_owner, agent_of, unit_of, parked, r = settle(
                unit_price, unit_owner, agent_of, unit_of, parked, eps, r)
            eps = jnp.maximum(eps / theta, eps_final)
            return unit_price, unit_owner, agent_of, unit_of, parked, eps, r

        def phase_cond(carry):
            *_state, eps, rounds = carry
            return (eps > eps_final * 1.0000000001) & (rounds < max_rounds)

        init = (jnp.asarray(p0, W.dtype),
                jnp.full((m, cmax), -1, jnp.int32),
                jnp.full((n,), -1, jnp.int32),
                jnp.full((n,), -1, jnp.int32),
                jnp.zeros((n,), bool),
                jnp.asarray(eps0, W.dtype), jnp.asarray(0, jnp.int32))
        # one final settle at eps_final after the loop drives eps down
        carry = lax.while_loop(phase_cond, phase, init)
        unit_price, unit_owner, agent_of, unit_of, parked, rounds = settle(
            *carry[:5], jnp.asarray(eps_final, W.dtype), carry[6])
        return unit_price, agent_of, unit_of, rounds

    return solve


def _get_jax_solver(max_rounds: int, batched: bool, bid_round=None):
    """jit (and, for hub batches, vmap) wrappers around the staged solve.

    The vmapped variant maps over every argument — (H, n, m) weight blocks
    with per-hub (counts, p0-grid, ε₀, ε_final, θ) vectors — so hubs padded
    to one shape bucket share a single traced program; `lax.while_loop`'s
    batching rule freezes already-converged hubs while the stragglers keep
    bidding.  ``bid_round`` swaps the forward-bidding implementation (keyed
    into the trace cache), which is how the Pallas backend rides this exact
    solver.
    """
    import jax

    key = (max_rounds, batched, bid_round)
    solver = _JAX_CACHE.get(key)
    if solver is None:
        solve = _build_jax_solver(max_rounds, bid_round)
        solver = jax.jit(jax.vmap(solve)) if batched else jax.jit(solve)
        _JAX_CACHE[key] = solver
    return solver


def solve_dense_auction_jax(w, caps, *, eps_final: float | None = None,
                            theta: float = THETA,
                            max_rounds: int = 200_000,
                            start_prices: np.ndarray | None = None,
                            bid_round=None, pad_shape=None, solver_name="jax"):
    """JAX variant. Returns a DenseAuctionResult (host-side numpy values).

    Runs in the input dtype (float32 under default JAX config), so the
    certified gap is wider than the NumPy/float64 path; the NumPy solver is
    the reference, this one is the accelerator-resident building block.
    ``start_prices`` (flat agent-major, length K = Σ min(b_i, n)) seeds the
    unit-price grid exactly like the NumPy solver's warm path (skipped
    coarse phase, cold re-solve on round-budget exhaustion).  ``bid_round``
    swaps the staged forward-bidding round (Pallas backend);
    ``pad_shape=(n_pad, m_pad, cmax_pad)`` zero-pads the column market into
    a shape bucket before staging (behavior-neutral, see the module
    docstring) so wobbling market sizes reuse a handful of traced programs.
    """
    import jax.numpy as jnp

    w_np = np.asarray(w, dtype=np.float64)
    n, m = w_np.shape
    counts = column_counts(caps, n)
    K = int(counts.sum())
    if n == 0 or K == 0:
        return empty_result(n, counts)
    W_np = np.maximum(w_np, 0.0)
    # ε anchors on the largest weight an agent WITH units can sell at (see
    # the NumPy solver: zero-capacity columns never trade)
    wmax = float(W_np[:, counts > 0].max(initial=0.0))
    if wmax <= 0.0:
        return empty_result(n, counts)
    cmax = int(counts.max())
    warm = start_prices is not None
    if warm:
        p0_np = check_start_prices(start_prices, K)
    n_pad, m_pad, c_pad = pad_shape or (n, m, cmax)
    if (n_pad, m_pad) != (n, m):
        W_np = np.pad(W_np, ((0, n_pad - n), (0, m_pad - m)))
    counts_pad = np.zeros(m_pad, np.int32)
    counts_pad[:m] = counts
    W = jnp.asarray(W_np.astype(np.float32) if W_np.dtype != np.float32
                    else W_np)
    if eps_final is None:
        eps_final = jax_eps_final(wmax, W.dtype)
    cold_eps0 = max(wmax / theta, eps_final)
    solver = _get_jax_solver(max_rounds, batched=False, bid_round=bid_round)

    if warm:
        grid0 = np.zeros((m_pad, c_pad), np.float64)
        grid0[:m, :cmax] = _price_grid(p0_np, counts, cmax)
        eps0 = min(warm_eps0(p0_np, wmax, eps_final, theta), cold_eps0)
        budget = warm_round_budget(n_pad, m_pad * c_pad, max_rounds)
        warm_solver = _get_jax_solver(budget, batched=False,
                                      bid_round=bid_round)
        unit_price, agent_of, unit_of, rounds = warm_solver(
            W, jnp.asarray(counts_pad), jnp.asarray(grid0.astype(W.dtype)),
            float(eps0), float(eps_final), float(theta))
        if int(rounds) < budget:
            return materialize_staged(
                w_np, counts, np.asarray(unit_price)[:m, :cmax],
                np.asarray(agent_of)[:n], np.asarray(unit_of)[:n],
                rounds, eps_final, warm_started=True)
        # warm attempt tripped its budget -> cold re-solve below
    unit_price, agent_of, unit_of, rounds = solver(
        W, jnp.asarray(counts_pad), jnp.zeros((m_pad, c_pad), W.dtype),
        float(cold_eps0), float(eps_final), float(theta))
    if int(rounds) >= max_rounds:
        # the staged while_loops stop silently at the cap; surface it the
        # same way the NumPy solver does instead of returning a bad matching
        raise RuntimeError(
            f"dense auction ({solver_name}) failed to converge in "
            f"{max_rounds} rounds (n={n}, m={m}, eps_final={eps_final:g})")
    return materialize_staged(
        w_np, counts, np.asarray(unit_price)[:m, :cmax],
        np.asarray(agent_of)[:n], np.asarray(unit_of)[:n], rounds, eps_final,
        warm_started=warm, fallback=warm)


def solve_dense_auction_jax_batch(ws, caps_list, *,
                                  eps_final: float | None = None,
                                  theta: float = THETA,
                                  max_rounds: int = 200_000,
                                  start_prices_list=None,
                                  bid_round=None
                                  ) -> list[DenseAuctionResult]:
    """Solve many independent hub blocks in one vmapped program per bucket.

    ``ws[h]`` is hub h's dense (n_h, m_h) weight block and ``caps_list[h]``
    its per-agent capacities.  Blocks are zero-padded to power-of-two
    (n, m, cmax) shape buckets (padding is behavior-neutral — see the
    module docstring) and every bucket is solved by ONE `jax.vmap`-of-`jit`
    call, so H hubs of uneven size cost one trace + one device dispatch per
    distinct bucket instead of H dispatches.  ``start_prices_list[h]``
    optionally warm-starts hub h (None entries cold-start); any block whose
    staged solve hits the round cap is transparently re-solved by the
    float64 NumPy reference solver (``result.fallback``).  ``bid_round``
    swaps the staged bidding round (the Pallas backend's batch path).
    """
    import jax.numpy as jnp

    H = len(ws)
    sp_list = start_prices_list or [None] * H
    results: list[DenseAuctionResult | None] = [None] * H
    prep = []          # (h, w_np, counts, W, grid0, eps0, eps_f, warm)
    for h, (w, caps) in enumerate(zip(ws, caps_list)):
        w_np = np.asarray(w, dtype=np.float64)
        n = w_np.shape[0]
        counts = column_counts(caps, n)
        K = int(counts.sum())
        W = np.maximum(w_np, 0.0).astype(np.float32)
        wmax = 0.0 if (n == 0 or K == 0) \
            else float(W[:, counts > 0].max(initial=0.0))
        if n == 0 or K == 0 or wmax <= 0.0:
            results[h] = empty_result(n, counts)
            continue
        cmax = int(counts.max())
        eps_f = eps_final if eps_final is not None \
            else jax_eps_final(wmax, W.dtype)
        sp = sp_list[h]
        if sp is not None:
            p0 = check_start_prices(sp, K, block=h)
            grid0 = _price_grid(p0, counts, cmax).astype(np.float32)
            eps0 = min(warm_eps0(p0, wmax, eps_f, theta),
                       max(wmax / theta, eps_f))
            warm = True
        else:
            grid0 = np.zeros((len(counts), cmax), np.float32)
            eps0 = max(wmax / theta, eps_f)
            warm = False
        prep.append((h, w_np, counts, W, grid0, eps0, eps_f, warm))

    # group by (shape bucket, warm?) so uneven hubs share one traced solve;
    # warm and cold hubs never share a group — warm groups run under the
    # warm round budget (a bad seed must not drag the group to the global
    # cap) and that budget must not apply to cold solves
    groups: dict[tuple[int, int, int, bool], list] = {}
    for item in prep:
        _, _w, counts, W, grid0, *_rest, warm = item
        bucket = (pow2_bucket(W.shape[0]), pow2_bucket(W.shape[1]),
                  pow2_bucket(grid0.shape[1]), warm)
        groups.setdefault(bucket, []).append(item)

    for (bn, bm, bc, warm_group), members in groups.items():
        G = len(members)
        cap = max_rounds
        if warm_group:
            cap = warm_round_budget(bn, bm * bc, max_rounds)
        vsolver = _get_jax_solver(cap, batched=True, bid_round=bid_round)
        Ws = np.zeros((G, bn, bm), np.float32)
        cnts = np.zeros((G, bm), np.int32)
        grids = np.zeros((G, bm, bc), np.float32)
        eps0s = np.zeros(G, np.float32)
        eps_fs = np.zeros(G, np.float32)
        for g, (_h, _w, counts, W, grid0, eps0, eps_f, _warm) in \
                enumerate(members):
            Ws[g, :W.shape[0], :W.shape[1]] = W
            cnts[g, :len(counts)] = counts
            grids[g, :grid0.shape[0], :grid0.shape[1]] = grid0
            eps0s[g] = eps0
            eps_fs[g] = eps_f
        thetas = np.full(G, theta, np.float32)
        unit_price, agent_of, unit_of, rounds = vsolver(
            jnp.asarray(Ws), jnp.asarray(cnts), jnp.asarray(grids),
            jnp.asarray(eps0s), jnp.asarray(eps_fs), jnp.asarray(thetas))
        unit_price = np.asarray(unit_price)
        agent_of = np.asarray(agent_of)
        unit_of = np.asarray(unit_of)
        rounds = np.asarray(rounds)
        for g, (h, w_np, counts, W, grid0, eps0, eps_f, warm) in \
                enumerate(members):
            n, m = W.shape
            cmax = grid0.shape[1]
            if int(rounds[g]) >= cap:
                # capped mid-solve: the float64 reference re-solves this hub
                results[h] = solve_dense_auction(w_np, caps_list[h])
                results[h].warm_started = warm
                results[h].fallback = True
                continue
            results[h] = materialize_staged(
                w_np, counts, unit_price[g, :m, :cmax], agent_of[g, :n],
                unit_of[g, :n], rounds[g], eps_f, warm_started=warm)
    return results


class DenseJaxBackend:
    """``solver="dense-jax"``: the jit-staged float32 auction (hot path)."""

    name = "dense-jax"
    supports_warm_start = True
    supports_batch = True

    def solve(self, w, costs, caps, *, payment_mode: str = "warmstart",
              start_prices=None) -> AuctionResult:
        """One market through the staged solver + batched Clarke payments."""
        res = solve_dense_auction_jax(w, caps, start_prices=start_prices)
        return package_dense(self.name, w, costs, caps, res)

    def solve_batch(self, ws, costs_list, caps_list, *,
                    payment_mode: str = "warmstart", start_prices_list=None
                    ) -> list[AuctionResult]:
        """All markets padded into pow-2 buckets, one vmapped solve each."""
        dres = solve_dense_auction_jax_batch(
            ws, caps_list, start_prices_list=start_prices_list)
        return [package_dense(self.name, w, c, caps, r)
                for w, c, caps, r in zip(ws, costs_list, caps_list, dres)]

    def certificate(self, result: AuctionResult) -> float:
        """2·n·ε_final at the float32 resolution-bounded ε schedule."""
        return float(result.solver_stats["gap_bound"])
