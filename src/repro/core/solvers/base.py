"""Phase-2 solver backend protocol + registry.

Every welfare-matching solver the router can run — the exact MCMF oracle,
the NumPy ε-scaling auction, its jax.jit-staged variant, the Pallas-kernel
variant, and whatever comes next — is a :class:`SolverBackend` registered
here by name.  ``run_auction``/``run_sharded_auction`` (and through them
``RouterConfig``/``make_router``/``launch.serve --solver``) resolve the
``solver=`` string through :func:`get_solver`, so adding a backend is one
new module plus one :func:`register_solver` call — ``core/auction.py``
never changes.

The protocol's surface is deliberately small:

* ``solve``        — one market: pruned weight matrix + costs + capacities
                     (and an optional warm-start dual seed) in, a full
                     :class:`AuctionResult` (allocation, welfare, VCG
                     payments, solver stats) out.
* ``solve_batch``  — many independent markets (the per-hub blocks of the
                     sharded auction); backends that can batch (vmapped
                     shape buckets) override it, everyone else inherits the
                     sequential default via :func:`sequential_solve_batch`.
* ``certificate``  — the certified welfare gap of a result (0 for exact
                     solvers, 2·n·ε for the auction family), so callers can
                     reason about optimality without per-backend knowledge.
* capability flags — ``supports_warm_start`` (accepts ``start_prices`` dual
                     seeds; the router's price book consults this instead
                     of hard-coding solver names) and ``supports_batch``
                     (``solve_batch`` is genuinely batched, not a loop).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np


@dataclass
class AuctionResult:
    """One Phase-2 solve: allocation, welfare, payments + solver stats."""

    assignment: list            # request j -> agent index or -1
    welfare: float              # W(C)
    payments: list              # VCG payment per request (0 if unmatched)
    weights: np.ndarray         # w_ij matrix used
    costs: np.ndarray           # c_ij matrix used
    solver_stats: dict = field(default_factory=dict)


@runtime_checkable
class SolverBackend(Protocol):
    """What a Phase-2 solver must provide to join the registry.

    Implementations are stateless singletons: all per-solve state lives in
    the returned :class:`AuctionResult` (warm-start duals round-trip through
    ``solver_stats["agent_prices"]`` and the caller's price book).
    """

    name: str
    supports_warm_start: bool   # accepts start_prices dual seeds
    supports_batch: bool        # solve_batch is vmapped, not a loop

    def solve(self, w: np.ndarray, costs: np.ndarray, caps, *,
              payment_mode: str = "warmstart",
              start_prices: np.ndarray | None = None) -> AuctionResult:
        """Solve one market given the pruned weight matrix ``w`` (>= 0)."""
        ...

    def solve_batch(self, ws, costs_list, caps_list, *,
                    payment_mode: str = "warmstart",
                    start_prices_list=None) -> list[AuctionResult]:
        """Solve many independent markets (one per hub block)."""
        ...

    def certificate(self, result: AuctionResult) -> float:
        """Certified welfare gap of ``result`` (0.0 for exact solvers)."""
        ...


def sequential_solve_batch(backend: SolverBackend, ws, costs_list, caps_list,
                           *, payment_mode: str = "warmstart",
                           start_prices_list=None) -> list[AuctionResult]:
    """Default ``solve_batch``: one independent ``solve`` per market."""
    sp = start_prices_list or [None] * len(ws)
    return [backend.solve(w, c, caps, payment_mode=payment_mode,
                          start_prices=s)
            for w, c, caps, s in zip(ws, costs_list, caps_list, sp)]


_REGISTRY: dict[str, SolverBackend] = {}


def register_solver(backend: SolverBackend) -> SolverBackend:
    """Add (or replace) a backend under ``backend.name``; returns it."""
    if not isinstance(backend, SolverBackend):
        raise TypeError(f"{backend!r} does not satisfy SolverBackend")
    _REGISTRY[backend.name] = backend
    return backend


def get_solver(name: str) -> SolverBackend:
    """Resolve a ``solver=`` string; raises ValueError when unknown."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown solver {name!r}; available: "
                         f"{available_solvers()}") from None


def available_solvers() -> list[str]:
    """Registered backend names, sorted (the CLI's ``--solver`` choices)."""
    return sorted(_REGISTRY)
