"""Shared machinery of the dense ε-scaling auction backends.

The NumPy, jax and Pallas backends all solve the same capacitated column
market (one column per agent holding a counter of ``min(b_i, n)`` unit
prices, requests bidding under ε-complementary slackness) and return the
same dual state; this module holds the pieces they share — the per-agent
column layout, the ε schedules and warm-start round budgets, the
:class:`DenseAuctionResult` dual-state record, the batched Clarke-pivot
payment solver, and the helpers that package a dense solve into the
registry-level :class:`~repro.core.solvers.base.AuctionResult`.

Column market vs slot expansion
-------------------------------
Earlier revisions expanded every agent into ``min(b_i, n)`` explicit unit
slots, paying O(n·K) per bidding round with ``K = Σ min(b_i, n)``.  The
column market keeps one column per agent: a request's ask against agent i
is the agent's CHEAPEST unassigned-or-displaceable unit (the segment-min of
its unit-price vector), and a winning bid fills exactly one unit of the
counter.  Because all of an agent's slots carry identical weights, every
request in a slot-level round targets the same (cheapest) slot of its
favourite agent — so the column round is decision-identical to the
slot-expanded round while scanning O(n·m + K) instead of O(n·K).  The
retained slot-expanded solver (``dense_np.solve_dense_auction_slots``) is
the parity oracle for this equivalence.
"""
from __future__ import annotations

import numpy as np

from repro.core.solvers.base import AuctionResult

# gap_bound = 2 * n * eps_final; the default keeps it below 1e-7 for any
# n <= ~500 at unit weight scale, comfortably inside the 1e-6 tolerances
# used by the mechanism tests.
EPS_FINAL_REL = 1e-10
THETA = 5.0
# warm solves skip the coarsest scaling phases (ε₀ = wmax/θ³ vs wmax/θ) and
# run under a bounded round budget; tripping it falls back to a cold solve
WARM_ROUNDS_PER_NODE = 40
WARM_ROUNDS_FLOOR = 2_000


class DenseAuctionResult:
    """Allocation + dual state of one dense-auction solve.

    ``agent_prices[i]`` is agent i's ascending unit-price vector (length
    ``unit_counts[i] = min(b_i, n)``): the duals of its capacity units,
    cheapest first.  The flat agent-major concatenation (``flat_prices``)
    is the warm-start wire format — units of one agent are interchangeable,
    so the ascending order is canonical and safe to reseed from.
    """

    __slots__ = ("assignment", "welfare", "agent_prices", "unit_counts",
                 "profits", "eps", "phases", "rounds", "gap_bound",
                 "warm_started", "fallback")

    def __init__(self, assignment, welfare, agent_prices, unit_counts,
                 profits, eps, phases, rounds, gap_bound, warm_started=False,
                 fallback=False):
        self.assignment = assignment        # request j -> agent index or -1
        self.welfare = welfare              # sum of matched w_ij
        self.agent_prices = agent_prices    # per-agent ascending unit duals
        self.unit_counts = unit_counts      # agent i -> min(b_i, n) units
        self.profits = profits              # per-request profit pi_j
        self.eps = eps                      # final epsilon
        self.phases = phases
        self.rounds = rounds                # total Jacobi bidding rounds
        self.gap_bound = gap_bound          # certified welfare gap (2*n*eps)
        self.warm_started = warm_started    # seeded from prior unit prices
        self.fallback = fallback            # warm attempt tripped -> re-ran cold

    @property
    def flat_prices(self) -> np.ndarray:
        """Agent-major flat concatenation of the per-agent price vectors."""
        if not len(self.agent_prices):
            return np.zeros(0)
        return np.concatenate([np.asarray(p, dtype=np.float64).ravel()
                               for p in self.agent_prices])


def column_counts(caps, n: int) -> np.ndarray:
    """Agent capacities -> per-agent unit counts (min(b_i, n) each)."""
    caps = np.asarray([int(c) for c in caps], dtype=np.int64)
    if (caps < 0).any():
        raise ValueError("negative capacity")
    return np.minimum(caps, n)


def expand_slots(caps, n: int) -> np.ndarray:
    """Agent capacities -> the slot -> agent map (min(b_i, n) unit slots).

    Only the retained slot-expanded parity oracle uses this; the production
    backends operate on :func:`column_counts` directly.
    """
    return np.repeat(np.arange(len(column_counts(caps, n))),
                     column_counts(caps, n))


def split_agent_prices(flat, counts) -> list:
    """Flat agent-major price vector -> per-agent ascending price arrays."""
    flat = np.asarray(flat, dtype=np.float64)
    out, pos = [], 0
    for c in counts:
        c = int(c)
        out.append(np.sort(flat[pos:pos + c]))
        pos += c
    return out


def warm_round_budget(n: int, K: int, max_rounds: int) -> int:
    """Round cap for a warm attempt before falling back to a cold solve."""
    return min(max_rounds, WARM_ROUNDS_PER_NODE * (n + K) + WARM_ROUNDS_FLOOR)


def warm_eps0(p0, wmax: float, eps_final: float,
              theta: float = THETA) -> float:
    """ε₀ for a warm attempt, scaled to how informative the seed is.

    The fine schedule (ε₀ = wmax/θ³, skipping the coarse scaling phases)
    only pays off when the seeded prices actually carry equilibrium signal
    worth protecting.  A seed that is ~zero everywhere (e.g. duals of units
    that never sold, or a spill market drawn mostly from idle donors) is
    indistinguishable from cold prices — running the fine schedule over it
    replaces a few coarse phases with long bidding wars and *costs* rounds.
    So: fine schedule iff the seed's price mass rises above the fine ε
    level; the coarse cold schedule otherwise (warm ≤ cold by construction).
    """
    fine = max(wmax / theta ** 3, eps_final)
    if float(np.asarray(p0).max(initial=0.0)) > fine:
        return fine
    return max(wmax / theta, eps_final)


def check_start_prices(start_prices, K: int, *, block: int | None = None
                       ) -> np.ndarray:
    """Validate a warm-start seed against this market's column layout.

    A seed of the wrong length means the caller is replaying duals from a
    DIFFERENT market (an agent's capacity changed, or the agent set moved
    under it) — silently clipping or padding such a seed re-anchors prices
    to the wrong units and costs correctness-adjacent rounds, so layout
    mismatches raise instead.  Negative entries are equally a layout bug
    (duals are non-negative by construction) and also raise.
    """
    p0 = np.asarray(start_prices, dtype=np.float64)
    where = f"start_prices for block {block}: " if block is not None \
        else "start_prices "
    if p0.shape != (int(K),):
        raise ValueError(f"{where}shape {p0.shape} does not match the "
                         f"column layout ({K},) for this (caps, n)")
    if (p0 < 0.0).any():
        raise ValueError(f"{where}contains negative prices; unit duals are "
                         "non-negative, a negative seed means the layout "
                         "is stale")
    return p0


def jax_eps_final(wmax: float, dtype) -> float:
    """Resolution-bounded ε_final for reduced-precision (float32) solves."""
    # ε (and the ε/8 slack) must stay well above one ulp at price
    # magnitude or CS tests cycle on rounding noise
    ulp = float(np.finfo(dtype).eps) * max(wmax, 1.0)
    return max(1e-5 * max(wmax, 1.0), 64.0 * ulp)


def empty_result(n: int, counts) -> DenseAuctionResult:
    """The trivial result for a degenerate market (no requests/units/edges)."""
    counts = np.asarray(counts, dtype=np.int64)
    return DenseAuctionResult(
        [-1] * n, 0.0, [np.zeros(int(c)) for c in counts], counts,
        np.zeros(n), 0.0, 0, 0, 0.0)


def materialize_staged(w_np, counts, unit_price, agent_of, unit_of, rounds,
                       eps_final, *, warm_started=False, fallback=False
                       ) -> DenseAuctionResult:
    """Host-side DenseAuctionResult from one staged column solve's state.

    ``unit_price`` is the (m, cmax) unit-price grid (garbage beyond each
    agent's count), ``agent_of``/``unit_of`` the per-request assignment.
    """
    n = w_np.shape[0]
    counts = np.asarray(counts, dtype=np.int64)
    agent_of = np.asarray(agent_of)
    unit_of = np.asarray(unit_of)
    grid = np.asarray(unit_price, dtype=np.float64)
    rows = np.arange(n)
    assigned = agent_of >= 0
    ai = np.maximum(agent_of, 0)
    welfare = float(np.where(assigned, w_np[rows, ai], 0.0).sum())
    profits = np.where(
        assigned,
        np.maximum(w_np, 0.0)[rows, ai] - grid[ai, np.maximum(unit_of, 0)],
        0.0)
    agent_prices = [np.sort(grid[i, :int(c)]) for i, c in enumerate(counts)]
    return DenseAuctionResult(
        [int(a) for a in agent_of], welfare, agent_prices, counts, profits,
        float(eps_final), -1, int(rounds), 2.0 * n * float(eps_final),
        warm_started=warm_started, fallback=fallback)


def dense_stats(solver: str, res: DenseAuctionResult) -> dict:
    """The ``solver_stats`` dict a dense backend attaches to its result."""
    return {"solver": solver, "payment_mode": "dual-batched",
            "phases": res.phases, "rounds": res.rounds,
            "eps": res.eps, "gap_bound": res.gap_bound,
            "agent_prices": res.agent_prices, "unit_counts": res.unit_counts,
            "warm_started": res.warm_started, "warm_fallback": res.fallback}


def package_dense(solver: str, w: np.ndarray, costs: np.ndarray, caps,
                  res: DenseAuctionResult) -> AuctionResult:
    """DenseAuctionResult -> AuctionResult: batched Clarke payments + stats."""
    payments = dense_clarke_payments(w, costs, caps, res.assignment)
    return AuctionResult(
        assignment=list(res.assignment), welfare=res.welfare,
        payments=payments, weights=w, costs=costs,
        solver_stats=dense_stats(solver, res))


# --------------------------------------------------------------------------
# Batched Clarke-pivot payments from the final matching.
# --------------------------------------------------------------------------
def dense_clarke_payments(w: np.ndarray, costs: np.ndarray, caps,
                          assignment) -> list:
    """p_j = c_ij + max(0, -d_j) for matched j, where d_j is the cheapest
    residual walk absorbing the unit freed by removing request j — all
    matched requests solved at once by one batched Bellman-Ford.

    Mirrors the mcmf backend's ``payment_mode="warmstart"``: per batch member
    b, request j_b's node is blocked and agent i_b's sink arc is blocked; the
    target distance is min(dist_from_s[i_b], dist_from_t[i_b]).

    Contract: ``assignment`` must be (near-)welfare-optimal — the residual
    graph of an optimal matching has no negative cycles, which is what makes
    the iteration-capped Bellman-Ford exact. On an ε-optimal matching the
    error is bounded by (n+m+3)·2n·ε; keep ε at the float64 default (the
    NumPy solver) for DSIC-grade payments and treat the float32 staged
    paths' payments as approximate to their reported gap_bound.
    """
    w = np.asarray(w, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    n, m = w.shape
    caps_arr = np.asarray([int(c) for c in caps], dtype=np.int64)
    payments = [0.0] * n
    matched = [j for j, i in enumerate(assignment) if i >= 0]
    if not matched:
        return payments
    B = len(matched)
    j_blk = np.asarray(matched)
    i_blk = np.asarray([assignment[j] for j in matched])

    X = np.zeros((n, m), dtype=bool)
    for j, i in enumerate(assignment):
        if i >= 0:
            X[j, i] = True
    used = X.sum(axis=0)
    row_matched = X.any(axis=1)
    mi = np.where(row_matched, np.argmax(X, axis=1), -1)   # agent of request
    inf = np.inf
    # forward matching arcs j -> i: cost -w where an unused edge exists
    Cf = np.where((w > 0) & ~X, -w, inf)                    # (n, m)
    # backward arcs i -> j (undo match): cost +w on matched pairs
    w_back = np.where(row_matched, w[np.arange(n), np.maximum(mi, 0)], inf)
    has_free = used < caps_arr                              # i -> t arcs
    has_flow = used > 0                                     # t -> i arcs
    brange = np.arange(B)

    def _bf(from_t: bool) -> np.ndarray:
        """Batched Bellman-Ford; returns dist-to-agent matrix (B, m)."""
        D_req = np.full((B, n), inf)
        D_ag = np.full((B, m), inf)
        D_s = np.full(B, 0.0 if not from_t else inf)
        D_t = np.full(B, 0.0 if from_t else inf)
        for _ in range(n + m + 3):
            changed = False
            # s -> j' (unmatched rows, cost 0)
            upd = np.where(~row_matched[None, :], D_s[:, None], inf)
            # i -> j' (matched rows, cost +w)
            upd_b = np.where(row_matched[None, :],
                             D_ag[:, np.maximum(mi, 0)] + w_back[None, :], inf)
            upd = np.minimum(upd, upd_b)
            upd[brange, j_blk] = inf                        # blocked request
            new = np.minimum(D_req, upd)
            changed |= (new < D_req).any()
            D_req = new
            # j' -> i (forward, cost -w): the big dense relaxation
            upd = (D_req[:, :, None] + Cf[None, :, :]).min(axis=1)
            # t -> i (cost 0) where flow exists, minus the blocked sink arc
            upd_t = np.where(has_flow[None, :], D_t[:, None], inf)
            upd_t[brange, i_blk] = inf
            new = np.minimum(D_ag, np.minimum(upd, upd_t))
            changed |= (new < D_ag).any()
            D_ag = new
            # i -> t (cost 0) where free capacity, minus the blocked sink arc
            cand = np.where(has_free[None, :], D_ag, inf)
            cand[brange, i_blk] = inf
            new = np.minimum(D_t, cand.min(axis=1))
            changed |= (new < D_t).any()
            D_t = new
            # j' -> s (matched rows, cost 0)
            cand = np.where(row_matched[None, :], D_req, inf)
            new = np.minimum(D_s, cand.min(axis=1))
            changed |= (new < D_s).any()
            D_s = new
            if not changed:
                break
        return D_ag

    d = np.minimum(_bf(from_t=False)[brange, i_blk],
                   _bf(from_t=True)[brange, i_blk])
    gain = np.where(np.isfinite(d), np.maximum(0.0, -d), 0.0)
    for b, j in enumerate(matched):
        payments[j] = float(gain[b] + costs[j, assignment[j]])
    return payments
