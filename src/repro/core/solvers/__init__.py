"""Pluggable Phase-2 solver backends.

One module per backend, one :class:`~repro.core.solvers.base.SolverBackend`
protocol, one registry — ``run_auction``/``run_sharded_auction`` and the
whole config/CLI stack resolve ``solver=`` names through
:func:`get_solver`, so a new solver is a new module plus a
:func:`register_solver` call (``core/auction.py`` stays untouched).

Registered backends:

========== ================================================= ===== ======
name       implementation                                    warm  batch
========== ================================================= ===== ======
mcmf       exact MCMF oracle (pure Python, float64)          no    no
dense      vectorized NumPy ε-scaling auction (float64)      yes   no
dense-jax  jit-staged auction, lax.while_loop (float32)      yes   vmap
pallas     staged auction, Pallas-kernel bidding round       yes   vmap
========== ================================================= ===== ======
"""
from repro.core.solvers.base import (AuctionResult, SolverBackend,
                                     available_solvers, get_solver,
                                     register_solver,
                                     sequential_solve_batch)
from repro.core.solvers.dense_common import (DenseAuctionResult,
                                             dense_clarke_payments)
from repro.core.solvers.dense_jax import (DenseJaxBackend,
                                          solve_dense_auction_jax,
                                          solve_dense_auction_jax_batch)
from repro.core.solvers.dense_np import DenseNumpyBackend, solve_dense_auction
from repro.core.solvers.mcmf import McmfBackend, solve_allocation
from repro.core.solvers.pallas_backend import (PallasBackend,
                                               solve_dense_auction_pallas)

register_solver(McmfBackend())
register_solver(DenseNumpyBackend())
register_solver(DenseJaxBackend())
register_solver(PallasBackend())

__all__ = [
    "AuctionResult", "SolverBackend", "available_solvers", "get_solver",
    "register_solver", "sequential_solve_batch",
    "DenseAuctionResult", "dense_clarke_payments",
    "DenseNumpyBackend", "DenseJaxBackend", "McmfBackend", "PallasBackend",
    "solve_allocation", "solve_dense_auction", "solve_dense_auction_jax",
    "solve_dense_auction_jax_batch", "solve_dense_auction_pallas",
]
