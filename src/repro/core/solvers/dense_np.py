"""Dense ε-scaling auction, vectorized NumPy (the float64 reference solver).

Drop-in alternative to the pure-Python successive-shortest-paths MCMF
(`repro.core.mcmf`) for the router's hot path.  Max-weight b-matching over a
dense (n_requests x n_agents) weight matrix is solved by Bertsekas' auction
algorithm with ε-scaling, fully vectorized in NumPy (one Jacobi bidding
round = a handful of array ops).

Formulation: the capacitated column market
------------------------------------------
Each agent i is ONE column holding a counter of ``min(b_i, n)`` unit
prices; a request's ask against agent i is the agent's cheapest unit (the
segment-min of its price vector) and a winning bid fills exactly one unit.
A request may also stay unmatched (outside option with profit 0).  Within a
phase the algorithm maintains ε-CS: every assigned request's profit is
within ε of its best available option (including the outside option), and
parked (voluntarily unmatched) requests have no option with profit > ε.

This is decision-equivalent to the classical per-unit slot expansion (every
agent split into ``min(b_i, n)`` identical slots): all slots of one agent
carry the same weight column, so every bidder in a slot-level round targets
its favourite agent's cheapest slot, and the runner-up value v2 only ever
sees other agents' cheapest slots plus the favourite agent's SECOND-cheapest
unit.  The column round therefore scans O(n·m + K) per round instead of the
slot market's O(n·K), with ``K = Σ min(b_i, n)`` — a ~K/m cut in the slack
regime (caps ≫ batch).  ``solve_dense_auction_slots`` retains the
slot-expanded solver as the parity oracle; the two agree on assignments and
welfare (always within the certified 2·n·ε bound; bit-equal on every
instance that is not degenerate).  Exact trajectory parity is impossible
only when two unit prices of one agent differ below the ULP of a bidder's
weight: the slot market compares prices THROUGH the rounded profit
``w − p`` (a tie, broken per bidder toward the lower slot index) while the
column market's segment-min compares prices directly — a sub-ULP
perturbation of the dual trajectory that the ε-CS certificate absorbs.

Between scaling phases, assignments AND prices are kept; only requests whose
ε-CS is violated at the tighter ε are evicted and re-bid.  Forward bidding
never lowers a price — lowering a contested price replays the bidding war in
ε-sized steps, which is exactly the pathology scaling exists to avoid.
Instead, the asymmetric-assignment condition (free units must carry price 0,
the outside option playing Bertsekas–Castañón's λ = 0) is maintained by
REVERSE auction rounds after each forward settle: a free unit whose price is
still positive lowers it to the second-best support level β₂ − ε and grabs
the best-supporting request (exactly preserving ε-CS for everyone else), or
drops to 0 when no request supports even that.  Forward and reverse rounds
alternate until neither has work; the assignment is then certified within
2·n·ε_final of the true optimum — with the default ε_final this is far
below any payment/valuation tolerance used in the system.

Warm starts (cross-round price reuse)
-------------------------------------
The serving loop re-auctions statistically similar request sets every few
hundred milliseconds, so the previous round's final unit prices are already
near the new round's equilibrium.  ``start_prices=`` (the flat agent-major
concatenation of per-agent ascending price vectors — ``res.flat_prices``)
seeds the solve from them.  Soundness: Bertsekas' auction terminates with
ε-CS satisfied from *any* non-negative initial price vector — the
certificate (2·n·ε_final) depends only on the final ε, never on where
prices started.  What warm prices buy is fewer bidding rounds: the
ε-scaling schedule can skip its coarse phases (warm solves start at
ε₀ = wmax/θ³ instead of wmax/θ) and most requests' first bid sticks.  What
they can cost is extra rounds when the guess is bad — overpriced free units
re-anchor to their support level in one reverse step, but underpriced
contested units replay the bidding war in ε-sized increments; the solve
therefore runs the warm attempt under a bounded round budget and
transparently falls back to a cold solve when it trips
(``result.fallback``).  Warm starts are *unsound* to reuse across a changed
column layout — caller contract is: same agent set, same per-agent unit
counts (``SlotPriceBook`` in `repro.core.hub` keys stored prices by hub id
+ elastic agent-set version + per-agent capacities to enforce this;
``check_start_prices`` raises on any layout mismatch).

Worked example
--------------
Two requests, two unit-capacity agents.  Both requests prefer agent 0, but
assigning request 1 there would strand request 0's larger surplus, so the
welfare optimum splits them (3.0 + 0.5 = 3.5 beats 2.0 + 1.0 = 3.0):

>>> import numpy as np
>>> from repro.core.solvers.dense_np import solve_dense_auction
>>> w = np.array([[3.0, 1.0],
...               [2.0, 0.5]])
>>> res = solve_dense_auction(w, [1, 1])
>>> res.assignment                     # request j -> agent index
[0, 1]
>>> res.welfare
3.5
>>> res.gap_bound < 1e-6               # certified distance to the optimum
True

Re-solving the same market seeded from the final prices converges without
re-running the coarse ε phases and certifies the same welfare:

>>> warm = solve_dense_auction(w, [1, 1], start_prices=res.flat_prices)
>>> (warm.assignment, warm.welfare) == (res.assignment, res.welfare)
True
>>> warm.warm_started and not warm.fallback
True
"""
from __future__ import annotations

import numpy as np

from repro.core.solvers.base import (AuctionResult, sequential_solve_batch)
from repro.core.solvers.dense_common import (DenseAuctionResult,
                                             EPS_FINAL_REL, THETA,
                                             check_start_prices, column_counts,
                                             empty_result, expand_slots,
                                             package_dense, warm_eps0,
                                             warm_round_budget)

__all__ = ["solve_dense_auction", "solve_dense_auction_slots",
           "DenseNumpyBackend"]


def _price_grid(flat, counts, cmax: int) -> np.ndarray:
    """Flat agent-major seed -> (m, cmax) unit-price grid (agent i's seed
    segment fills its units 0..count_i-1 in the given order)."""
    m = len(counts)
    grid = np.zeros((m, cmax), dtype=np.float64)
    pos = 0
    for i, c in enumerate(counts):
        c = int(c)
        grid[i, :c] = flat[pos:pos + c]
        pos += c
    return grid


def solve_dense_auction(w: np.ndarray, caps, *, eps_final: float | None = None,
                        theta: float = THETA,
                        max_rounds: int = 500_000,
                        start_prices: np.ndarray | None = None,
                        start_eps: float | None = None) -> DenseAuctionResult:
    """ε-scaling column auction over dense weights. w[j, i] <= 0 = "no edge".

    ``start_prices`` (flat agent-major, length ``K = sum(min(b_i, n))``)
    seeds the duals from a previous solve of a similar market; the warm
    attempt starts its ε schedule at ``start_eps`` (default wmax/θ³ when
    the seed is informative) and is round-budgeted — on budget exhaustion
    the solve silently restarts cold (``result.fallback`` reports it).  The
    optimality certificate is identical either way: 2·n·ε_final regardless
    of starting prices.
    """
    w = np.asarray(w, dtype=np.float64)
    n, m = w.shape
    counts = column_counts(caps, n)
    K = int(counts.sum())
    if n == 0 or K == 0:
        return empty_result(n, counts)
    W = np.maximum(w, 0.0)
    # the ε schedule anchors on the largest weight an agent WITH units can
    # sell at — zero-capacity agents' columns never trade (their ask is +inf)
    # and must not widen ε₀ (the slot market never even materializes them)
    wmax = float(W[:, counts > 0].max(initial=0.0))
    if wmax <= 0.0:
        return empty_result(n, counts)
    cmax = int(counts.max())
    if eps_final is None:
        eps_final = EPS_FINAL_REL * max(wmax, 1.0)
    cold_eps0 = max(wmax / theta, eps_final)
    if start_prices is None:
        return _solve_dense_columns(w, W, counts, np.zeros((m, cmax)),
                                    cold_eps0, eps_final, theta, max_rounds)
    p0 = check_start_prices(start_prices, K)
    eps0 = start_eps if start_eps is not None \
        else warm_eps0(p0, wmax, eps_final, theta)
    eps0 = min(max(eps0, eps_final), cold_eps0)
    budget = warm_round_budget(n, K, max_rounds)
    try:
        res = _solve_dense_columns(w, W, counts, _price_grid(p0, counts, cmax),
                                   eps0, eps_final, theta, budget)
        res.warm_started = True
        return res
    except RuntimeError:
        res = _solve_dense_columns(w, W, counts, np.zeros((m, cmax)),
                                   cold_eps0, eps_final, theta, max_rounds)
        res.warm_started = True
        res.fallback = True
        return res


def _solve_dense_columns(w, W, counts, grid0, eps0, eps_final, theta,
                         max_rounds) -> DenseAuctionResult:
    """The forward/reverse ε-scaling loop over the capacitated column
    market, from a given (unit-price grid, ε₀) state."""
    n, m = W.shape
    cmax = grid0.shape[1]
    K = int(counts.sum())
    valid = np.arange(cmax)[None, :] < counts[:, None]      # (m, cmax)
    eps = eps0
    # absolute slack for ε-CS tests: comparisons happen at price magnitude
    # ~wmax, where a relative-only slack can fall below one ulp and turn an
    # exactly-ε equilibrium gap into a perpetual evict/re-bid cycle.
    tol = eps_final / 8.0

    unit_price = grid0.copy()
    unit_owner = np.full((m, cmax), -1, dtype=np.int64)
    agent_of = np.full(n, -1, dtype=np.int64)       # request -> agent
    unit_of = np.full(n, -1, dtype=np.int64)        # request -> unit index
    parked = np.zeros(n, dtype=bool)
    rows = np.arange(n)
    phases = 0
    rounds = [0]

    def _asks():
        """Per-agent cheapest unit (price, index) and second-cheapest price.

        The ask is the segment-min over the agent's unit counter — the only
        price a bidder can ever face; ask2 (duplicates included, +inf for
        single-unit agents) is what v2 needs when the favourite agent's
        runner-up option is its own second unit."""
        priced = np.where(valid, unit_price, np.inf)
        ask = priced.min(axis=1)
        ku = priced.argmin(axis=1)
        ask2 = np.partition(priced, 1, axis=1)[:, 1] if cmax >= 2 \
            else np.full(m, np.inf)
        return ask, ask2, ku

    def _evict(eps) -> bool:
        """Unpark/evict requests whose ε-CS fails at current prices; returns
        whether anything is left to bid.

        Prices are kept (forward bidding never lowers them): freed units
        retain their duals so re-bidding starts near the previous phase's
        equilibrium; reverse rounds handle price decreases."""
        ask, _, _ = _asks()
        v1 = (W - ask[None, :]).max(axis=1)
        assigned = agent_of >= 0
        ai = np.maximum(agent_of, 0)
        prof = np.where(assigned,
                        W[rows, ai] - unit_price[ai, np.maximum(unit_of, 0)],
                        0.0)
        np.logical_and(parked, v1 <= eps + tol, out=parked)
        # best available option includes the outside option (profit 0): a
        # request left at profit < -ε by an earlier coarser phase must leave
        viol = assigned & (prof < np.maximum(v1, 0.0) - eps - tol)
        if viol.any():
            unit_owner[agent_of[viol], unit_of[viol]] = -1
            agent_of[viol] = -1
            unit_of[viol] = -1
        return bool(((agent_of < 0) & ~parked).any())

    def _bid_until_settled(eps):
        """Jacobi bidding rounds until every request is assigned or parked."""
        while True:
            active = np.nonzero((agent_of < 0) & ~parked)[0]
            if len(active) == 0:
                return
            rounds[0] += 1
            if rounds[0] > max_rounds:
                raise RuntimeError(
                    f"dense auction failed to converge in {max_rounds} rounds"
                    f" (n={n}, m={m}, eps={eps:g})")
            ask, ask2, ku = _asks()
            P = W[active] - ask[None, :]                 # (A, m) profits
            v1 = P.max(axis=1)
            k1 = P.argmax(axis=1)
            # runner-up option: other agents' cheapest units, plus the
            # favourite agent's own second-cheapest unit (ask2) — exactly
            # the slot market's v2 with the single chosen slot masked out
            P[np.arange(len(active)), k1] = W[active, k1] - ask2[k1]
            v2 = np.maximum(P.max(axis=1), 0.0)          # incl. outside option
            wants = v1 > 0.0
            parked[active[~wants]] = True                # outside option wins
            bidders = active[wants]
            if len(bidders) == 0:
                continue
            kb = k1[wants]
            bid = ask[kb] + (v1[wants] - v2[wants]) + eps
            # per-agent winner: highest bid, ties to the lowest request index
            # (every bidder targets the agent's cheapest unit, so per-agent
            # aggregation IS the slot market's per-slot aggregation)
            best = np.full(m, -np.inf)
            np.maximum.at(best, kb, bid)
            winner = np.full(m, n, dtype=np.int64)
            at_best = bid == best[kb]                    # exact float match
            np.minimum.at(winner, kb[at_best], bidders[at_best])
            won = np.nonzero(winner < n)[0]              # agents that sold
            uw = ku[won]
            # displace previous owners first (a displaced request may itself
            # be winning a different agent this very round)
            prev = unit_owner[won, uw]
            live = prev[prev >= 0]
            agent_of[live] = -1
            unit_of[live] = -1
            wj = winner[won]
            unit_owner[won, uw] = wj
            agent_of[wj] = won
            unit_of[wj] = uw
            unit_price[won, uw] = best[won]

    def _reverse_until_clean(eps) -> None:
        """Reverse auction rounds: every free unit with a positive (stale)
        price lowers it to β₂ − ε — the second-best support over requests —
        and grabs its best supporter, or drops to 0 when unsupported.

        Support depends only on the agent (all its units share one weight
        column), so all stale units of a weak agent drop to 0 together and
        at most one stale unit per agent (the lowest-index one, matching
        the slot market's global-index tie-break) re-prices per round.
        Price decreases of ≥ ε (or request-profit gains of ≥ ε) bound the
        number of rounds; ε-CS is preserved exactly (Bertsekas–Castañón)."""
        while True:
            stale = (unit_owner < 0) & (unit_price > 0.0) & valid
            si = np.nonzero(stale.any(axis=1))[0]
            if len(si) == 0:
                return
            rounds[0] += 1
            if rounds[0] > max_rounds:
                raise RuntimeError("dense auction reverse rounds exceeded "
                                   f"{max_rounds} (n={n}, m={m})")
            assigned = agent_of >= 0
            ai = np.maximum(agent_of, 0)
            pi = np.where(assigned,
                          W[rows, ai]
                          - unit_price[ai, np.maximum(unit_of, 0)], 0.0)
            V = W[:, si] - pi[:, None]            # support for each agent
            b1 = V.max(axis=0)
            j1 = V.argmax(axis=0)
            V[j1, np.arange(len(si))] = -np.inf
            b2 = V.max(axis=0) if n > 1 else np.full(len(si), -np.inf)
            weak = b1 <= eps                      # nobody worth grabbing
            weak_agents = np.zeros(m, dtype=bool)
            weak_agents[si[weak]] = True
            unit_price[stale & weak_agents[:, None]] = 0.0
            ks = si[~weak]
            if len(ks) == 0:
                continue
            js = j1[~weak]
            newp = np.maximum(b2[~weak] - eps, 0.0)
            # request-side conflicts: accept the best offer, ties to the
            # lowest agent index
            off = W[js, ks] - newp
            bestoff = np.full(n, -np.inf)
            np.maximum.at(bestoff, js, off)
            at_best = off == bestoff[js]
            take = np.full(n, m, dtype=np.int64)
            np.minimum.at(take, js[at_best], ks[at_best])
            sel = take[js] == ks
            ks, js, newp = ks[sel], js[sel], newp[sel]
            us = stale[ks].argmax(axis=1)         # lowest-index stale unit
            old_a, old_u = agent_of[js], unit_of[js]
            live = old_a >= 0
            # freed, keeps price (maybe stale)
            unit_owner[old_a[live], old_u[live]] = -1
            unit_price[ks, us] = newp
            unit_owner[ks, us] = js
            agent_of[js] = ks
            unit_of[js] = us
            parked[js] = False

    while True:
        phases += 1
        # forward/reverse alternation at this ε until neither has work
        for _ in range(8 * (n + K) + 8):
            if _evict(eps):
                _bid_until_settled(eps)
                _reverse_until_clean(eps)
                continue
            if ((unit_owner < 0) & (unit_price > 0.0) & valid).any():
                _reverse_until_clean(eps)
                continue
            break
        else:
            raise RuntimeError("dense auction forward/reverse alternation "
                               f"failed to settle (n={n}, m={m}, eps={eps:g})")
        if eps <= eps_final * (1.0 + 1e-12):
            break
        eps = max(eps / theta, eps_final)

    assigned = agent_of >= 0
    ai = np.maximum(agent_of, 0)
    welfare = float(np.where(assigned, w[rows, ai], 0.0).sum())
    profits = np.where(assigned,
                       W[rows, ai] - unit_price[ai, np.maximum(unit_of, 0)],
                       0.0)
    agent_prices = [np.sort(unit_price[i, :int(c)])
                    for i, c in enumerate(counts)]
    return DenseAuctionResult(
        [int(a) for a in agent_of], welfare, agent_prices, counts, profits,
        eps, phases, rounds[0], 2.0 * n * eps)


# --------------------------------------------------------------------------
# Retained slot-expanded solver: the column market's parity oracle.
# --------------------------------------------------------------------------
def solve_dense_auction_slots(w: np.ndarray, caps, *,
                              eps_final: float | None = None,
                              theta: float = THETA,
                              max_rounds: int = 500_000,
                              start_prices: np.ndarray | None = None,
                              start_eps: float | None = None
                              ) -> DenseAuctionResult:
    """The classical per-unit slot expansion (agents split into min(b_i, n)
    identical slots), kept as the decision-parity oracle and the baseline
    the benchmarks measure the column market's ~K/m round cost cut against.
    Same result contract as :func:`solve_dense_auction` (per-agent ascending
    price vectors); O(n·K) per round instead of O(n·m + K).
    """
    w = np.asarray(w, dtype=np.float64)
    n, m = w.shape
    counts = column_counts(caps, n)
    slot_agent = expand_slots(caps, n)
    K = len(slot_agent)
    if n == 0 or K == 0:
        return empty_result(n, counts)
    B = np.maximum(w, 0.0)[:, slot_agent]          # (n, K) slot-level weights
    wmax = float(B.max(initial=0.0))
    if wmax <= 0.0:
        return empty_result(n, counts)
    if eps_final is None:
        eps_final = EPS_FINAL_REL * max(wmax, 1.0)
    cold_eps0 = max(wmax / theta, eps_final)
    if start_prices is None:
        return _solve_dense_slots(w, B, slot_agent, counts, np.zeros(K),
                                  cold_eps0, eps_final, theta, max_rounds)
    p0 = check_start_prices(start_prices, K)
    eps0 = start_eps if start_eps is not None \
        else warm_eps0(p0, wmax, eps_final, theta)
    eps0 = min(max(eps0, eps_final), cold_eps0)
    budget = warm_round_budget(n, K, max_rounds)
    try:
        res = _solve_dense_slots(w, B, slot_agent, counts, p0, eps0,
                                 eps_final, theta, budget)
        res.warm_started = True
        return res
    except RuntimeError:
        res = _solve_dense_slots(w, B, slot_agent, counts, np.zeros(K),
                                 cold_eps0, eps_final, theta, max_rounds)
        res.warm_started = True
        res.fallback = True
        return res


def _solve_dense_slots(w, B, slot_agent, counts, prices0, eps0, eps_final,
                       theta, max_rounds) -> DenseAuctionResult:
    """The forward/reverse ε-scaling loop over explicit unit slots."""
    n, K = B.shape
    m = w.shape[1]
    eps = eps0
    tol = eps_final / 8.0

    prices = prices0.copy()
    owner = np.full(K, -1, dtype=np.int64)          # slot -> request
    slot_of = np.full(n, -1, dtype=np.int64)        # request -> slot
    parked = np.zeros(n, dtype=bool)
    rows = np.arange(n)
    phases = 0
    rounds = [0]

    def _evict(eps) -> bool:
        v1 = (B - prices).max(axis=1)
        assigned = slot_of >= 0
        prof = np.where(assigned, B[rows, np.maximum(slot_of, 0)]
                        - prices[np.maximum(slot_of, 0)], 0.0)
        np.logical_and(parked, v1 <= eps + tol, out=parked)
        viol = assigned & (prof < np.maximum(v1, 0.0) - eps - tol)
        if viol.any():
            owner[slot_of[viol]] = -1
            slot_of[viol] = -1
        return bool(((slot_of < 0) & ~parked).any())

    def _bid_until_settled(eps):
        while True:
            active = np.nonzero((slot_of < 0) & ~parked)[0]
            if len(active) == 0:
                return
            rounds[0] += 1
            if rounds[0] > max_rounds:
                raise RuntimeError(
                    f"dense auction failed to converge in {max_rounds} rounds"
                    f" (n={n}, m={m}, eps={eps:g})")
            P = B[active] - prices                       # (A, K) profits
            v1 = P.max(axis=1)
            k1 = P.argmax(axis=1)
            P[np.arange(len(active)), k1] = -np.inf
            v2 = np.maximum(P.max(axis=1), 0.0)          # incl. outside option
            wants = v1 > 0.0
            parked[active[~wants]] = True
            bidders = active[wants]
            if len(bidders) == 0:
                continue
            kb = k1[wants]
            bid = prices[kb] + (v1[wants] - v2[wants]) + eps
            best = np.full(K, -np.inf)
            np.maximum.at(best, kb, bid)
            winner = np.full(K, n, dtype=np.int64)
            at_best = bid == best[kb]
            np.minimum.at(winner, kb[at_best], bidders[at_best])
            slots_won = np.nonzero(winner < n)[0]
            prev = owner[slots_won]
            slot_of[prev[prev >= 0]] = -1
            owner[slots_won] = winner[slots_won]
            slot_of[winner[slots_won]] = slots_won
            prices[slots_won] = best[slots_won]

    def _reverse_until_clean(eps) -> None:
        while True:
            stale = np.nonzero((owner < 0) & (prices > 0.0))[0]
            if len(stale) == 0:
                return
            rounds[0] += 1
            if rounds[0] > max_rounds:
                raise RuntimeError("dense auction reverse rounds exceeded "
                                   f"{max_rounds} (n={n}, m={m})")
            assigned = slot_of >= 0
            pi = np.where(assigned, B[rows, np.maximum(slot_of, 0)]
                          - prices[np.maximum(slot_of, 0)], 0.0)
            V = B[:, stale] - pi[:, None]
            b1 = V.max(axis=0)
            j1 = V.argmax(axis=0)
            V[j1, np.arange(len(stale))] = -np.inf
            b2 = V.max(axis=0) if n > 1 else np.full(len(stale), -np.inf)
            weak = b1 <= eps
            prices[stale[weak]] = 0.0
            ks = stale[~weak]
            if len(ks) == 0:
                continue
            js = j1[~weak]
            newp = np.maximum(b2[~weak] - eps, 0.0)
            off = B[js, ks] - newp
            bestoff = np.full(n, -np.inf)
            np.maximum.at(bestoff, js, off)
            at_best = off == bestoff[js]
            take = np.full(n, K, dtype=np.int64)
            np.minimum.at(take, js[at_best], ks[at_best])
            sel = take[js] == ks
            ks, js, newp = ks[sel], js[sel], newp[sel]
            old = slot_of[js]
            owner[old[old >= 0]] = -1    # freed, keeps price (maybe stale)
            prices[ks] = newp
            owner[ks] = js
            slot_of[js] = ks
            parked[js] = False

    while True:
        phases += 1
        for _ in range(8 * (n + K) + 8):
            if _evict(eps):
                _bid_until_settled(eps)
                _reverse_until_clean(eps)
                continue
            if ((owner < 0) & (prices > 0.0)).any():
                _reverse_until_clean(eps)
                continue
            break
        else:
            raise RuntimeError("dense auction forward/reverse alternation "
                               f"failed to settle (n={n}, m={m}, eps={eps:g})")
        if eps <= eps_final * (1.0 + 1e-12):
            break
        eps = max(eps / theta, eps_final)

    assignment = np.where(slot_of >= 0, slot_agent[np.maximum(slot_of, 0)], -1)
    welfare = float(np.where(slot_of >= 0,
                             w[rows, np.maximum(assignment, 0)], 0.0).sum())
    profits = np.where(slot_of >= 0,
                       B[rows, np.maximum(slot_of, 0)]
                       - prices[np.maximum(slot_of, 0)], 0.0)
    agent_prices = [np.sort(prices[slot_agent == i])
                    for i in range(len(counts))]
    return DenseAuctionResult(
        [int(a) for a in assignment], welfare, agent_prices, counts, profits,
        eps, phases, rounds[0], 2.0 * n * eps)


class DenseNumpyBackend:
    """``solver="dense"``: the float64 NumPy auction (DSIC-grade payments)."""

    name = "dense"
    supports_warm_start = True
    supports_batch = False

    def solve(self, w, costs, caps, *, payment_mode: str = "warmstart",
              start_prices=None) -> AuctionResult:
        """One market through the NumPy auction + batched Clarke payments."""
        res = solve_dense_auction(w, caps, start_prices=start_prices)
        return package_dense(self.name, w, costs, caps, res)

    def solve_batch(self, ws, costs_list, caps_list, *,
                    payment_mode: str = "warmstart", start_prices_list=None
                    ) -> list[AuctionResult]:
        """Sequential per-market solves (NumPy has no batched program)."""
        return sequential_solve_batch(
            self, ws, costs_list, caps_list, payment_mode=payment_mode,
            start_prices_list=start_prices_list)

    def certificate(self, result: AuctionResult) -> float:
        """2·n·ε_final — the ε-CS optimality bound of the returned solve."""
        return float(result.solver_stats["gap_bound"])
