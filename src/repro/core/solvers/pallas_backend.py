"""``solver="pallas"``: the staged dense auction with a Pallas bidding round.

Same algorithm, schedules and certificates as ``dense-jax`` — the ONLY
difference is the forward bidding round, which runs as the
`repro.kernels.auction_bid` Pallas kernel (per-request top-2 agent profits
against the per-agent ask/ask2 quotes + segment-max scatter of bids into
agent columns, tiled over the (n × m) weight matrix) instead of the
pure-jnp transcription.  Off-TPU the kernel runs in interpret mode (the
`repro.kernels.ops` dispatch), so the backend works — and is tested
bit-for-bit against the jnp oracle — everywhere, while on TPU the bidding
round compiles to a real VMEM-tiled kernel.

Tile plan (backend-aware padding): the column market is zero-padded before
staging — the PR-3 padding argument applies unchanged (a zero-weight row
parks on its first bid; a zero-count agent quotes ask = +big, so it can
neither attract bids nor hold stale units).  On TPU the pad target is the
power-of-two (n, m, cmax) bucket with 128-row tiles, so the
shape-specialized Pallas grid is traced once per bucket (trace reuse
across market-size wobble) and every weight tile stays ≤ 128·m·4 B in
VMEM.  In interpret mode (CPU) per-program overhead dominates and XLA:CPU
column reductions fall off a cache-aliasing cliff when the row stride is a
large power of two, so the plan instead pads minimally — n to one tall
tile of ≤ 1024 rows per grid step, m to a multiple of 8 nudged off
512-multiples — which keeps the kernelized solve within noise of the raw
``dense-jax`` program (`benchmarks/mcmf_scaling`).  The batch path reuses
`solve_dense_auction_jax_batch`'s vmapped pow-2 buckets verbatim with the
kernel swapped in.
"""
from __future__ import annotations

from repro.core.solvers.base import AuctionResult
from repro.core.solvers.dense_common import package_dense
from repro.core.solvers.dense_jax import (solve_dense_auction_jax,
                                          solve_dense_auction_jax_batch)
from repro.core.buckets import pow2_bucket

__all__ = ["solve_dense_auction_pallas", "PallasBackend"]

#: rows per tile in interpret mode; real kernels tile at 128 rows (VMEM)
_TILE_ROWS_INTERPRET = 1024
_TILE_ROWS_TPU = 128


def _tile_split(n: int) -> tuple[int, int]:
    """Interpret-mode (grid, bn) for n rows: the fewest ≤ 1024-row tiles.

    The single source of the tiling invariant: `_pad_plan` pads n to
    ``bn·grid`` and `_bid_round_pallas` re-derives the same (grid, bn)
    from the padded n — ``_tile_split(bn·grid) == (grid, bn)`` by
    construction (bn is a multiple of 8, grid is minimal for it).
    """
    grid = -(-n // _TILE_ROWS_INTERPRET)
    rows = -(-n // grid)                     # ceil(n / grid)
    return grid, max(8, -(-rows // 8) * 8)   # ... rounded up to a mult of 8


def _bid_round_pallas(W, ask, ask2, active, eps):
    """The kernelized forward-bidding round (interpret-mode off TPU).

    The tile height adapts to the (static) padded market: tall tiles
    amortize per-program overhead in interpret mode; 128-row tiles keep
    real TPU weight tiles comfortably inside VMEM.
    """
    from repro.kernels.ops import _interpret, auction_bid_op

    n = W.shape[0]
    bn = _tile_split(n)[1] if _interpret() else min(n, _TILE_ROWS_TPU)
    return auction_bid_op(W, ask, ask2, active, eps, bn=bn)


def _pad_plan(n: int, m: int, cmax: int, interpret: bool
              ) -> tuple[int, int, int]:
    """Padded (n, m, cmax) for one staged solve (see the module docstring)."""
    if not interpret:
        return pow2_bucket(n), pow2_bucket(m), pow2_bucket(cmax)
    grid, bn = _tile_split(n)
    m_pad = -(-m // 8) * 8
    if m_pad % 512 == 0:
        m_pad += 8          # dodge the pow-2 row-stride aliasing cliff
    return bn * grid, m_pad, cmax


def solve_dense_auction_pallas(w, caps, *, max_rounds: int = 200_000,
                               start_prices=None):
    """Pallas-kernel dense auction solve; returns a DenseAuctionResult.

    Delegates to the shared staged solver with ``bid_round`` swapped for
    the kernel dispatcher and the market padded per the backend-aware tile
    plan (pow-2 shape buckets on TPU, minimal aliasing-safe padding in
    interpret mode).
    """
    import numpy as np

    from repro.core.solvers.dense_common import column_counts

    w = np.asarray(w, dtype=np.float64)
    n, m = w.shape
    counts = column_counts([int(c) for c in caps], n)
    K = int(counts.sum())
    if n and K:
        from repro.kernels.ops import _interpret

        pad = _pad_plan(n, m, int(counts.max()), _interpret())
    else:
        pad = None
    return solve_dense_auction_jax(
        w, caps, max_rounds=max_rounds, start_prices=start_prices,
        bid_round=_bid_round_pallas, pad_shape=pad, solver_name="pallas")


class PallasBackend:
    """``solver="pallas"``: staged auction with the Pallas bidding kernel."""

    name = "pallas"
    supports_warm_start = True
    supports_batch = True

    def solve(self, w, costs, caps, *, payment_mode: str = "warmstart",
              start_prices=None) -> AuctionResult:
        """One market through the kernelized staged solver."""
        res = solve_dense_auction_pallas(w, caps, start_prices=start_prices)
        return package_dense(self.name, w, costs, caps, res)

    def solve_batch(self, ws, costs_list, caps_list, *,
                    payment_mode: str = "warmstart", start_prices_list=None
                    ) -> list[AuctionResult]:
        """The vmapped pow-2 bucket batch with the kernel bidding round."""
        dres = solve_dense_auction_jax_batch(
            ws, caps_list, start_prices_list=start_prices_list,
            bid_round=_bid_round_pallas)
        return [package_dense(self.name, w, c, caps, r)
                for w, c, caps, r in zip(ws, costs_list, caps_list, dres)]

    def certificate(self, result: AuctionResult) -> float:
        """2·n·ε_final at the float32 resolution-bounded ε schedule."""
        return float(result.solver_stats["gap_bound"])
