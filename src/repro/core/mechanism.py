"""IEMAS router — the paper's Algorithm 1 as a deployable component.

Per micro-batch of requests:
  Phase 1  cache-aware prediction & valuation (ledger LCP -> o_ij; Hoeffding
           QoS -> (L,C,P); Eq. 1 -> v_ij; w_ij = v_ij - c_ij, pruned).
           Batched by default: the full (n, m, F) Eq.-5 feature tensor is
           scored by ``PredictorPool.predict_matrix`` in one vectorized
           pass (compiled tree forests); ``batched=False`` keeps the
           per-pair scalar loop as the semantic oracle — both produce
           bit-identical decisions (tests/test_predictor_batch.py).
  Phase 2  welfare maximization per proxy hub (Eq. 7 / Thm 4.1): any
           backend in the ``core/solvers`` registry (``solver=`` kwarg —
           exact MCMF oracle, dense NumPy/jax ε-scaling auction, or the
           Pallas-kernel variant).  With ``n_hubs > 1`` the batch's welfare
           matrix is carved into per-hub blocks and each block is auctioned
           independently (``run_sharded_auction``; batch-capable backends
           solve the uneven blocks through one vmapped program per shape
           bucket), with ``warm_start=True`` each hub's final slot prices
           seed the next round's ε-scaling — keyed by hub id + elastic
           agent-set version, cold-starting whenever membership changed —
           and with ``spill=True`` (default) requests a saturated hub left
           unmatched re-auction once over every hub's residual capacity
           (cross-hub spill), so hard hub pinning no longer strands
           welfare when another hub has slack.  Incentive caveat: payments
           are Clarke pivots *within each round's market*.  Hub sharding
           already trades exact global VCG for speed (Fig. 6), and the
           spill round inherits that: a bidder who tanks round 1 to buy
           uncontested residual capacity in round 2 can profit, so the
           DSIC theorems hold per-market, not across rounds.  Deployments
           that need strict DSIC at ``n_hubs > 1`` should run
           ``spill=False`` (``--no-spill``) and accept the stranded-welfare
           tail that `benchmarks/hub_sharding.py` quantifies.
  Phase 3  VCG Clarke-pivot payments (Eq. 8) + dispatch.
  Phase 4  execution feedback: predictor updates + prefix-ledger updates.

The router never touches engine internals — it sees only the telemetry the
proxy layer exposes (Appendix C), so it drops onto any backend that reports
(latency, usage, quality) per completed request.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.affinity import PrefixLedger
from repro.core.auction import SPILL_HUB, _spill_round, run_sharded_auction
from repro.core.hub import (Hub, SlotPriceBook, cluster_agents, route_to_hub)
from repro.core.ledger import SettlementLedger
from repro.core.solvers import get_solver
from repro.distributed.elastic import AgentSetVersion
from repro.core.predictor import (PredictorInput, PredictorPool, QoSEstimate,
                                  feature_tensor)
from repro.core.pricing import TokenPrices, observed_cost
from repro.core.valuation import ValuationConfig, client_value
from repro.utils.timing import phase_scope


@dataclass
class AgentInfo:
    """Published profile of one market participant (prices, capacity, tags)."""

    agent_id: str
    prices: TokenPrices
    capacity: int
    domains: tuple[str, ...]
    scale: float = 1.0
    recurrent: bool = False  # extension-only cache semantics (rwkv/zamba)
    cache_slots: int = 0     # published cache capacity (0 = unknown/unbounded)


@dataclass
class Request:
    """One dialogue turn to route: prompt tokens + domain + metadata."""

    request_id: str
    dialogue_id: str
    tokens: np.ndarray          # prompt token ids (full conversation so far)
    turn: int
    domain: str = ""
    max_new_tokens: int = 32
    meta: dict = field(default_factory=dict)


@dataclass
class RouteDecision:
    """Algorithm-1 output for one request: winner, payment, QoS estimate."""

    request: Request
    agent_id: str | None
    payment: float
    estimate: QoSEstimate | None
    welfare_weight: float
    hub_id: int


@dataclass
class CompletionObs:
    """Engine telemetry for one completed request (Phase-4 feedback)."""

    latency: float          # TTFT seconds (paper's Lat)
    n_prompt: int
    n_hit: int              # cached prompt tokens reported by the engine
    n_gen: int
    quality: float          # evaluator score in [0,1] as REPORTED
    failed: bool = False
    # audited ground-truth quality (settlement audit channel): None means no
    # audit ran and the report is taken at face value — bit-identical to the
    # pre-audit router.  When set, value is settled at the audited score and
    # the inflation residual max(0, quality - audit_quality) feeds the
    # agent's reputation (repro.core.adversary).
    audit_quality: float | None = None


class IEMASRouter:
    """The paper's Algorithm 1 (see module docstring for the four phases)."""

    name = "iemas"

    def __init__(self, agents: list[AgentInfo], *,
                 valuation: ValuationConfig | None = None,
                 payment_mode: str = "warmstart",
                 solver: str = "mcmf",
                 n_hubs: int = 1, hub_scheme: str = "domain",
                 warm_start: bool = False, spill: bool = True,
                 use_kernel_affinity: bool = False,
                 batched: bool = True, predictor_backend: str = "numpy",
                 predictor_kw: dict | None = None,
                 reputation: bool = True, audit_ledger: bool = False,
                 fused: bool = False):
        self.agents = list(agents)
        self.valuation = valuation or ValuationConfig()
        self.payment_mode = payment_mode
        # optional serving-layer RoutingProfiler (duck-typed: anything with a
        # phase(name) context manager); attributes per-phase wall-clock for
        # the overhead-crossover study — None keeps every section a no-op
        self.profiler = None
        self.solver = solver
        self.spill = spill
        # cross-round slot-price reuse needs persistent duals; the registry
        # capability flag says which backends have them (the mcmf oracle
        # does not) — silently a no-op otherwise
        self.warm_start = warm_start and get_solver(solver).supports_warm_start
        self.use_kernel_affinity = use_kernel_affinity
        self.batched = batched
        self.predictor_backend = predictor_backend
        self.ledger = PrefixLedger()
        self._refresh_ledger_cap()
        self.pool = PredictorPool({a.agent_id: a.prices for a in agents},
                                  **(predictor_kw or {}))
        # reputation-weighted priors (on by default, exactly neutral without
        # an audit channel) + the optional hash-chained settlement ledger
        self.use_reputation = reputation
        self.settlement = SettlementLedger() if audit_ledger else None
        self._pending: dict[str, tuple] = {}  # request_id -> (x, agent, req)
        self.accounts = {"payments": 0.0, "agent_costs": 0.0,
                         "welfare_realized": 0.0, "surplus": 0.0,
                         "matched": 0, "unmatched": 0, "spill_rescued": 0,
                         "incremental_routed": 0, "incremental_confirmed": 0,
                         "incremental_rerouted": 0}
        # provisional routes issued since the last batch auction: the next
        # route_batch re-equilibrates them (request_id -> decision, plus the
        # per-agent count of provisionally consumed units)
        self._provisional: dict[str, RouteDecision] = {}
        self._prov_units: dict[str, int] = {}
        self.n_hubs = n_hubs
        self.hub_scheme = hub_scheme
        self.agent_set_version = AgentSetVersion()
        self.price_book = SlotPriceBook()
        self._rebuild_hubs()
        self.quarantined: set[str] = set()
        # fused device-resident routing step (core/routing_fused.py): one
        # jitted program replaces _phase1 + the hub-0 solve; host-side spill,
        # price-book splice and payments are shared with the staged path
        self.fused = fused
        self._fused = None
        if fused:
            from repro.core.routing_fused import (FUSED_SOLVERS,
                                                  FusedRoutingStep)
            if n_hubs != 1:
                raise ValueError(
                    "fused=True runs one global device-resident column "
                    f"market and requires n_hubs=1 (got {n_hubs}); use the "
                    "staged path for hub sharding")
            if solver not in FUSED_SOLVERS:
                raise ValueError(
                    "fused=True requires a solver whose bidding loop stages "
                    f"inside the fused program {FUSED_SOLVERS}; got "
                    f"{solver!r}")
            self._fused = FusedRoutingStep(self)

    # ---------------- elastic membership ----------------
    def _refresh_ledger_cap(self):
        """Bound ledger memory when every agent publishes a cache size.

        Sessions older than an agent's ``cache_slots`` most recent are
        presumed evicted and affinity-masked by ``apply_lru`` regardless, so
        an LRU cap at 2x the largest published cache is behavior-neutral on
        the routing path while keeping streamed runs' ledger bounded.  Any
        agent publishing 0 (= unknown/unbounded cache) disables the cap.
        """
        slots = [a.cache_slots for a in self.agents]
        if slots and all(s > 0 for s in slots):
            self.ledger.max_sessions_per_agent = 2 * max(slots)
        else:
            self.ledger.max_sessions_per_agent = None

    def _rebuild_hubs(self):
        self.hubs = cluster_agents([a.domains for a in self.agents],
                                   [a.scale for a in self.agents],
                                   self.n_hubs, self.hub_scheme)
        # hub cuts moved -> every stored slot-price vector is for a dead
        # layout; stamp a new agent-set version so lookups cold-start
        self.agent_set_version.bump()
        self.price_book.invalidate()

    def add_agent(self, agent: AgentInfo) -> None:
        """Elastic scale-out: admit an agent and recut the proxy hubs."""
        self.agents.append(agent)
        self.pool.add_agent(agent.agent_id, agent.prices)
        self._refresh_ledger_cap()
        self._rebuild_hubs()

    def remove_agent(self, agent_id: str) -> None:
        """Elastic scale-in: drop an agent, its predictors and ledger state."""
        self.agents = [a for a in self.agents if a.agent_id != agent_id]
        self.pool.remove_agent(agent_id)
        self.ledger.evict(agent_id)
        self.quarantined.discard(agent_id)
        self._refresh_ledger_cap()
        self._rebuild_hubs()

    def quarantine(self, agent_id: str) -> None:
        """Fault isolation: exclude a failed/timing-out agent from auctions."""
        self.quarantined.add(agent_id)

    def reinstate(self, agent_id: str) -> None:
        """Lift a quarantine after the cluster-layer cooldown."""
        self.quarantined.discard(agent_id)

    # ---------------- Algorithm 1 ----------------
    def _phase(self, name: str):
        """Profiler section ``name`` — a no-op unless a profiler is attached."""
        return phase_scope(self.profiler, name)

    def _phase1(self, requests, live, telemetry):
        """Phase 1a/1b: affinity + QoS matrices + Eq.-1 values (see
        route_batch); returns (lat, cst, qual, values, X, xs)."""
        # Phase 1a: affinity matrix over LIVE agents.  DAG steps carry their
        # own session key (``meta["session"]``) distinct from the dialogue id
        # so sibling steps do not clobber each other's ledger entries; linear
        # requests fall back to the dialogue id — bit-identical to before.
        prompts = [r.tokens for r in requests]
        sess = [r.meta.get("session", r.dialogue_id) for r in requests]
        ext_mask = [a.recurrent for a in live]
        o = self.ledger.affinity_matrix(
            prompts, sess, [a.agent_id for a in live],
            extension_only_mask=ext_mask,
            use_kernel=self.use_kernel_affinity)
        # LRU cache model (§4.4 published cache summaries): zero the affinity
        # of sessions the backend has presumably evicted, so the auction does
        # not pay for dead caches (and Eq.6 predictions stay calibrated under
        # the paper's constrained-memory / frequent-eviction regime).
        o = self.ledger.apply_lru(o, sess, [a.agent_id for a in live],
                                  [a.cache_slots for a in live])
        # Precedence-aware credit (workflow DAGs): a handoff step's prompt
        # starts with its parents' contexts, so an agent holding a PARENT
        # step's KV prefix is as warm as one holding the step's own — fold
        # that into o before it enters the Eq.-5 feature tensor.
        parents = [r.meta.get("parent_sessions", ()) for r in requests]
        if any(parents):
            o = self.ledger.parent_credit(
                o, prompts, parents, [a.agent_id for a in live],
                extension_only_mask=ext_mask,
                cache_slots=[a.cache_slots for a in live])

        # Phase 1b: QoS prediction per candidate pair — the whole (n, m, F)
        # Eq.-5 tensor in one vectorized pass (default), or the scalar
        # per-pair oracle loop (batched=False); PredictorInput objects are
        # then materialized only for the pairs the auction actually matches.
        n, m = len(requests), len(live)
        inflight = telemetry.get("agent_inflight", {})
        agent_rps = telemetry.get("agent_rps", {})
        if self.batched:
            # domain membership via a per-unique-domain lookup row (a batch
            # has few distinct domains; avoids n*m Python membership tests)
            dom_rows: dict[str, np.ndarray] = {}
            for r in requests:
                if r.domain not in dom_rows:
                    dom_rows[r.domain] = np.array(
                        [float(r.domain in a.domains) for a in live])
            X = feature_tensor(
                [float(len(r.tokens)) for r in requests],
                [float(r.turn) for r in requests], o,
                router_inflight=float(telemetry.get("router_inflight", 0)),
                router_rps=float(telemetry.get("router_rps", 0.0)),
                agent_inflight=[float(inflight.get(a.agent_id, 0))
                                for a in live],
                agent_rps=[float(agent_rps.get(a.agent_id, 0.0))
                           for a in live],
                capacity=[float(a.capacity) for a in live],
                domain_match=np.stack([dom_rows[r.domain] for r in requests]))
            lat, cst, qual = self.pool.predict_matrix(
                [a.agent_id for a in live], X,
                backend=self.predictor_backend)
            xs = None
        else:
            lat = np.zeros((n, m)); cst = np.zeros((n, m)); qual = np.zeros((n, m))
            xs = []
            for j, r in enumerate(requests):
                row = []
                for i, a in enumerate(live):
                    util = inflight.get(a.agent_id, 0) / max(1, a.capacity)
                    x = PredictorInput(
                        prompt_len=float(len(r.tokens)), turn=float(r.turn),
                        affinity=float(o[j, i]),
                        router_inflight=float(telemetry.get("router_inflight", 0)),
                        router_rps=float(telemetry.get("router_rps", 0.0)),
                        agent_inflight=float(inflight.get(a.agent_id, 0)),
                        agent_rps=float(agent_rps.get(a.agent_id, 0.0)),
                        capacity=float(a.capacity), utilization=float(util),
                        domain_match=float(r.domain in a.domains),
                    )
                    est = self.pool[a.agent_id].predict(x)
                    lat[j, i], cst[j, i], qual[j, i] = est.latency, est.cost, est.quality
                    row.append((x, est))
                xs.append(row)

        values = client_value(qual, lat, self.valuation)
        return lat, cst, qual, values, (X if self.batched else None), xs

    def route_batch(self, requests: list[Request], telemetry: dict,
                    free_slots: dict | None = None) -> list[RouteDecision]:
        """telemetry: router_inflight, router_rps, per-agent inflight/rps.
        free_slots (optional) caps per-agent concurrency below capacity.

        Also the window's re-equilibration oracle for provisional routes
        issued by :meth:`route_incremental` since the last batch: the
        provisionals re-enter the market as SHADOW participants (with the
        units they consumed returned to the pool) and the batch solution
        confirms each one (same agent ->
        ``accounts["incremental_confirmed"]``) or disavows it
        (``accounts["incremental_rerouted"]``); the dispatched execution is
        never moved — the counters quantify how often the posted-price
        greedy agreed with the equilibrium.  Every *batch* request is
        tallied exactly once per window — matched or unmatched, with spill
        rescues counted inside matched (plus ``spill_rescued``), never as
        an unmatched-then-rescued double entry.
        """
        if self.profiler is not None and \
                hasattr(self.profiler, "note_route_batch"):
            self.profiler.note_route_batch(len(requests))
        prov = list(self._provisional.values())
        prov_units = self._prov_units
        self._provisional = {}
        self._prov_units = {}
        shadow = len(prov)
        all_reqs = [d.request for d in prov] + list(requests)
        if not all_reqs:
            return []
        if prov_units and free_slots is not None:
            # shadow participants re-bid for the units they already consumed
            free_slots = dict(free_slots)
            for aid, k in prov_units.items():
                free_slots[aid] = free_slots.get(aid, 0) + k
        live = [a for a in self.agents if a.agent_id not in self.quarantined]
        if not live:
            decisions = [RouteDecision(r, None, 0.0, None, 0.0, -1)
                         for r in all_reqs]
            return self._finish_window(prov, decisions, shadow)
        n, m = len(all_reqs), len(live)

        # Phase 1c/2/3 per hub (capacities, hub blocks and warm-start seeds
        # are pure functions of membership/telemetry, so they are assembled
        # before Phase 1 — the fused path feeds them INTO its single program)
        caps = []
        for a in live:
            free = (free_slots or {}).get(a.agent_id, a.capacity)
            caps.append(max(0, int(free)))
        decisions: list[RouteDecision] = [None] * n  # type: ignore
        live_pos = {a.agent_id: i for i, a in enumerate(live)}
        hub_of_agent = {}
        for h, hub in enumerate(self.hubs):
            for gi in hub.agent_indices:
                aid = self.agents[gi].agent_id
                if aid in live_pos:
                    hub_of_agent[live_pos[aid]] = h

        req_hub = [route_to_hub(r.domain, self.hubs,
                                [a.domains for a in self.agents])
                   for r in all_reqs]
        blocks: dict[int, tuple[list[int], list[int]]] = {}
        for h in range(len(self.hubs)):
            r_idx = [j for j in range(n) if req_hub[j] == h]
            a_idx = [i for i in range(m) if hub_of_agent.get(i, -1) == h]
            if not r_idx:
                continue
            # a hub whose live agents are all gone (quarantine/scale-in)
            # still gets an EMPTY block: its requests trivially lose round 1
            # there, which keeps them eligible for the cross-hub spill round
            # and keeps the matched/unmatched ledger honest
            blocks[h] = (r_idx, a_idx)

        # warm-start seeds: last round's duals, replayed only when the hub's
        # exact live-agent set, the elastic version AND the agents'
        # published capacities still match
        start_prices: dict[int, np.ndarray] = {}
        if self.warm_start:
            with self._phase("price_book"):
                for h, (r_idx, a_idx) in blocks.items():
                    if not a_idx:
                        continue
                    version, ids = self.agent_set_version.fingerprint(
                        live[i].agent_id for i in a_idx)
                    counts = [min(caps[i], len(r_idx)) for i in a_idx]
                    seed = self.price_book.lookup(
                        h, version, ids, [live[i].capacity for i in a_idx],
                        counts)
                    if seed is not None:
                        start_prices[h] = seed

        if self._fused is not None:
            # one device-resident program from the ledger gather to the
            # settled auction (n_hubs == 1, so block 0 IS the global market);
            # the cross-hub spill helper still runs host-side for parity
            # with the staged path (it is vacuous unless capacity ran out)
            with self._phase("fused_route"):
                lat, cst, qual, values, X, result = self._fused.step(
                    all_reqs, live, telemetry, caps,
                    start_prices=start_prices.get(0))
            xs = None
            results = {0: result}
            if self.spill:
                with self._phase("phase2_spill"):
                    sres = _spill_round(values, cst, caps, blocks, results,
                                        get_solver(self.solver),
                                        self.payment_mode,
                                        sorted(hub_of_agent))
                if sres is not None:
                    results[SPILL_HUB] = sres
        else:
            with self._phase("phase1_predict"):
                lat, cst, qual, values, X, xs = self._phase1(all_reqs, live,
                                                             telemetry)
            results = run_sharded_auction(values, cst, caps, blocks,
                                          payment_mode=self.payment_mode,
                                          solver=self.solver,
                                          start_prices=start_prices,
                                          spill=self.spill,
                                          spill_agents=sorted(hub_of_agent),
                                          profiler=self.profiler)

        def _record_match(j, i, pay, weight, pred_cost, h):
            """Decision (+ a pending-feedback entry for real batch members —
            shadow provisionals are already pending from their dispatch)."""
            agent = live[i]
            if xs is None:  # batched: materialize matched pairs only
                x = PredictorInput(*(float(v) for v in X[j, i]))
                est = QoSEstimate(float(lat[j, i]), float(cst[j, i]),
                                  float(qual[j, i]))
            else:
                x, est = xs[j][i]
            decisions[j] = RouteDecision(all_reqs[j], agent.agent_id, pay,
                                         est, weight, h)
            if j >= shadow:
                self._pending[all_reqs[j].request_id] = (x, agent,
                                                         all_reqs[j], pay,
                                                         pred_cost)

        for h, result in results.items():
            if h == SPILL_HUB:
                continue  # cross-hub second round, spliced below
            r_idx, a_idx = blocks[h]
            cc = result.costs
            if self.warm_start and a_idx and \
                    "agent_prices" in result.solver_stats:
                with self._phase("price_book"):
                    version, ids = self.agent_set_version.fingerprint(
                        live[i].agent_id for i in a_idx)
                    self.price_book.store(
                        h, version, ids,
                        [live[i].capacity for i in a_idx],
                        result.solver_stats["agent_prices"])
            for local_j, j in enumerate(r_idx):
                li = result.assignment[local_j]
                if li < 0:
                    decisions[j] = RouteDecision(all_reqs[j], None, 0.0, None,
                                                 0.0, h)
                    continue
                _record_match(j, a_idx[li], result.payments[local_j],
                              result.weights[local_j, li], cc[local_j, li], h)

        spill_result = results.get(SPILL_HUB)
        if spill_result is not None:
            # second-round winners override their first-round "unmatched"
            # decisions; payments are Clarke pivots within the spill market
            blk = spill_result.solver_stats["spill"]
            for local_j, j in enumerate(blk["r_idx"]):
                li = spill_result.assignment[local_j]
                if li < 0:
                    continue
                i = blk["a_idx"][li]
                _record_match(j, i, spill_result.payments[local_j],
                              spill_result.weights[local_j, li],
                              spill_result.costs[local_j, li],
                              hub_of_agent.get(i, -1))
                if j >= shadow:
                    self.accounts["spill_rescued"] += 1
        return self._finish_window(prov, decisions, shadow)

    def _finish_window(self, prov, decisions, shadow) -> list[RouteDecision]:
        """Provisional confirmation + the exactly-once-per-window tally.

        The first ``shadow`` decisions are the re-equilibrated provisionals:
        each is compared against its dispatched agent (confirm/disavow
        counters only — they were tallied as matched when provisionally
        routed, and their execution is not moved).  The remaining decisions
        are this batch's requests, each counted exactly once as matched or
        unmatched — spill rescues land directly in matched, so a rescued
        request never transits the unmatched tally.
        """
        for d0, d1 in zip(prov, decisions[:shadow]):
            if d1 is not None and d1.agent_id == d0.agent_id:
                self.accounts["incremental_confirmed"] += 1
            else:
                self.accounts["incremental_rerouted"] += 1
        out = decisions[shadow:]
        matched = sum(1 for d in out if d is not None
                      and d.agent_id is not None)
        self.accounts["matched"] += matched
        self.accounts["unmatched"] += len(out) - matched
        return out

    def route_incremental(self, requests: list[Request], telemetry: dict,
                          free_slots: dict | None = None
                          ) -> list[RouteDecision]:
        """Mid-window arrivals bid directly into the standing duals.

        Each request is routed greedily at posted prices: against every
        live agent of its hub, agent i's next provisional unit is offered
        at the standing dual ``asks[i][k]`` (k = units already provisionally
        taken from i this window, so repeated arrivals walk up the agent's
        ascending price vector exactly like auction bids would); the
        request takes the agent maximizing ``w_ij − ask`` when that profit
        is positive, paying predicted cost + the posted ask.  The route is
        PROVISIONAL: the next :meth:`route_batch` re-equilibrates the
        window's market with the provisionals as shadow participants and
        confirms or disavows each one.

        Requests that cannot be routed provisionally — warm starts
        disabled, no fresh duals for their hub, no free unit left at a
        posted price, or no positive profit — come back with ``agent_id
        None`` and are NOT tallied as unmatched: they are deferred to the
        next batch auction, which owns their accounting.
        """
        if not requests:
            return []
        misses = [RouteDecision(r, None, 0.0, None, 0.0, -1)
                  for r in requests]
        live = [a for a in self.agents if a.agent_id not in self.quarantined]
        if not live or not self.warm_start:
            return misses
        with self._phase("phase1_predict"):
            lat, cst, qual, values, X, xs = self._phase1(requests, live,
                                                         telemetry)
        w = np.asarray(values, dtype=np.float64) - np.asarray(
            cst, dtype=np.float64)
        w = np.where(w > 0, w, 0.0)
        live_pos = {a.agent_id: i for i, a in enumerate(live)}
        hub_agents: dict[int, list[int]] = {}
        for h, hub in enumerate(self.hubs):
            for gi in hub.agent_indices:
                aid = self.agents[gi].agent_id
                if aid in live_pos:
                    hub_agents.setdefault(h, []).append(live_pos[aid])
        asks_of: dict[int, dict | None] = {}
        decisions: list[RouteDecision] = []
        for j, r in enumerate(requests):
            h = route_to_hub(r.domain, self.hubs,
                             [a.domains for a in self.agents])
            a_idx = sorted(hub_agents.get(h, []))
            if h not in asks_of:
                asks_of[h] = None
                if a_idx:
                    with self._phase("price_book"):
                        version, ids = self.agent_set_version.fingerprint(
                            live[i].agent_id for i in a_idx)
                        asks_of[h] = self.price_book.posted_asks(
                            h, version, ids,
                            [live[i].capacity for i in a_idx])
            asks = asks_of[h]
            if asks is None:
                decisions.append(misses[j])
                continue
            best = None          # (profit, live index, posted ask)
            for i in a_idx:      # ascending i: ties keep the lowest index
                aid = live[i].agent_id
                k = self._prov_units.get(aid, 0)
                free = (free_slots or {}).get(aid, live[i].capacity) - k
                prev = asks.get(aid)
                if free <= 0 or prev is None or k >= len(prev):
                    continue
                profit = float(w[j, i]) - float(prev[k])
                if profit > 0.0 and (best is None or profit > best[0]):
                    best = (profit, i, float(prev[k]))
            if best is None:
                decisions.append(misses[j])
                continue
            _, i, ask = best
            agent = live[i]
            if xs is None:
                x = PredictorInput(*(float(v) for v in X[j, i]))
                est = QoSEstimate(float(lat[j, i]), float(cst[j, i]),
                                  float(qual[j, i]))
            else:
                x, est = xs[j][i]
            pay = float(cst[j, i]) + ask
            d = RouteDecision(r, agent.agent_id, pay, est, float(w[j, i]), h)
            decisions.append(d)
            self._pending[r.request_id] = (x, agent, r, pay,
                                           float(cst[j, i]))
            self._provisional[r.request_id] = d
            self._prov_units[agent.agent_id] = \
                self._prov_units.get(agent.agent_id, 0) + 1
            self.accounts["matched"] += 1
            self.accounts["incremental_routed"] += 1
        return decisions

    # ---------------- Phase 4: feedback ----------------
    def on_complete(self, request_id: str, obs: CompletionObs) -> None:
        """Phase 4: predictor/ledger updates + market accounting (or the
        fault path: quarantine, no payment) for one completed request."""
        entry = self._pending.pop(request_id, None)
        # a provisional that completed before the next batch auction needs no
        # re-equilibration: retire it and release its provisional unit
        prov = self._provisional.pop(request_id, None)
        if prov is not None and prov.agent_id is not None:
            k = self._prov_units.get(prov.agent_id, 0) - 1
            if k > 0:
                self._prov_units[prov.agent_id] = k
            else:
                self._prov_units.pop(prov.agent_id, None)
        if entry is None:
            return
        x, agent, req, payment, pred_cost = entry
        if obs.failed:
            # fault path: no payment, quarantine the agent; the request is
            # re-auctioned by the cluster layer.
            self.quarantine(agent.agent_id)
            if self.settlement is not None:
                rep = (self.pool[agent.agent_id].reputation
                       if agent.agent_id in self.pool else 1.0)
                self.settlement.append(
                    kind="fault", request_id=request_id,
                    agent_id=agent.agent_id,
                    reputation_before=rep, reputation_after=rep)
            return
        if agent.agent_id not in self.pool:
            # churn: the agent left between dispatch and completion — no
            # predictor to teach and nothing to settle against (the cluster
            # keeps the ground-truth record; accounts and ledger stay
            # consistent by both skipping the orphan)
            return
        cost = observed_cost(agent.prices, obs.n_prompt, obs.n_hit, obs.n_gen)
        pred = self.pool[agent.agent_id]
        rep_before = pred.reputation
        # settlement audit channel: when ground truth rides along, settle
        # value at the audited quality and charge the inflation residual to
        # the agent's reputation; a None channel reproduces the pre-audit
        # router bit for bit (audited == reported, no residual update)
        audited_q = (obs.quality if obs.audit_quality is None
                     else float(obs.audit_quality))
        if self.use_reputation and obs.audit_quality is not None:
            pred.note_residual(max(0.0, obs.quality - audited_q))
        pred.update(x, obs.latency, cost, obs.quality)
        pred.ewma_gen = 0.9 * pred.ewma_gen + 0.1 * obs.n_gen
        # eviction resync (Appendix C.2.2): the engine reported zero cached
        # tokens despite a confident ledger match -> the backend evicted its
        # KV; drop our record so affinity reflects reality next round.  DAG
        # steps live under their own session key; the confident match may
        # have come from a parent entry (parent_credit), so drop those too.
        sess = req.meta.get("session", req.dialogue_id)
        if obs.n_hit == 0 and x.affinity > 0.3:
            self.ledger.evict(agent.agent_id, sess)
            for ps in req.meta.get("parent_sessions", ()):
                self.ledger.evict(agent.agent_id, ps)
        self.ledger.update(agent.agent_id, sess, req.tokens)
        # market accounting (weak budget balance bookkeeping, Thm 4.3);
        # realized value settles at the AUDITED quality when available
        true_value = client_value(audited_q, obs.latency, self.valuation)
        self.accounts["payments"] += payment
        self.accounts["agent_costs"] += cost
        self.accounts["surplus"] += payment - cost
        self.accounts["welfare_realized"] += float(true_value) - cost
        if self.settlement is not None:
            self.settlement.append(
                kind="settle", request_id=request_id,
                agent_id=agent.agent_id, payment=payment, cost=cost,
                reported_quality=float(obs.quality),
                audited_quality=float(audited_q),
                true_value=float(true_value),
                reputation_before=rep_before,
                reputation_after=pred.reputation)
