"""Power-of-two shape buckets for jit-staged array programs.

JAX retraces a jitted program whenever an argument's shape changes, and the
serving loop's array shapes wobble constantly — batch sizes per round, slot
counts per hub, node-pool sizes as Hoeffding trees split.  Padding every
such dimension up to the next power of two collapses the shape space to
O(log) distinct buckets, so steady-state traffic reuses a handful of traced
programs instead of recompiling per shape.  The PR-3 hub-sharded auction
introduced the trick (`solve_dense_auction_jax_batch`); this module is the
shared home so the dense/Pallas auction backends and the jax predictor
walker bucket the same way.  Kept stdlib-only (core imports jax lazily).

Callers are responsible for making the padding behavior-neutral (zero-weight
auction rows/columns, leaf-marked tree nodes, discarded output rows).
"""
from __future__ import annotations


def pow2_bucket(x: int, floor: int = 8) -> int:
    """Smallest power of two >= max(x, floor) — the jit shape bucket."""
    return 1 << (max(int(x), floor) - 1).bit_length()
