"""Append-only hash-chained settlement ledger (the market's audit layer).

Reference design: the blockchain-driven incentive-compatibility line in
PAPERS.md — verifiable settlement without the chain consensus.  Every
request the router settles (Phase 4) appends exactly one entry carrying
the economically meaningful quantities of that settlement: the Clarke
payment, the cost booked at the agent's *published* prices, the reported
vs audited QoS, the client value the welfare account realized, and the
reputation transition the report caused.  Entries are chained by SHA-256
over a canonical serialization (floats rendered with ``float.hex`` so the
chain commits to exact bit patterns, not printf roundings), which makes
two audits mechanical:

* ``verify_chain()`` — recompute every hash and its linkage; any mutation,
  insertion, deletion or reordering of a past entry breaks the chain.
* ``replay_balances()`` / ``audit(accounts)`` — recompute the router's
  account balances from the ledger alone, in append order.  Because the
  ledger is appended inside ``IEMASRouter.on_complete`` with the exact
  floats the accounts accumulated, and float addition is replayed in the
  same order, the replay is *exactly* equal to ``accounts`` — the audit
  tolerance exists only as a guard rail, not as slack for drift.

The ledger records faults too (``kind="fault"``: no payment, agent
quarantined) so the audit trail covers every completion the router saw,
not just the paid ones.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass

#: hash of the empty chain (the genesis predecessor)
GENESIS = "0" * 64

#: account keys the replay audit must reproduce exactly
AUDITED_KEYS = ("payments", "agent_costs", "surplus", "welfare_realized")


@dataclass(frozen=True)
class SettlementEntry:
    """One immutable settlement record (hash-chained to its predecessor).

    ``kind`` is ``"settle"`` for a paid completion or ``"fault"`` for a
    failed one (no payment, agent quarantined).  ``cost`` is the cost
    booked at the agent's published prices — under a misreporting agent it
    deliberately differs from the cluster's ground-truth cost, which is
    the whole point of auditing.  ``audited_quality`` equals
    ``reported_quality`` whenever no audit channel was attached.
    """

    seq: int
    kind: str
    request_id: str
    agent_id: str
    payment: float
    cost: float
    reported_quality: float
    audited_quality: float
    true_value: float
    reputation_before: float
    reputation_after: float
    prev_hash: str
    entry_hash: str = ""

    def payload(self) -> str:
        """Canonical serialization covered by ``entry_hash``.

        Floats are rendered with ``float.hex`` so the hash commits to the
        exact IEEE-754 values the accounts accumulated — a replay that
        verifies is bit-faithful, not approximately faithful.
        """
        return "|".join((
            str(self.seq), self.kind, self.request_id, self.agent_id,
            float(self.payment).hex(), float(self.cost).hex(),
            float(self.reported_quality).hex(),
            float(self.audited_quality).hex(),
            float(self.true_value).hex(),
            float(self.reputation_before).hex(),
            float(self.reputation_after).hex(),
            self.prev_hash,
        ))


def _hash(payload: str) -> str:
    """SHA-256 hex digest of one canonical entry payload."""
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()


class SettlementLedger:
    """Append-only hash-chained log of every settlement the router made.

    Attach one to ``IEMASRouter(audit_ledger=True)`` and it receives one
    entry per completed request (paid or faulted).  See the module
    docstring for the two audits it supports.
    """

    def __init__(self):
        self.entries: list[SettlementEntry] = []

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def head(self) -> str:
        """Hash of the newest entry (``GENESIS`` when the chain is empty)."""
        return self.entries[-1].entry_hash if self.entries else GENESIS

    def append(self, *, kind: str, request_id: str, agent_id: str,
               payment: float = 0.0, cost: float = 0.0,
               reported_quality: float = 0.0, audited_quality: float = 0.0,
               true_value: float = 0.0, reputation_before: float = 1.0,
               reputation_after: float = 1.0) -> SettlementEntry:
        """Chain one settlement record and return the sealed entry."""
        entry = SettlementEntry(
            seq=len(self.entries), kind=kind, request_id=request_id,
            agent_id=agent_id, payment=float(payment), cost=float(cost),
            reported_quality=float(reported_quality),
            audited_quality=float(audited_quality),
            true_value=float(true_value),
            reputation_before=float(reputation_before),
            reputation_after=float(reputation_after), prev_hash=self.head)
        entry = dataclasses.replace(entry, entry_hash=_hash(entry.payload()))
        self.entries.append(entry)
        return entry

    def verify_chain(self) -> bool:
        """True iff every hash and linkage recomputes — i.e. no entry was
        mutated, inserted, deleted or reordered since it was appended."""
        prev = GENESIS
        for k, e in enumerate(self.entries):
            if e.seq != k or e.prev_hash != prev:
                return False
            if _hash(e.payload()) != e.entry_hash:
                return False
            prev = e.entry_hash
        return True

    def replay_balances(self) -> dict:
        """Recompute the router's account balances from entries alone.

        Summation runs in append order — the same order (and the same
        floats) ``on_complete`` accumulated into ``accounts`` — so the
        replayed balances are exactly equal, not merely close.
        """
        bal = {k: 0.0 for k in AUDITED_KEYS}
        bal["settled"] = 0
        bal["faults"] = 0
        for e in self.entries:
            if e.kind != "settle":
                bal["faults"] += 1
                continue
            bal["payments"] += e.payment
            bal["agent_costs"] += e.cost
            bal["surplus"] += e.payment - e.cost
            bal["welfare_realized"] += e.true_value - e.cost
            bal["settled"] += 1
        return bal

    def revenue_by_agent(self) -> dict[str, float]:
        """Settled payment totals per agent (revenue attribution)."""
        out: dict[str, float] = {}
        for e in self.entries:
            if e.kind == "settle":
                out[e.agent_id] = out.get(e.agent_id, 0.0) + e.payment
        return out

    def audit(self, accounts: dict, *, atol: float = 1e-9) -> dict:
        """Full replay audit against the router's live ``accounts``.

        Verifies the hash chain, replays the balances, and raises
        ``ValueError`` on any divergence (``atol`` is a guard rail — the
        replay is exact by construction).  Returns the replayed balances.
        """
        if not self.verify_chain():
            raise ValueError("settlement ledger hash chain failed to verify")
        bal = self.replay_balances()
        for key in AUDITED_KEYS:
            if abs(bal[key] - accounts[key]) > atol:
                raise ValueError(
                    f"ledger replay diverges from accounts on {key!r}: "
                    f"replayed {bal[key]!r} vs booked {accounts[key]!r}")
        return bal
