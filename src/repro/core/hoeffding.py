"""Hoeffding trees (VFDT) — online regressor & classifier, dependency-free.

The paper's QoS predictors (§4.1) use river's HoeffdingTreeRegressor /
HoeffdingTreeClassifier; river is not available offline so this implements
the same algorithmic family: leaves accumulate sufficient statistics per
feature bin; a leaf splits when the Hoeffding bound separates the best from
the second-best split gain with confidence 1-delta.

API mirrors river: ``learn_one(x, y)`` / ``predict_one(x)`` with x a 1-D
numpy array (the framework's feature vectors are fixed-length, Eq. 5).

Batched inference: a tree compiles lazily to a flat array-of-nodes form
(:class:`CompiledTree`) whose ``descend`` scores a whole (B, n_features)
matrix in one vectorized pass — a pure oracle-parity optimization of
``predict_one`` (identical doubles: leaf values are baked at compile time
with the same divisions ``predict_one`` performs). Every ``learn_one``
bumps a version counter (leaf means shift even without a split), so the
compiled form is invalidated and rebuilt on next use. ``stack_compiled``
concatenates many trees into one node pool with per-tree roots, so an
ensemble over m agents scores an (n·m, F) feature matrix in a single pass.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.buckets import pow2_bucket


class _LeafStats:
    """Per-leaf sufficient statistics with per-feature binned sub-stats."""

    __slots__ = ("n", "s", "ss", "cls", "bins_lo", "bins_hi", "bin_n",
                 "bin_s", "bin_ss", "bin_cls", "n_feat", "n_bins", "frozen")

    def __init__(self, n_feat: int, n_bins: int = 8):
        self.n = 0
        self.s = 0.0
        self.ss = 0.0
        self.cls = np.zeros(2)  # class counts (classifier)
        self.n_feat = n_feat
        self.n_bins = n_bins
        self.bins_lo = np.full(n_feat, np.inf)
        self.bins_hi = np.full(n_feat, -np.inf)
        self.bin_n = np.zeros((n_feat, n_bins))
        self.bin_s = np.zeros((n_feat, n_bins))
        self.bin_ss = np.zeros((n_feat, n_bins))
        self.bin_cls = np.zeros((n_feat, n_bins, 2))

    def add(self, x: np.ndarray, y: float, y_cls: int | None = None):
        self.n += 1
        self.s += y
        self.ss += y * y
        if y_cls is not None:
            self.cls[y_cls] += 1
        self.bins_lo = np.minimum(self.bins_lo, x)
        self.bins_hi = np.maximum(self.bins_hi, x)
        span = np.maximum(self.bins_hi - self.bins_lo, 1e-12)
        idx = np.clip(((x - self.bins_lo) / span * self.n_bins).astype(int),
                      0, self.n_bins - 1)
        f = np.arange(self.n_feat)
        self.bin_n[f, idx] += 1
        self.bin_s[f, idx] += y
        self.bin_ss[f, idx] += y * y
        if y_cls is not None:
            self.bin_cls[f, idx, y_cls] += 1

    # -- split gain evaluation --
    def _var(self, n, s, ss):
        n = np.maximum(n, 1e-12)
        return np.maximum(ss / n - (s / n) ** 2, 0.0)

    def best_splits_regression(self):
        """Per feature: best variance-reduction split over bin boundaries."""
        total_var = self._var(self.n, self.s, self.ss)
        best_gain = np.zeros(self.n_feat)
        best_thresh = np.zeros(self.n_feat)
        cn = np.cumsum(self.bin_n, axis=1)
        cs = np.cumsum(self.bin_s, axis=1)
        css = np.cumsum(self.bin_ss, axis=1)
        for f in range(self.n_feat):
            for b in range(self.n_bins - 1):
                nl, nr = cn[f, b], self.n - cn[f, b]
                if nl < 2 or nr < 2:
                    continue
                vl = self._var(nl, cs[f, b], css[f, b])
                vr = self._var(nr, self.s - cs[f, b], self.ss - css[f, b])
                gain = total_var - (nl * vl + nr * vr) / self.n
                if gain > best_gain[f]:
                    best_gain[f] = gain
                    span = self.bins_hi[f] - self.bins_lo[f]
                    best_thresh[f] = self.bins_lo[f] + span * (b + 1) / self.n_bins
        return best_gain, best_thresh

    @staticmethod
    def _entropy(counts):
        tot = counts.sum()
        if tot <= 0:
            return 0.0
        p = counts / tot
        p = p[p > 0]
        return float(-(p * np.log2(p)).sum())

    def best_splits_classification(self):
        base = self._entropy(self.cls)
        best_gain = np.zeros(self.n_feat)
        best_thresh = np.zeros(self.n_feat)
        ccls = np.cumsum(self.bin_cls, axis=1)  # [F, bins, 2]
        for f in range(self.n_feat):
            for b in range(self.n_bins - 1):
                left = ccls[f, b]
                right = self.cls - left
                nl, nr = left.sum(), right.sum()
                if nl < 2 or nr < 2:
                    continue
                gain = base - (nl * self._entropy(left)
                               + nr * self._entropy(right)) / self.n
                if gain > best_gain[f]:
                    best_gain[f] = gain
                    span = self.bins_hi[f] - self.bins_lo[f]
                    best_thresh[f] = self.bins_lo[f] + span * (b + 1) / self.n_bins
        return best_gain, best_thresh


class _Node:
    __slots__ = ("stats", "feature", "threshold", "left", "right", "depth")

    def __init__(self, n_feat, depth):
        self.stats = _LeafStats(n_feat)
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.depth = depth

    @property
    def is_leaf(self):
        return self.feature < 0


@dataclass(frozen=True)
class CompiledTree:
    """Flat array-of-nodes form of one (or several stacked) Hoeffding trees.

    ``feature[k] < 0`` marks node ``k`` as a leaf whose prediction is
    ``value[k]``; internal nodes route ``x[feature] <= threshold`` to
    ``left`` else ``right``. ``depth`` bounds the descend iteration count.

    frozen covers the FIELDS, not the arrays: the owning tree's
    ``compiled()`` refreshes ``value`` IN PLACE after non-split
    observations (and the predictor pool does the same to its stacked
    copy), so this is a live view, not a snapshot — ``.value.copy()``
    if you need before/after comparisons.
    """
    feature: np.ndarray    # int32 [K]
    threshold: np.ndarray  # float64 [K]
    left: np.ndarray       # int32 [K]
    right: np.ndarray      # int32 [K]
    value: np.ndarray      # float64 [K]; 0.0 at internal nodes
    depth: int


def descend(tree: CompiledTree, X: np.ndarray,
            roots: np.ndarray | None = None) -> np.ndarray:
    """Vectorized tree walk: scores every row of ``X`` in one NumPy pass.

    ``roots`` gives each row its starting node (stacked multi-tree form);
    ``None`` starts every row at node 0. Rows already at a leaf keep their
    position, so ragged trees coexist in one node pool.
    """
    X = np.asarray(X, dtype=np.float64)
    n_rows = X.shape[0]
    if roots is None:
        cur = np.zeros(n_rows, dtype=np.int64)
    else:
        cur = np.asarray(roots, dtype=np.int64).copy()
    if n_rows == 0:
        return np.zeros(0, dtype=np.float64)
    rows = np.arange(n_rows)
    for _ in range(tree.depth + 1):
        f = tree.feature[cur]
        internal = f >= 0
        if not internal.any():
            break
        go_left = X[rows, np.where(internal, f, 0)] <= tree.threshold[cur]
        nxt = np.where(go_left, tree.left[cur], tree.right[cur])
        cur = np.where(internal, nxt, cur)
    return tree.value[cur]


def stack_compiled(trees: list[CompiledTree]) -> tuple[CompiledTree, np.ndarray]:
    """Concatenate compiled trees into one node pool; returns (stacked,
    root offsets) so row ``r`` of a feature matrix descends tree
    ``tree_of_row[r]`` via ``roots[tree_of_row]``."""
    sizes = np.array([len(t.feature) for t in trees], dtype=np.int64)
    offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]])

    def shift(child, off):
        return np.where(child >= 0, child + off, child).astype(np.int32)

    stacked = CompiledTree(
        feature=np.concatenate([t.feature for t in trees]),
        threshold=np.concatenate([t.threshold for t in trees]),
        left=np.concatenate([shift(t.left, o)
                             for t, o in zip(trees, offsets)]),
        right=np.concatenate([shift(t.right, o)
                              for t, o in zip(trees, offsets)]),
        value=np.concatenate([t.value for t in trees]),
        depth=max(t.depth for t in trees),
    )
    return stacked, offsets


_JAX_DESCEND = None


def _jax_descend():
    """jit-staged descend (fori_loop over depth); float32 on default jax
    configs, so vs the NumPy oracle expect ~1e-6 typically — and, when a
    feature lands within float32 rounding of a threshold, a flipped
    comparison can route to a DIFFERENT leaf (error up to the leaf-value
    gap). The NumPy backend is the only bit-exact path."""
    global _JAX_DESCEND
    if _JAX_DESCEND is None:
        import jax
        import jax.numpy as jnp
        from jax import lax

        def run(feature, threshold, left, right, value, roots, X, depth):
            rows = jnp.arange(X.shape[0])

            def body(_, cur):
                f = feature[cur]
                internal = f >= 0
                go_left = X[rows, jnp.where(internal, f, 0)] <= threshold[cur]
                nxt = jnp.where(go_left, left[cur], right[cur])
                return jnp.where(internal, nxt, cur)

            return value[lax.fori_loop(0, depth, body, roots)]

        _JAX_DESCEND = jax.jit(run, static_argnames=("depth",))
    return _JAX_DESCEND


def descend_jax(tree: CompiledTree, X, roots=None) -> np.ndarray:
    """`descend` via the jit-staged fori_loop walker (float32 on device).

    Shape-bucketed: the feature matrix's batch dimension, the node pool and
    the loop depth are all padded up to power-of-two buckets before hitting
    the jit cache, so batch-size wobble between serving rounds and
    node-count growth from tree splits reuse O(log) traced programs instead
    of retracing per shape.  Padding is behavior-neutral — padded rows
    descend from node 0 and are sliced off, padded nodes are unreachable
    leaves, and extra depth iterations leave settled rows in place.
    """
    X = np.asarray(X)
    n_rows = X.shape[0]
    if roots is None:
        roots = np.zeros(n_rows, dtype=np.int32)
    nb = pow2_bucket(n_rows)
    if nb != n_rows:
        X = np.pad(X, ((0, nb - n_rows), (0, 0)))
        roots = np.pad(np.asarray(roots, np.int32), (0, nb - n_rows))
    n_nodes = len(tree.feature)
    kb = pow2_bucket(n_nodes)
    feature, threshold = tree.feature, tree.threshold
    left, right, value = tree.left, tree.right, tree.value
    if kb != n_nodes:
        pad = kb - n_nodes
        feature = np.pad(feature, (0, pad), constant_values=-1)  # leaves
        threshold = np.pad(threshold, (0, pad))
        left = np.pad(left, (0, pad))
        right = np.pad(right, (0, pad))
        value = np.pad(value, (0, pad))
    out = _jax_descend()(feature, threshold, left, right, value,
                         np.asarray(roots, np.int32), X,
                         pow2_bucket(tree.depth + 1, floor=4))
    return np.asarray(out, dtype=np.float64)[:n_rows]


class _HoeffdingTreeBase:
    def __init__(self, n_features: int, *, delta: float = 1e-4,
                 grace_period: int = 40, max_depth: int = 7,
                 tie_threshold: float = 0.05, classification: bool = False):
        self.n_features = n_features
        self.delta = delta
        self.grace = grace_period
        self.max_depth = max_depth
        self.tau = tie_threshold
        self.classification = classification
        self.root = _Node(n_features, 0)
        self.n_seen = 0
        self._y_min = np.inf
        self._y_max = -np.inf
        # batched-inference cache, two-speed: structure (features/thresholds/
        # children) changes only on splits, while leaf values shift on EVERY
        # learn_one — so the flat form recompiles on _struct_version and
        # merely refreshes its value array in place on _version
        self._version = 0
        self._struct_version = 0
        self._compiled: CompiledTree | None = None
        self._compiled_version = -1
        self._compiled_struct_version = -1
        self._leaf_slots: list[tuple[int, _Node]] = []

    def _sort(self, x) -> _Node:
        node = self.root
        while not node.is_leaf:
            node = node.left if x[node.feature] <= node.threshold else node.right
        return node

    def learn_one(self, x, y):
        x = np.asarray(x, dtype=np.float64)
        self.n_seen += 1
        self._version += 1
        self._y_min = min(self._y_min, float(y))
        self._y_max = max(self._y_max, float(y))
        node = self._sort(x)
        node.stats.add(x, float(y),
                       int(y > 0.5) if self.classification else None)
        if (node.stats.n % self.grace == 0 and node.depth < self.max_depth):
            self._try_split(node)
        return self

    def _try_split(self, node: _Node):
        st = node.stats
        if self.classification:
            gains, thresholds = st.best_splits_classification()
            value_range = 1.0  # entropy gain range for binary
        else:
            gains, thresholds = st.best_splits_regression()
            value_range = max(self._y_max - self._y_min, 1e-9) ** 2
        order = np.argsort(gains)[::-1]
        g1, g2 = gains[order[0]], gains[order[1]] if len(order) > 1 else 0.0
        eps = math.sqrt(value_range ** 2 * math.log(1.0 / self.delta)
                        / (2.0 * st.n))
        if g1 > 0 and (g1 - g2 > eps or eps < self.tau * value_range):
            f = int(order[0])
            node.feature = f
            node.threshold = float(thresholds[f])
            node.left = _Node(self.n_features, node.depth + 1)
            node.right = _Node(self.n_features, node.depth + 1)
            node.stats = None  # freed; children start fresh
            self._struct_version += 1

    # ---------------- batched inference ----------------
    def _leaf_value(self, node: _Node) -> float:
        raise NotImplementedError

    def _compile(self) -> CompiledTree:
        feats: list[int] = []
        thrs: list[float] = []
        lefts: list[int] = []
        rights: list[int] = []
        vals: list[float] = []
        leaf_slots: list[tuple[int, _Node]] = []
        depth = 0

        def emit(node: _Node) -> int:
            nonlocal depth
            k = len(feats)
            depth = max(depth, node.depth)
            feats.append(node.feature)
            thrs.append(node.threshold)
            lefts.append(-1)
            rights.append(-1)
            if node.is_leaf:
                vals.append(self._leaf_value(node))
                leaf_slots.append((k, node))
            else:
                vals.append(0.0)
                lefts[k] = emit(node.left)
                rights[k] = emit(node.right)
            return k

        emit(self.root)
        self._leaf_slots = leaf_slots
        return CompiledTree(np.asarray(feats, np.int32),
                            np.asarray(thrs, np.float64),
                            np.asarray(lefts, np.int32),
                            np.asarray(rights, np.int32),
                            np.asarray(vals, np.float64), depth)

    def compiled(self) -> CompiledTree:
        """Current flat form, refreshed lazily at two speeds: a full
        recompile only after a ``learn_one`` split changed the structure
        (O(#nodes), bounded by 2^max_depth); otherwise just the leaf-value
        array rewritten in place (O(#leaves)) — non-split observations move
        leaf means and the global fallback, never the routing arrays."""
        if (self._compiled is None
                or self._compiled_struct_version != self._struct_version):
            self._compiled = self._compile()
            self._compiled_struct_version = self._struct_version
            self._compiled_version = self._version
        elif self._compiled_version != self._version:
            value = self._compiled.value
            for k, node in self._leaf_slots:
                value[k] = self._leaf_value(node)
            self._compiled_version = self._version
        return self._compiled

    def predict_batch(self, X, backend: str = "numpy") -> np.ndarray:
        """Score every row of ``X`` (B, n_features); matches per-row
        ``predict_one`` exactly on the NumPy backend."""
        X = np.asarray(X, dtype=np.float64)
        if backend == "jax":
            return descend_jax(self.compiled(), X)
        return descend(self.compiled(), X)


class HoeffdingTreeRegressor(_HoeffdingTreeBase):
    """Incremental regression tree; leaves predict their running mean."""

    def __init__(self, n_features: int, **kw):
        super().__init__(n_features, classification=False, **kw)
        self._global_s = 0.0

    def learn_one(self, x, y):
        """Absorb one (features, target) observation; may split a leaf."""
        self._global_s += float(y)
        return super().learn_one(x, y)

    def predict_one(self, x) -> float:
        """Mean of x's leaf (global mean while the leaf is still empty)."""
        if self.n_seen == 0:
            return 0.0
        node = self._sort(np.asarray(x, dtype=np.float64))
        # walk up conceptually: empty fresh leaves fall back to global mean
        if node.stats is not None and node.stats.n > 0:
            return node.stats.s / node.stats.n
        return self._global_s / self.n_seen

    def _leaf_value(self, node: _Node) -> float:
        st = node.stats
        if st is not None and st.n > 0:
            return st.s / st.n
        return self._global_s / self.n_seen if self.n_seen else 0.0


class HoeffdingTreeClassifier(_HoeffdingTreeBase):
    """Binary classifier; predict_one returns P(class=1)."""

    def __init__(self, n_features: int, **kw):
        super().__init__(n_features, classification=True, **kw)
        self._global_cls = np.zeros(2)

    def learn_one(self, x, y):
        """Absorb one observation (y thresholded at 0.5 into {0, 1})."""
        self._global_cls[int(y > 0.5)] += 1
        return super().learn_one(x, y)

    def predict_one(self, x) -> float:
        """Laplace-smoothed P(class=1) at x's leaf."""
        if self.n_seen == 0:
            return 0.5
        node = self._sort(np.asarray(x, dtype=np.float64))
        if node.stats is not None and node.stats.n > 0:
            c = node.stats.cls
            return float((c[1] + 1.0) / (c.sum() + 2.0))  # Laplace
        g = self._global_cls
        return float((g[1] + 1.0) / (g.sum() + 2.0))

    def _leaf_value(self, node: _Node) -> float:
        st = node.stats
        if st is not None and st.n > 0:
            c = st.cls
            return float((c[1] + 1.0) / (c.sum() + 2.0))
        g = self._global_cls
        # n_seen == 0 included: (0+1)/(0+2) is predict_one's 0.5 default
        return float((g[1] + 1.0) / (g.sum() + 2.0))
