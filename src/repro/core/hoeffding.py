"""Hoeffding trees (VFDT) — online regressor & classifier, dependency-free.

The paper's QoS predictors (§4.1) use river's HoeffdingTreeRegressor /
HoeffdingTreeClassifier; river is not available offline so this implements
the same algorithmic family: leaves accumulate sufficient statistics per
feature bin; a leaf splits when the Hoeffding bound separates the best from
the second-best split gain with confidence 1-delta.

API mirrors river: ``learn_one(x, y)`` / ``predict_one(x)`` with x a 1-D
numpy array (the framework's feature vectors are fixed-length, Eq. 5).
"""
from __future__ import annotations

import math

import numpy as np


class _LeafStats:
    """Per-leaf sufficient statistics with per-feature binned sub-stats."""

    __slots__ = ("n", "s", "ss", "cls", "bins_lo", "bins_hi", "bin_n",
                 "bin_s", "bin_ss", "bin_cls", "n_feat", "n_bins", "frozen")

    def __init__(self, n_feat: int, n_bins: int = 8):
        self.n = 0
        self.s = 0.0
        self.ss = 0.0
        self.cls = np.zeros(2)  # class counts (classifier)
        self.n_feat = n_feat
        self.n_bins = n_bins
        self.bins_lo = np.full(n_feat, np.inf)
        self.bins_hi = np.full(n_feat, -np.inf)
        self.bin_n = np.zeros((n_feat, n_bins))
        self.bin_s = np.zeros((n_feat, n_bins))
        self.bin_ss = np.zeros((n_feat, n_bins))
        self.bin_cls = np.zeros((n_feat, n_bins, 2))

    def add(self, x: np.ndarray, y: float, y_cls: int | None = None):
        self.n += 1
        self.s += y
        self.ss += y * y
        if y_cls is not None:
            self.cls[y_cls] += 1
        self.bins_lo = np.minimum(self.bins_lo, x)
        self.bins_hi = np.maximum(self.bins_hi, x)
        span = np.maximum(self.bins_hi - self.bins_lo, 1e-12)
        idx = np.clip(((x - self.bins_lo) / span * self.n_bins).astype(int),
                      0, self.n_bins - 1)
        f = np.arange(self.n_feat)
        self.bin_n[f, idx] += 1
        self.bin_s[f, idx] += y
        self.bin_ss[f, idx] += y * y
        if y_cls is not None:
            self.bin_cls[f, idx, y_cls] += 1

    # -- split gain evaluation --
    def _var(self, n, s, ss):
        n = np.maximum(n, 1e-12)
        return np.maximum(ss / n - (s / n) ** 2, 0.0)

    def best_splits_regression(self):
        """Per feature: best variance-reduction split over bin boundaries."""
        total_var = self._var(self.n, self.s, self.ss)
        best_gain = np.zeros(self.n_feat)
        best_thresh = np.zeros(self.n_feat)
        cn = np.cumsum(self.bin_n, axis=1)
        cs = np.cumsum(self.bin_s, axis=1)
        css = np.cumsum(self.bin_ss, axis=1)
        for f in range(self.n_feat):
            for b in range(self.n_bins - 1):
                nl, nr = cn[f, b], self.n - cn[f, b]
                if nl < 2 or nr < 2:
                    continue
                vl = self._var(nl, cs[f, b], css[f, b])
                vr = self._var(nr, self.s - cs[f, b], self.ss - css[f, b])
                gain = total_var - (nl * vl + nr * vr) / self.n
                if gain > best_gain[f]:
                    best_gain[f] = gain
                    span = self.bins_hi[f] - self.bins_lo[f]
                    best_thresh[f] = self.bins_lo[f] + span * (b + 1) / self.n_bins
        return best_gain, best_thresh

    @staticmethod
    def _entropy(counts):
        tot = counts.sum()
        if tot <= 0:
            return 0.0
        p = counts / tot
        p = p[p > 0]
        return float(-(p * np.log2(p)).sum())

    def best_splits_classification(self):
        base = self._entropy(self.cls)
        best_gain = np.zeros(self.n_feat)
        best_thresh = np.zeros(self.n_feat)
        ccls = np.cumsum(self.bin_cls, axis=1)  # [F, bins, 2]
        for f in range(self.n_feat):
            for b in range(self.n_bins - 1):
                left = ccls[f, b]
                right = self.cls - left
                nl, nr = left.sum(), right.sum()
                if nl < 2 or nr < 2:
                    continue
                gain = base - (nl * self._entropy(left)
                               + nr * self._entropy(right)) / self.n
                if gain > best_gain[f]:
                    best_gain[f] = gain
                    span = self.bins_hi[f] - self.bins_lo[f]
                    best_thresh[f] = self.bins_lo[f] + span * (b + 1) / self.n_bins
        return best_gain, best_thresh


class _Node:
    __slots__ = ("stats", "feature", "threshold", "left", "right", "depth")

    def __init__(self, n_feat, depth):
        self.stats = _LeafStats(n_feat)
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.depth = depth

    @property
    def is_leaf(self):
        return self.feature < 0


class _HoeffdingTreeBase:
    def __init__(self, n_features: int, *, delta: float = 1e-4,
                 grace_period: int = 40, max_depth: int = 7,
                 tie_threshold: float = 0.05, classification: bool = False):
        self.n_features = n_features
        self.delta = delta
        self.grace = grace_period
        self.max_depth = max_depth
        self.tau = tie_threshold
        self.classification = classification
        self.root = _Node(n_features, 0)
        self.n_seen = 0
        self._y_min = np.inf
        self._y_max = -np.inf

    def _sort(self, x) -> _Node:
        node = self.root
        while not node.is_leaf:
            node = node.left if x[node.feature] <= node.threshold else node.right
        return node

    def learn_one(self, x, y):
        x = np.asarray(x, dtype=np.float64)
        self.n_seen += 1
        self._y_min = min(self._y_min, float(y))
        self._y_max = max(self._y_max, float(y))
        node = self._sort(x)
        node.stats.add(x, float(y),
                       int(y > 0.5) if self.classification else None)
        if (node.stats.n % self.grace == 0 and node.depth < self.max_depth):
            self._try_split(node)
        return self

    def _try_split(self, node: _Node):
        st = node.stats
        if self.classification:
            gains, thresholds = st.best_splits_classification()
            value_range = 1.0  # entropy gain range for binary
        else:
            gains, thresholds = st.best_splits_regression()
            value_range = max(self._y_max - self._y_min, 1e-9) ** 2
        order = np.argsort(gains)[::-1]
        g1, g2 = gains[order[0]], gains[order[1]] if len(order) > 1 else 0.0
        eps = math.sqrt(value_range ** 2 * math.log(1.0 / self.delta)
                        / (2.0 * st.n))
        if g1 > 0 and (g1 - g2 > eps or eps < self.tau * value_range):
            f = int(order[0])
            node.feature = f
            node.threshold = float(thresholds[f])
            node.left = _Node(self.n_features, node.depth + 1)
            node.right = _Node(self.n_features, node.depth + 1)
            node.stats = None  # freed; children start fresh


class HoeffdingTreeRegressor(_HoeffdingTreeBase):
    def __init__(self, n_features: int, **kw):
        super().__init__(n_features, classification=False, **kw)
        self._global_s = 0.0

    def learn_one(self, x, y):
        self._global_s += float(y)
        return super().learn_one(x, y)

    def predict_one(self, x) -> float:
        if self.n_seen == 0:
            return 0.0
        node = self._sort(np.asarray(x, dtype=np.float64))
        # walk up conceptually: empty fresh leaves fall back to global mean
        if node.stats is not None and node.stats.n > 0:
            return node.stats.s / node.stats.n
        return self._global_s / self.n_seen


class HoeffdingTreeClassifier(_HoeffdingTreeBase):
    """Binary classifier; predict_one returns P(class=1)."""

    def __init__(self, n_features: int, **kw):
        super().__init__(n_features, classification=True, **kw)
        self._global_cls = np.zeros(2)

    def learn_one(self, x, y):
        self._global_cls[int(y > 0.5)] += 1
        return super().learn_one(x, y)

    def predict_one(self, x) -> float:
        if self.n_seen == 0:
            return 0.5
        node = self._sort(np.asarray(x, dtype=np.float64))
        if node.stats is not None and node.stats.n > 0:
            c = node.stats.cls
            return float((c[1] + 1.0) / (c.sum() + 2.0))  # Laplace
        g = self._global_cls
        return float((g[1] + 1.0) / (g.sum() + 2.0))
