"""Strategic-agent adversary layer (economic stress model).

IEMAS proves per-round DSIC for truthful, independent agents; a production
routing market faces strategic populations.  This module supplies them:
an ``AdversaryPolicy`` mutates only what an agent *reports* — its
published ``AgentInfo`` profile (Phase 0), its Phase-4 ``CompletionObs``
feedback — or its membership behavior (churn).  Ground-truth execution is
never touched: the cluster's ``RequestRecord`` keeps measured latency,
cost-at-true-prices and audited quality, so benchmarks can price exactly
what each lie bought (`benchmarks/adversarial.py`).

The audit channel: whenever any adversary is active, every report carries
``CompletionObs.audit_quality`` — the ground-truth evaluator score.  The
router settles value at the audited quality and feeds the inflation
residual ``max(0, reported - audited)`` into the agent's reputation
(`repro.core.predictor`), which scales the Hoeffding w-blend so habitual
inflators see their predicted QoS (hence Eq.-1 value) decay instead of
poisoning the estimate.  An honest agent's residual is identically zero
and its reputation stays at exactly 1.0, which the blend multiplies
through bit-neutrally — adversary-free runs are bit-identical with or
without the audit channel.

Policies:

* ``CostMisreportPolicy``   — publishes deflated token prices, so the
  router's cost prior (and the costs it books) understate the truth and
  the cheater wins matches its real cost cannot justify.
* ``CollusionRingPolicy``   — a domain-clustered cartel publishing jointly
  inflated prices: each member's Clarke pivot is propped up by its
  ring-mates' inflated "next-best" costs.
* ``FreeRiderPolicy``       — inflates reported quality in Phase-4
  feedback while the audit channel carries the truth; reputation is the
  countermeasure under test.
* ``ChurnStormPolicy``      — membership/capacity/quarantine flapping that
  thrashes hub cuts and the ``SlotPriceBook`` (every flip must cold-start
  the warm-start cache; tests/test_churn_storm.py).

``AdversaryMix`` deterministically (seeded) assigns a policy to a fraction
of the fleet; ``fraction=0`` assigns nobody and leaves the run
bit-identical to an honest one.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.core.mechanism import AgentInfo, CompletionObs
from repro.core.pricing import TokenPrices

#: policy names ``AdversaryMix`` accepts
POLICIES = ("misreport", "collusion", "freerider", "churn")


def _scaled_prices(prices: TokenPrices, factor: float) -> TokenPrices:
    """Uniformly rescaled token prices (a proportional price misreport)."""
    return TokenPrices(prices.miss * factor, prices.hit * factor,
                       prices.out * factor)


class AdversaryPolicy:
    """Base strategic policy: truthful, but wired into the audit channel.

    Subclasses override any of the three hooks; every hook mutates
    *reported* state only, never ground truth.
    """

    name = "honest"

    def publish(self, info: AgentInfo) -> AgentInfo:
        """The profile this agent reports to the router (true by default;
        overrides must return a copy, leaving the runtime's info intact)."""
        return info

    def report(self, obs: CompletionObs, true_quality: float) -> CompletionObs:
        """The Phase-4 feedback this agent reports.  The base policy reports
        truthfully but attaches the audited ground truth, so the settlement
        residual is exactly zero and reputation stays at exactly 1.0."""
        return dataclasses.replace(obs, audit_quality=float(true_quality))

    def tick(self, cluster, router, agent_id: str) -> None:
        """Per-round action hook (membership/capacity churn); no-op here."""


class CostMisreportPolicy(AdversaryPolicy):
    """Publishes token prices deflated by ``theta`` (reported capability
    misreport): the router's Eq.-6 cost prior and booked settlement costs
    understate the agent's true cost, buying matches honest pricing would
    lose.  The cluster keeps charging true prices in its ground-truth
    records, so the welfare gap is measurable."""

    name = "misreport"

    def __init__(self, theta: float = 0.4):
        self.theta = float(theta)

    def publish(self, info: AgentInfo) -> AgentInfo:
        """Deflate every published token price by ``1 - theta``."""
        return dataclasses.replace(
            info, prices=_scaled_prices(info.prices, 1.0 - self.theta))


class CollusionRingPolicy(AdversaryPolicy):
    """Domain-clustered cartel jointly inflating published prices by
    ``1 + theta``.  One shared instance serves every ring member: a
    member's Clarke pivot is computed against its ring-mates' inflated
    next-best costs, so the cartel extracts payments above the competitive
    level inside its domain hub."""

    name = "collusion"

    def __init__(self, theta: float = 0.4, members: tuple[str, ...] = ()):
        self.theta = float(theta)
        self.members = tuple(members)

    def publish(self, info: AgentInfo) -> AgentInfo:
        """Inflate every published token price by ``1 + theta``."""
        return dataclasses.replace(
            info, prices=_scaled_prices(info.prices, 1.0 + self.theta))


class FreeRiderPolicy(AdversaryPolicy):
    """Inflates reported quality by ``theta`` (clipped to 1.0) while the
    audit channel carries the evaluator's truth.  The inflation residual
    decays the agent's reputation, which scales its predicted quality —
    the reputation-weighted prior is the countermeasure under test."""

    name = "freerider"

    def __init__(self, theta: float = 0.4):
        self.theta = float(theta)

    def report(self, obs: CompletionObs, true_quality: float) -> CompletionObs:
        """Report ``min(1, quality + theta)``; audit carries the truth."""
        return dataclasses.replace(
            obs, quality=min(1.0, float(true_quality) + self.theta),
            audit_quality=float(true_quality))


class ChurnStormPolicy(AdversaryPolicy):
    """Membership flapping: every ``period`` ticks the agent takes one
    seeded action — flip its published capacity, leave and immediately
    rejoin (losing engine caches, recutting hubs), or self-quarantine for
    one cycle.  Each flip invalidates the ``SlotPriceBook`` warm-start key
    (capacity, membership, or agent-set version), so a storm of them
    stress-tests cold-start correctness and exactly-once settlement."""

    name = "churn"

    def __init__(self, theta: float = 0.4, period: int = 4, seed: int = 0):
        self.theta = float(theta)
        self.period = max(1, int(period))
        self.rng = np.random.default_rng(seed)
        self._ticks = 0
        self._quarantined = False

    def tick(self, cluster, router, agent_id: str) -> None:
        """One churn action every ``period`` ticks (see class docstring)."""
        self._ticks += 1
        if self._ticks % self.period:
            return
        if self._quarantined:
            router.reinstate(agent_id)
            self._quarantined = False
            return
        rt = cluster.agents.get(agent_id)
        if rt is None:
            return
        action = int(self.rng.integers(0, 3))
        if action == 0:
            # capacity flap on the profile the router prices with — the
            # price book's capacity staleness key must cold-start on it
            info = next((a for a in router.agents
                         if a.agent_id == agent_id), None)
            if info is not None:
                info.capacity = max(
                    1, info.capacity + int(self.rng.choice((-1, 1))))
        elif action == 1 and \
                cluster.telemetry.agent_inflight.get(agent_id, 0) == 0:
            # leave + rejoin: only when idle, so no completion is orphaned
            # against a runtime that no longer exists (the router-side
            # orphan guard covers the racing case regardless)
            profile = rt.profile
            cluster.remove_agent(agent_id, router)
            cluster.add_agent(profile, router)
        else:
            router.quarantine(agent_id)
            self._quarantined = True


@dataclass
class AdversaryMix:
    """Seeded assignment of one strategic policy to a fleet fraction.

    ``assign`` is deterministic in ``seed``; ``fraction=0`` returns an
    empty mapping, leaving the run bit-identical to an honest one (the
    gate `benchmarks/adversarial.py --smoke` enforces).  ``collusion``
    picks its ring from the largest shared-domain cluster so the cartel
    actually shares a hub; the other policies sample uniformly.
    """

    policy: str = "misreport"
    fraction: float = 0.25
    theta: float = 0.4
    seed: int = 0
    churn_period: int = 4

    def n_adversaries(self, n_agents: int) -> int:
        """Number of strategic agents at this fraction of ``n_agents``."""
        return int(round(self.fraction * n_agents))

    def assign(self, infos: list[AgentInfo]) -> dict[str, AdversaryPolicy]:
        """Deterministically map chosen agent ids to policy instances."""
        if self.policy not in POLICIES:
            raise ValueError(f"unknown adversary policy {self.policy!r}; "
                             f"known: {POLICIES}")
        k = self.n_adversaries(len(infos))
        if k <= 0:
            return {}
        if self.policy == "collusion":
            ring = self._domain_ring(infos, k)
            shared = CollusionRingPolicy(theta=self.theta, members=ring)
            return {aid: shared for aid in ring}
        rng = np.random.default_rng(self.seed)
        ids = [a.agent_id for a in infos]
        chosen = rng.choice(len(ids), size=k, replace=False)
        out: dict[str, AdversaryPolicy] = {}
        for j in sorted(int(c) for c in chosen):
            aid = ids[j]
            if self.policy == "misreport":
                out[aid] = CostMisreportPolicy(theta=self.theta)
            elif self.policy == "freerider":
                out[aid] = FreeRiderPolicy(theta=self.theta)
            else:
                out[aid] = ChurnStormPolicy(theta=self.theta,
                                            period=self.churn_period,
                                            seed=self.seed + j)
        return out

    def _domain_ring(self, infos: list[AgentInfo], k: int) -> tuple[str, ...]:
        """The ``k`` ring members, filled from the largest domain cluster
        outward (deterministic tie-break on domain name)."""
        by_dom: dict[str, list[str]] = {}
        for a in infos:
            for d in a.domains:
                by_dom.setdefault(d, []).append(a.agent_id)
        ring: list[str] = []
        for d in sorted(by_dom, key=lambda d: (-len(by_dom[d]), d)):
            for aid in by_dom[d]:
                if aid not in ring:
                    ring.append(aid)
                if len(ring) == k:
                    return tuple(ring)
        return tuple(ring)
