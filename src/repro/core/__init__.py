"""IEMAS core: the paper's primary contribution.

Cache-aware prediction (PrefixLedger + Hoeffding QoS), VCG/MCMF matching
(run_auction), proxy hubs, and the Algorithm-1 router (IEMASRouter).
"""
from repro.core.adversary import (AdversaryMix, AdversaryPolicy,
                                  ChurnStormPolicy, CollusionRingPolicy,
                                  CostMisreportPolicy, FreeRiderPolicy)
from repro.core.affinity import PrefixLedger, lcp_length
from repro.core.ledger import SettlementEntry, SettlementLedger
from repro.core.auction import (AuctionResult, run_auction,
                                run_sharded_auction, solve_allocation)
from repro.core.solvers import (DenseAuctionResult, SolverBackend,
                                available_solvers, dense_clarke_payments,
                                get_solver, register_solver,
                                solve_dense_auction, solve_dense_auction_jax)
from repro.core.baselines import BASELINES
from repro.core.hoeffding import (CompiledTree, HoeffdingTreeClassifier,
                                  HoeffdingTreeRegressor, descend,
                                  stack_compiled)
from repro.core.hub import Hub, cluster_agents, route_to_hub
from repro.core.mechanism import (AgentInfo, CompletionObs, IEMASRouter,
                                  Request, RouteDecision)
from repro.core.predictor import (AgentPredictor, PredictorInput,
                                  PredictorPool, QoSEstimate, feature_tensor)
from repro.core.pricing import TokenPrices, observed_cost, predicted_cost
from repro.core.valuation import ValuationConfig, client_value, welfare_weights
