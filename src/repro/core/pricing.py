"""Token-level cost accounting (Eq. 6) and cost prediction helpers.

    C_ij_obs = pi_miss * (n_prompt - n_hit) + pi_hit * n_hit + pi_out * n_gen

The serving engine reports exact (n_prompt, n_hit, n_gen) per request
(ground truth for the cost predictor); the router predicts n_hit from the
ledger affinity and n_gen from history.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TokenPrices:
    """Per-token prices: uncached prompt (miss), cached (hit), generated."""

    miss: float
    hit: float
    out: float


def observed_cost(prices: TokenPrices, n_prompt: int, n_hit: int,
                  n_gen: int) -> float:
    """Exact Eq.-6 cost from the engine-reported token counts."""
    n_hit = min(n_hit, n_prompt)
    return (prices.miss * (n_prompt - n_hit)
            + prices.hit * n_hit
            + prices.out * n_gen)


def predicted_cost(prices: TokenPrices, n_prompt: int, affinity: float,
                   expected_gen: float) -> float:
    """Structural cost prior from the affinity score (used to seed the
    Hoeffding cost predictor and as its cold-start fallback)."""
    n_hit = affinity * n_prompt
    return (prices.miss * (n_prompt - n_hit)
            + prices.hit * n_hit
            + prices.out * expected_gen)
