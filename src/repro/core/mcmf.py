"""Min-cost max-flow via successive shortest paths (Johnson potentials).

Dependency-free (the paper's Appendix C.2.4 ships the same design: Bellman-
Ford potentials to absorb negative edge costs + Dijkstra augmentations).

Used by the auction layer as a *welfare maximizer*: with matching edges of
cost -w_ij (w_ij > 0 only), augmentation stops when the shortest residual
path has non-negative cost, which yields the min-cost flow over ALL flow
values = the max-weight b-matching (Theorem 4.1 / Hoffman-Kruskal).

Also provides the warm-start counterfactual solver used for VCG payments
(§4.3 "computational consistency"): W(C \\ {j}) from ONE Dijkstra on the
residual graph instead of a full re-solve.
"""
from __future__ import annotations

import heapq
import math


class FlowNetwork:
    """Residual flow network in paired-edge (forward, reverse) layout."""

    def __init__(self, n: int):
        self.n = n
        self.to: list[int] = []
        self.cap: list[float] = []
        self.cost: list[float] = []
        self.adj: list[list[int]] = [[] for _ in range(n)]

    def add_edge(self, u: int, v: int, cap: float, cost: float) -> int:
        """Add a u->v arc (and its zero-cap reverse); returns the edge id."""
        eid = len(self.to)
        self.to.append(v); self.cap.append(cap); self.cost.append(cost)
        self.adj[u].append(eid)
        self.to.append(u); self.cap.append(0.0); self.cost.append(-cost)
        self.adj[v].append(eid + 1)
        return eid

    def clone(self) -> "FlowNetwork":
        """Deep copy (for counterfactual re-solves on the residual graph)."""
        g = FlowNetwork(self.n)
        g.to = list(self.to); g.cap = list(self.cap); g.cost = list(self.cost)
        g.adj = [list(a) for a in self.adj]
        return g


def _bellman_ford_dag_potentials(g: FlowNetwork, s: int) -> list[float]:
    """Initial potentials: Bellman-Ford (queue-based SPFA, terminates for any
    graph without negative cycles; our auction graphs are DAGs)."""
    inf = math.inf
    dist = [inf] * g.n
    dist[s] = 0.0
    inq = [False] * g.n
    from collections import deque
    q = deque([s]); inq[s] = True
    while q:
        u = q.popleft(); inq[u] = False
        for eid in g.adj[u]:
            if g.cap[eid] <= 1e-12:
                continue
            v = g.to[eid]
            nd = dist[u] + g.cost[eid]
            if nd < dist[v] - 1e-12:
                dist[v] = nd
                if not inq[v]:
                    q.append(v); inq[v] = True
    return dist


def _dijkstra(g: FlowNetwork, s: int, t: int, pot: list[float]):
    """Shortest path with reduced costs. Returns (dist, parent_edge)."""
    inf = math.inf
    dist = [inf] * g.n
    parent = [-1] * g.n
    dist[s] = 0.0
    pq = [(0.0, s)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u] + 1e-12:
            continue
        for eid in g.adj[u]:
            if g.cap[eid] <= 1e-12:
                continue
            v = g.to[eid]
            if pot[u] == inf:
                continue
            w = g.cost[eid] + pot[u] - (pot[v] if pot[v] != inf else 0.0)
            if w < -1e-7:
                w = 0.0  # clamp tiny negatives from float noise
            nd = d + w
            if nd < dist[v] - 1e-12:
                dist[v] = nd
                parent[v] = eid
                heapq.heappush(pq, (nd, v))
    return dist, parent


def solve_min_cost_flow(g: FlowNetwork, s: int, t: int,
                        stop_when_positive: bool = True):
    """Successive shortest paths. Mutates g (flow stored in caps).

    Returns (flow, cost, potentials). With ``stop_when_positive`` the result
    is the global min-cost flow over all flow values (= welfare maximum for
    negated-welfare edge costs).
    """
    inf = math.inf
    pot = _bellman_ford_dag_potentials(g, s)
    flow, cost = 0.0, 0.0
    while True:
        dist, parent = _dijkstra(g, s, t, pot)
        if dist[t] == inf:
            break
        # true path cost = reduced dist + pot[t] - pot[s]
        true_cost = dist[t] + (pot[t] if pot[t] != inf else 0.0) - pot[s]
        if stop_when_positive and true_cost >= -1e-12:
            break
        # update potentials
        for v in range(g.n):
            if dist[v] != inf and pot[v] != inf:
                pot[v] += dist[v]
        # bottleneck
        push = inf
        v = t
        while v != s:
            eid = parent[v]
            push = min(push, g.cap[eid])
            v = g.to[eid ^ 1]
        v = t
        while v != s:
            eid = parent[v]
            g.cap[eid] -= push
            g.cap[eid ^ 1] += push
            cost += push * g.cost[eid]
            v = g.to[eid ^ 1]
        flow += push
    return flow, cost, pot


def residual_shortest_path(g: FlowNetwork, s: int, t: int,
                           blocked: set[int] | None = None,
                           blocked_edges: set[int] | None = None):
    """(cost, parent_edges) of the cheapest residual s->t path, skipping
    ``blocked`` nodes and ``blocked_edges`` (edge ids, both directions).
    Bellman-Ford based; callers must ensure the explored subgraph has no
    negative cycles (see auction.run_auction warmstart). +inf if unreachable."""
    inf = math.inf
    dist = [inf] * g.n
    parent = [-1] * g.n
    dist[s] = 0.0
    from collections import deque
    q = deque([s])
    inq = [False] * g.n
    inq[s] = True
    blocked = blocked or set()
    blocked_edges = blocked_edges or set()
    while q:
        u = q.popleft(); inq[u] = False
        for eid in g.adj[u]:
            if g.cap[eid] <= 1e-12 or eid in blocked_edges:
                continue
            v = g.to[eid]
            if v in blocked:
                continue
            nd = dist[u] + g.cost[eid]
            if nd < dist[v] - 1e-9:
                dist[v] = nd
                parent[v] = eid
                if not inq[v]:
                    q.append(v); inq[v] = True
    return dist[t], parent


def augment_unit(g: FlowNetwork, s: int, t: int, parent) -> None:
    """Push one unit of flow along a parent-edge path t<-...<-s."""
    v = t
    while v != s:
        eid = parent[v]
        g.cap[eid] -= 1.0
        g.cap[eid ^ 1] += 1.0
        v = g.to[eid ^ 1]


def brute_force_matching(w: "list[list[float]]", caps: "list[int]"):
    """Exact max-weight b-matching by exhaustive search (test oracle).

    w[j][i] = welfare of assigning request j to agent i (<=0 means no edge).
    Returns (best_welfare, assignment list with -1 for unmatched).
    """
    n = len(w)
    m = len(caps) if caps else 0
    best = [0.0, [-1] * n]

    def rec(j, used, cur, assign):
        if j == n:
            if cur > best[0] + 1e-12:
                best[0] = cur
                best[1] = list(assign)
            return
        # option: leave j unmatched
        rec(j + 1, used, cur, assign + [-1])
        for i in range(m):
            if used[i] < caps[i] and w[j][i] > 0:
                used[i] += 1
                rec(j + 1, used, cur + w[j][i], assign + [i])
                used[i] -= 1

    rec(0, [0] * m, 0.0, [])
    return best[0], best[1]
