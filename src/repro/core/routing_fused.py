"""Fused device-resident routing step: Phase 1 descend -> column-market bids.

The staged router (`core/mechanism.py`) runs the per-batch hot path as a
chain of separately-jitted programs stitched together with NumPy host
round-trips: `_phase1` builds the Eq.-5 feature tensor on host, the affinity
kernel materializes padded ledger tiles per batch, and `dense_jax` bounces
prices through ``np.asarray`` between ε-stages.  This module fuses the whole
step into ONE jitted program that stays device-resident from the ledger
gather to the final auction state:

    (a) Eq.-4 cache affinity — ledger rows gathered from a persistent device
        mirror of the `PaddedLedgerStore` arena (dirty-row scatter updates,
        no per-batch upload), LCP via the cumulative-product-of-equality
        trick, LRU keep-masking and `parent_credit` folded in as a
        scatter-max over parent-candidate rows;
    (b) the Eq.-5 feature tensor assembled from device telemetry vectors;
    (c) Phase-1 QoS prediction — the stacked Hoeffding forests (device
        mirrors refreshed only when tree versions move) descended by the
        same fori_loop walker as `hoeffding._jax_descend`, blended with the
        structural cold-start prior exactly like
        `predictor._blend_with_prior`, then Eq.-1 client values;
    (d) the capacitated-column ε-scaling auction — `dense_jax`'s staged
        ``solve`` composed INSIDE the program (warm attempt under the warm
        round budget with an in-program `lax.cond` cold fallback), with the
        ε schedule (`jax_eps_final` / `warm_eps0`) computed as traced
        scalars instead of host floats.

No host sync happens until the program returns: the single ``np.asarray``
materialization block at the end feeds the same `materialize_staged` /
`package_dense` host packaging (float64 Clarke payments) the staged path
uses, so `IEMASRouter.route_batch` splices fused results identically.

Shape discipline (retrace bound): batch, fleet, token width, parent
candidates, node pools, loop depth and unit count are all padded to pow-2
buckets (`core/buckets.pow2_bucket`), so a serving run traces O(log) fused
programs, not one per batch shape — mirrored by the regression test in
tests/test_routing_fused.py.  The warm-start price grid is the only donated
buffer (it is consumed by the solve and rebuilt from the price book each
round); the ledger arena and forest mirrors persist across calls and are
never donated.  Donation is skipped on CPU where XLA cannot honor it.

Precision contract: the program runs in float32 (default JAX config), while
the staged oracle's Phase 1 is float64 NumPy — assignments agree except
when two assignments' TOTAL welfare lands within the auction's own
ε-optimality gap (measured ~1e-6 relative when it happens), where the
differing float32 welfare bits can legally terminate the ε-scaling on the
other equally-good assignment; payments/estimates agree to ~1e-6 relative
whenever the assignment matches.  A feature landing
within float32 rounding of a trained tree threshold can flip a leaf (same
caveat as `hoeffding._jax_descend`).  The staged path remains the oracle;
parity is property-tested in tests/test_routing_fused.py.
"""
from __future__ import annotations

import numpy as np

from repro.core.affinity import PAD_PROMPT
from repro.core.buckets import pow2_bucket
from repro.core.predictor import N_FEATURES
from repro.core.solvers.dense_common import (THETA, check_start_prices,
                                             column_counts, empty_result,
                                             materialize_staged,
                                             package_dense, warm_round_budget)
from repro.core.solvers.dense_np import _price_grid

#: solver backends whose bidding loop can compose inside the fused program
#: (both ride `dense_jax._build_jax_solver`; pallas swaps the bid round).
FUSED_SOLVERS = ("dense-jax", "pallas")

_EPS32 = float(np.finfo(np.float32).eps)

_SCATTER = None


def _donate_ok() -> bool:
    """Whether buffer donation is honored on this backend (not on CPU)."""
    import jax
    return jax.default_backend() != "cpu"


def _scatter_fn():
    """Jitted dirty-row scatter into the device ledger mirror (donated)."""
    global _SCATTER
    if _SCATTER is None:
        import jax

        def scat(tokens, lens, rows, vals, lvals):
            return tokens.at[rows].set(vals), lens.at[rows].set(lvals)

        _SCATTER = jax.jit(scat,
                           donate_argnums=(0, 1) if _donate_ok() else ())
    return _SCATTER


class _LedgerMirror:
    """Device-resident copy of the `PaddedLedgerStore` token arena.

    ``sync`` drains the store's dirty-row set and scatters just those rows
    into the persistent device arrays (pow-2 bucketed row count per scatter,
    so the scatter program itself stays retrace-bounded); a ``shape_version``
    bump (arena regrow) triggers a full re-upload instead.  Rows beyond the
    dirty count pad with row 0 — the store's reserved all-pad sentinel —
    whose rewrite is a no-op by construction.
    """

    def __init__(self, store):
        self.store = store
        self.tokens = None
        self.lens = None
        self._shape_version = -1

    def sync(self):
        """Bring the device arena up to date with the host store."""
        import jax.numpy as jnp

        st = self.store
        if self.tokens is None or self._shape_version != st.shape_version:
            st.consume_dirty()          # the full upload covers everything
            self.tokens = jnp.asarray(st.tokens)
            self.lens = jnp.asarray(st.lens)
            self._shape_version = st.shape_version
            return
        rows = st.consume_dirty()
        if rows.size == 0:
            return
        rb = pow2_bucket(rows.size)
        rpad = np.zeros(rb, np.int32)   # pad with the row-0 sentinel
        rpad[: rows.size] = rows
        self.tokens, self.lens = _scatter_fn()(
            self.tokens, self.lens, rpad, st.tokens[rpad], st.lens[rpad])


class _ForestMirror:
    """Device copy of one target's stacked Hoeffding forest.

    Piggybacks on `PredictorPool._stacked_forest` (host incremental restack)
    and re-uploads at two speeds, mirroring the host cache: a structure
    change (split / membership, detected by node count or agent-id key)
    re-uploads all five node arrays padded to the pow-2 node bucket; mere
    leaf-value drift (tree version counters moved, node count unchanged)
    re-uploads only the value array.
    """

    def __init__(self):
        self._key = None
        self._versions = None
        self.arrays = None              # (feature, threshold, left, right, value, roots)
        self.depth_bucket = 4

    def sync(self, pool, name: str, agent_ids: list, mb: int):
        """Refresh the device forest; returns (arrays, static depth bucket)."""
        import jax.numpy as jnp

        stacked, roots = pool._stacked_forest(name, agent_ids)
        versions = tuple(getattr(pool._preds[a], name)._version
                         for a in agent_ids)
        n_nodes = len(stacked.feature)
        kb = pow2_bucket(n_nodes)
        key = (tuple(agent_ids), n_nodes, mb)
        if key != self._key:
            feat = np.full(kb, -1, np.int32)        # padded nodes are leaves
            feat[:n_nodes] = stacked.feature
            thr = np.zeros(kb, np.float32)
            thr[:n_nodes] = stacked.threshold
            left = np.zeros(kb, np.int32)
            left[:n_nodes] = stacked.left
            right = np.zeros(kb, np.int32)
            right[:n_nodes] = stacked.right
            val = np.zeros(kb, np.float32)
            val[:n_nodes] = stacked.value
            rootpad = np.zeros(mb, np.int32)        # padded agents: tree 0
            rootpad[: len(roots)] = roots
            self.arrays = tuple(jnp.asarray(a) for a in
                                (feat, thr, left, right, val, rootpad))
            self._key = key
            self._versions = versions
        elif versions != self._versions:
            val = np.zeros(kb, np.float32)
            val[:n_nodes] = stacked.value
            self.arrays = self.arrays[:4] + (jnp.asarray(val),
                                             self.arrays[5])
            self._versions = versions
        self.depth_bucket = pow2_bucket(stacked.depth + 1, floor=4)
        return self.arrays, self.depth_bucket


def _build_program(warm: bool, has_parents: bool, budget: int,
                   max_rounds: int, bid_round):
    """Trace-time factory for one fused program variant.

    ``warm``/``has_parents`` select program structure (warm solve + cold
    fallback vs cold only; parent-credit scatter present or compiled out);
    ``budget`` is the warm attempt's static round cap.  Everything else is
    shape-polymorphic under jit.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.core.solvers.dense_jax import _build_jax_solver

    solve_cold = _build_jax_solver(max_rounds, bid_round)
    solve_warm = _build_jax_solver(budget, bid_round) if warm else None

    def program(arena, alen, lrows, pmat, plen, keep, crows, cj, ckeep,
                turns, dom, req_mask, router_scalars, a_inflight, a_rps,
                caps_f, ext, agent_mask, blend, f_lat, f_cst, f_q,
                val_cfg, counts, p0, *, dl, dc, dq):
        fdt = p0.dtype
        nb, mb = dom.shape

        # ---- (a) Eq.-4 affinity: arena gather + cumprod-of-equality LCP
        def lcp_scores(rows_ix, prompts, plens):
            led = arena[rows_ix]                       # (B, mb, L)
            llen = alen[rows_ix]
            eq = (prompts[:, None, :] == led).astype(jnp.int32)
            raw = jnp.cumprod(eq, axis=-1).sum(-1)
            lcp = jnp.minimum(raw, jnp.minimum(plens[:, None], llen))
            pl1 = jnp.maximum(plens[:, None], 1).astype(fdt)
            sc = lcp.astype(fdt) / pl1
            # recurrent agents: exact-extension-only cache reuse
            full_prev = (lcp == llen) & (llen > 0)
            return jnp.where(ext[None, :],
                             jnp.where(full_prev, llen.astype(fdt) / pl1,
                                       0.0), sc)

        o = jnp.where(keep, lcp_scores(lrows, pmat, plen), 0.0)
        if has_parents:
            # precedence credit: candidate (row, parent-session) pairs were
            # flattened on host; fold their best affinity into o by a
            # scatter-max (cj == nb marks padding, dropped by mode="drop")
            cjc = jnp.clip(cj, 0, nb - 1)
            cred = jnp.where(ckeep,
                             lcp_scores(crows, pmat[cjc], plen[cjc]), 0.0)
            o = o.at[cj].max(cred, mode="drop")

        # ---- (b) Eq.-5 feature tensor, assembled on device
        util = a_inflight / jnp.maximum(1.0, caps_f)

        def bc(v):
            return jnp.broadcast_to(v, (nb, mb))

        X = jnp.stack([
            bc(plen.astype(fdt)[:, None]), bc(turns[:, None]), o,
            bc(router_scalars[0]), bc(router_scalars[1]),
            bc(a_inflight[None, :]), bc(a_rps[None, :]),
            bc(caps_f[None, :]), bc(util[None, :]), dom,
        ], axis=-1)

        # ---- (c) Phase-1 descend over the stacked forests + prior blend
        flat = X.reshape(nb * mb, N_FEATURES)
        rows = jnp.arange(nb * mb)
        col = jnp.arange(nb * mb, dtype=jnp.int32) % mb

        def desc(forest, depth):
            feature, threshold, left, right, value, roots = forest

            def body(_, cur):
                f = feature[cur]
                internal = f >= 0
                go_left = flat[rows, jnp.where(internal, f, 0)] \
                    <= threshold[cur]
                nxt = jnp.where(go_left, left[cur], right[cur])
                return jnp.where(internal, nxt, cur)

            return value[lax.fori_loop(0, depth, body,
                                       roots[col])].reshape(nb, mb)

        raw_lat = desc(f_lat, dl)
        raw_cst = desc(f_cst, dc)
        raw_q = desc(f_q, dq)

        # transcription of predictor._blend_with_prior (same op order)
        (lpt, lb_, miss, hit, out_, ewma, n_obs, warm_n, prior_q, rep,
         expl) = blend
        pl_, aff, util2 = X[..., 0], X[..., 2], X[..., 8]
        uncached = pl_ * (1.0 - aff)
        prior_lat = (lb_ + lpt * uncached) * (1.0 + util2)
        npmt = jnp.trunc(pl_)
        nhit = aff * npmt
        prior_cst = miss * (npmt - nhit) + hit * nhit + out_ * ewma
        wgt = jnp.minimum(1.0, n_obs / 60.0) * rep
        lat = (1.0 - wgt) * prior_lat + wgt * jnp.maximum(0.0, raw_lat)
        cst = (1.0 - wgt) * prior_cst + wgt * jnp.maximum(0.0, raw_cst)
        cold = n_obs < warm_n
        lat = jnp.where(cold, prior_lat, lat)
        cst = jnp.where(cold, prior_cst, cst)
        qual = jnp.where(cold, prior_q * rep,
                         jnp.clip(raw_q, 0.0, 1.0) * rep)
        # optimism bonus (predictor._optimism): applied only where the
        # per-agent explore knob is nonzero, so the default-0 fleet keeps
        # the exact pre-bonus values (no min-clamp is ever taken)
        qual = jnp.where(expl != 0.0,
                         jnp.minimum(1.0, qual
                                     + expl / jnp.sqrt(1.0 + n_obs)),
                         qual)

        # ---- Eq.-1 client value -> pruned welfare (valuation.client_value)
        delta, lscale, vscale = val_cfg[0], val_cfg[1], val_cfg[2]
        values = vscale * (delta * jnp.clip(qual, 0.0, 1.0)
                           - (1.0 - delta) * lat / lscale)
        W = values - cst
        W = jnp.where(W > 0.0, W, 0.0)
        W = jnp.where(req_mask[:, None] & agent_mask[None, :], W, 0.0)

        # ---- ε schedule as traced scalars (dense_common.jax_eps_final /
        #      warm_eps0; the staged path computes these on host floats)
        wmax = jnp.max(jnp.where(counts[None, :] > 0, W, 0.0))
        anchor = jnp.maximum(wmax, 1.0)
        eps_final = jnp.maximum(1e-5 * anchor, 64.0 * _EPS32 * anchor)
        theta = jnp.asarray(THETA, fdt)
        cold_eps0 = jnp.maximum(wmax / theta, eps_final)

        # ---- (d) capacitated-column ε-scaling auction, in-program
        if warm:
            # fine schedule iff the seed carries price mass above it
            # (warm_eps0); fine <= cold_eps0 by construction, so the host
            # path's min() is already folded in
            fine = jnp.maximum(wmax / theta ** 3, eps_final)
            eps0 = jnp.where(p0.max() > fine, fine, cold_eps0)
            up, ao, uo, rounds = solve_warm(W, counts, p0, eps0, eps_final,
                                            theta)
            tripped = rounds >= budget

            def cold_solve(_):
                return solve_cold(W, counts, jnp.zeros_like(p0), cold_eps0,
                                  eps_final, theta)

            def keep(_):
                return up, ao, uo, rounds

            up, ao, uo, rounds = lax.cond(tripped, cold_solve, keep,
                                          operand=None)
        else:
            up, ao, uo, rounds = solve_cold(W, counts, p0, cold_eps0,
                                            eps_final, theta)
            tripped = jnp.asarray(False)
        return (lat, cst, qual, values, X, up, ao, uo, rounds, tripped,
                eps_final, wmax)

    donate = ("p0",) if _donate_ok() else ()
    return jax.jit(program, static_argnames=("dl", "dc", "dq"),
                   donate_argnames=donate)


class FusedRoutingStep:
    """One device-resident program per route_batch call (see module doc).

    Owned by an `IEMASRouter` constructed with ``fused=True`` (which
    validates ``n_hubs == 1`` and a `FUSED_SOLVERS` backend).  ``step``
    replaces the staged ``_phase1`` + ``run_sharded_auction`` pair for the
    single global market; spill, price-book splice and Phase-3 payments
    remain on the shared host path so fused and staged results package
    identically.
    """

    def __init__(self, router, max_rounds: int = 200_000):
        self.router = router
        if router.solver == "pallas":
            from repro.core.solvers.pallas_backend import _bid_round_pallas
            self.bid_round = _bid_round_pallas
        else:
            self.bid_round = None
        self.max_rounds = max_rounds
        self.ledger_mirror = _LedgerMirror(router.ledger.store)
        self.forests = {name: _ForestMirror()
                        for name in ("lat", "cost", "quality")}
        self._programs: dict = {}
        self._cache_seen = 0

    def cache_size(self) -> int:
        """Total traced-program count across the fused program variants —
        the retrace-bound regression signal (pow-2 bucketing keeps it
        O(log) in batch/fleet/ledger growth)."""
        return sum(p._cache_size() for p in self._programs.values())

    def _program(self, warm: bool, has_parents: bool, budget: int):
        key = (warm, has_parents, budget)
        prog = self._programs.get(key)
        if prog is None:
            prog = _build_program(warm, has_parents, budget, self.max_rounds,
                                  self.bid_round)
            self._programs[key] = prog
        return prog

    def step(self, requests, live, telemetry, caps,
             start_prices=None):
        """Run the fused program for one batch.

        ``requests``/``live``/``telemetry``/``caps`` exactly as
        `IEMASRouter.route_batch` prepares them; ``start_prices`` is the
        hub-0 flat warm-start seed (or None).  Returns ``(lat, cst, qual,
        values, X, result)`` — float64 host matrices shaped like the staged
        `_phase1` outputs plus the packaged
        :class:`~repro.core.solvers.base.AuctionResult`.
        """
        r = self.router
        n, m = len(requests), len(live)
        nb, mb = pow2_bucket(n), pow2_bucket(m)
        agent_ids = [a.agent_id for a in live]
        sess = [req.meta.get("session", req.dialogue_id) for req in requests]
        ledger = r.ledger
        store = ledger.store

        # ---- host-side assembly: tiny index/param arrays only (the token
        #      payloads and every O(n*m) operation stay on device)
        self.ledger_mirror.sync()
        L = store.width
        lrows = np.zeros((nb, mb), np.int32)
        lrows[:n, :m] = store.rows_for(sess, agent_ids)
        pmat = np.full((nb, L), PAD_PROMPT, np.int32)
        plen = np.zeros(nb, np.int32)
        for j, req in enumerate(requests):
            t = np.asarray(req.tokens, np.int32)
            k = min(len(t), L)          # LCP is clamped by entry length <= L
            pmat[j, :k] = t[:k]
            plen[j] = len(t)
        slots = [a.cache_slots for a in live]
        keep = np.zeros((nb, mb), bool)
        keep[:n, :m] = ledger.keep_mask(sess, agent_ids, slots)

        parents = [req.meta.get("parent_sessions", ()) for req in requests]
        cand = [(j, s) for j, ps in enumerate(parents) for s in ps]
        has_parents = bool(cand)
        cb = pow2_bucket(len(cand)) if has_parents else 8
        crows = np.zeros((cb, mb), np.int32)
        cj = np.full(cb, nb, np.int32)          # nb = scatter-drop sentinel
        ckeep = np.zeros((cb, mb), bool)
        if has_parents:
            csess = [s for _, s in cand]
            crows[: len(cand), :m] = store.rows_for(csess, agent_ids)
            cj[: len(cand)] = [j for j, _ in cand]
            ck = np.ones((len(cand), m), bool)
            for i, (aid, sl) in enumerate(zip(agent_ids, slots)):
                if sl > 0:
                    recent = ledger.recent_sessions(aid, int(sl))
                    ck[:, i] = [s in recent for s in csess]
            ckeep[: len(cand), :m] = ck

        inflight = telemetry.get("agent_inflight", {})
        agent_rps = telemetry.get("agent_rps", {})
        turns = np.zeros(nb, np.float32)
        turns[:n] = [float(req.turn) for req in requests]
        dom = np.zeros((nb, mb), np.float32)
        dom_rows: dict[str, np.ndarray] = {}
        for j, req in enumerate(requests):
            row = dom_rows.get(req.domain)
            if row is None:
                row = dom_rows[req.domain] = np.array(
                    [float(req.domain in a.domains) for a in live],
                    np.float32)
            dom[j, :m] = row
        req_mask = np.zeros(nb, bool)
        req_mask[:n] = True
        agent_mask = np.zeros(mb, bool)
        agent_mask[:m] = True
        a_inflight = np.zeros(mb, np.float32)
        a_rps = np.zeros(mb, np.float32)
        caps_f = np.zeros(mb, np.float32)
        ext = np.zeros(mb, bool)
        for i, a in enumerate(live):
            a_inflight[i] = float(inflight.get(a.agent_id, 0))
            a_rps[i] = float(agent_rps.get(a.agent_id, 0.0))
            caps_f[i] = float(a.capacity)
            ext[i] = a.recurrent
        router_scalars = np.array(
            [float(telemetry.get("router_inflight", 0)),
             float(telemetry.get("router_rps", 0.0))], np.float32)

        # per-agent blend parameters (padded agents: all-zero params with
        # warm_n=1 -> cold prior-only -> value 0, masked out regardless)
        blend = np.zeros((11, mb), np.float32)
        for i, aid in enumerate(agent_ids):
            p = r.pool[aid]
            blend[:, i] = (p.prior_lpt, p.prior_lb, p.prices.miss,
                           p.prices.hit, p.prices.out, p.ewma_gen,
                           p.n_obs, p.warm_n, p.prior_q, p.reputation,
                           p.explore)
        blend[7, m:] = 1.0

        f_lat, dl = self.forests["lat"].sync(r.pool, "lat", agent_ids, mb)
        f_cst, dc = self.forests["cost"].sync(r.pool, "cost", agent_ids, mb)
        f_q, dq = self.forests["quality"].sync(r.pool, "quality",
                                               agent_ids, mb)

        vc = r.valuation
        val_cfg = np.array([vc.delta, vc.latency_scale, vc.value_scale],
                           np.float32)

        counts_np = column_counts(caps, n)
        K = int(counts_np.sum())
        cmax = int(counts_np.max()) if m else 0
        cbu = pow2_bucket(max(cmax, 1))
        counts = np.zeros(mb, np.int32)
        counts[:m] = counts_np
        warm = start_prices is not None and K > 0
        grid = np.zeros((mb, cbu), np.float32)
        if warm:
            p0 = check_start_prices(start_prices, K)
            grid[:m, :cmax] = _price_grid(p0, counts_np, cmax)
        budget = warm_round_budget(nb, mb * cbu, self.max_rounds) \
            if warm else 0

        prog = self._program(warm, has_parents, budget)
        out = prog(self.ledger_mirror.tokens, self.ledger_mirror.lens,
                   lrows, pmat, plen, keep, crows, cj, ckeep, turns, dom,
                   req_mask, router_scalars, a_inflight, a_rps, caps_f, ext,
                   agent_mask, blend, f_lat, f_cst, f_q, val_cfg, counts,
                   grid, dl=dl, dc=dc, dq=dq)

        # ---- the batch's ONE device->host boundary: RouteDecision inputs
        #      materialize here, after the auction settled
        (lat, cst, qual, values, X, up, ao, uo, rounds, tripped, eps_f,
         wmax) = out
        lat = np.asarray(lat, np.float64)[:n, :m]
        cst = np.asarray(cst, np.float64)[:n, :m]
        qual = np.asarray(qual, np.float64)[:n, :m]
        values = np.asarray(values, np.float64)[:n, :m]
        X = np.asarray(X, np.float64)[:n, :m]
        rounds_h = int(rounds)
        prof = getattr(r, "profiler", None)
        if prof is not None and hasattr(prof, "note_fused_step"):
            c = self.cache_size()
            prof.note_fused_step(host_transfers=1, mid_syncs=0,
                                 retraces=max(0, c - self._cache_seen))
            self._cache_seen = c

        # host packaging — same helpers as the staged backends, float64
        # weights recomputed host-side for Clarke payments (auction._prune)
        w64 = values - cst
        w64 = np.where(w64 > 0.0, w64, 0.0)
        if n == 0 or K == 0 or float(wmax) <= 0.0:
            dres = empty_result(n, counts_np)
        else:
            if rounds_h >= self.max_rounds:
                raise RuntimeError(
                    f"dense auction (fused/{r.solver}) failed to converge "
                    f"in {self.max_rounds} rounds (n={n}, m={m})")
            dres = materialize_staged(
                w64, counts_np, np.asarray(up, np.float64)[:m, :cmax],
                np.asarray(ao)[:n], np.asarray(uo)[:n], rounds_h,
                float(eps_f), warm_started=warm,
                fallback=warm and bool(tripped))
        result = package_dense(r.solver, w64, cst, caps, dres)
        return lat, cst, qual, values, X, result
