"""Dense ε-scaling auction solver for the Phase-2 welfare matching (Eq. 7).

Drop-in alternative to the pure-Python successive-shortest-paths MCMF
(`repro.core.mcmf`) for the router's hot path.  Max-weight b-matching over a
dense (n_requests x n_agents) weight matrix is solved by Bertsekas' auction
algorithm with ε-scaling, fully vectorized in NumPy (one Jacobi bidding round
= a handful of array ops), plus a `jax.jit`-able variant whose bidding rounds
run inside `lax.while_loop` so the whole solve stages into one XLA program.

Formulation
-----------
Each agent i with capacity b_i is expanded into min(b_i, n) identical unit
slots; requests bid for slots.  A request may also stay unmatched (outside
option with profit 0).  Within a phase the algorithm maintains ε-CS: every
assigned request's profit is within ε of its best available option
(including the outside option), and parked (voluntarily unmatched) requests
have no option with profit > ε.

Between scaling phases, assignments AND prices are kept; only requests whose
ε-CS is violated at the tighter ε are evicted and re-bid.  Forward bidding
never lowers a price — lowering a contested price replays the bidding war in
ε-sized steps, which is exactly the pathology scaling exists to avoid.
Instead, the asymmetric-assignment condition (free slots must carry price 0,
the outside option playing Bertsekas–Castañón's λ = 0) is maintained by
REVERSE auction rounds after each forward settle: a free slot whose price is
still positive lowers it to the second-best support level β₂ − ε and grabs
the best-supporting request (exactly preserving ε-CS for everyone else), or
drops to 0 when no request supports even that.  Forward and reverse rounds
alternate until neither has work; the assignment is then certified within
2·n·ε_final of the true optimum — with the default ε_final this is far
below any payment/valuation tolerance used in the system.

Warm starts (cross-round price reuse)
-------------------------------------
The serving loop re-auctions statistically similar request sets every few
hundred milliseconds, so the previous round's final slot prices are already
near the new round's equilibrium.  ``start_prices=`` seeds the solve from
them.  Soundness: Bertsekas' auction terminates with ε-CS satisfied from
*any* non-negative initial price vector — the certificate (2·n·ε_final)
depends only on the final ε, never on where prices started.  What warm
prices buy is fewer bidding rounds: the ε-scaling schedule can skip its
coarse phases (warm solves start at ε₀ = wmax/θ³ instead of wmax/θ) and
most requests' first bid sticks.  What they can cost is extra rounds when
the guess is bad — overpriced free slots re-anchor to their support level
in one reverse step, but underpriced contested slots replay the bidding war
in ε-sized increments; the solve therefore runs the warm attempt under a
bounded round budget and transparently falls back to a cold solve when it
trips (``result.fallback``).  Warm starts are *unsound*
to reuse across a changed slot layout — caller contract is: same agent set,
same per-agent slot ordering (``SlotPriceBook`` in `repro.core.hub` keys
stored prices by hub id + elastic agent-set version to enforce this).

Worked example
--------------
Two requests, two unit-capacity agents.  Both requests prefer agent 0, but
assigning request 1 there would strand request 0's larger surplus, so the
welfare optimum splits them (3.0 + 0.5 = 3.5 beats 2.0 + 1.0 = 3.0):

>>> import numpy as np
>>> from repro.core.auction_dense import solve_dense_auction
>>> w = np.array([[3.0, 1.0],
...               [2.0, 0.5]])
>>> res = solve_dense_auction(w, [1, 1])
>>> res.assignment                     # request j -> agent index
[0, 1]
>>> res.welfare
3.5
>>> res.gap_bound < 1e-6               # certified distance to the optimum
True

Re-solving the same market seeded from the final prices converges without
re-running the coarse ε phases and certifies the same welfare:

>>> warm = solve_dense_auction(w, [1, 1], start_prices=res.slot_prices)
>>> (warm.assignment, warm.welfare) == (res.assignment, res.welfare)
True
>>> warm.warm_started and not warm.fallback
True

Payments
--------
VCG Clarke-pivot payments (Eq. 8) need W(C \\ {j}) for every matched j.
Instead of per-request counterfactual re-solves, `dense_clarke_payments`
computes every counterfactual simultaneously: one *batched* Bellman-Ford over
the residual graph of the final matching (batch dimension = matched request),
where each batch member blocks its own request node and its agent's sink arc,
mirroring `auction.run_auction`'s warm-start logic exactly but in O(B·n·m)
vectorized relaxations instead of Python graph walks.

Hub sharding
------------
`solve_dense_auction_jax_batch` solves many independent hub blocks of
uneven (n_h, K_h) shape as ONE traced program per shape bucket: blocks are
padded to power-of-two (n, K) buckets with zero-weight rows/columns and the
bucket is solved by `jax.vmap` of the staged solver.  Zero padding is
behavior-neutral — a padded request's best profit is ≤ 0 so it parks on its
first bid, and a padded slot carries price 0 and weight 0 so it neither
attracts bids (bids require strictly positive profit) nor goes stale in
reverse rounds (stale needs price > 0).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "DenseAuctionResult",
    "solve_dense_auction",
    "solve_dense_auction_jax",
    "solve_dense_auction_jax_batch",
    "dense_clarke_payments",
]

# gap_bound = 2 * n * eps_final; the default keeps it below 1e-7 for any
# n <= ~500 at unit weight scale, comfortably inside the 1e-6 tolerances
# used by the mechanism tests.
_EPS_FINAL_REL = 1e-10
_THETA = 5.0
# warm solves skip the coarsest scaling phases (ε₀ = wmax/θ³ vs wmax/θ) and
# run under a bounded round budget; tripping it falls back to a cold solve
_WARM_ROUNDS_PER_NODE = 40
_WARM_ROUNDS_FLOOR = 2_000


class DenseAuctionResult:
    """Allocation + dual state of one dense-auction solve."""

    __slots__ = ("assignment", "welfare", "slot_prices", "slot_agent",
                 "profits", "eps", "phases", "rounds", "gap_bound",
                 "warm_started", "fallback")

    def __init__(self, assignment, welfare, slot_prices, slot_agent, profits,
                 eps, phases, rounds, gap_bound, warm_started=False,
                 fallback=False):
        self.assignment = assignment        # request j -> agent index or -1
        self.welfare = welfare              # sum of matched w_ij
        self.slot_prices = slot_prices      # dual price per unit slot
        self.slot_agent = slot_agent        # slot -> agent index
        self.profits = profits              # per-request profit pi_j
        self.eps = eps                      # final epsilon
        self.phases = phases
        self.rounds = rounds                # total Jacobi bidding rounds
        self.gap_bound = gap_bound          # certified welfare gap (2*n*eps)
        self.warm_started = warm_started    # seeded from prior slot prices
        self.fallback = fallback            # warm attempt tripped -> re-ran cold


def _expand_slots(caps, n: int) -> np.ndarray:
    caps = np.asarray([int(c) for c in caps], dtype=np.int64)
    if (caps < 0).any():
        raise ValueError("negative capacity")
    return np.repeat(np.arange(len(caps)), np.minimum(caps, n))


def solve_dense_auction(w: np.ndarray, caps, *, eps_final: float | None = None,
                        theta: float = _THETA,
                        max_rounds: int = 500_000,
                        start_prices: np.ndarray | None = None,
                        start_eps: float | None = None) -> DenseAuctionResult:
    """ε-scaling auction over dense weights. w[j, i] <= 0 means "no edge".

    ``start_prices`` (length = total unit slots, i.e. ``sum(min(b_i, n))``)
    seeds the duals from a previous solve of a similar market; the warm
    attempt starts its ε schedule at ``start_eps`` (default wmax/θ²) and is
    round-budgeted — on budget exhaustion the solve silently restarts cold
    (``result.fallback`` reports it).  The optimality certificate is
    identical either way: 2·n·ε_final regardless of starting prices.
    """
    w = np.asarray(w, dtype=np.float64)
    n, m = w.shape
    slot_agent = _expand_slots(caps, n)
    K = len(slot_agent)
    empty = DenseAuctionResult([-1] * n, 0.0, np.zeros(K), slot_agent,
                               np.zeros(n), 0.0, 0, 0, 0.0)
    if n == 0 or K == 0:
        return empty
    B = np.maximum(w, 0.0)[:, slot_agent]          # (n, K) slot-level weights
    wmax = float(B.max(initial=0.0))
    if wmax <= 0.0:
        return empty
    if eps_final is None:
        eps_final = _EPS_FINAL_REL * max(wmax, 1.0)
    cold_eps0 = max(wmax / theta, eps_final)
    if start_prices is None:
        return _solve_dense_numpy(w, B, slot_agent, np.zeros(K), cold_eps0,
                                  eps_final, theta, max_rounds)
    p0 = np.clip(np.asarray(start_prices, dtype=np.float64), 0.0, None)
    if p0.shape != (K,):
        raise ValueError(f"start_prices shape {p0.shape} does not match the "
                         f"slot layout ({K},) for this (caps, n)")
    eps0 = start_eps if start_eps is not None \
        else max(wmax / theta ** 3, eps_final)
    eps0 = min(max(eps0, eps_final), cold_eps0)
    budget = min(max_rounds,
                 _WARM_ROUNDS_PER_NODE * (n + K) + _WARM_ROUNDS_FLOOR)
    try:
        res = _solve_dense_numpy(w, B, slot_agent, p0, eps0, eps_final,
                                 theta, budget)
        res.warm_started = True
        return res
    except RuntimeError:
        res = _solve_dense_numpy(w, B, slot_agent, np.zeros(K), cold_eps0,
                                 eps_final, theta, max_rounds)
        res.warm_started = True
        res.fallback = True
        return res


def _solve_dense_numpy(w, B, slot_agent, prices0, eps0, eps_final, theta,
                       max_rounds) -> DenseAuctionResult:
    """The forward/reverse ε-scaling loop from a given (prices, ε₀) state."""
    n, K = B.shape
    m = w.shape[1]
    eps = eps0
    # absolute slack for ε-CS tests: comparisons happen at price magnitude
    # ~wmax, where a relative-only slack can fall below one ulp and turn an
    # exactly-ε equilibrium gap into a perpetual evict/re-bid cycle.
    tol = eps_final / 8.0

    prices = prices0.copy()
    owner = np.full(K, -1, dtype=np.int64)          # slot -> request
    slot_of = np.full(n, -1, dtype=np.int64)        # request -> slot
    parked = np.zeros(n, dtype=bool)
    rows = np.arange(n)
    phases = 0
    rounds = [0]

    def _evict(eps) -> bool:
        """Unpark/evict requests whose ε-CS fails at current prices; returns
        whether anything is left to bid.

        Prices are kept (forward bidding never lowers them): freed slots
        retain their duals so re-bidding starts near the previous phase's
        equilibrium; reverse rounds handle price decreases."""
        v1 = (B - prices).max(axis=1)
        assigned = slot_of >= 0
        prof = np.where(assigned, B[rows, np.maximum(slot_of, 0)]
                        - prices[np.maximum(slot_of, 0)], 0.0)
        np.logical_and(parked, v1 <= eps + tol, out=parked)
        # best available option includes the outside option (profit 0): a
        # request left at profit < -ε by an earlier coarser phase must leave
        viol = assigned & (prof < np.maximum(v1, 0.0) - eps - tol)
        if viol.any():
            owner[slot_of[viol]] = -1
            slot_of[viol] = -1
        return bool(((slot_of < 0) & ~parked).any())

    def _bid_until_settled(eps):
        """Jacobi bidding rounds until every request is assigned or parked."""
        while True:
            active = np.nonzero((slot_of < 0) & ~parked)[0]
            if len(active) == 0:
                return
            rounds[0] += 1
            if rounds[0] > max_rounds:
                raise RuntimeError(
                    f"dense auction failed to converge in {max_rounds} rounds"
                    f" (n={n}, m={m}, eps={eps:g})")
            P = B[active] - prices                       # (A, K) profits
            v1 = P.max(axis=1)
            k1 = P.argmax(axis=1)
            P[np.arange(len(active)), k1] = -np.inf
            v2 = np.maximum(P.max(axis=1), 0.0)          # incl. outside option
            wants = v1 > 0.0
            parked[active[~wants]] = True                # outside option wins
            bidders = active[wants]
            if len(bidders) == 0:
                continue
            kb = k1[wants]
            bid = prices[kb] + (v1[wants] - v2[wants]) + eps
            # per-slot winner: highest bid, ties to the lowest request index
            best = np.full(K, -np.inf)
            np.maximum.at(best, kb, bid)
            winner = np.full(K, n, dtype=np.int64)
            at_best = bid == best[kb]                    # exact float match
            np.minimum.at(winner, kb[at_best], bidders[at_best])
            slots_won = np.nonzero(winner < n)[0]
            # displace previous owners first (a displaced request may itself
            # be winning a different slot this very round)
            prev = owner[slots_won]
            slot_of[prev[prev >= 0]] = -1
            owner[slots_won] = winner[slots_won]
            slot_of[winner[slots_won]] = slots_won
            prices[slots_won] = best[slots_won]

    def _reverse_until_clean(eps) -> None:
        """Reverse auction rounds: every free slot with a positive (stale)
        price lowers it to β₂ − ε — the second-best support over requests —
        and grabs its best supporter, or drops to 0 when unsupported.
        Price decreases of ≥ ε (or request-profit gains of ≥ ε) bound the
        number of rounds; ε-CS is preserved exactly (Bertsekas–Castañón)."""
        while True:
            stale = np.nonzero((owner < 0) & (prices > 0.0))[0]
            if len(stale) == 0:
                return
            rounds[0] += 1
            if rounds[0] > max_rounds:
                raise RuntimeError("dense auction reverse rounds exceeded "
                                   f"{max_rounds} (n={n}, m={m})")
            assigned = slot_of >= 0
            pi = np.where(assigned, B[rows, np.maximum(slot_of, 0)]
                          - prices[np.maximum(slot_of, 0)], 0.0)
            V = B[:, stale] - pi[:, None]            # support for each slot
            b1 = V.max(axis=0)
            j1 = V.argmax(axis=0)
            V[j1, np.arange(len(stale))] = -np.inf
            b2 = V.max(axis=0) if n > 1 else np.full(len(stale), -np.inf)
            weak = b1 <= eps                         # nobody worth grabbing
            prices[stale[weak]] = 0.0
            ks = stale[~weak]
            if len(ks) == 0:
                continue
            js = j1[~weak]
            newp = np.maximum(b2[~weak] - eps, 0.0)
            # request-side conflicts: accept the best offer, ties to the
            # lowest slot index
            off = B[js, ks] - newp
            bestoff = np.full(n, -np.inf)
            np.maximum.at(bestoff, js, off)
            at_best = off == bestoff[js]
            take = np.full(n, K, dtype=np.int64)
            np.minimum.at(take, js[at_best], ks[at_best])
            sel = take[js] == ks
            ks, js, newp = ks[sel], js[sel], newp[sel]
            old = slot_of[js]
            owner[old[old >= 0]] = -1    # freed, keeps price (maybe stale)
            prices[ks] = newp
            owner[ks] = js
            slot_of[js] = ks
            parked[js] = False

    while True:
        phases += 1
        # forward/reverse alternation at this ε until neither has work
        for _ in range(8 * (n + K) + 8):
            if _evict(eps):
                _bid_until_settled(eps)
                _reverse_until_clean(eps)
                continue
            if ((owner < 0) & (prices > 0.0)).any():
                _reverse_until_clean(eps)
                continue
            break
        else:
            raise RuntimeError("dense auction forward/reverse alternation "
                               f"failed to settle (n={n}, m={m}, eps={eps:g})")
        if eps <= eps_final * (1.0 + 1e-12):
            break
        eps = max(eps / theta, eps_final)

    assignment = np.where(slot_of >= 0, slot_agent[np.maximum(slot_of, 0)], -1)
    welfare = float(np.where(slot_of >= 0,
                             w[rows, np.maximum(assignment, 0)], 0.0).sum())
    profits = np.where(slot_of >= 0,
                       B[rows, np.maximum(slot_of, 0)]
                       - prices[np.maximum(slot_of, 0)], 0.0)
    return DenseAuctionResult(
        [int(a) for a in assignment], welfare, prices, slot_agent, profits,
        eps, phases, rounds[0], 2.0 * n * eps)


# --------------------------------------------------------------------------
# jax.jit-able variant: identical algorithm, bidding rounds inside
# lax.while_loop (fixed iteration cap) so the solve is one staged program.
# --------------------------------------------------------------------------
_JAX_CACHE: dict = {}


def _build_jax_solver(max_rounds: int):
    import jax  # noqa: F401  (kept for parity with the jit/vmap wrappers)
    import jax.numpy as jnp
    from jax import lax

    def solve(B, p0, eps0, eps_final, theta):
        n, K = B.shape
        rows = jnp.arange(n)
        big = jnp.asarray(jnp.finfo(B.dtype).max / 4, B.dtype)
        tol = eps_final / 8.0

        def cs_state(prices, owner, slot_of, parked, eps):
            """(unpark-violators, evict-violators, any-stale) predicates."""
            v1 = (B - prices[None, :]).max(axis=1)
            assigned = slot_of >= 0
            prof = jnp.where(assigned,
                             B[rows, jnp.maximum(slot_of, 0)]
                             - prices[jnp.maximum(slot_of, 0)], 0.0)
            unpark = parked & (v1 > eps + tol)
            viol = assigned & (prof < jnp.maximum(v1, 0.0) - eps - tol)
            stale = (owner < 0) & (prices > 0.0)
            return unpark, viol, stale

        def evict(prices, owner, slot_of, parked, eps):
            # prices are KEPT: with unchanged prices the eviction pass is
            # idempotent, so a single sweep suffices (no cascade loop)
            unpark, viol, _ = cs_state(prices, owner, slot_of, parked, eps)
            parked = parked & ~unpark
            owner = owner.at[jnp.where(viol, slot_of, K)].set(
                -1, mode="drop")
            slot_of = jnp.where(viol, -1, slot_of)
            return owner, slot_of, parked

        def bid_until_settled(prices, owner, slot_of, parked, eps, rounds):
            def bid_cond(st):
                _prices, _owner, slot_of, parked, r = st
                return ((slot_of < 0) & ~parked).any() & (r < max_rounds)

            def bid_body(st):
                prices, owner, slot_of, parked, r = st
                active = (slot_of < 0) & ~parked
                P = jnp.where(active[:, None], B - prices[None, :], -big)
                v1 = P.max(axis=1)
                k1 = P.argmax(axis=1)
                P2 = P.at[rows, k1].set(-big)
                v2 = jnp.maximum(P2.max(axis=1), 0.0)
                bidder = active & (v1 > 0.0)
                parked = parked | (active & (v1 <= 0.0))
                bid = jnp.where(bidder, prices[k1] + (v1 - v2) + eps, -big)
                kb = jnp.where(bidder, k1, K)
                best = jnp.full((K,), -big, B.dtype).at[kb].max(
                    bid, mode="drop")
                at_best = bidder & (bid == best[jnp.minimum(kb, K - 1)])
                winner = jnp.full((K,), n, jnp.int32).at[
                    jnp.where(at_best, kb, K)].min(
                        rows.astype(jnp.int32), mode="drop")
                won = winner < n
                new_owner = jnp.where(won, winner, owner)
                # displaced: my slot is now owned by someone else
                displaced = (slot_of >= 0) & (
                    new_owner[jnp.maximum(slot_of, 0)] != rows)
                slot_of = jnp.where(displaced, -1, slot_of)
                slot_won = jnp.full((n,), -1, jnp.int32).at[
                    jnp.where(won, winner, n)].set(
                        jnp.arange(K, dtype=jnp.int32), mode="drop")
                slot_of = jnp.where(slot_won >= 0, slot_won, slot_of)
                prices = jnp.where(won, best, prices)
                return prices, new_owner, slot_of, parked, r + 1

            return lax.while_loop(
                bid_cond, bid_body, (prices, owner, slot_of, parked, rounds))

        def reverse_until_clean(prices, owner, slot_of, parked, eps, rounds):
            def rev_cond(st):
                prices, owner, _slot_of, _parked, r = st
                return ((owner < 0) & (prices > 0.0)).any() & (r < max_rounds)

            def rev_body(st):
                prices, owner, slot_of, parked, r = st
                stale = (owner < 0) & (prices > 0.0)
                assigned = slot_of >= 0
                pi = jnp.where(assigned,
                               B[rows, jnp.maximum(slot_of, 0)]
                               - prices[jnp.maximum(slot_of, 0)], 0.0)
                V = jnp.where(stale[None, :], B - pi[:, None], -big)
                b1 = V.max(axis=0)
                j1 = V.argmax(axis=0).astype(jnp.int32)
                V2 = V.at[j1, jnp.arange(K)].set(-big)
                b2 = V2.max(axis=0)
                weak = stale & (b1 <= eps)
                prices = jnp.where(weak, 0.0, prices)
                strong = stale & ~weak
                newp = jnp.maximum(b2 - eps, 0.0)
                off = jnp.where(strong, B[j1, jnp.arange(K)] - newp, -big)
                # request-side conflicts: best offer wins, ties to lowest slot
                bestoff = jnp.full((n,), -big, B.dtype).at[
                    jnp.where(strong, j1, n)].max(off, mode="drop")
                at_best = strong & (off == bestoff[jnp.minimum(j1, n - 1)])
                take = jnp.full((n,), K, jnp.int32).at[
                    jnp.where(at_best, j1, n)].min(
                        jnp.arange(K, dtype=jnp.int32), mode="drop")
                sel = strong & (take[jnp.minimum(j1, n - 1)]
                                == jnp.arange(K))
                grab = jnp.full((n,), -1, jnp.int32).at[
                    jnp.where(sel, j1, n)].set(
                        jnp.arange(K, dtype=jnp.int32), mode="drop")
                grabbed = grab >= 0
                old = jnp.where(grabbed & (slot_of >= 0), slot_of, K)
                owner = owner.at[old].set(-1, mode="drop")
                owner = owner.at[jnp.where(sel, jnp.arange(K), K)].set(
                    jnp.where(sel, j1, -1), mode="drop")
                prices = jnp.where(sel, newp, prices)
                slot_of = jnp.where(grabbed, grab, slot_of)
                parked = parked & ~grabbed
                return prices, owner, slot_of, parked, r + 1

            return lax.while_loop(
                rev_cond, rev_body, (prices, owner, slot_of, parked, rounds))

        def settle(prices, owner, slot_of, parked, eps, rounds):
            """Alternate forward bidding and reverse rounds at this ε."""
            def alt_cond(st):
                prices, owner, slot_of, parked, r = st
                unpark, viol, stale = cs_state(
                    prices, owner, slot_of, parked, eps)
                active = (slot_of < 0) & ~parked
                return (unpark.any() | viol.any() | stale.any()
                        | active.any()) & (r < max_rounds)

            def alt_body(st):
                prices, owner, slot_of, parked, r = st
                owner, slot_of, parked = evict(
                    prices, owner, slot_of, parked, eps)
                prices, owner, slot_of, parked, r = bid_until_settled(
                    prices, owner, slot_of, parked, eps, r)
                return reverse_until_clean(
                    prices, owner, slot_of, parked, eps, r)

            return lax.while_loop(
                alt_cond, alt_body, (prices, owner, slot_of, parked, rounds))

        def phase(carry):
            prices, owner, slot_of, parked, eps, rounds = carry
            prices, owner, slot_of, parked, rounds = settle(
                prices, owner, slot_of, parked, eps, rounds)
            eps = jnp.maximum(eps / theta, eps_final)
            return prices, owner, slot_of, parked, eps, rounds

        def phase_cond(carry):
            _p, _o, _s, _pk, eps, rounds = carry
            return (eps > eps_final * 1.0000000001) & (rounds < max_rounds)

        init = (jnp.asarray(p0, B.dtype),
                jnp.full((K,), -1, jnp.int32),
                jnp.full((n,), -1, jnp.int32),
                jnp.zeros((n,), bool),
                jnp.asarray(eps0, B.dtype), jnp.asarray(0, jnp.int32))
        # one final settle at eps_final after the loop drives eps down
        carry = lax.while_loop(phase_cond, phase, init)
        prices, owner, slot_of, parked, rounds = settle(
            *carry[:4], jnp.asarray(eps_final, B.dtype), carry[5])
        return prices, owner, slot_of, rounds

    return solve


def _get_jax_solver(max_rounds: int, batched: bool):
    """jit (and, for hub batches, vmap) wrappers around the staged solve.

    The vmapped variant maps over every argument — (H, n, K) weight blocks
    with per-hub (p0, ε₀, ε_final, θ) vectors — so hubs padded to one shape
    bucket share a single traced program; `lax.while_loop`'s batching rule
    freezes already-converged hubs while the stragglers keep bidding.
    """
    import jax

    key = (max_rounds, batched)
    solver = _JAX_CACHE.get(key)
    if solver is None:
        solve = _build_jax_solver(max_rounds)
        solver = jax.jit(jax.vmap(solve)) if batched else jax.jit(solve)
        _JAX_CACHE[key] = solver
    return solver


def _jax_eps_final(wmax: float, dtype) -> float:
    # resolution bound: ε (and the ε/8 slack) must stay well above one
    # ulp at price magnitude or CS tests cycle on rounding noise
    ulp = float(np.finfo(dtype).eps) * max(wmax, 1.0)
    return max(1e-5 * max(wmax, 1.0), 64.0 * ulp)


def _materialize_jax(w_np, slot_agent, prices, slot_of, rounds, eps_final,
                     *, warm_started=False, fallback=False):
    """Host-side DenseAuctionResult from one staged solve's final state."""
    n = w_np.shape[0]
    slot_of = np.asarray(slot_of)
    prices_np = np.asarray(prices, dtype=np.float64)
    rows = np.arange(n)
    assignment = np.where(slot_of >= 0, slot_agent[np.maximum(slot_of, 0)], -1)
    welfare = float(np.where(slot_of >= 0,
                             w_np[rows, np.maximum(assignment, 0)], 0.0).sum())
    profits = np.where(
        slot_of >= 0,
        np.maximum(w_np, 0.0)[rows, np.maximum(assignment, 0)]
        - prices_np[np.maximum(slot_of, 0)], 0.0)
    return DenseAuctionResult(
        [int(a) for a in assignment], welfare, prices_np, slot_agent, profits,
        float(eps_final), -1, int(rounds), 2.0 * n * float(eps_final),
        warm_started=warm_started, fallback=fallback)


def solve_dense_auction_jax(w, caps, *, eps_final: float | None = None,
                            theta: float = _THETA,
                            max_rounds: int = 200_000,
                            start_prices: np.ndarray | None = None):
    """JAX variant. Returns a DenseAuctionResult (host-side numpy values).

    Runs in the input dtype (float32 under default JAX config), so the
    certified gap is wider than the NumPy/float64 path; the NumPy solver is
    the reference, this one is the accelerator-resident building block.
    ``start_prices`` seeds the duals exactly like the NumPy solver's warm
    path (skipped coarse phase, cold re-solve on round-budget exhaustion).
    """
    import jax.numpy as jnp

    w_np = np.asarray(w, dtype=np.float64)
    n, m = w_np.shape
    slot_agent = _expand_slots(caps, n)
    K = len(slot_agent)
    if n == 0 or K == 0 or float(w_np.max(initial=0.0)) <= 0.0:
        return DenseAuctionResult([-1] * n, 0.0, np.zeros(K), slot_agent,
                                  np.zeros(n), 0.0, 0, 0, 0.0)
    B = jnp.asarray(np.maximum(w_np, 0.0)[:, slot_agent])
    wmax = float(w_np.max())
    if eps_final is None:
        eps_final = _jax_eps_final(wmax, B.dtype)
    cold_eps0 = max(wmax / theta, eps_final)
    solver = _get_jax_solver(max_rounds, batched=False)

    warm = start_prices is not None
    if warm:
        p0 = np.clip(np.asarray(start_prices, dtype=np.float64),
                     0.0, None).astype(B.dtype)
        if p0.shape != (K,):
            raise ValueError(f"start_prices shape {p0.shape} does not match "
                             f"the slot layout ({K},) for this (caps, n)")
        eps0 = min(max(wmax / theta ** 3, eps_final), cold_eps0)
        budget = min(max_rounds,
                     _WARM_ROUNDS_PER_NODE * (n + K) + _WARM_ROUNDS_FLOOR)
        warm_solver = _get_jax_solver(budget, batched=False)
        prices, owner, slot_of, rounds = warm_solver(
            B, jnp.asarray(p0), float(eps0), float(eps_final), float(theta))
        if int(rounds) < budget:
            return _materialize_jax(w_np, slot_agent, prices, slot_of, rounds,
                                    eps_final, warm_started=True)
        # warm attempt tripped its budget -> cold re-solve below
    prices, owner, slot_of, rounds = solver(
        B, jnp.zeros((K,), B.dtype), float(cold_eps0), float(eps_final),
        float(theta))
    if int(rounds) >= max_rounds:
        # the staged while_loops stop silently at the cap; surface it the
        # same way the NumPy solver does instead of returning a bad matching
        raise RuntimeError(
            f"dense auction (jax) failed to converge in {max_rounds} rounds"
            f" (n={n}, m={m}, eps_final={eps_final:g})")
    return _materialize_jax(w_np, slot_agent, prices, slot_of, rounds,
                            eps_final, warm_started=warm, fallback=warm)


def _pow2_bucket(x: int, floor: int = 8) -> int:
    """Smallest power of two >= max(x, floor) — the vmap shape bucket."""
    return 1 << (max(int(x), floor) - 1).bit_length()


def solve_dense_auction_jax_batch(ws, caps_list, *,
                                  eps_final: float | None = None,
                                  theta: float = _THETA,
                                  max_rounds: int = 200_000,
                                  start_prices_list=None
                                  ) -> list[DenseAuctionResult]:
    """Solve many independent hub blocks in one vmapped program per bucket.

    ``ws[h]`` is hub h's dense (n_h, m_h) weight block and ``caps_list[h]``
    its per-agent capacities.  Blocks are zero-padded to power-of-two
    (n, K) shape buckets (padding is behavior-neutral — see the module
    docstring) and every bucket is solved by ONE `jax.vmap`-of-`jit` call,
    so K hubs of uneven size cost one trace + one device dispatch per
    distinct bucket instead of K dispatches.  ``start_prices_list[h]``
    optionally warm-starts hub h (None entries cold-start); any block whose
    staged solve hits the round cap is transparently re-solved by the
    float64 NumPy reference solver (``result.fallback``).
    """
    import jax.numpy as jnp

    H = len(ws)
    sp_list = start_prices_list or [None] * H
    results: list[DenseAuctionResult | None] = [None] * H
    prep = []                      # (h, w_np, slot_agent, B, p0, eps0, eps_f)
    for h, (w, caps) in enumerate(zip(ws, caps_list)):
        w_np = np.asarray(w, dtype=np.float64)
        n = w_np.shape[0]
        slot_agent = _expand_slots(caps, n)
        K = len(slot_agent)
        if n == 0 or K == 0 or float(w_np.max(initial=0.0)) <= 0.0:
            results[h] = DenseAuctionResult(
                [-1] * n, 0.0, np.zeros(K), slot_agent, np.zeros(n),
                0.0, 0, 0, 0.0)
            continue
        B = np.maximum(w_np, 0.0)[:, slot_agent].astype(np.float32)
        wmax = float(B.max())
        eps_f = eps_final if eps_final is not None \
            else _jax_eps_final(wmax, B.dtype)
        sp = sp_list[h]
        if sp is not None:
            p0 = np.clip(np.asarray(sp, np.float64), 0.0, None)
            if p0.shape != (K,):
                raise ValueError(
                    f"start_prices for block {h}: shape {p0.shape} does not "
                    f"match the slot layout ({K},) for this (caps, n)")
            p0 = p0.astype(np.float32)
            eps0 = min(max(wmax / theta ** 3, eps_f),
                       max(wmax / theta, eps_f))
            warm = True
        else:
            p0 = np.zeros(K, np.float32)
            eps0 = max(wmax / theta, eps_f)
            warm = False
        prep.append((h, w_np, slot_agent, B, p0, eps0, eps_f, warm))

    # group by (shape bucket, warm?) so uneven hubs share one traced solve;
    # warm and cold hubs never share a group — warm groups run under the
    # warm round budget (a bad seed must not drag the group to the global
    # cap) and that budget must not apply to cold solves
    groups: dict[tuple[int, int, bool], list] = {}
    for item in prep:
        _, w_np, slot_agent, B, *_, warm = item
        bucket = (_pow2_bucket(B.shape[0]), _pow2_bucket(B.shape[1]), warm)
        groups.setdefault(bucket, []).append(item)

    for (bn, bK, warm_group), members in groups.items():
        G = len(members)
        cap = max_rounds
        if warm_group:
            cap = min(max_rounds,
                      _WARM_ROUNDS_PER_NODE * (bn + bK) + _WARM_ROUNDS_FLOOR)
        vsolver = _get_jax_solver(cap, batched=True)
        Bs = np.zeros((G, bn, bK), np.float32)
        p0s = np.zeros((G, bK), np.float32)
        eps0s = np.zeros(G, np.float32)
        eps_fs = np.zeros(G, np.float32)
        for g, (_h, _w, _sa, B, p0, eps0, eps_f, _warm) in enumerate(members):
            Bs[g, :B.shape[0], :B.shape[1]] = B
            p0s[g, :len(p0)] = p0
            eps0s[g] = eps0
            eps_fs[g] = eps_f
        thetas = np.full(G, theta, np.float32)
        prices, owner, slot_of, rounds = vsolver(
            jnp.asarray(Bs), jnp.asarray(p0s), jnp.asarray(eps0s),
            jnp.asarray(eps_fs), jnp.asarray(thetas))
        prices = np.asarray(prices)
        slot_of = np.asarray(slot_of)
        rounds = np.asarray(rounds)
        for g, (h, w_np, slot_agent, B, p0, eps0, eps_f, warm) in \
                enumerate(members):
            n, K = B.shape
            if int(rounds[g]) >= cap:
                # capped mid-solve: the float64 reference re-solves this hub
                results[h] = solve_dense_auction(w_np, caps_list[h])
                results[h].warm_started = warm
                results[h].fallback = True
                continue
            results[h] = _materialize_jax(
                w_np, slot_agent, prices[g, :K], slot_of[g, :n], rounds[g],
                eps_f, warm_started=warm)
    return results


# --------------------------------------------------------------------------
# Batched Clarke-pivot payments from the final matching.
# --------------------------------------------------------------------------
def dense_clarke_payments(w: np.ndarray, costs: np.ndarray, caps,
                          assignment) -> list:
    """p_j = c_ij + max(0, -d_j) for matched j, where d_j is the cheapest
    residual walk absorbing the unit freed by removing request j — all
    matched requests solved at once by one batched Bellman-Ford.

    Mirrors `auction.run_auction(payment_mode="warmstart")`: per batch member
    b, request j_b's node is blocked and agent i_b's sink arc is blocked; the
    target distance is min(dist_from_s[i_b], dist_from_t[i_b]).

    Contract: ``assignment`` must be (near-)welfare-optimal — the residual
    graph of an optimal matching has no negative cycles, which is what makes
    the iteration-capped Bellman-Ford exact. On an ε-optimal matching the
    error is bounded by (n+m+3)·2n·ε; keep ε at the float64 default (the
    NumPy solver) for DSIC-grade payments and treat the float32 jax path's
    payments as approximate to its reported gap_bound.
    """
    w = np.asarray(w, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    n, m = w.shape
    caps_arr = np.asarray([int(c) for c in caps], dtype=np.int64)
    payments = [0.0] * n
    matched = [j for j, i in enumerate(assignment) if i >= 0]
    if not matched:
        return payments
    B = len(matched)
    j_blk = np.asarray(matched)
    i_blk = np.asarray([assignment[j] for j in matched])

    X = np.zeros((n, m), dtype=bool)
    for j, i in enumerate(assignment):
        if i >= 0:
            X[j, i] = True
    used = X.sum(axis=0)
    row_matched = X.any(axis=1)
    mi = np.where(row_matched, np.argmax(X, axis=1), -1)   # agent of request
    inf = np.inf
    # forward matching arcs j -> i: cost -w where an unused edge exists
    Cf = np.where((w > 0) & ~X, -w, inf)                    # (n, m)
    # backward arcs i -> j (undo match): cost +w on matched pairs
    w_back = np.where(row_matched, w[np.arange(n), np.maximum(mi, 0)], inf)
    has_free = used < caps_arr                              # i -> t arcs
    has_flow = used > 0                                     # t -> i arcs
    brange = np.arange(B)

    def _bf(from_t: bool) -> np.ndarray:
        """Batched Bellman-Ford; returns dist-to-agent matrix (B, m)."""
        D_req = np.full((B, n), inf)
        D_ag = np.full((B, m), inf)
        D_s = np.full(B, 0.0 if not from_t else inf)
        D_t = np.full(B, 0.0 if from_t else inf)
        for _ in range(n + m + 3):
            changed = False
            # s -> j' (unmatched rows, cost 0)
            upd = np.where(~row_matched[None, :], D_s[:, None], inf)
            # i -> j' (matched rows, cost +w)
            upd_b = np.where(row_matched[None, :],
                             D_ag[:, np.maximum(mi, 0)] + w_back[None, :], inf)
            upd = np.minimum(upd, upd_b)
            upd[brange, j_blk] = inf                        # blocked request
            new = np.minimum(D_req, upd)
            changed |= (new < D_req).any()
            D_req = new
            # j' -> i (forward, cost -w): the big dense relaxation
            upd = (D_req[:, :, None] + Cf[None, :, :]).min(axis=1)
            # t -> i (cost 0) where flow exists, minus the blocked sink arc
            upd_t = np.where(has_flow[None, :], D_t[:, None], inf)
            upd_t[brange, i_blk] = inf
            new = np.minimum(D_ag, np.minimum(upd, upd_t))
            changed |= (new < D_ag).any()
            D_ag = new
            # i -> t (cost 0) where free capacity, minus the blocked sink arc
            cand = np.where(has_free[None, :], D_ag, inf)
            cand[brange, i_blk] = inf
            new = np.minimum(D_t, cand.min(axis=1))
            changed |= (new < D_t).any()
            D_t = new
            # j' -> s (matched rows, cost 0)
            cand = np.where(row_matched[None, :], D_req, inf)
            new = np.minimum(D_s, cand.min(axis=1))
            changed |= (new < D_s).any()
            D_s = new
            if not changed:
                break
        return D_ag

    d = np.minimum(_bf(from_t=False)[brange, i_blk],
                   _bf(from_t=True)[brange, i_blk])
    gain = np.where(np.isfinite(d), np.maximum(0.0, -d), 0.0)
    for b, j in enumerate(matched):
        payments[j] = float(gain[b] + costs[j, assignment[j]])
    return payments
