"""Back-compat shim: the dense auction now lives in ``repro.core.solvers``.

The PR-1 monolith was split into the pluggable solver-backend package —
``solvers/dense_np.py`` (float64 NumPy reference), ``solvers/dense_jax.py``
(jit-staged + vmapped shape buckets), ``solvers/pallas_backend.py`` (Pallas
bidding kernel) and ``solvers/dense_common.py`` (slot expansion, ε
schedules, Clarke payments).  This module re-exports the historical public
names so existing imports keep working; new code should import from
``repro.core.solvers`` directly.
"""
from repro.core.solvers.dense_common import (DenseAuctionResult,
                                             dense_clarke_payments)
from repro.core.solvers.dense_jax import (solve_dense_auction_jax,
                                          solve_dense_auction_jax_batch)
from repro.core.solvers.dense_np import solve_dense_auction

__all__ = [
    "DenseAuctionResult",
    "solve_dense_auction",
    "solve_dense_auction_jax",
    "solve_dense_auction_jax_batch",
    "dense_clarke_payments",
]
