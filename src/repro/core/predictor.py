"""Online QoS prediction (§4.1): per-agent Hoeffding trees over Eq. 5 features.

    x_ij = (|p_j|, t_j, o_ij, I_r, R_r, I_i, R_i, B_i, u_i, xi_j)

Latency and cost use HoeffdingTreeRegressor; quality ("performance") uses
HoeffdingTreeClassifier, exactly as in the paper. Cold start is handled by a
structural prior (token pricing + a latency model linear in uncached tokens)
until ``warm_n`` observations arrive — the paper's startup warm-up issues a
few dialogues per agent to cross this threshold (PredictorPool.warmup).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.hoeffding import HoeffdingTreeClassifier, HoeffdingTreeRegressor
from repro.core.pricing import TokenPrices, predicted_cost

N_FEATURES = 10


@dataclass
class PredictorInput:
    prompt_len: float
    turn: float
    affinity: float
    router_inflight: float
    router_rps: float
    agent_inflight: float
    agent_rps: float
    capacity: float
    utilization: float
    domain_match: float

    def vector(self) -> np.ndarray:
        return np.array([
            self.prompt_len, self.turn, self.affinity,
            self.router_inflight, self.router_rps,
            self.agent_inflight, self.agent_rps,
            self.capacity, self.utilization, self.domain_match,
        ], dtype=np.float64)


@dataclass
class QoSEstimate:
    latency: float
    cost: float
    quality: float


class AgentPredictor:
    def __init__(self, agent_id: str, prices: TokenPrices, *,
                 warm_n: int = 6, prior_latency_per_tok: float = 1e-3,
                 prior_latency_base: float = 0.02, prior_quality: float = 0.6):
        self.agent_id = agent_id
        self.prices = prices
        self.lat = HoeffdingTreeRegressor(N_FEATURES)
        self.cost = HoeffdingTreeRegressor(N_FEATURES)
        self.quality = HoeffdingTreeClassifier(N_FEATURES)
        self.n_obs = 0
        self.warm_n = warm_n
        self.prior_lpt = prior_latency_per_tok
        self.prior_lb = prior_latency_base
        self.prior_q = prior_quality
        self.ewma_gen = 32.0  # expected generation length

    def predict(self, x: PredictorInput) -> QoSEstimate:
        uncached = x.prompt_len * (1.0 - x.affinity)
        prior_lat = (self.prior_lb + self.prior_lpt * uncached) * (1.0 + x.utilization)
        prior_cst = predicted_cost(self.prices, int(x.prompt_len), x.affinity,
                                   self.ewma_gen)
        if self.n_obs < self.warm_n:
            return QoSEstimate(prior_lat, prior_cst, self.prior_q)
        v = x.vector()
        # blend structural prior -> tree as evidence accumulates: the Eq.6
        # cost prior is nearly exact given affinity, so a barely-trained tree
        # must not displace it abruptly (tests/test_system.py convergence)
        w = min(1.0, self.n_obs / 60.0)
        lat = (1 - w) * prior_lat + w * max(0.0, self.lat.predict_one(v))
        cst = (1 - w) * prior_cst + w * max(0.0, self.cost.predict_one(v))
        return QoSEstimate(
            latency=lat,
            cost=cst,
            quality=float(np.clip(self.quality.predict_one(v), 0.0, 1.0)),
        )

    def update(self, x: PredictorInput, latency_obs: float, cost_obs: float,
               quality_obs: float) -> None:
        v = x.vector()
        self.lat.learn_one(v, float(latency_obs))
        self.cost.learn_one(v, float(cost_obs))
        self.quality.learn_one(v, float(quality_obs))
        self.n_obs += 1


class PredictorPool:
    """Independent AgentPredictor per backend (Appendix C.2.3)."""

    def __init__(self, prices_by_agent: dict[str, TokenPrices], **kw):
        self._preds = {aid: AgentPredictor(aid, pr, **kw)
                       for aid, pr in prices_by_agent.items()}

    def __getitem__(self, agent_id: str) -> AgentPredictor:
        return self._preds[agent_id]

    def __contains__(self, agent_id):
        return agent_id in self._preds

    def add_agent(self, agent_id: str, prices: TokenPrices, **kw) -> None:
        """Elastic scale-out: a new agent joins mid-flight."""
        self._preds[agent_id] = AgentPredictor(agent_id, prices, **kw)

    def remove_agent(self, agent_id: str) -> None:
        self._preds.pop(agent_id, None)

    def agents(self):
        return list(self._preds)
