"""Online QoS prediction (§4.1): per-agent Hoeffding trees over Eq. 5 features.

    x_ij = (|p_j|, t_j, o_ij, I_r, R_r, I_i, R_i, B_i, u_i, xi_j)

Latency and cost use HoeffdingTreeRegressor; quality ("performance") uses
HoeffdingTreeClassifier, exactly as in the paper. Cold start is handled by a
structural prior (token pricing + a latency model linear in uncached tokens)
until ``warm_n`` observations arrive — the paper's startup warm-up issues a
few dialogues per agent to cross this threshold (PredictorPool.warmup).

Batched path (router Phase 1b hot loop): ``feature_tensor`` assembles the
full (n requests, m agents, N_FEATURES) Eq.-5 tensor with broadcasting,
and ``PredictorPool.predict_matrix`` scores it in a handful of array ops —
all m agents' trees stacked into one node pool (one vectorized descend per
target), the structural prior and the ``w = min(1, n_obs/60)`` blend applied
as arrays. Every operation mirrors ``AgentPredictor.predict`` double-for-
double, so the batched path is a pure oracle-parity optimization
(tests/test_predictor_batch.py).

Reputation-weighted priors (adversarial stress, `repro.core.adversary`):
each agent carries a multiplicative reputation in [0, 1], EWMA-updated from
settled report-vs-audit quality-inflation residuals
(``note_residual``).  Reputation scales the w-blend (``w_eff = w * rep``,
leaning a distrusted agent's latency/cost back onto the structural prior)
and multiplies predicted quality in both the warm and cold paths, so an
inflating agent's Eq.-1 value decays instead of its lies poisoning the
estimate.  At reputation exactly 1.0 — the honest fixed point, preserved
exactly by the EWMA — every scaling is a bit-neutral multiply-by-one, so
honest runs are bit-identical to the pre-reputation router.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.hoeffding import (HoeffdingTreeClassifier,
                                  HoeffdingTreeRegressor, descend,
                                  descend_jax, stack_compiled)
from repro.core.pricing import TokenPrices, predicted_cost

N_FEATURES = 10


def feature_tensor(prompt_lens, turns, affinity, *, router_inflight=0.0,
                   router_rps=0.0, agent_inflight, agent_rps, capacity,
                   domain_match) -> np.ndarray:
    """(n, m, N_FEATURES) tensor; X[j, i] equals the ``PredictorInput(...)
    .vector()`` the scalar router builds for pair (request j, agent i).

    ``prompt_lens``/``turns``: (n,); ``affinity``/``domain_match``: (n, m);
    ``agent_inflight``/``agent_rps``/``capacity``: (m,); router_* scalars.
    Utilization is derived per agent exactly as the scalar path does:
    inflight / max(1, capacity).
    """
    affinity = np.asarray(affinity, dtype=np.float64)
    n, m = affinity.shape
    inflight = np.asarray(agent_inflight, dtype=np.float64)
    cap = np.asarray(capacity, dtype=np.float64)
    X = np.empty((n, m, N_FEATURES), dtype=np.float64)
    X[..., 0] = np.asarray(prompt_lens, dtype=np.float64)[:, None]
    X[..., 1] = np.asarray(turns, dtype=np.float64)[:, None]
    X[..., 2] = affinity
    X[..., 3] = float(router_inflight)
    X[..., 4] = float(router_rps)
    X[..., 5] = inflight[None, :]
    X[..., 6] = np.asarray(agent_rps, dtype=np.float64)[None, :]
    X[..., 7] = cap[None, :]
    X[..., 8] = (inflight / np.maximum(1.0, cap))[None, :]
    X[..., 9] = np.asarray(domain_match, dtype=np.float64)
    return X


def _blend_with_prior(X, *, lpt, lb, miss, hit, out, ewma, n_obs, warm_n,
                      prior_q, rep, raw_lat, raw_cst, raw_q, explore=0.0):
    """Structural cold-start prior + ``w = min(1, n_obs/60)`` tree blend as
    array ops — the single vectorized transcription of the scalar
    ``AgentPredictor.predict`` math (kept bit-equivalent: same op order,
    same ``trunc``/``maximum``/``clip`` semantics), shared by
    ``predict_rows`` (scalar per-agent params) and ``predict_matrix``
    ((m,) per-agent param arrays broadcast against (n, m) features).
    ``rep`` is the reputation weight: it scales the tree-blend weight and
    multiplies quality in both warm and cold branches (exactly neutral at
    1.0, the honest fixed point).  ``explore`` is the per-agent optimism
    bonus (`AgentPredictor.explore`); quality is lifted by
    ``explore / sqrt(1 + n_obs)`` (capped at 1.0) ONLY for agents whose
    bonus is nonzero, so the default 0.0 leaves the arrays untouched."""
    pl, aff, util = X[..., 0], X[..., 2], X[..., 8]
    uncached = pl * (1.0 - aff)
    prior_lat = (lb + lpt * uncached) * (1.0 + util)
    npmt = np.trunc(pl)  # == int(prompt_len) for non-negative lengths
    nhit = aff * npmt
    prior_cst = miss * (npmt - nhit) + hit * nhit + out * ewma
    w = np.minimum(1.0, n_obs / 60.0) * rep
    lat = (1 - w) * prior_lat + w * np.maximum(0.0, raw_lat)
    cst = (1 - w) * prior_cst + w * np.maximum(0.0, raw_cst)
    cold = n_obs < warm_n
    qual = np.where(cold, prior_q * rep, np.clip(raw_q, 0.0, 1.0) * rep)
    expl = np.asarray(explore, dtype=np.float64)
    if np.any(expl != 0.0):
        qual = np.where(expl != 0.0,
                        np.minimum(1.0, qual + expl / np.sqrt(1.0 + n_obs)),
                        qual)
    return (np.where(cold, prior_lat, lat),
            np.where(cold, prior_cst, cst),
            qual)


@dataclass
class PredictorInput:
    """One (request, agent) Eq.-5 feature row x_ij, field-per-feature."""

    prompt_len: float
    turn: float
    affinity: float
    router_inflight: float
    router_rps: float
    agent_inflight: float
    agent_rps: float
    capacity: float
    utilization: float
    domain_match: float

    def vector(self) -> np.ndarray:
        """The N_FEATURES-long float64 array the trees consume."""
        return np.array([
            self.prompt_len, self.turn, self.affinity,
            self.router_inflight, self.router_rps,
            self.agent_inflight, self.agent_rps,
            self.capacity, self.utilization, self.domain_match,
        ], dtype=np.float64)


@dataclass
class QoSEstimate:
    """Predicted (Lat, Cost, Perf) triple for one (request, agent) pair."""

    latency: float
    cost: float
    quality: float


class AgentPredictor:
    """One agent's three Hoeffding targets + structural cold-start prior."""

    def __init__(self, agent_id: str, prices: TokenPrices, *,
                 warm_n: int = 6, prior_latency_per_tok: float = 1e-3,
                 prior_latency_base: float = 0.02, prior_quality: float = 0.6,
                 rep_alpha: float = 0.25, explore: float = 0.0):
        self.agent_id = agent_id
        self.prices = prices
        self.lat = HoeffdingTreeRegressor(N_FEATURES)
        self.cost = HoeffdingTreeRegressor(N_FEATURES)
        self.quality = HoeffdingTreeClassifier(N_FEATURES)
        self.n_obs = 0
        self.warm_n = warm_n
        self.prior_lpt = prior_latency_per_tok
        self.prior_lb = prior_latency_base
        self.prior_q = prior_quality
        self.ewma_gen = 32.0  # expected generation length
        self.reputation = 1.0  # report-trust weight in [0, 1]; 1.0 = honest
        self.rep_alpha = rep_alpha
        # optimism bonus against affinity entrenchment (PR 7 pathology):
        # predicted quality is lifted by explore/sqrt(1+n_obs), capped at
        # 1.0, so a rarely-sampled specialist can outbid an entrenched
        # cache-warm generalist until real observations arrive.  The
        # default 0.0 is an exact IEEE no-op (the lift is never applied).
        self.explore = float(explore)

    def _optimism(self, q: float) -> float:
        """Apply the exploration lift (exact passthrough at ``explore=0``)."""
        if self.explore == 0.0:
            return q
        return min(1.0, q + self.explore / float(np.sqrt(1.0 + self.n_obs)))

    def note_residual(self, residual: float) -> None:
        """Fold one settled report-vs-audit residual into reputation.

        ``residual`` is the quality inflation ``max(0, reported - audited)``
        in [0, 1]; the EWMA target is ``1 - residual``.  A zero residual
        leaves a 1.0 reputation at exactly 1.0 (``0.75*1.0 + 0.25*1.0``
        is exact in IEEE arithmetic), so honest fleets stay bit-identical
        with or without the audit channel attached.
        """
        target = 1.0 - min(1.0, max(0.0, float(residual)))
        self.reputation = ((1.0 - self.rep_alpha) * self.reputation
                           + self.rep_alpha * target)

    def predict(self, x: PredictorInput) -> QoSEstimate:
        """Eq.-5 QoS estimate: structural prior blended into tree output,
        scaled by the agent's reputation (neutral at 1.0)."""
        uncached = x.prompt_len * (1.0 - x.affinity)
        prior_lat = (self.prior_lb + self.prior_lpt * uncached) * (1.0 + x.utilization)
        prior_cst = predicted_cost(self.prices, int(x.prompt_len), x.affinity,
                                   self.ewma_gen)
        rep = self.reputation
        if self.n_obs < self.warm_n:
            return QoSEstimate(prior_lat, prior_cst,
                               self._optimism(self.prior_q * rep))
        v = x.vector()
        # blend structural prior -> tree as evidence accumulates: the Eq.6
        # cost prior is nearly exact given affinity, so a barely-trained tree
        # must not displace it abruptly (tests/test_system.py convergence).
        # Reputation scales the blend: a distrusted agent's self-reported
        # telemetry counts for less, and its quality is discounted outright.
        w = min(1.0, self.n_obs / 60.0) * rep
        lat = (1 - w) * prior_lat + w * max(0.0, self.lat.predict_one(v))
        cst = (1 - w) * prior_cst + w * max(0.0, self.cost.predict_one(v))
        return QoSEstimate(
            latency=lat,
            cost=cst,
            quality=self._optimism(
                float(np.clip(self.quality.predict_one(v), 0.0, 1.0)) * rep),
        )

    def predict_rows(self, X, backend: str = "numpy"):
        """Vectorized ``predict`` over the rows of ``X`` (B, N_FEATURES).

        Returns (latency, cost, quality) arrays; every op mirrors the
        scalar path double-for-double (NumPy backend), so
        ``predict_rows(X)[k][b] == predict(PredictorInput(*X[b]))``.
        """
        X = np.asarray(X, dtype=np.float64)
        return _blend_with_prior(
            X, lpt=self.prior_lpt, lb=self.prior_lb, miss=self.prices.miss,
            hit=self.prices.hit, out=self.prices.out, ewma=self.ewma_gen,
            n_obs=self.n_obs, warm_n=self.warm_n,
            prior_q=np.full(X.shape[0], self.prior_q), rep=self.reputation,
            raw_lat=self.lat.predict_batch(X, backend),
            raw_cst=self.cost.predict_batch(X, backend),
            raw_q=self.quality.predict_batch(X, backend),
            explore=self.explore)

    def update(self, x: PredictorInput, latency_obs: float, cost_obs: float,
               quality_obs: float) -> None:
        """Phase-4 feedback: one observed (Lat, Cost, Perf) triple."""
        v = x.vector()
        self.lat.learn_one(v, float(latency_obs))
        self.cost.learn_one(v, float(cost_obs))
        self.quality.learn_one(v, float(quality_obs))
        self.n_obs += 1


def identity_fingerprint(agent_id: str, prices: TokenPrices) -> str:
    """Stable identity key for reputation persistence across churn.

    An agent that leaves and rejoins under the same id and published
    token prices is the SAME market identity — ``float.hex`` makes the
    price part exact (no repr rounding), so the fingerprint never
    aliases two distinct price points.  Changing any published price
    creates a fresh identity (and a fresh reputation): re-entering at a
    different market position is a new offer, not a laundered one.
    """
    return "|".join((str(agent_id), float(prices.miss).hex(),
                     float(prices.hit).hex(), float(prices.out).hex()))


class PredictorPool:
    """Independent AgentPredictor per backend (Appendix C.2.3).

    Reputation is keyed on `identity_fingerprint` and survives
    leave/rejoin churn: `remove_agent` parks the departing predictor's
    reputation in a pool-lifetime ledger and `add_agent` restores it for
    a matching fingerprint, so the PR 8 laundering move — decay your
    reputation, churn out, rejoin with fresh 1.0 trust — inherits the
    decayed weight instead.  Honest agents (reputation exactly 1.0) are
    bit-unaffected: restoring 1.0 equals the fresh-predictor default.
    """

    def __init__(self, prices_by_agent: dict[str, TokenPrices], **kw):
        self._default_kw = dict(kw)
        self._preds = {aid: AgentPredictor(aid, pr, **kw)
                       for aid, pr in prices_by_agent.items()}
        # per-target stacked-forest cache, invalidated by membership or any
        # tree version change (any learn_one shifts leaf means)
        self._stacks: dict[str, dict] = {}
        # identity_fingerprint -> parked reputation of departed agents
        self._rep_ledger: dict[str, float] = {}

    def __getitem__(self, agent_id: str) -> AgentPredictor:
        return self._preds[agent_id]

    def __contains__(self, agent_id):
        return agent_id in self._preds

    def add_agent(self, agent_id: str, prices: TokenPrices, **kw) -> None:
        """Elastic scale-out: a new agent joins mid-flight.

        Predictor knobs default to the pool's construction-time ``**kw``
        (so e.g. an exploration bonus survives churn); a rejoining
        identity inherits its parked reputation (see class docstring).
        """
        kw = {**self._default_kw, **kw}
        pred = AgentPredictor(agent_id, prices, **kw)
        parked = self._rep_ledger.get(identity_fingerprint(agent_id, prices))
        if parked is not None:
            pred.reputation = parked
        self._preds[agent_id] = pred
        # a re-added id gets FRESH trees whose version counters restart, so
        # a version-keyed cache entry could collide with the old trees' —
        # membership changes always drop the stacks
        self._stacks.clear()

    def remove_agent(self, agent_id: str) -> None:
        """Elastic scale-in: drop an agent and its stacked-forest caches.

        The departing reputation is parked under the agent's identity
        fingerprint so churn cannot reset it (anti-laundering layer).
        """
        pred = self._preds.pop(agent_id, None)
        if pred is not None:
            fp = identity_fingerprint(pred.agent_id, pred.prices)
            self._rep_ledger[fp] = pred.reputation
        self._stacks.clear()

    def agents(self):
        """Agent ids currently in the pool."""
        return list(self._preds)

    def note_residual(self, agent_id: str, residual: float) -> None:
        """Route one settled quality-inflation residual into the agent's
        reputation (no-op for unknown/removed agents).  Reputation lives
        blend-side, not in the trees, so no stacked-forest invalidation."""
        pred = self._preds.get(agent_id)
        if pred is not None:
            pred.note_residual(residual)

    def reputations(self) -> dict[str, float]:
        """Current reputation weight per agent (1.0 = fully trusted)."""
        return {aid: p.reputation for aid, p in self._preds.items()}

    # ---------------- batched Phase-1 scoring ----------------
    def _stacked_forest(self, name: str, agent_ids: list[str]):
        """Stacked node pool for one target, refreshed incrementally: a
        ``learn_one`` without a split only shifts leaf values (node count
        unchanged), so the changed tree is recompiled and written back into
        its slice of the pool; a split (or membership change) triggers a
        full restack. Per-round cost is thus proportional to the number of
        trees feedback actually touched, not the fleet size."""
        trees = [getattr(self._preds[a], name) for a in agent_ids]
        versions = [t._version for t in trees]
        entry = self._stacks.get(name)
        if entry is not None and entry["ids"] == tuple(agent_ids):
            changed = [k for k in range(len(trees))
                       if entry["versions"][k] != versions[k]]
            fresh = {k: trees[k].compiled() for k in changed}
            if all(len(c.feature) == entry["sizes"][k]
                   for k, c in fresh.items()):
                # unchanged node count == unchanged structure (nodes are only
                # ever added, by splits): only leaf values moved, so refresh
                # just the value slices of the touched trees
                st, roots = entry["stacked"], entry["roots"]
                for k, c in fresh.items():
                    off = roots[k]
                    st.value[off:off + entry["sizes"][k]] = c.value
                entry["versions"] = versions
                return st, roots
        compiled = [t.compiled() for t in trees]
        stacked, roots = stack_compiled(compiled)
        self._stacks[name] = {"ids": tuple(agent_ids), "versions": versions,
                              "sizes": [len(c.feature) for c in compiled],
                              "stacked": stacked, "roots": roots}
        return stacked, roots

    def predict_matrix(self, agent_ids: list[str], X: np.ndarray,
                       backend: str = "numpy"):
        """Score the full (n, m, N_FEATURES) feature tensor in array ops.

        Returns (latency, cost, quality) matrices, (n, m) each, equal to
        looping ``self[agent_ids[i]].predict(PredictorInput(*X[j, i]))``
        over every pair — the m agents' trees are stacked into one node
        pool per target (one vectorized descend over the (n·m, F) matrix),
        and the structural cold-start prior + the ``min(1, n_obs/60)``
        blend are applied as broadcast array ops.
        """
        X = np.asarray(X, dtype=np.float64)
        n, m = X.shape[:2]
        preds = [self._preds[a] for a in agent_ids]
        flat = X.reshape(n * m, N_FEATURES)
        col = np.tile(np.arange(m), n)  # agent index of each flat row
        walker = descend_jax if backend == "jax" else descend
        raw = {}
        for name in ("lat", "cost", "quality"):
            stacked, roots = self._stacked_forest(name, agent_ids)
            raw[name] = walker(stacked, flat, roots[col]).reshape(n, m)

        return _blend_with_prior(
            X,
            lpt=np.array([p.prior_lpt for p in preds]),
            lb=np.array([p.prior_lb for p in preds]),
            miss=np.array([p.prices.miss for p in preds]),
            hit=np.array([p.prices.hit for p in preds]),
            out=np.array([p.prices.out for p in preds]),
            ewma=np.array([p.ewma_gen for p in preds]),
            n_obs=np.array([p.n_obs for p in preds], dtype=np.float64),
            warm_n=np.array([p.warm_n for p in preds], dtype=np.float64),
            prior_q=np.array([p.prior_q for p in preds]),
            rep=np.array([p.reputation for p in preds]),
            raw_lat=raw["lat"], raw_cst=raw["cost"], raw_q=raw["quality"],
            explore=np.array([p.explore for p in preds]))
