"""Client valuation (Eq. 1) and welfare weights.

    v_j = delta * P_j(T_j, S_i, K_i) - (1 - delta) * L_j(T_j, S_i, o_ij)

P is the predicted quality in [0, 1]; L is the predicted latency normalized
by ``latency_scale`` so both terms live in comparable units, then scaled to
currency by ``value_scale`` (the client's willingness to pay for a perfect,
instant answer).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ValuationConfig:
    """Eq.-1 knobs: quality/latency trade-off and currency scaling."""

    delta: float = 0.7          # quality-vs-latency preference
    latency_scale: float = 1.0  # seconds at which latency penalty ~ 1
    value_scale: float = 10.0   # currency per unit of valuation


def client_value(pred_quality, pred_latency, cfg: ValuationConfig):
    """Vectorized Eq. 1. Inputs broadcast; returns same-shape valuations."""
    p = np.clip(np.asarray(pred_quality, dtype=np.float64), 0.0, 1.0)
    l_norm = np.asarray(pred_latency, dtype=np.float64) / cfg.latency_scale
    v = cfg.delta * p - (1.0 - cfg.delta) * l_norm
    return cfg.value_scale * v


def welfare_weights(values: np.ndarray, costs: np.ndarray) -> np.ndarray:
    """w_ij = v_ij - c_ij, pruned at 0 (Algorithm 1 line 11)."""
    w = np.asarray(values, dtype=np.float64) - np.asarray(costs, dtype=np.float64)
    return np.where(w > 0, w, 0.0)
