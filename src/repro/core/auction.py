"""Welfare-maximizing allocation (Eq. 7) + VCG Clarke-pivot payments (Eq. 8).

Two allocation solvers (``solver=`` of :func:`run_auction`):
  * ``mcmf``  — successive-shortest-paths min-cost max-flow (exact oracle,
                pure Python; `repro.core.mcmf`).
  * ``dense`` — vectorized Bertsekas ε-scaling auction over the dense weight
                matrix (`repro.core.auction_dense`), the hot-path solver;
                welfare is within a certified 2·n·ε of the MCMF optimum and
                payments are batched Clarke pivots from one vectorized
                Bellman-Ford instead of per-request Python graph walks.

Three payment computation modes for the MCMF solver (§4.3):
  * ``naive``     — re-solve the MCMF from scratch for every matched request
                    (the textbook N+1-solve VCG).
  * ``warmstart`` — ONE residual-graph shortest path per matched request:
                    W(C\\{j}) = (W(C) - w_ij) + max(0, -SP_cost(G_f - j)).
                    This is the paper's Hershberger-Suri-style reoptimization
                    and is validated against ``naive`` in tests.
  * payments from unmatched requests are 0; unmatched requests pay nothing.

All welfare numbers returned are from EXACT optimization (Theorem 4.1), so
DSIC (Theorem 4.2) holds; tests/test_auction.py empirically verifies both
truthfulness and weak budget balance (Theorem 4.3), and
tests/test_auction_dense.py verifies the dense solver preserves them.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.auction_dense import (dense_clarke_payments,
                                      solve_dense_auction,
                                      solve_dense_auction_jax,
                                      solve_dense_auction_jax_batch)
from repro.core.mcmf import (FlowNetwork, residual_shortest_path,
                             solve_min_cost_flow)


@dataclass
class AuctionResult:
    """One Phase-2 solve: allocation, welfare, payments + solver stats."""

    assignment: list            # request j -> agent index or -1
    welfare: float              # W(C)
    payments: list              # VCG payment per request (0 if unmatched)
    weights: np.ndarray         # w_ij matrix used
    costs: np.ndarray           # c_ij matrix used
    solver_stats: dict = field(default_factory=dict)


def _build_network(w: np.ndarray, caps):
    n, m = w.shape
    s, t = n + m, n + m + 1
    g = FlowNetwork(n + m + 2)
    req_edges = []
    for j in range(n):
        req_edges.append(g.add_edge(s, j, 1.0, 0.0))
    match_edges = {}
    for j in range(n):
        for i in range(m):
            if w[j, i] > 0:
                match_edges[(j, i)] = g.add_edge(j, n + i, 1.0, -float(w[j, i]))
    sink_edges = [g.add_edge(n + i, t, float(caps[i]), 0.0) for i in range(m)]
    g.match_edges = match_edges
    g.sink_edges = sink_edges
    return g, s, t, match_edges


def solve_allocation(w: np.ndarray, caps) -> tuple[list, float, FlowNetwork]:
    """Max-weight b-matching via MCMF. Returns (assignment, welfare, residual)."""
    n, m = w.shape
    g, s, t, match_edges = _build_network(w, caps)
    flow, cost, _pot = solve_min_cost_flow(g, s, t)
    assignment = [-1] * n
    for (j, i), eid in match_edges.items():
        if g.cap[eid] <= 1e-9:  # saturated forward edge = matched
            assignment[j] = i
    return assignment, -cost, g


def _welfare_without(w: np.ndarray, caps, j: int) -> float:
    w2 = np.delete(w, j, axis=0)
    _, wf, _ = solve_allocation(w2, caps)
    return wf


def run_auction(values: np.ndarray, costs: np.ndarray, caps,
                payment_mode: str = "warmstart",
                solver: str = "mcmf",
                start_prices: np.ndarray | None = None) -> AuctionResult:
    """values/costs: [N requests, M agents] predicted v_ij and c_ij.

    Welfare weights w_ij = v_ij - c_ij; non-positive pairs pruned (Alg. 1).
    ``solver`` picks the Phase-2 allocator: ``"mcmf"`` (exact oracle) or
    ``"dense"`` (vectorized ε-scaling auction; ``"dense-jax"`` stages the
    bidding loop through jax.jit). The dense solvers compute payments in one
    batched pass regardless of ``payment_mode``, and accept a warm-start
    slot-price seed via ``start_prices`` (ignored by the mcmf oracle, which
    has no persistent duals); the final duals come back in
    ``solver_stats["slot_prices"]`` for the caller's price book.
    """
    w = np.asarray(values, dtype=np.float64) - np.asarray(costs, dtype=np.float64)
    w = np.where(w > 0, w, 0.0)
    n, m = w.shape
    if solver in ("dense", "dense-jax"):
        return _run_dense(w, np.asarray(costs, dtype=np.float64), caps, solver,
                          start_prices)
    if solver != "mcmf":
        raise ValueError(f"unknown solver {solver!r}")
    assignment, welfare, gf = solve_allocation(w, caps)

    payments = [0.0] * n
    n_resolves = 0
    for j, i in enumerate(assignment):
        if i < 0:
            continue
        w_ij = w[j, i]
        c_ij = float(costs[j, i])
        if payment_mode == "naive":
            w_without = _welfare_without(w, caps, j)
            n_resolves += 1
        else:
            # warmstart: cancel j's unit; the only NEW residual capacity is
            # one unit on (agent i -> t). The optimum without j improves over
            # (W - w_ij) by at most one augmenting walk that consumes that
            # unit: either a path s~>i->t (a displaced request gets matched)
            # or a cycle t~>i->t (an existing match reroutes onto agent i).
            g2 = gf.clone()
            s, t = n + m, n + m + 1
            _cancel_unit(g2, s, j, n + i, t)
            # block the i->t arc itself (both directions): the improving walk
            # ends there conceptually; traversing it mid-walk would re-use
            # the single freed unit and creates negative cycles for BF.
            sink_eid = gf.sink_edges[i]
            be = {sink_eid, sink_eid ^ 1}
            d_s, _ = residual_shortest_path(g2, s, n + i, blocked={j},
                                            blocked_edges=be)
            d_t, _ = residual_shortest_path(g2, t, n + i, blocked={j},
                                            blocked_edges=be)
            d = min(d_s, d_t)
            gain = max(0.0, -d) if d != float("inf") else 0.0
            w_without = (welfare - w_ij) + gain
        # Eq. 8: p_j = W(C\{j}) - (W(C) - w_ij) + c_ij
        payments[j] = w_without - (welfare - w_ij) + c_ij

    return AuctionResult(
        assignment=assignment, welfare=welfare, payments=payments,
        weights=w, costs=np.asarray(costs, dtype=np.float64),
        solver_stats={"solver": "mcmf", "payment_mode": payment_mode,
                      "resolves": n_resolves},
    )


def _dense_stats(solver: str, res) -> dict:
    return {"solver": solver, "payment_mode": "dual-batched",
            "phases": res.phases, "rounds": res.rounds,
            "eps": res.eps, "gap_bound": res.gap_bound,
            "slot_prices": res.slot_prices, "slot_agent": res.slot_agent,
            "warm_started": res.warm_started, "warm_fallback": res.fallback}


def _run_dense(w: np.ndarray, costs: np.ndarray, caps, solver: str,
               start_prices: np.ndarray | None = None) -> AuctionResult:
    solve = solve_dense_auction_jax if solver == "dense-jax" \
        else solve_dense_auction
    res = solve(w, caps, start_prices=start_prices)
    payments = dense_clarke_payments(w, costs, caps, res.assignment)
    return AuctionResult(
        assignment=list(res.assignment), welfare=res.welfare,
        payments=payments, weights=w, costs=costs,
        solver_stats=_dense_stats(solver, res),
    )


def run_sharded_auction(values: np.ndarray, costs: np.ndarray, caps,
                        blocks: dict[int, tuple[list[int], list[int]]],
                        payment_mode: str = "warmstart",
                        solver: str = "mcmf",
                        start_prices: dict[int, np.ndarray] | None = None,
                        ) -> dict[int, AuctionResult]:
    """Phase 2 sharded across proxy hubs: one independent auction per block.

    ``blocks[h] = (request_indices, agent_indices)`` carves the global
    (values, costs, caps) market into hub h's sub-market; blocks must be
    agent-disjoint (the hub partition guarantees it), so the per-hub results
    splice into a global matching without capacity conflicts.  Every result
    is *identical* to calling :func:`run_auction` on that block alone — the
    only difference is scheduling: for ``dense-jax`` all blocks are padded
    into shape buckets and solved by one vmapped program per bucket
    (`solve_dense_auction_jax_batch`) instead of one dispatch per hub.

    ``start_prices[h]`` warm-starts hub h's dense solve (see
    `repro.core.hub.SlotPriceBook` for the cache-keying contract).

    Returns ``{hub_id: AuctionResult}`` — assignments/payments indexed
    *within* the block; the caller maps them back through ``blocks[h]``.
    """
    values = np.asarray(values, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    sp = start_prices or {}
    out: dict[int, AuctionResult] = {}
    if solver == "dense-jax" and len(blocks) > 1:
        hub_ids = sorted(blocks)
        ws, costs_b, caps_b, seeds = [], [], [], []
        for h in hub_ids:
            r_idx, a_idx = blocks[h]
            v = values[np.ix_(r_idx, a_idx)]
            c = costs[np.ix_(r_idx, a_idx)]
            ws.append(np.where(v - c > 0, v - c, 0.0))
            costs_b.append(c)
            caps_b.append([caps[i] for i in a_idx])
            seeds.append(sp.get(h))
        dres = solve_dense_auction_jax_batch(ws, caps_b,
                                             start_prices_list=seeds)
        for h, w, c, cb, res in zip(hub_ids, ws, costs_b, caps_b, dres):
            payments = dense_clarke_payments(w, c, cb, res.assignment)
            out[h] = AuctionResult(
                assignment=list(res.assignment), welfare=res.welfare,
                payments=payments, weights=w, costs=c,
                solver_stats=_dense_stats(solver, res))
        return out
    for h, (r_idx, a_idx) in blocks.items():
        out[h] = run_auction(values[np.ix_(r_idx, a_idx)],
                             costs[np.ix_(r_idx, a_idx)],
                             [caps[i] for i in a_idx],
                             payment_mode=payment_mode, solver=solver,
                             start_prices=sp.get(h))
    return out


def _cancel_unit(g: FlowNetwork, s: int, j: int, agent_node: int, t: int):
    """Remove one unit of flow along s->j->agent->t in a residual network."""
    def _undo(u, v):
        for eid in g.adj[u]:
            if g.to[eid] == v and eid % 2 == 0 and g.cap[eid ^ 1] > 1e-12:
                g.cap[eid] += 1.0
                g.cap[eid ^ 1] -= 1.0
                return True
        return False

    assert _undo(s, j), "request j was not matched"
    assert _undo(j, agent_node), "no flow j->i"
    assert _undo(agent_node, t), "no flow i->t"


def client_utilities(result: AuctionResult, true_values: np.ndarray) -> np.ndarray:
    """u_j = v_j(true) - p_j for matched requests (0 otherwise)."""
    n = len(result.assignment)
    u = np.zeros(n)
    for j, i in enumerate(result.assignment):
        if i >= 0:
            u[j] = float(true_values[j, i]) - result.payments[j]
    return u
