"""Phase-2/3 façade: welfare matching (Eq. 7) + VCG payments (Eq. 8).

All solver selection goes through the ``core/solvers`` registry — this
module contains NO per-solver branching.  ``run_auction`` prunes the welfare
matrix and delegates to the named :class:`~repro.core.solvers.SolverBackend`
(``mcmf`` exact oracle, ``dense`` NumPy auction, ``dense-jax`` staged
auction, ``pallas`` kernelized auction — see ``available_solvers()``);
``run_sharded_auction`` does the same per hub block, batching the blocks
through ``solve_batch`` when the backend supports it, and optionally runs a
cross-hub **spill** round: unmatched requests from saturated hubs re-auction
once over the residual capacity of every hub, recovering the welfare a hard
hub partition forfeits when one hub runs out of slots while another has
slack.

All welfare numbers returned by the exact oracle are from EXACT optimization
(Theorem 4.1), so DSIC (Theorem 4.2) holds; the dense family is certified
within each result's ``solver_stats["gap_bound"]``.  tests/test_auction.py
empirically verifies truthfulness and weak budget balance (Theorem 4.3), and
tests/test_auction_dense.py + tests/test_auction_pallas.py verify the dense
backends preserve them.
"""
from __future__ import annotations

import numpy as np

from repro.core.solvers import (AuctionResult, available_solvers, get_solver,
                                solve_allocation)
from repro.utils.timing import phase_scope

__all__ = ["AuctionResult", "run_auction", "run_sharded_auction",
           "client_utilities", "solve_allocation", "available_solvers",
           "SPILL_HUB"]

#: pseudo hub id under which run_sharded_auction(..., spill=True) returns the
#: cross-hub second-round result; its request/agent indices are GLOBAL and
#: live in the result's solver_stats["spill"] block.
SPILL_HUB = -1


def _prune(values, costs) -> np.ndarray:
    """Welfare weights w_ij = v_ij - c_ij with non-positive pairs pruned."""
    w = np.asarray(values, dtype=np.float64) - np.asarray(costs,
                                                          dtype=np.float64)
    return np.where(w > 0, w, 0.0)




def run_auction(values: np.ndarray, costs: np.ndarray, caps,
                payment_mode: str = "warmstart",
                solver: str = "mcmf",
                start_prices: np.ndarray | None = None) -> AuctionResult:
    """values/costs: [N requests, M agents] predicted v_ij and c_ij.

    Welfare weights w_ij = v_ij - c_ij; non-positive pairs pruned (Alg. 1).
    ``solver`` names a registered backend (``available_solvers()``); the
    dense family computes payments in one batched pass regardless of
    ``payment_mode`` and accepts a warm-start unit-price seed via
    ``start_prices`` (silently dropped for backends without persistent
    duals, e.g. the mcmf oracle); the final duals come back in
    ``solver_stats["agent_prices"]`` for the caller's price book.
    """
    backend = get_solver(solver)
    if not backend.supports_warm_start:
        start_prices = None
    return backend.solve(_prune(values, costs),
                         np.asarray(costs, dtype=np.float64), caps,
                         payment_mode=payment_mode, start_prices=start_prices)


def run_sharded_auction(values: np.ndarray, costs: np.ndarray, caps,
                        blocks: dict[int, tuple[list[int], list[int]]],
                        payment_mode: str = "warmstart",
                        solver: str = "mcmf",
                        start_prices: dict[int, np.ndarray] | None = None,
                        spill: bool = False,
                        spill_agents: list[int] | None = None,
                        spill_warm: bool = True,
                        profiler=None,
                        ) -> dict[int, AuctionResult]:
    """Phase 2 sharded across proxy hubs: one independent auction per block.

    ``blocks[h] = (request_indices, agent_indices)`` carves the global
    (values, costs, caps) market into hub h's sub-market; blocks must be
    agent-disjoint (the hub partition guarantees it), so the per-hub results
    splice into a global matching without capacity conflicts.  Every result
    is *identical* to calling :func:`run_auction` on that block alone — the
    only difference is scheduling: backends with ``supports_batch`` solve
    all blocks padded into shape buckets by one vmapped program per bucket
    instead of one dispatch per hub.

    ``start_prices[h]`` warm-starts hub h's dense solve (see
    `repro.core.hub.SlotPriceBook` for the cache-keying contract).

    ``spill=True`` adds a cross-hub second round: requests left unmatched by
    their hub's auction bid once more over the residual capacity of ALL hub
    agents (hard hub pinning strands exactly this welfare when a hub
    saturates), and the extra result lands under :data:`SPILL_HUB` with its
    GLOBAL request/agent index lists in ``solver_stats["spill"]``.  First-
    round results are never altered, so the splice-parity contract above
    still holds hub by hub.  ``spill_agents`` widens the residual market to
    agents outside every block (a hub that received no requests this batch
    still has slack worth spilling onto); it defaults to the union of the
    blocks' agents.  With ``spill_warm=True`` (default) the spill round is
    seeded from the donor hubs' first-round duals: each agent's residual
    slots inherit its *lowest* first-round slot prices (the unsold slots —
    exactly the goods the spill market is selling), which the warm-capable
    dense backends use as ε-scaling start prices.  ``spill_warm=False``
    keeps the cold-start behaviour for A/B measurement.

    ``profiler`` (duck-typed ``phase(name)`` context manager, e.g.
    `repro.serving.simulator.RoutingProfiler`) attributes wall-clock to
    ``phase2_solve[<solver>]`` and ``phase2_spill``.

    Returns ``{hub_id: AuctionResult}`` — assignments/payments indexed
    *within* the block; the caller maps them back through ``blocks[h]``
    (and through ``solver_stats["spill"]`` for the spill round).
    """
    values = np.asarray(values, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    backend = get_solver(solver)
    sp = start_prices or {}
    hub_ids = sorted(blocks)
    ws, costs_b, caps_b, seeds = [], [], [], []
    for h in hub_ids:
        r_idx, a_idx = blocks[h]
        ws.append(_prune(values[np.ix_(r_idx, a_idx)],
                         costs[np.ix_(r_idx, a_idx)]))
        costs_b.append(costs[np.ix_(r_idx, a_idx)])
        caps_b.append([caps[i] for i in a_idx])
        seeds.append(sp.get(h) if backend.supports_warm_start else None)
    with phase_scope(profiler, f"phase2_solve[{solver}]"):
        if backend.supports_batch and len(blocks) > 1:
            results = backend.solve_batch(ws, costs_b, caps_b,
                                          payment_mode=payment_mode,
                                          start_prices_list=seeds)
        else:
            results = [backend.solve(w, c, cb, payment_mode=payment_mode,
                                     start_prices=s)
                       for w, c, cb, s in zip(ws, costs_b, caps_b, seeds)]
    out = dict(zip(hub_ids, results))
    if spill:
        with phase_scope(profiler, "phase2_spill"):
            spill_res = _spill_round(values, costs, caps, blocks, out,
                                     backend, payment_mode, spill_agents,
                                     warm=spill_warm)
        if spill_res is not None:
            out[SPILL_HUB] = spill_res
    return out


def _spill_seed(results, blocks, a_idx, residual, n_spill
                ) -> np.ndarray | None:
    """Warm-start seed for the spill market from the donor hubs' duals.

    The spill market sells each agent's ``min(residual, n_spill)`` leftover
    capacity units.  A first-round dense solve left per-agent ascending
    unit-price vectors behind (``solver_stats["agent_prices"]``); an
    agent's cheapest units are the unsold ones — the very goods on sale
    here — so they are a near-equilibrium seed for the residual market.
    Agents with no first-round dual state (e.g. members of a hub that
    received no requests this batch) seed at 0, the free-unit boundary
    price.  Returns None when no donor duals exist at all (exact backends
    without persistent duals).
    """
    per_agent: dict[int, np.ndarray] = {}
    for h, (_br, ba) in blocks.items():
        stats = results[h].solver_stats
        if "agent_prices" not in stats:
            continue
        for li, gi in enumerate(ba):
            per_agent[gi] = np.asarray(stats["agent_prices"][li],
                                       dtype=np.float64)
    if not per_agent:
        return None
    segs = []
    for gi in a_idx:
        k = min(int(residual[gi]), n_spill)
        seg = np.zeros(k)
        prev = per_agent.get(gi)
        if prev is not None and k:
            take = min(k, len(prev))
            seg[:take] = prev[:take]
        segs.append(seg)
    return np.concatenate(segs) if segs else None


def _spill_round(values, costs, caps, blocks, results, backend,
                 payment_mode, spill_agents=None, warm: bool = True
                 ) -> AuctionResult | None:
    """One cross-hub re-auction of first-round losers over residual slots.

    Gathers every request its hub left unmatched, computes each agent's
    residual capacity after the first round, and runs ONE more auction
    (same backend) over that global residual market.  Welfare can only
    increase: first-round matches are untouched and residual capacity was,
    by construction, going unused.  With ``warm=True`` and a warm-capable
    backend the solve is seeded from the donor hubs' duals (`_spill_seed`);
    the budgeted warm attempt falls back to a cold solve transparently, so
    the result is identical within the solver's certificate either way.
    Returns None when there is nothing to re-auction (no losers, no slack,
    or no positive cross-hub edge).
    """
    r_idx: list[int] = []
    used: dict[int, int] = {}
    for h in sorted(blocks):
        br, ba = blocks[h]
        res = results[h]
        for lj, j in enumerate(br):
            li = res.assignment[lj]
            if li < 0:
                r_idx.append(j)
            else:
                used[ba[li]] = used.get(ba[li], 0) + 1
    universe = spill_agents if spill_agents is not None else \
        {i for h in blocks for i in blocks[h][1]}
    a_idx = sorted(i for i in set(universe)
                   if caps[i] - used.get(i, 0) > 0)
    if not r_idx or not a_idx:
        return None
    w = _prune(values[np.ix_(r_idx, a_idx)], costs[np.ix_(r_idx, a_idx)])
    if float(w.max(initial=0.0)) <= 0.0:
        return None
    residual = {i: caps[i] - used.get(i, 0) for i in a_idx}
    seed = None
    if warm and backend.supports_warm_start:
        seed = _spill_seed(results, blocks, a_idx, residual, len(r_idx))
    res = backend.solve(w, costs[np.ix_(r_idx, a_idx)],
                        [residual[i] for i in a_idx],
                        payment_mode=payment_mode, start_prices=seed)
    res.solver_stats["spill"] = {
        "r_idx": r_idx, "a_idx": a_idx,
        "candidates": len(r_idx),
        "rescued": sum(1 for a in res.assignment if a >= 0),
        "warm_started": seed is not None,
    }
    return res


def client_utilities(result: AuctionResult, true_values: np.ndarray) -> np.ndarray:
    """u_j = v_j(true) - p_j for matched requests (0 otherwise)."""
    n = len(result.assignment)
    u = np.zeros(n)
    for j, i in enumerate(result.assignment):
        if i >= 0:
            u[j] = float(true_values[j, i]) - result.payments[j]
    return u
