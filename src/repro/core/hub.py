"""Proxy-hub architecture (§4.4): a-priori agent clustering + coarse routing.

Agents are clustered on static capability signals (domain specialization,
model scale); requests are routed to a hub with a lightweight domain
classifier; the fine-grained IEMAS auction then runs inside the hub only.
This bounds the MCMF problem size (Fig. 6) and reduces the agent
heterogeneity that drives Green-Laffont IR violations (Appendix B.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import zlib

import numpy as np


@dataclass
class Hub:
    hub_id: int
    agent_indices: list
    domains: tuple = ()

    # periodically published, privacy-preserving metadata (§4.4)
    published: dict = field(default_factory=dict)

    def publish(self, *, price_signal: float, free_capacity: int,
                cache_sessions: int) -> None:
        self.published = {
            "price_signal": price_signal,
            "free_capacity": free_capacity,
            "cache_sessions": cache_sessions,
        }


def cluster_agents(agent_domains: list, agent_scales: list, k: int,
                   scheme: str = "domain", seed: int = 0) -> list[Hub]:
    """Partition agents into k hubs.

    schemes: ``domain`` (group by primary specialization — the paper's
    choice), ``scale`` (by model-size quantiles), ``random``.
    """
    m = len(agent_domains)
    k = max(1, min(k, m))
    rng = np.random.default_rng(seed)
    if scheme == "random":
        perm = rng.permutation(m)
        parts = np.array_split(perm, k)
        return [Hub(h, sorted(int(i) for i in p)) for h, p in enumerate(parts)]
    if scheme == "scale":
        order = np.argsort(np.asarray(agent_scales, dtype=float))
        parts = np.array_split(order, k)
        return [Hub(h, sorted(int(i) for i in p)) for h, p in enumerate(parts)]
    # domain scheme: hash primary domain into k buckets, then balance
    buckets: dict[int, list] = {h: [] for h in range(k)}
    domains_of: dict[int, set] = {h: set() for h in range(k)}
    order = sorted(range(m), key=lambda i: (agent_domains[i][0] if agent_domains[i] else "", i))
    for i in order:
        primary = agent_domains[i][0] if agent_domains[i] else ""
        h = zlib.crc32(primary.encode()) % k
        # balance: spill to the smallest bucket when 2x over average
        if len(buckets[h]) >= 2 * max(1, m // k):
            h = min(buckets, key=lambda b: len(buckets[b]))
        buckets[h].append(i)
        domains_of[h].update(agent_domains[i])
    hubs = [Hub(h, sorted(buckets[h]), tuple(sorted(domains_of[h])))
            for h in range(k) if buckets[h]]
    return hubs


def route_to_hub(request_domain: str, hubs: list[Hub],
                 agent_domains: list) -> int:
    """Coarse-grained classifier: pick the hub with the best domain overlap;
    ties broken by published free capacity then hub size."""
    best, best_score = 0, -1.0
    for idx, hub in enumerate(hubs):
        match = sum(1 for i in hub.agent_indices
                    if request_domain in agent_domains[i])
        score = match / max(1, len(hub.agent_indices))
        cap = hub.published.get("free_capacity", 0)
        score += 1e-3 * cap + 1e-6 * len(hub.agent_indices)
        if score > best_score:
            best, best_score = idx, score
    return best
