"""Proxy-hub architecture (§4.4): a-priori agent clustering + coarse routing.

Agents are clustered on static capability signals (domain specialization,
model scale); requests are routed to a hub with a lightweight domain
classifier; the fine-grained IEMAS auction then runs inside the hub only.
This bounds the MCMF problem size (Fig. 6) and reduces the agent
heterogeneity that drives Green-Laffont IR violations (Appendix B.1).

Clustering signals
------------------
``cluster_agents`` partitions on *static, published* metadata only — an
agent's primary domain tag (the paper's choice), its model scale, or
nothing (random control).  Nothing per-request enters the partition, so
hubs are stable across batches; that stability is what makes cross-round
slot-price warm starts (``SlotPriceBook``) sound.

Hub routing contract
--------------------
``route_to_hub`` is the coarse classifier in front of the per-hub auction:
every request lands in EXACTLY ONE hub, chosen by domain overlap with the
hub's members, with published free capacity and hub size as tie-breakers.
The fine-grained Phase-2 matching then sees only that hub's block of the
(requests × agents) welfare matrix, and the hub blocks are disjoint — so
per-hub auctions compose into a global matching with no slot double-spend
(the splice is exact; only cross-hub edges are forfeited, which is the
measured welfare-vs-speedup trade of Fig. 6 / `benchmarks/hub_sharding.py`).

Worked example
--------------
>>> from repro.core.hub import cluster_agents, route_to_hub
>>> domains = [("code",), ("code",), ("math",), ("math",)]
>>> hubs = cluster_agents(domains, [7.0, 4.0, 7.0, 4.0], k=2)
>>> sorted(sorted(h.agent_indices) for h in hubs)
[[0, 1], [2, 3]]
>>> hubs[route_to_hub("math", hubs, domains)].domains
('math',)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import zlib

import numpy as np


@dataclass
class Hub:
    """One proxy hub: a stable subset of agents plus published metadata."""

    hub_id: int
    agent_indices: list[int]
    domains: tuple[str, ...] = ()

    # periodically published, privacy-preserving metadata (§4.4)
    published: dict[str, float] = field(default_factory=dict)

    def publish(self, *, price_signal: float, free_capacity: int,
                cache_sessions: int) -> None:
        """Refresh the hub's published summary (price/capacity/cache)."""
        self.published = {
            "price_signal": price_signal,
            "free_capacity": free_capacity,
            "cache_sessions": cache_sessions,
        }


def cluster_agents(agent_domains: list[tuple[str, ...]],
                   agent_scales: list[float], k: int,
                   scheme: str = "domain", seed: int = 0) -> list[Hub]:
    """Partition agents into k hubs.

    schemes: ``domain`` (group by primary specialization — the paper's
    choice), ``scale`` (by model-size quantiles), ``random``.
    """
    m = len(agent_domains)
    k = max(1, min(k, m))
    rng = np.random.default_rng(seed)
    if scheme == "random":
        perm = rng.permutation(m)
        parts = np.array_split(perm, k)
        return [Hub(h, sorted(int(i) for i in p)) for h, p in enumerate(parts)]
    if scheme == "scale":
        order = np.argsort(np.asarray(agent_scales, dtype=float))
        parts = np.array_split(order, k)
        return [Hub(h, sorted(int(i) for i in p)) for h, p in enumerate(parts)]
    # domain scheme: hash primary domain into k buckets, then balance
    buckets: dict[int, list[int]] = {h: [] for h in range(k)}
    domains_of: dict[int, set[str]] = {h: set() for h in range(k)}
    order = sorted(range(m), key=lambda i: (agent_domains[i][0] if agent_domains[i] else "", i))
    for i in order:
        primary = agent_domains[i][0] if agent_domains[i] else ""
        h = zlib.crc32(primary.encode()) % k
        # balance: spill to the smallest bucket when 2x over average
        if len(buckets[h]) >= 2 * max(1, m // k):
            h = min(buckets, key=lambda b: len(buckets[b]))
        buckets[h].append(i)
        domains_of[h].update(agent_domains[i])
    hubs = [Hub(h, sorted(buckets[h]), tuple(sorted(domains_of[h])))
            for h in range(k) if buckets[h]]
    return hubs


def route_to_hub(request_domain: str, hubs: list[Hub],
                 agent_domains: list[tuple[str, ...]]) -> int:
    """Coarse-grained classifier: pick the hub with the best domain overlap;
    ties broken by published free capacity then hub size."""
    best, best_score = 0, -1.0
    for idx, hub in enumerate(hubs):
        match = sum(1 for i in hub.agent_indices
                    if request_domain in agent_domains[i])
        score = match / max(1, len(hub.agent_indices))
        cap = hub.published.get("free_capacity", 0)
        score += 1e-3 * cap + 1e-6 * len(hub.agent_indices)
        if score > best_score:
            best, best_score = idx, score
    return best


class SlotPriceBook:
    """Cross-round warm-start state: each hub's final unit-price duals.

    The dense ε-scaling auction's duals (one price per capacity unit,
    ascending per agent) from round t are a near-equilibrium seed for round
    t+1 — the serving loop re-auctions statistically overlapping request
    sets.  Prices are stored *per agent* (an agent's units are
    interchangeable), so the book can re-assemble a seed for the next
    round's column layout even when per-agent free capacity or the batch
    size changed; units that did not exist last round seed at price 0,
    which is exactly the free-unit (λ = 0) boundary condition the solver
    maintains anyway.  Because each stored vector is ascending, truncating
    to a smaller unit count keeps exactly the CHEAPEST units — the ones a
    shrunken market still sells.

    Safety contract: a stored entry is only replayed when the elastic
    agent-set version (bumped by the router on every membership or hub
    rebuild — `repro.distributed.elastic.AgentSetVersion`), the hub's exact
    live-agent tuple, AND the agents' published capacities all match.  Any
    mismatch — an agent joined, left, was quarantined, hubs were recut, or
    an agent's capacity b_i changed — is a cold start; warm-starting across
    a changed unit layout would seed prices onto the wrong goods (and a
    capacity change moves the equilibrium price of every unit the agent
    sells, so the old splits are stale even at matching membership).
    """

    def __init__(self) -> None:
        # hub_id -> (agent-set version, live agent ids, published
        #            capacities, per-agent ascending unit prices)
        self._book: dict[int, tuple[int, tuple[str, ...], tuple[int, ...],
                                    dict[str, np.ndarray]]] = {}
        self.warm_hits = 0
        self.cold_starts = 0
        self.stores = 0

    def lookup(self, hub_id: int, version: int, agent_ids: tuple[str, ...],
               caps: list[int], unit_counts: list[int]) -> np.ndarray | None:
        """Seed prices for this round's column layout, or None (cold start).

        ``caps[i]`` is agent ``agent_ids[i]``'s published capacity (the
        layout key — a capacity change invalidates the entry) and
        ``unit_counts[i]`` the number of units it exposes this round
        (``min(free capacity, batch size)`` — the
        `repro.core.solvers.dense_common.column_counts` layout, agents
        contiguous in ``agent_ids`` order).
        """
        entry = self._book.get(hub_id)
        if entry is None or entry[0] != version \
                or entry[1] != tuple(agent_ids) \
                or entry[2] != tuple(int(c) for c in caps):
            self.cold_starts += 1
            return None
        per_agent = entry[3]
        segs = []
        for aid, count in zip(agent_ids, unit_counts):
            seg = np.zeros(int(count))
            prev = per_agent.get(aid)
            if prev is not None and count:
                take = min(int(count), len(prev))
                seg[:take] = prev[:take]    # ascending: cheapest units first
            segs.append(seg)
        self.warm_hits += 1
        return np.concatenate(segs) if segs else np.zeros(0)

    def store(self, hub_id: int, version: int, agent_ids: tuple[str, ...],
              caps: list[int], agent_prices) -> None:
        """Record a solve's final duals (``agent_prices[i]`` is agent i's
        ascending unit-price vector), keyed by the published capacities."""
        per_agent = {aid: np.sort(np.asarray(p, dtype=np.float64))
                     for aid, p in zip(agent_ids, agent_prices)}
        self._book[hub_id] = (version, tuple(agent_ids),
                              tuple(int(c) for c in caps), per_agent)
        self.stores += 1

    def posted_asks(self, hub_id: int, version: int,
                    agent_ids: tuple[str, ...], caps: list[int]
                    ) -> dict[str, np.ndarray] | None:
        """Standing per-agent ascending unit duals for incremental bidding.

        A mid-window arrival bids against these posted prices directly (its
        k-th provisional unit at agent i costs ``asks[aid][k]``).  Returns
        None when no fresh entry exists — same staleness contract as
        `lookup`, without consuming a warm-hit/cold-start counter (posted
        asks are read many times per window).
        """
        entry = self._book.get(hub_id)
        if entry is None or entry[0] != version \
                or entry[1] != tuple(agent_ids) \
                or entry[2] != tuple(int(c) for c in caps):
            return None
        return entry[3]

    def invalidate(self, hub_id: int | None = None) -> None:
        """Drop one hub's entry, or the whole book (hub_id=None)."""
        if hub_id is None:
            self._book.clear()
        else:
            self._book.pop(hub_id, None)

    def stats(self) -> dict[str, int]:
        """Warm-start effectiveness counters for telemetry/benchmarks."""
        return {"warm_hits": self.warm_hits, "cold_starts": self.cold_starts,
                "stores": self.stores, "hubs_tracked": len(self._book)}


# ---------------------------------------------------------------------------
# Super-hub layer (hubs-of-hubs federation)
# ---------------------------------------------------------------------------
# One level up from proxy hubs: S super-hubs each own a SHARD of the fleet —
# their own IEMASRouter (which re-clusters its members into inner proxy
# hubs), their own SlotPriceBook, and their own independently-advancing
# event heap (`repro.serving.simulator.ShardEventLoop`).  Between
# synchronization epochs the shards never communicate; at each epoch
# boundary they exchange `GossipDigest`s (per-agent posted asks + slack,
# epoch-stamped so staleness is measurable) and the federation re-auctions
# stuck residual dialogues against the gossiped remote capacity
# (`repro.serving.federation.FederatedSimulator`).


@dataclass
class SuperHub(Hub):
    """One federation shard's membership: a stable super-set of hubs.

    Subclasses `Hub` so the same coarse domain-overlap router
    (`route_to_hub`) assigns a dialogue its HOME super-hub; the
    fine-grained structure below (the shard's inner proxy hubs) is the
    shard router's own business.  ``agent_indices`` index the GLOBAL
    profile list, which is what keeps federated agent ids/prices/engine
    seeds identical to the single-heap fleet.
    """

    n_inner_hubs: int = 1


def cluster_super_hubs(agent_domains: list[tuple[str, ...]],
                       agent_scales: list[float], s: int,
                       scheme: str = "domain", seed: int = 0,
                       agents_per_hub: int = 16) -> list[SuperHub]:
    """Partition the global fleet into ``s`` super-hubs.

    Reuses `cluster_agents` (same static published-metadata-only signals,
    same balance rule) one level up, then sizes each shard's inner hub
    count from ``agents_per_hub`` — so an S-way federation of K-hub
    shards covers the same fleet the single-heap router would cut into
    S*K hubs.
    """
    hubs = cluster_agents(agent_domains, agent_scales, s,
                          scheme=scheme, seed=seed)
    # renumber positionally: `cluster_agents` may skip empty bucket ids,
    # but the federation keys shard lists / seeds / request-id prefixes on
    # LIST POSITION (which is also what route_to_hub returns)
    return [SuperHub(pos, h.agent_indices, h.domains,
                     n_inner_hubs=max(1, len(h.agent_indices)
                                      // max(1, agents_per_hub)))
            for pos, h in enumerate(hubs)]


def route_to_super_hub(request_domain: str, super_hubs: list[SuperHub],
                       agent_domains: list[tuple[str, ...]]) -> int:
    """Home-shard assignment for an arriving dialogue.

    Same coarse classifier as `route_to_hub` (domain overlap, published
    free capacity and size as tie-breakers) — a dialogue's whole lifetime
    anchors to this shard unless a cross-super-hub spill migrates it.
    """
    return route_to_hub(request_domain, super_hubs, agent_domains)


@dataclass
class AgentAsk:
    """One agent's gossiped market summary (published metadata only).

    Everything a REMOTE federation shard may legitimately see: the
    published profile (prices, capacity, domains, scale), current free
    slack, a utilization signal, the predictor's generation-length EWMA
    (needed for the Eq.-6 structural cost prior) and the standing
    ascending unit asks from the shard's `SlotPriceBook` (empty = cold
    book, i.e. price-0 free-unit boundary — the same capacity-keyed
    cold-start rule `lookup` applies locally).  No tree state, no
    observation history: remote valuation runs on the structural
    cold-start prior alone.
    """

    agent_id: str
    free: int
    capacity: int
    price_miss: float
    price_hit: float
    price_out: float
    scale: float
    domains: tuple[str, ...]
    utilization: float
    ewma_gen: float
    asks: np.ndarray   # ascending standing unit duals (may be empty)


@dataclass
class GossipDigest:
    """One shard's epoch-stamped gossip payload: its agents' `AgentAsk`s.

    ``epoch`` is the synchronization-epoch index at whose boundary the
    digest was cut; a reader measures staleness as ``reader_epoch -
    digest.epoch`` (the federation smoke gate bounds this by one).
    """

    super_id: int
    epoch: int
    asks: list[AgentAsk] = field(default_factory=list)

    def total_slack(self) -> int:
        """Summed free capacity across the shard's live agents."""
        return int(sum(a.free for a in self.asks))


class GossipBook:
    """The federation's view of every shard's last digest + staleness.

    A tiny version-tracking store: `publish` overwrites a shard's entry,
    `fresh` returns the digests visible to a reader at ``epoch``
    (excluding the reader's own shard), and staleness telemetry records
    the max/mean age actually *consumed* by spill valuation — the
    number the CI gate bounds, not the worst age that merely sat unread.
    """

    def __init__(self) -> None:
        self._digests: dict[int, GossipDigest] = {}
        self.max_staleness = 0
        self._staleness_sum = 0
        self._staleness_n = 0

    def publish(self, digest: GossipDigest) -> None:
        """Record (overwrite) one shard's latest digest."""
        self._digests[digest.super_id] = digest

    def fresh(self, reader_super_id: int, epoch: int) -> list[GossipDigest]:
        """Remote digests visible to ``reader_super_id`` at ``epoch``,
        recording the staleness of each digest consumed."""
        out = []
        for sid, d in sorted(self._digests.items()):
            if sid == reader_super_id:
                continue
            age = max(0, int(epoch) - d.epoch)
            self.max_staleness = max(self.max_staleness, age)
            self._staleness_sum += age
            self._staleness_n += 1
            out.append(d)
        return out

    def stats(self) -> dict[str, float]:
        """Staleness telemetry for the federation report/smoke gates."""
        return {
            "digests": len(self._digests),
            "max_staleness_epochs": self.max_staleness,
            "mean_staleness_epochs": (
                self._staleness_sum / self._staleness_n
                if self._staleness_n else 0.0),
        }
