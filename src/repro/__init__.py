"""repro — IEMAS (Incentive-Efficiency Mechanism for Multi-Agent Systems) on JAX.

A production-grade reproduction + extension of:
  "IEMAS: An Incentive-Efficiency Routing Framework for Open Agentic Web
   Ecosystems" (CS.NI 2026).

Public API highlights:
  repro.configs.get_config(arch_id)     -- the 10 assigned architecture configs
  repro.models.build_model(cfg)         -- JAX model (init / loss / prefill / decode)
  repro.core.IEMASRouter                -- the paper's Algorithm 1
  repro.serving.SimCluster              -- simulated heterogeneous agent cluster
  repro.launch.mesh.make_production_mesh
"""

__version__ = "0.1.0"
