"""Gradient compression with error feedback (cross-pod DP traffic reduction).

Int8 block quantization: per-block scale = max|g|/127, with the quantization
residual fed back into the next step's gradient (error feedback), which is
what keeps convergence intact (tests/test_compress.py shows loss parity).

On a real multi-pod mesh this pairs the math with int8 reduce-scatter over
the ``pod`` axis (4x wire-byte reduction on the slowest links — quantified
against the dry-run collective bytes in EXPERIMENTS.md §Perf). The lowered
train step applies the transform to the pod-axis gradient contributions.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = True
    block: int = 256
    bits: int = 8


def _quant_dequant(g: jnp.ndarray, cfg: CompressionConfig) -> jnp.ndarray:
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % cfg.block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, cfg.block)
    qmax = 2.0 ** (cfg.bits - 1) - 1
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -qmax, qmax)
    deq = (q * scale).reshape(-1)[: g.size].reshape(g.shape)
    return deq


def init_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, feedback, cfg: CompressionConfig):
    """Returns (compressed grads, new feedback residuals)."""
    if not cfg.enabled:
        return grads, feedback

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        deq = _quant_dequant(g32, cfg)
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(feedback)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
