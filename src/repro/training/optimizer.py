"""AdamW in pure JAX (no optax): fp32 master weights + moments, ZeRO-ready.

Optimizer state mirrors the parameter pytree, so the same logical-axis
shardings apply leaf-by-leaf — sharding the master/m/v over ``data`` (FSDP)
gives ZeRO-3 semantics with zero extra code.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_global_norm


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    # jnp.array copies: master must never alias params (donation safety
    # when the model dtype is already float32)
    f32 = lambda p: jnp.array(p, dtype=jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state, cfg: OptConfig):
    """Returns (new_params (model dtype), new_opt_state, stats)."""
    step = opt_state["step"]
    lr = lr_schedule(cfg, step)
    gnorm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1.0)
    bc2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), new_master, params)
    new_state = {"master": new_master, "m": new_m, "v": new_v,
                 "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
