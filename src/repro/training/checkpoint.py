"""Sharded, atomic checkpointing with manifest + resume (no orbax offline).

Layout:  <dir>/step_<N>/
             manifest.json      tree structure, shapes, dtypes, metadata
             leaf_00000.npy ... one file per pytree leaf

Writes go to ``<dir>/.tmp_step_<N>`` then os.replace() — a crashed save can
never shadow a complete one (tested by killing mid-save in tests).
On multi-host deployments each process writes its addressable shards under
``proc_<k>/`` with the same manifest (single-process path exercised here).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, metadata: dict | None = None):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f".tmp_step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"path": p, "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (shapes validated)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    _, leaves, treedef = _flatten_with_paths(like_tree)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError("checkpoint structure mismatch")
    new_leaves = []
    for leaf, entry in zip(leaves, manifest["leaves"]):
        arr = np.load(os.path.join(path, entry["file"]))
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {entry['path']}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        new_leaves.append(arr.astype(entry["dtype"]))
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return tree, manifest["metadata"], manifest["step"]
