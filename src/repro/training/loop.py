"""Training loop: grad accumulation, compression, checkpoint/restart.

``make_train_step`` builds the jit-able step the dry-run lowers for every
``train_4k`` cell; ``train_loop`` adds the fault-tolerance shell (periodic
atomic checkpoints, resume-from-latest, optional injected crash for tests).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.models.scan_config import layer_scan
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.compress import (CompressionConfig, compress_with_feedback,
                                     init_feedback)
from repro.training.optimizer import OptConfig, adamw_init, adamw_update


def make_train_step(model, opt_cfg: OptConfig,
                    compression: CompressionConfig | None = None,
                    accum_steps: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With accum_steps > 1, the batch's leading axis is split into microbatches
    scanned sequentially (activation memory / accum trade — a §Perf knob).
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + l, jax.tree.map(jnp.add, g_acc, g)), None

        micro_batch = jax.tree.map(
            lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                *x.shape[1:]), batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        (loss, grads), _ = layer_scan(micro, (0.0, zero), micro_batch)
        scale = 1.0 / accum_steps
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if compression is not None and compression.enabled:
            grads, fb = compress_with_feedback(grads, opt_state["feedback"],
                                               compression)
        new_params, new_opt, stats = adamw_update(params, grads,
                                                  opt_state["adam"], opt_cfg)
        out_state = {"adam": new_opt}
        if compression is not None and compression.enabled:
            out_state["feedback"] = fb
        elif "feedback" in opt_state:
            out_state["feedback"] = opt_state["feedback"]
        return new_params, out_state, {"loss": loss, **stats}

    return train_step


def init_opt_state(params, compression: CompressionConfig | None = None):
    state = {"adam": adamw_init(params)}
    if compression is not None and compression.enabled:
        state["feedback"] = init_feedback(params)
    return state


def train_loop(model, data, *, steps: int, opt_cfg: OptConfig | None = None,
               compression: CompressionConfig | None = None,
               accum_steps: int = 1, ckpt_dir: str | None = None,
               ckpt_every: int = 50, resume: bool = True, seed: int = 0,
               crash_at_step: int | None = None, log_every: int = 10,
               donate: bool = True) -> dict:
    """Run (or resume) training; returns {losses, final_step, params...}.

    ``crash_at_step`` raises after that step's checkpoint window — used by
    tests to prove bitwise-identical resume.
    """
    opt_cfg = opt_cfg or OptConfig(total_steps=steps)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params, compression)
    start = 0
    if ckpt_dir and resume:
        last = latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), _, start = restore_checkpoint(
                ckpt_dir, last, (params, opt_state))
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)

    step_fn = make_train_step(model, opt_cfg, compression, accum_steps)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
    losses = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            losses.append((step, float(metrics["loss"])))
        if ckpt_dir and ((step + 1) % ckpt_every == 0 or step == steps - 1):
            save_checkpoint(ckpt_dir, step + 1, (params, opt_state),
                            {"loss": float(metrics["loss"])})
        if crash_at_step is not None and step + 1 >= crash_at_step:
            raise RuntimeError(f"injected crash after step {step + 1}")
    return {"losses": losses, "final_step": steps, "params": params,
            "opt_state": opt_state,
            "wall_s": time.perf_counter() - t0}
