from repro.training.optimizer import OptConfig, adamw_init, adamw_update
from repro.training.data import SyntheticLM
from repro.training.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.training.compress import CompressionConfig, compress_with_feedback
from repro.training.loop import make_train_step, train_loop
