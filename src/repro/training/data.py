"""Deterministic synthetic LM data pipeline.

``SyntheticLM`` draws token streams from a fixed random bigram transition
table with epsilon-noise — learnable structure (a small model's loss drops
well below the unigram entropy) while being fully reproducible from (seed,
step) with no files. Batches are produced per step index, so fault-tolerant
resume re-generates the exact same stream (tested in tests/test_training.py).
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, noise: float = 0.1):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.noise = noise
        rng = np.random.default_rng(seed)
        self.table = rng.integers(0, vocab_size, size=vocab_size)  # bigram map

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + step)
        toks = np.empty((self.batch, self.seq), np.int32)
        cur = rng.integers(0, self.vocab, size=self.batch)
        for t in range(self.seq):
            toks[:, t] = cur
            nxt = self.table[cur]
            flip = rng.random(self.batch) < self.noise
            nxt = np.where(flip, rng.integers(0, self.vocab, self.batch), nxt)
            cur = nxt
        return {"tokens": toks}
