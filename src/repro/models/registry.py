"""Model registry: config -> Model, plus dry-run input specs per shape cell."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


class Model(NamedTuple):
    config: ModelConfig
    init: Callable
    param_axes: Callable
    loss: Callable          # (params, batch) -> scalar
    prefill: Callable       # (params, batch) -> (logits [B,V], cache)
    decode_step: Callable   # (params, cache, tokens [B]) -> (logits, cache)
    extend: Callable        # (params, cache, tokens [B,Sn], lens_new) -> ...
    init_cache: Callable    # (b, max_len) -> cache pytree
    family: str
    extras: dict


def build_model(cfg: ModelConfig) -> Model:
    if cfg.is_encdec:
        from repro.models.encdec import build_encdec
        fns = build_encdec(cfg)
    else:
        from repro.models.lm import build_lm
        fns = build_lm(cfg)
    extras = {k: v for k, v in fns.items()
              if k not in Model._fields and k != "family"}
    return Model(
        config=cfg,
        init=fns["init"],
        param_axes=fns["param_axes"],
        loss=fns["loss"],
        prefill=fns["prefill"],
        decode_step=fns["decode_step"],
        extend=fns["extend"],
        init_cache=fns["init_cache"],
        family=fns["family"],
        extras=extras,
    )


def _tok_spec(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.

    Conventions (DESIGN.md §4):
      * vlm: seq_len = n_patches + text tokens; patch embeddings are a
        stub input [B, P, D] float.
      * audio (enc-dec): seq_len refers to the decoder; the encoder consumes
        src_len=1024 frame embeddings [B, src, D] float.
      * decode shapes: the cache covers seq_len tokens of context; inputs are
        the cache pytree + one token per sequence (handled by the launcher
        via ``decode_state_specs``).
    """
    b = shape.global_batch
    s = shape.seq_len
    batch: dict[str, Any] = {}
    if cfg.family == "vlm":
        n_text = max(s - cfg.n_patches, 1)
        batch["tokens"] = _tok_spec((b, n_text))
        batch["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model),
                                                jnp.dtype(cfg.dtype))
    elif cfg.is_encdec:
        batch["tokens"] = _tok_spec((b, s))
        batch["frames"] = jax.ShapeDtypeStruct((b, cfg.src_len, cfg.d_model),
                                               jnp.dtype(cfg.dtype))
    else:
        batch["tokens"] = _tok_spec((b, s))
    return batch


def decode_state_specs(model: Model, shape: ShapeConfig):
    """(cache_specs, token_specs) for lowering decode_step without allocation."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    tokens = _tok_spec((b,))
    return cache, tokens


def cache_axes(model: Model):
    """Logical-axis strings mirroring init_cache's pytree (for shardings)."""
    cfg = model.config
    if cfg.is_encdec:
        return {
            "k": "layers batch cache_seq kv_heads head_dim",
            "v": "layers batch cache_seq kv_heads head_dim",
            "xk": "layers batch src_seq kv_heads head_dim",
            "xv": "layers batch src_seq kv_heads head_dim",
            "slot_pos": "batch cache_seq", "pos": "batch",
        }
    if model.family == "rwkv":
        return {"pos": "batch",
                "states": ("layers batch embed",
                           "layers batch heads head_dim state",
                           "layers batch embed")}
    if model.family == "zamba":
        from repro.models.lm import _zamba_groups
        g, per, tail = _zamba_groups(cfg)
        mamba = {"groups": ("groups layers batch conv_k inner",
                            "groups layers batch heads head_dim state")}
        if tail:
            mamba["tail"] = ("layers batch conv_k inner",
                             "layers batch heads head_dim state")
        return {
            "pos": "batch", "slot_pos": "batch cache_seq", "mamba": mamba,
            "attn_k": "groups batch cache_seq kv_heads head_dim",
            "attn_v": "groups batch cache_seq kv_heads head_dim",
        }
    # attention stacks
    from repro.models.lm import _make_stacks
    ax: dict = {"pos": "batch", "slot_pos": "batch cache_seq"}
    for i, _spec in enumerate(_make_stacks(cfg)):
        if cfg.attn_kind == "mla":
            ax[f"stack{i}"] = {"ckv": "layers batch cache_seq kv_lora",
                               "krope": "layers batch cache_seq qk_dim"}
        else:
            ax[f"stack{i}"] = {
                "k": "layers batch cache_seq kv_heads head_dim",
                "v": "layers batch cache_seq kv_heads head_dim"}
    return ax
