"""Layer-scan control for the dry-run cost methodology.

XLA's cost_analysis counts a ``while`` (lax.scan) body ONCE, ignoring trip
count (verified in EXPERIMENTS.md §Dry-run methodology). The roofline
therefore compiles unrolled L=1 / L=2 *variants* to measure exact per-layer
deltas, while the full-depth compile keeps scans (for compile time and
memory realism).

``layer_scan`` is used for every layer/group-level scan in the model zoo;
``unrolled()`` flips them to full unrolling during variant compiles. The
chunkwise WKV/SSD recurrences stay rolled even then: their per-token flops
are <1% of the layer's projection flops at the assigned dims (documented).
"""
from __future__ import annotations

import contextlib

import jax

_UNROLL = False


@contextlib.contextmanager
def unrolled():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


def layer_scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length, unroll=True if _UNROLL else 1)


def indexed_layer_loop(n: int, body, carry):
    """fori_loop over layer index with the FULL state as carry — decode-path
    cache updates stay in one buffer (in-place dynamic-update-slice) instead
    of double-buffering through scan xs/ys. Unrolls under ``unrolled()`` so
    dry-run variants get exact per-layer costs."""
    if _UNROLL:
        for l in range(n):
            carry = body(l, carry)
        return carry
    return jax.lax.fori_loop(0, n, body, carry)


def chunk_scan_checkpointed(step, init, xs, n: int, super_size: int = 16):
    """Scan over n chunk steps with sqrt-style recursive checkpointing:
    only every ``super_size``-th recurrent state is saved for backward; the
    inner segment is recomputed (jax.checkpoint). Cuts the BPTT state
    footprint by ~super_size at <1% extra flops (the recurrence is tiny next
    to the layer's projections)."""
    if n < 2 * super_size or n % super_size != 0:
        return jax.lax.scan(step, init, xs)

    n_super = n // super_size
    xs_g = jax.tree.map(
        lambda x: x.reshape(n_super, super_size, *x.shape[1:]), xs)

    @jax.checkpoint
    def super_step(state, xs_seg):
        return jax.lax.scan(step, state, xs_seg)

    final, ys = jax.lax.scan(super_step, init, xs_g)
    ys = jax.tree.map(lambda y: y.reshape(n, *y.shape[2:]), ys)
    return final, ys
