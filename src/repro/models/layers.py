"""Shared model primitives: norms, RoPE, SwiGLU, inits, losses.

No flax/haiku available — parameters are plain nested dicts of jnp arrays,
and every module is a pair of functions (init, apply). Logical sharding axes
for each parameter live in a mirror pytree of space-separated axis strings
(see repro.distributed.sharding.param_shardings).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, fan_in: int, dtype, scale: float = 1.0):
    std = scale / max(fan_in, 1) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * rms) * weight.astype(jnp.float32)).astype(x.dtype)


def group_norm_heads(x, weight, bias, n_heads: int, eps: float = 1e-5):
    """Per-head group norm over [..., n_heads*head_dim] (RWKV6 output norm)."""
    orig = x.shape
    xf = x.astype(jnp.float32).reshape(*orig[:-1], n_heads, orig[-1] // n_heads)
    mean = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(orig)
    return (xf * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------- RoPE ----------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, pos, theta: float):
    """x: [..., seq, heads, head_dim] (llama half-rotation), pos: [..., seq]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [d/2]
    angles = pos[..., None].astype(jnp.float32) * freqs  # [..., seq, d/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., : d // 2].astype(jnp.float32)
    x2 = x[..., d // 2 :].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------- SwiGLU FFN ----------------

def ffn_init(key, d_model: int, d_ff: int, dtype):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": normal_init(kg, (d_model, d_ff), d_model, dtype),
        "wu": normal_init(ku, (d_model, d_ff), d_model, dtype),
        "wd": normal_init(kd, (d_ff, d_model), d_ff, dtype),
    }


FFN_AXES = {"wg": "embed ff", "wu": "embed ff", "wd": "ff embed"}


def ffn_apply(p, x):
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["wg"]))
    h = h * jnp.einsum("...d,df->...f", x, p["wu"])
    return jnp.einsum("...f,fd->...d", h, p["wd"])


# ---------------- losses ----------------

def next_token_loss(logits, tokens, ignore: int = -100):
    """Causal LM loss: logits[:, t] predicts tokens[:, t+1]. fp32 softmax.

    The correct-class logit is picked with a one-hot contraction rather than
    take_along_axis: a vocab-sharded gather would force XLA to all-gather
    the full fp32 logits (measured in EXPERIMENTS.md §Perf); the contraction
    keeps the vocab axis sharded and reduces to a tiny [B,S] partial sum.
    """
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    valid = targets != ignore
    safe_t = jnp.where(valid, targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(safe_t, logits.shape[-1], dtype=jnp.float32)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = jnp.where(valid, lse - picked, 0.0)
    denom = jnp.maximum(valid.sum(), 1)
    return nll.sum() / denom
