"""Mixture-of-Experts FFN with capacity-bounded, sort-based dispatch.

TPU adaptation (see DESIGN.md §3): instead of CUDA grouped-GEMM/ragged
dispatch, tokens are bucketed per expert with a *row-local* argsort (no
cross-device sort) and experts run as one batched einsum over [E, C, D]
buckets — MXU-friendly and exact up to capacity drops. Dropped tokens
pass through the residual stream (standard GShard semantics).

Two dispatch modes:
  * ``sort``   (default): gather-based, no one-hot matmuls, flops ~ k/E of
    the dense-all-experts lowering.
  * ``onehot``: GShard einsum dispatch, kept for comparison in §Perf.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import normal_init


def moe_init(key, cfg, dtype):
    d = cfg.d_model
    e_ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": normal_init(ks[0], (d, cfg.n_experts), d, dtype),
        "wg": normal_init(ks[1], (cfg.n_experts, d, e_ff), d, dtype),
        "wu": normal_init(ks[2], (cfg.n_experts, d, e_ff), d, dtype),
        "wd": normal_init(ks[3], (cfg.n_experts, e_ff, d), e_ff, dtype,
                          scale=1.0 / max(2 * cfg.n_layers, 1) ** 0.5),
    }
    if cfg.n_shared_experts:
        sf = cfg.n_shared_experts * e_ff
        kg, ku, kd = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": normal_init(kg, (d, sf), d, dtype),
            "wu": normal_init(ku, (d, sf), d, dtype),
            "wd": normal_init(kd, (sf, d), sf, dtype),
        }
    return p


def moe_axes(cfg):
    ax = {
        "router": "embed expert",
        "wg": "expert embed ff",
        "wu": "expert embed ff",
        "wd": "expert ff embed",
    }
    if cfg.n_shared_experts:
        ax["shared"] = {"wg": "embed ff", "wu": "embed ff", "wd": "ff embed"}
    return ax


def _capacity(s: int, k: int, e: int, cf: float) -> int:
    return max(1, int(math.ceil(s * k / e * cf)))


def _route(p, x, cfg):
    """Router: top-k normalized gates. x: [B,S,D] -> (gates, idx) [B,S,k]."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def _expert_ffn(p, xe):
    """xe: [B, E, C, D] -> [B, E, C, D]."""
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["wg"]))
    h = h * jnp.einsum("becd,edf->becf", xe, p["wu"])
    h = shard(h, "batch", "expert", "expert_capacity", "ff")
    return jnp.einsum("becf,efd->becd", h, p["wd"])


def moe_ffn_sort(p, x, cfg):
    """Gather-based dispatch, row-local capacity. x: [B, S, D]."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(s, k, e, cfg.capacity_factor)
    gates, idx = _route(p, x, cfg)  # [B,S,k]

    flat_idx = idx.reshape(b, s * k)  # expert of each (token, slot)
    flat_gate = gates.reshape(b, s * k)

    # rank of each (token,slot) within its expert, per row
    order = jnp.argsort(flat_idx, axis=-1, stable=True)  # [B, S*k]
    sorted_e = jnp.take_along_axis(flat_idx, order, axis=-1)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)  # [B,S*k,E]
    counts = onehot.sum(axis=1)  # [B,E]
    starts = jnp.cumsum(counts, axis=-1) - counts  # exclusive
    rank = jnp.arange(s * k)[None, :] - jnp.take_along_axis(starts, sorted_e, axis=-1)
    ok = rank < c
    dest = jnp.where(ok, sorted_e * c + rank, e * c)  # overflow slot

    # invert: destination bucket slot of each flat (token,slot)
    dest_of_flat = jnp.zeros((b, s * k), jnp.int32)
    dest_of_flat = jax.vmap(lambda dof, o, de: dof.at[o].set(de))(dest_of_flat, order, dest)

    token_of_sorted = order // k  # token index of each sorted slot
    # bucket -> source token (E*C + 1 with dummy overflow row)
    src = jnp.full((b, e * c + 1), s, jnp.int32)
    src = jax.vmap(lambda sr, de, to: sr.at[de].set(to))(src, dest, token_of_sorted)
    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xe = jax.vmap(lambda xp, sr: xp[sr])(x_pad, src[:, : e * c])  # [B, E*C, D]
    xe = xe.reshape(b, e, c, d)
    xe = shard(xe, "batch", "expert", "expert_capacity", "embed")

    ye = _expert_ffn(p, xe).reshape(b, e * c, d)
    ye = jnp.concatenate([ye, jnp.zeros((b, 1, d), ye.dtype)], axis=1)
    contrib = jax.vmap(lambda yp, df: yp[df])(ye, dest_of_flat)  # [B,S*k,D]
    out = (contrib.reshape(b, s, k, d)
           * flat_gate.reshape(b, s, k, 1).astype(contrib.dtype)).sum(axis=2)

    if cfg.n_shared_experts:
        sp = p["shared"]
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp["wg"]))
        h = h * jnp.einsum("bsd,df->bsf", x, sp["wu"])
        out = out + jnp.einsum("bsf,fd->bsd", h, sp["wd"])
    return out


def moe_ffn_onehot(p, x, cfg):
    """GShard einsum dispatch (comparison path for §Perf)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(s, k, e, cfg.capacity_factor)
    gates, idx = _route(p, x, cfg)

    # position-in-expert via cumulative sums over sequence, per k-slot
    out = jnp.zeros_like(x)
    dispatch = jnp.zeros((b, s, e, c), x.dtype)
    combine = jnp.zeros((b, s, e, c), jnp.float32)
    prev = jnp.zeros((b, e), jnp.int32)
    for slot in range(k):
        oh = jax.nn.one_hot(idx[:, :, slot], e, dtype=jnp.int32)  # [B,S,E]
        pos = jnp.cumsum(oh, axis=1) - 1 + prev[:, None, :]
        prev = prev + oh.sum(axis=1)
        ok = (pos < c) & (oh > 0)
        pc = jax.nn.one_hot(jnp.where(ok, pos, c), c + 1, dtype=x.dtype)[..., :c]
        dispatch = dispatch + oh.astype(x.dtype)[..., None] * pc
        combine = combine + (gates[:, :, slot][..., None, None]
                             * oh.astype(jnp.float32)[..., None] * pc.astype(jnp.float32))
    xe = jnp.einsum("bsec,bsd->becd", dispatch, x)
    ye = _expert_ffn(p, xe)
    out = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), ye)

    if cfg.n_shared_experts:
        sp = p["shared"]
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp["wg"]))
        h = h * jnp.einsum("bsd,df->bsf", x, sp["wu"])
        out = out + jnp.einsum("bsf,fd->bsd", h, sp["wd"])
    return out


def moe_ffn(p, x, cfg, mode: str = "sort"):
    return moe_ffn_sort(p, x, cfg) if mode == "sort" else moe_ffn_onehot(p, x, cfg)
