"""Decoder-only LM driver for all assigned architecture families.

Design notes
------------
* Homogeneous layers are STACKED (leading dim L) and driven by ``lax.scan``
  so compile time is O(1) in depth (DESIGN.md §6). Heterogeneous archs are a
  short list of homogeneous stacks (deepseek: 1 dense + 26 MoE) or a grouped
  structure (zamba2: 13 x [6 mamba + shared attn] + 3 mamba).
* ``extend`` is the multi-turn entry point the serving engine uses for
  KV-prefix reuse — the physical substrate of the paper's affinity o_ij.
* Training uses jax.checkpoint around each block (scan-over-layers remat).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as attn
from repro.models import blocks as blk
from repro.models.scan_config import indexed_layer_loop, layer_scan
from repro.models.layers import next_token_loss, normal_init, rms_norm


@dataclass(frozen=True)
class StackSpec:
    n_layers: int
    ffn_kind: str  # dense | moe
    d_ff: int


def _make_stacks(cfg) -> list[StackSpec]:
    if cfg.is_moe:
        nd = cfg.first_dense_layers
        stacks = []
        if nd:
            stacks.append(StackSpec(nd, "dense", cfg.dense_d_ff or cfg.d_ff))
        stacks.append(StackSpec(cfg.n_layers - nd, "moe", cfg.moe_d_ff or cfg.d_ff))
        return stacks
    return [StackSpec(cfg.n_layers, "dense", cfg.d_ff)]


def _zamba_groups(cfg):
    g = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers - g * cfg.attn_every
    return g, cfg.attn_every, tail


def build_lm(cfg):
    dtype = jnp.dtype(cfg.dtype)
    family = ("rwkv" if cfg.ssm_kind == "rwkv6"
              else "zamba" if cfg.attn_every
              else "attn")
    stacks = _make_stacks(cfg) if family == "attn" else []

    # ---------------- init ----------------
    def init(key):
        keys = jax.random.split(key, 8)
        params = {
            "embed": normal_init(keys[0], (cfg.vocab_size, cfg.d_model),
                                 cfg.d_model, dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "lm_head": normal_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                   cfg.d_model, dtype),
        }
        if family == "attn":
            import dataclasses as dc
            for i, spec in enumerate(stacks):
                sub = dc.replace(cfg, d_ff=spec.d_ff)
                lkeys = jax.random.split(jax.random.fold_in(keys[2], i), spec.n_layers)
                params[f"stack{i}"] = jax.vmap(
                    lambda k: blk.attn_block_init(k, sub, dtype, ffn_kind=spec.ffn_kind)
                )(lkeys)
        elif family == "rwkv":
            lkeys = jax.random.split(keys[2], cfg.n_layers)
            params["layers"] = jax.vmap(
                lambda k: blk.rwkv_block_init(k, cfg, dtype))(lkeys)
        else:  # zamba
            g, per, tail = _zamba_groups(cfg)
            gkeys = jax.random.split(keys[2], g * per).reshape(g, per, -1)
            params["groups"] = jax.vmap(jax.vmap(
                lambda k: blk.mamba_block_init(k, cfg, dtype)))(gkeys)
            if tail:
                tkeys = jax.random.split(keys[3], tail)
                params["tail"] = jax.vmap(
                    lambda k: blk.mamba_block_init(k, cfg, dtype))(tkeys)
            params["shared"] = blk.shared_attn_init(keys[4], cfg, dtype, g)
        return params

    def param_axes():
        ax = {"embed": "vocab embed", "final_norm": "embed",
              "lm_head": "embed vocab"}
        if family == "attn":
            import dataclasses as dc
            for i, spec in enumerate(stacks):
                sub = dc.replace(cfg, d_ff=spec.d_ff)
                ax[f"stack{i}"] = _prefix_axes(
                    blk.attn_block_axes(sub, ffn_kind=spec.ffn_kind), "layers")
        elif family == "rwkv":
            ax["layers"] = _prefix_axes(blk.rwkv_block_axes(cfg), "layers")
        else:
            ax["groups"] = _prefix_axes(_prefix_axes(blk.mamba_block_axes(cfg),
                                                     "layers"), "groups")
            g, per, tail = _zamba_groups(cfg)
            if tail:
                ax["tail"] = _prefix_axes(blk.mamba_block_axes(cfg), "layers")
            ax["shared"] = blk.shared_attn_axes(cfg)
        return ax

    # ---------------- embedding / head ----------------
    def _embed_inputs(params, batch):
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        if cfg.n_patches and "patches" in batch:
            patches = batch["patches"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        x = shard(x, "batch", "seq", "embed")
        return x

    def _head(params, x):
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"])
        return logits

    # ---------------- parallel forward (train / fresh prefill) ----------
    def forward(params, batch, *, remat: bool, collect: bool, lens=None,
                init_state=None):
        """Returns (x_final, cache_parts dict or None)."""
        x = _embed_inputs(params, batch)
        parts = {}
        if family == "attn":
            for i, spec in enumerate(stacks):
                def body(carry, p_l, _spec=spec):
                    y, kv = blk.attn_block_parallel(p_l, carry, cfg,
                                                    ffn_kind=_spec.ffn_kind,
                                                    lens=lens)
                    return y, (kv if collect else None)
                f = jax.checkpoint(body) if remat else body
                x, kvs = layer_scan(f, x, params[f"stack{i}"])
                if collect:
                    parts[f"stack{i}"] = kvs
        elif family == "rwkv":
            def body(carry, xs):
                p_l, st = xs
                y, new_st = blk.rwkv_block_parallel(p_l, carry, cfg, state=st)
                return y, (new_st if collect else None)
            b = x.shape[0]
            st0 = init_state if init_state is not None else _rwkv_zero_state(
                cfg, cfg.n_layers, b, x.dtype)
            f = jax.checkpoint(body) if remat else body
            x, sts = layer_scan(f, x, (params["layers"], st0))
            if collect:
                parts["states"] = sts
        else:  # zamba
            g, per, tail = _zamba_groups(cfg)
            b = x.shape[0]
            st = init_state if init_state is not None else _zamba_zero_state(
                cfg, b, x.dtype)

            def group_body(carry, xs):
                p_g, lora_g, st_g = xs

                def inner(c, xs2):
                    p_l, st_l = xs2
                    y, new_st = blk.mamba_block_parallel(p_l, c, cfg, state=st_l)
                    return y, (new_st if collect else None)

                y, mstates = layer_scan(inner, carry, (p_g, st_g))
                y, kv = blk.shared_attn_parallel(params["shared"], lora_g, y,
                                                 cfg, lens=lens)
                return y, ((mstates, kv) if collect else None)

            f = jax.checkpoint(group_body) if remat else group_body
            x, gouts = layer_scan(f, x, (params["groups"],
                                           params["shared"]["lora"],
                                           st["groups"]))
            if collect:
                parts["groups"] = gouts
            if tail:
                def tbody(c, xs2):
                    p_l, st_l = xs2
                    y, new_st = blk.mamba_block_parallel(p_l, c, cfg, state=st_l)
                    return y, (new_st if collect else None)
                ft = jax.checkpoint(tbody) if remat else tbody
                x, touts = layer_scan(ft, x, (params["tail"], st["tail"]))
                if collect:
                    parts["tail"] = touts
        return x, parts

    # ---------------- loss ----------------
    def loss(params, batch):
        x, _ = forward(params, batch, remat=True, collect=False)
        if cfg.n_patches:
            targets = jnp.concatenate(
                [jnp.full((batch["tokens"].shape[0], cfg.n_patches), -100,
                          batch["tokens"].dtype), batch["tokens"]], axis=1)
        else:
            targets = batch["tokens"]
        logits = _head(params, x)
        logits = shard(logits, "batch", "logit_seq", "vocab")
        return next_token_loss(logits, targets)

    # ---------------- caches ----------------
    def init_cache(b: int, max_len: int):
        m = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
        pos = jnp.zeros((b,), jnp.int32)
        if family == "attn":
            c = {"pos": pos}
            for i, spec in enumerate(stacks):
                c[f"stack{i}"] = _attn_stack_cache(cfg, spec, b, m, dtype)
            c["slot_pos"] = jnp.full((b, m), -1, jnp.int32)
            return c
        if family == "rwkv":
            return {"pos": pos,
                    "states": _rwkv_zero_state(cfg, cfg.n_layers, b, dtype)}
        g, per, tail = _zamba_groups(cfg)
        c = {"pos": pos, "slot_pos": jnp.full((b, m), -1, jnp.int32),
             "mamba": _zamba_zero_state(cfg, b, dtype),
             "attn_k": jnp.zeros((g, b, m, cfg.n_kv_heads, cfg.hd), dtype),
             "attn_v": jnp.zeros((g, b, m, cfg.n_kv_heads, cfg.hd), dtype)}
        return c

    # ---------------- fresh prefill ----------------
    def prefill(params, batch):
        """batch: tokens [B,S] (+lens [B] for right-padded attn archs).
        Returns (last-token logits [B,V], cache)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        lens = batch.get("lens", jnp.full((b,), s, jnp.int32))
        if cfg.n_patches and "patches" in batch:
            lens = lens + cfg.n_patches
            s = s + cfg.n_patches
        max_len = int(batch.get("max_len", s))
        x, parts = forward(params, batch, remat=False, collect=True, lens=lens)
        x_last = jnp.take_along_axis(
            x, jnp.maximum(lens - 1, 0)[:, None, None], axis=1)[:, 0]
        logits = _head(params, x_last)
        logits = shard(logits, "batch", "vocab")

        cache = init_cache(b, max_len)
        cache["pos"] = lens
        if family == "attn":
            m = cache["slot_pos"].shape[1]
            for i, spec in enumerate(stacks):
                kvs = parts[f"stack{i}"]
                if cfg.attn_kind == "mla":
                    ckv, krope = kvs  # [L,B,S,lora], [L,B,S,rope]
                    # masked pad, not scatter (keeps seq sharding; §Perf)
                    take = lambda c_: jnp.pad(
                        c_, ((0, 0), (0, 0), (0, m - s), (0, 0)))
                    cache[f"stack{i}"]["ckv"] = take(ckv)
                    cache[f"stack{i}"]["krope"] = take(krope)
                    valid = jnp.arange(s)[None, :] < lens[:, None]
                    sp = jnp.pad(jnp.where(valid, jnp.arange(s)[None, :], -1),
                                 ((0, 0), (0, m - s)), constant_values=-1)
                    cache["slot_pos"] = sp.astype(jnp.int32)
                else:
                    k_l, v_l = kvs  # [L,B,S,Hkv,hd]
                    lay = jax.vmap(lambda kk, vv: attn.prefill_cache_layout(
                        kk, vv, lens, max_len, window=cfg.sliding_window))
                    kc, vc, sp = lay(k_l, v_l)
                    cache[f"stack{i}"]["k"] = kc
                    cache[f"stack{i}"]["v"] = vc
                    cache["slot_pos"] = sp[0]
        elif family == "rwkv":
            cache["states"] = parts["states"]
        else:
            mstates, kvs = parts["groups"]
            cache["mamba"]["groups"] = mstates
            if "tail" in parts:
                cache["mamba"]["tail"] = parts["tail"]
            k_g, v_g = kvs  # [G,B,S,Hkv,hd]
            m = cache["slot_pos"].shape[1]
            lay = jax.vmap(lambda kk, vv: attn.prefill_cache_layout(
                kk, vv, lens, max_len))
            kc, vc, sp = lay(k_g, v_g)
            cache["attn_k"], cache["attn_v"] = kc, vc
            cache["slot_pos"] = sp[0]
        return logits, cache

    # ---------------- decode step ----------------
    # Decode iterates layers with jax.lax.fori_loop carrying the FULL cache:
    # each layer's update is an in-place dynamic-update-slice on the carry,
    # so the cache is single-buffered (a scan's xs/ys would double-buffer
    # multi-GB caches; measured in EXPERIMENTS.md §Perf).
    def _slice_l(tree, l):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False),
            tree)

    def _put_l(tree, upd, l):
        return jax.tree.map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u, l, 0),
            tree, upd)

    def decode_step(params, cache, tokens):
        """tokens: [B] -> (logits [B,V], new cache)."""
        x = params["embed"][tokens]
        x = shard(x, "batch", "embed")
        pos = cache["pos"]
        new_cache = dict(cache)
        if family == "attn":
            sp_out = cache["slot_pos"]
            for i, spec in enumerate(stacks):
                st_cache = cache[f"stack{i}"]
                pstack = params[f"stack{i}"]
                keys = ("ckv", "krope") if cfg.attn_kind == "mla" else ("k", "v")

                def body(l, carry, _spec=spec, _pstack=pstack, _keys=keys):
                    y, st, sp = carry
                    p_l = _slice_l(_pstack, l)
                    cl = dict(zip(_keys, (_slice_l(st[kk], l) for kk in _keys)))
                    cl.update(slot_pos=cache["slot_pos"], pos=pos)
                    y, nc = blk.attn_block_decode(p_l, y, cl, cfg,
                                                  ffn_kind=_spec.ffn_kind)
                    st = {kk: _put_l(st[kk], nc[kk], l) for kk in _keys}
                    return (y, st, nc["slot_pos"])

                x, st_new, sp_out = indexed_layer_loop(
                    spec.n_layers, body, (x, dict(st_cache), sp_out))
                new_cache[f"stack{i}"] = st_new
            new_cache["slot_pos"] = sp_out
        elif family == "rwkv":
            def body(l, carry):
                y, states = carry
                p_l = _slice_l(params["layers"], l)
                st_l = _slice_l(states, l)
                y, new_st = blk.rwkv_block_step(p_l, y, cfg, st_l)
                return (y, _put_l(states, new_st, l))

            x, sts = indexed_layer_loop(cfg.n_layers, body,
                                        (x, cache["states"]))
            new_cache["states"] = sts
        else:  # zamba
            g, per, tail = _zamba_groups(cfg)

            def group_body(gi, carry):
                y, mst, kc, vc, sp = carry
                p_g = _slice_l(params["groups"], gi)
                lora_g = _slice_l(params["shared"]["lora"], gi)
                st_g = _slice_l(mst, gi)

                def inner(c, xs2):
                    p_l, st_l = xs2
                    z, new_st = blk.mamba_block_step(p_l, c, cfg, st_l)
                    return z, new_st

                y, mstates = layer_scan(inner, y, (p_g, st_g))
                cl = {"k": _slice_l(kc, gi), "v": _slice_l(vc, gi),
                      "slot_pos": cache["slot_pos"], "pos": pos}
                y, nc = blk.shared_attn_decode(params["shared"], lora_g, y,
                                               cl, cfg)
                return (y, _put_l(mst, mstates, gi),
                        _put_l(kc, nc["k"], gi), _put_l(vc, nc["v"], gi),
                        nc["slot_pos"])

            x, mstates, k_n, v_n, sp_n = indexed_layer_loop(
                g, group_body,
                (x, cache["mamba"]["groups"], cache["attn_k"],
                 cache["attn_v"], cache["slot_pos"]))
            new_cache["mamba"] = dict(cache["mamba"])
            new_cache["mamba"]["groups"] = mstates
            new_cache["attn_k"], new_cache["attn_v"] = k_n, v_n
            new_cache["slot_pos"] = sp_n
            if tail:
                def tbody(l, carry):
                    y, states = carry
                    p_l = _slice_l(params["tail"], l)
                    st_l = _slice_l(states, l)
                    y, new_st = blk.mamba_block_step(p_l, y, cfg, st_l)
                    return (y, _put_l(states, new_st, l))
                x, tst = indexed_layer_loop(tail, tbody,
                                            (x, cache["mamba"]["tail"]))
                new_cache["mamba"]["tail"] = tst
        new_cache["pos"] = pos + 1
        logits = _head(params, x)
        logits = shard(logits, "batch", "vocab")
        return logits, new_cache

    # ---------------- multi-turn extend (serving KV reuse) ----------------
    def extend(params, cache, tokens, lens_new):
        """Process a new block of tokens against an existing cache.

        tokens: [B, Sn]; lens_new: [B]. For attention archs this is chunked
        prefill over the KV cache; for recurrent archs it is a parallel run
        from the stored state (exact-extension semantics, DESIGN.md §4).
        """
        x = params["embed"][tokens]
        pos0 = cache["pos"]
        new_cache = dict(cache)
        if family == "attn":
            sp_out = cache["slot_pos"]
            for i, spec in enumerate(stacks):
                st_cache = cache[f"stack{i}"]
                if cfg.attn_kind == "mla":
                    def body(carry, xs, _spec=spec):
                        p_l, ckv_l, kr_l = xs
                        h = rms_norm(carry, p_l["ln1"], cfg.norm_eps)
                        cl = {"ckv": ckv_l, "krope": kr_l,
                              "slot_pos": cache["slot_pos"], "pos": pos0}
                        o, nc = attn.mla_extend(p_l["attn"], h, cl, cfg, lens_new)
                        y = carry + o
                        y = _block_ffn(p_l, y, cfg, _spec.ffn_kind)
                        return y, (nc["ckv"], nc["krope"], nc["slot_pos"])
                    x, (ckv_n, kr_n, sp_n) = layer_scan(
                        body, x, (params[f"stack{i}"], st_cache["ckv"],
                                  st_cache["krope"]))
                    new_cache[f"stack{i}"] = {"ckv": ckv_n, "krope": kr_n}
                    sp_out = sp_n[0]
                else:
                    def body(carry, xs, _spec=spec):
                        p_l, k_l, v_l = xs
                        h = rms_norm(carry, p_l["ln1"], cfg.norm_eps)
                        cl = {"k": k_l, "v": v_l,
                              "slot_pos": cache["slot_pos"], "pos": pos0}
                        o, nc = attn.gqa_extend(p_l["attn"], h, cl, cfg, lens_new)
                        y = carry + o
                        y = _block_ffn(p_l, y, cfg, _spec.ffn_kind)
                        return y, (nc["k"], nc["v"], nc["slot_pos"])
                    x, (k_n, v_n, sp_n) = layer_scan(
                        body, x, (params[f"stack{i}"], st_cache["k"],
                                  st_cache["v"]))
                    new_cache[f"stack{i}"] = {"k": k_n, "v": v_n}
                    sp_out = sp_n[0]
            new_cache["slot_pos"] = sp_out
        elif family == "rwkv":
            batch = {"tokens": tokens}
            x, parts = forward(params, batch, remat=False, collect=True,
                               init_state=cache["states"])
            new_cache["states"] = parts["states"]
        else:
            raise NotImplementedError(
                "zamba2 extend: use prefill from scratch (engine falls back)")
        new_cache["pos"] = pos0 + lens_new
        x_last = jnp.take_along_axis(
            x, jnp.maximum(lens_new - 1, 0)[:, None, None], axis=1)[:, 0]
        logits = _head(params, x_last)
        return logits, new_cache

    return {
        "init": init, "param_axes": param_axes, "loss": loss,
        "prefill": prefill, "decode_step": decode_step, "extend": extend,
        "init_cache": init_cache, "family": family,
    }


def _block_ffn(p_l, y, cfg, ffn_kind):
    from repro.models import moe as moe_mod
    from repro.models.layers import ffn_apply

    h = rms_norm(y, p_l["ln2"], cfg.norm_eps)
    if ffn_kind == "dense":
        return y + ffn_apply(p_l["mlp"], h)
    return y + moe_mod.moe_ffn(p_l["moe"], h, cfg)


def _attn_stack_cache(cfg, spec, b, m, dtype):
    """Per-stack KV cache arrays (leading dim = layers in the stack)."""
    l = spec.n_layers
    if cfg.attn_kind == "mla":
        return {"ckv": jnp.zeros((l, b, m, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((l, b, m, cfg.qk_rope_dim), dtype)}
    return {"k": jnp.zeros((l, b, m, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((l, b, m, cfg.n_kv_heads, cfg.hd), dtype)}


def _prefix_axes(ax, name: str):
    return jax.tree.map(lambda s: f"{name} {s}", ax)


def _rwkv_zero_state(cfg, n_layers, b, dtype):
    h, hd = cfg.ssm_heads, cfg.ssm_state
    return (jnp.zeros((n_layers, b, cfg.d_model), dtype),
            jnp.zeros((n_layers, b, h, hd, hd), jnp.float32),
            jnp.zeros((n_layers, b, cfg.d_model), dtype))


def _zamba_zero_state(cfg, b, dtype):
    g = cfg.n_layers // cfg.attn_every
    per = cfg.attn_every
    tail = cfg.n_layers - g * per
    di = 2 * cfg.d_model
    h, hd, ds = cfg.ssm_heads, (2 * cfg.d_model) // cfg.ssm_heads, cfg.ssm_state
    mk = lambda *lead: (jnp.zeros((*lead, b, 3, di), dtype),
                        jnp.zeros((*lead, b, h, hd, ds), jnp.float32))
    st = {"groups": mk(g, per)}
    if tail:
        st["tail"] = mk(tail)
    return st
