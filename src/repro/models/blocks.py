"""Transformer/SSM block assembly for every assigned architecture family."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import FFN_AXES, ffn_apply, ffn_init, normal_init, rms_norm


def _res(x):
    """Residual-stream constraint: partial sums from TP-contracted matmuls
    become reduce-scatters over the sequence (Megatron-SP) instead of full
    fp32 all-reduces — the dominant §Perf win on the train cells."""
    if x.ndim == 3:
        return shard(x, "batch", "seq", "embed")
    return shard(x, "batch", "embed")


# ---------------- dense / moe attention blocks ----------------

def attn_block_init(key, cfg, dtype, *, ffn_kind: str, d_ff: int | None = None):
    """ffn_kind: dense | moe."""
    k1, k2 = jax.random.split(key)
    if cfg.attn_kind == "mla":
        a = attn.mla_init(k1, cfg, dtype)
    else:
        a = attn.gqa_init(k1, cfg, dtype)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype), "attn": a,
         "ln2": jnp.ones((cfg.d_model,), dtype)}
    if ffn_kind == "dense":
        p["mlp"] = ffn_init(k2, cfg.d_model, d_ff or cfg.d_ff, dtype)
    else:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    return p


def attn_block_axes(cfg, *, ffn_kind: str):
    a = attn.mla_axes(cfg) if cfg.attn_kind == "mla" else attn.gqa_axes(cfg)
    ax = {"ln1": "embed", "attn": a, "ln2": "embed"}
    if ffn_kind == "dense":
        ax["mlp"] = dict(FFN_AXES)
    else:
        ax["moe"] = moe_mod.moe_axes(cfg)
    return ax


def attn_block_parallel(p, x, cfg, *, ffn_kind: str, lens=None, moe_mode="sort"):
    """Returns (x, kv) where kv are the cacheables of this layer."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        o, kv = attn.mla_parallel(p["attn"], h, cfg, lens=lens)
    else:
        o, kv = attn.gqa_parallel(p["attn"], h, cfg, lens=lens)
    x = _res(x + _res(o))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if ffn_kind == "dense":
        x = x + ffn_apply(p["mlp"], h)
    else:
        x = x + moe_mod.moe_ffn(p["moe"], h, cfg, mode=moe_mode)
    return _res(x), kv


def attn_block_decode(p, x, cache_layer, cfg, *, ffn_kind: str, moe_mode="sort"):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        o, new_cache = attn.mla_decode(p["attn"], h, cache_layer, cfg)
    else:
        o, new_cache = attn.gqa_decode(p["attn"], h, cache_layer, cfg)
    x = x + o
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if ffn_kind == "dense":
        x = x + ffn_apply(p["mlp"], h)
    else:
        x = x + moe_mod.moe_ffn(p["moe"], h[:, None, :], cfg, mode=moe_mode)[:, 0]
    return x, new_cache


# ---------------- RWKV6 block ----------------

def rwkv_block_init(key, cfg, dtype):
    k1, _ = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mix": ssm_mod.rwkv6_init(k1, cfg, dtype)}


def rwkv_block_axes(cfg):
    return {"ln1": "embed", "ln2": "embed", "mix": ssm_mod.rwkv6_axes(cfg)}


def rwkv_block_parallel(p, x, cfg, state=None):
    """state: (shift_t [B,D], wkv [B,H,hd,hd], shift_c [B,D]) or None."""
    shift_t, wkv, shift_c = state if state is not None else (None, None, None)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    o, (new_shift_t, new_wkv) = ssm_mod.rwkv6_time_mix(
        p["mix"], h, cfg, shift_state=shift_t, wkv_state=wkv, parallel=True)
    x = _res(x + _res(o))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    o, new_shift_c = ssm_mod.rwkv6_channel_mix(p["mix"], h, shift_state=shift_c,
                                               parallel=True)
    x = _res(x + o)
    return x, (new_shift_t, new_wkv, new_shift_c)


def rwkv_block_step(p, x, cfg, state):
    shift_t, wkv, shift_c = state
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    o, (new_shift_t, new_wkv) = ssm_mod.rwkv6_time_mix(
        p["mix"], h, cfg, shift_state=shift_t, wkv_state=wkv, parallel=False)
    x = x + o
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    o, new_shift_c = ssm_mod.rwkv6_channel_mix(p["mix"], h, shift_state=shift_c,
                                               parallel=False)
    x = x + o
    return x, (new_shift_t, new_wkv, new_shift_c)


# ---------------- Mamba2 block (zamba2 backbone) ----------------

def mamba_block_init(key, cfg, dtype):
    return {"ln": jnp.ones((cfg.d_model,), dtype),
            "mix": ssm_mod.mamba2_init(key, cfg, dtype)}


def mamba_block_axes(cfg):
    return {"ln": "embed", "mix": ssm_mod.mamba2_axes(cfg)}


def mamba_block_parallel(p, x, cfg, state=None):
    conv, ssm = state if state is not None else (None, None)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    o, (new_conv, new_ssm) = ssm_mod.mamba2_block(
        p["mix"], h, cfg, conv_state=conv, ssm_state=ssm, parallel=True)
    return _res(x + _res(o)), (new_conv, new_ssm)


def mamba_block_step(p, x, cfg, state):
    conv, ssm = state
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    o, (new_conv, new_ssm) = ssm_mod.mamba2_block(
        p["mix"], h, cfg, conv_state=conv, ssm_state=ssm, parallel=False)
    return x + o, (new_conv, new_ssm)


# ---------------- zamba2 shared attention block (+ per-invocation LoRA) ----

LORA_SHARED = 64


def shared_attn_init(key, cfg, dtype, n_groups: int):
    """One shared GQA+MLP block, with stacked per-invocation q/k/v LoRAs."""
    k1, k2, k3 = jax.random.split(key, 3)
    base = attn_block_init(k1, cfg, dtype, ffn_kind="dense")
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(k3, 6)
    lora = {
        "qa": normal_init(ks[0], (n_groups, d, LORA_SHARED), d, dtype),
        "qb": jnp.zeros((n_groups, LORA_SHARED, h, hd), dtype),
        "ka": normal_init(ks[1], (n_groups, d, LORA_SHARED), d, dtype),
        "kb": jnp.zeros((n_groups, LORA_SHARED, kv, hd), dtype),
        "va": normal_init(ks[2], (n_groups, d, LORA_SHARED), d, dtype),
        "vb": jnp.zeros((n_groups, LORA_SHARED, kv, hd), dtype),
    }
    return {"block": base, "lora": lora}


def shared_attn_axes(cfg):
    return {
        "block": attn_block_axes(cfg, ffn_kind="dense"),
        "lora": {
            "qa": "groups embed lora_rank", "qb": "groups lora_rank heads head_dim",
            "ka": "groups embed lora_rank", "kb": "groups lora_rank kv_heads head_dim",
            "va": "groups embed lora_rank", "vb": "groups lora_rank kv_heads head_dim",
        },
    }


def _lora_qkv_delta(lora_g, h):
    """Per-invocation low-rank q/k/v deltas. h: [..., D]."""
    dq = jnp.einsum("...r,rhk->...hk", jnp.einsum("...d,dr->...r", h, lora_g["qa"]), lora_g["qb"])
    dk = jnp.einsum("...r,rhk->...hk", jnp.einsum("...d,dr->...r", h, lora_g["ka"]), lora_g["kb"])
    dv = jnp.einsum("...r,rhk->...hk", jnp.einsum("...d,dr->...r", h, lora_g["va"]), lora_g["vb"])
    return dq, dk, dv


def shared_attn_parallel(p, lora_g, x, cfg, *, lens=None):
    from repro.models.layers import apply_rope

    blk = p["block"]
    h = rms_norm(x, blk["ln1"], cfg.norm_eps)
    q, k, v = attn._qkv(blk["attn"], h, cfg)
    dq, dk, dv = _lora_qkv_delta(lora_g, h)
    q, k, v = q + dq, k + dk, v + dv
    pos = jnp.arange(x.shape[1])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = attn.attend_parallel(q, k, v, causal=True, kv_valid_len=lens)
    x = _res(x + _res(jnp.einsum("...hk,hkd->...d", o, blk["attn"]["wo"])))
    h = rms_norm(x, blk["ln2"], cfg.norm_eps)
    x = _res(x + ffn_apply(blk["mlp"], h))
    return x, (k, v)


def shared_attn_decode(p, lora_g, x, cache_layer, cfg):
    from repro.models.layers import apply_rope

    blk = p["block"]
    pos = cache_layer["pos"]
    h = rms_norm(x, blk["ln1"], cfg.norm_eps)
    q, k, v = attn._qkv(blk["attn"], h[:, None, :], cfg)
    dq, dk, dv = _lora_qkv_delta(lora_g, h[:, None, :])
    q, k, v = q + dq, k + dk, v + dv
    q = apply_rope(q, pos[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k, pos[:, None], cfg.rope_theta)[:, 0]
    v = v[:, 0]
    kc, vc, sp = attn.cache_append(cache_layer["k"], cache_layer["v"],
                                   cache_layer["slot_pos"], k, v, pos)
    o = attn.attend_decode(q, kc, vc, sp, pos)
    x = x + jnp.einsum("bhk,hkd->bd", o, blk["attn"]["wo"])
    h = rms_norm(x, blk["ln2"], cfg.norm_eps)
    x = x + ffn_apply(blk["mlp"], h)
    return x, {"k": kc, "v": vc, "slot_pos": sp, "pos": pos + 1}
