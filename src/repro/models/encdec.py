"""Encoder-decoder LM (seamless-m4t backbone).

The speech frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings ``frames`` [B, src_len, D]. Encoder is
bidirectional; decoder is causal with cross-attention. The cross K/V are the
session-reusable state for the serving layer (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


def _res(x):
    if x.ndim == 3:
        return shard(x, "batch", "seq", "embed")
    return shard(x, "batch", "embed")
from repro.models import attention as attn
from repro.models.scan_config import indexed_layer_loop, layer_scan
from repro.models.layers import (FFN_AXES, apply_rope, ffn_apply, ffn_init,
                                 next_token_loss, normal_init, rms_norm)


def _xattn_init(key, cfg, dtype):
    return attn.gqa_init(key, cfg, dtype)


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.gqa_init(k1, cfg, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": ffn_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.gqa_init(k1, cfg, dtype),
            "lnx": jnp.ones((cfg.d_model,), dtype),
            "xattn": _xattn_init(k2, cfg, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": ffn_init(k3, cfg.d_model, cfg.d_ff, dtype)}


def _block_axes(cfg, cross: bool):
    ax = {"ln1": "embed", "attn": attn.gqa_axes(cfg), "ln2": "embed",
          "mlp": dict(FFN_AXES)}
    if cross:
        ax["lnx"] = "embed"
        ax["xattn"] = attn.gqa_axes(cfg)
    return ax


def _enc_block(p, x, cfg):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = attn._qkv(p["attn"], h, cfg)
    pos = jnp.arange(x.shape[1])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = attn.attend_parallel(q, k, v, causal=False)
    x = _res(x + _res(jnp.einsum("...hk,hkd->...d", o, p["attn"]["wo"])))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return _res(x + ffn_apply(p["mlp"], h))


def _cross_kv(p, enc_out, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wv"])
    return k, v


def _cross_attend(p, h, xk, xv, cfg):
    q = jnp.einsum("...d,dhk->...hk", h, p["xattn"]["wq"])
    if h.ndim == 2:  # decode step
        o = attn.attend_decode(q, xk, xv,
                               jnp.zeros(xk.shape[:2], jnp.int32),
                               jnp.full((h.shape[0],), xk.shape[1], jnp.int32))
    else:
        o = attn.attend_parallel(q, xk, xv, causal=False)
    return jnp.einsum("...hk,hkd->...d", o, p["xattn"]["wo"])


def _dec_block_parallel(p, x, xk, xv, cfg, lens=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    o, kv = attn.gqa_parallel(p["attn"], h, cfg, lens=lens)
    x = _res(x + _res(o))
    h = rms_norm(x, p["lnx"], cfg.norm_eps)
    x = _res(x + _res(_cross_attend(p, h, xk, xv, cfg)))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return _res(x + ffn_apply(p["mlp"], h)), kv


def _dec_block_step(p, x, cache_layer, xk, xv, cfg):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    o, nc = attn.gqa_decode(p["attn"], h, cache_layer, cfg)
    x = x + o
    h = rms_norm(x, p["lnx"], cfg.norm_eps)
    x = x + _cross_attend(p, h, xk, xv, cfg)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + ffn_apply(p["mlp"], h), nc


def build_encdec(cfg):
    dtype = jnp.dtype(cfg.dtype)

    def init(key):
        ks = jax.random.split(key, 6)
        enc_keys = jax.random.split(ks[0], cfg.enc_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": normal_init(ks[2], (cfg.vocab_size, cfg.d_model),
                                 cfg.d_model, dtype),
            "frame_proj": normal_init(ks[3], (cfg.d_model, cfg.d_model),
                                      cfg.d_model, dtype),
            "encoder": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
            "enc_norm": jnp.ones((cfg.d_model,), dtype),
            "decoder": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(dec_keys),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "lm_head": normal_init(ks[4], (cfg.d_model, cfg.vocab_size),
                                   cfg.d_model, dtype),
        }

    def param_axes():
        pre = lambda ax: jax.tree.map(lambda s: "layers " + s, ax)
        return {
            "embed": "vocab embed",
            "frame_proj": "embed embed",
            "encoder": pre(_block_axes(cfg, cross=False)),
            "enc_norm": "embed",
            "decoder": pre(_block_axes(cfg, cross=True)),
            "final_norm": "embed",
            "lm_head": "embed vocab",
        }

    def encode(params, frames, *, remat=False):
        x = jnp.einsum("bsd,de->bse", frames.astype(dtype), params["frame_proj"])
        x = shard(x, "batch", "seq", "embed")

        def body(c, p_l):
            return _enc_block(p_l, c, cfg), None
        f = jax.checkpoint(body) if remat else body
        x, _ = layer_scan(f, x, params["encoder"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _decoder_forward(params, x, enc_out, *, remat, collect, lens=None):
        def body(c, p_l):
            xk, xv = _cross_kv(p_l, enc_out, cfg)
            y, kv = _dec_block_parallel(p_l, c, xk, xv, cfg, lens=lens)
            return y, ((kv, (xk, xv)) if collect else None)
        f = jax.checkpoint(body) if remat else body
        x, parts = layer_scan(f, x, params["decoder"])
        return x, parts

    def loss(params, batch):
        enc_out = encode(params, batch["frames"], remat=True)
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        x = shard(x, "batch", "seq", "embed")
        x, _ = _decoder_forward(params, x, enc_out, remat=True, collect=False)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        logits = shard(logits, "batch", "logit_seq", "vocab")
        return next_token_loss(logits, tokens)

    def init_cache(b: int, max_len: int):
        return {
            "k": jnp.zeros((cfg.n_layers, b, max_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((cfg.n_layers, b, max_len, cfg.n_kv_heads, cfg.hd), dtype),
            "xk": jnp.zeros((cfg.n_layers, b, cfg.src_len, cfg.n_kv_heads, cfg.hd), dtype),
            "xv": jnp.zeros((cfg.n_layers, b, cfg.src_len, cfg.n_kv_heads, cfg.hd), dtype),
            "slot_pos": jnp.full((b, max_len), -1, jnp.int32),
            "pos": jnp.zeros((b,), jnp.int32),
        }

    def prefill(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        lens = batch.get("lens", jnp.full((b,), s, jnp.int32))
        max_len = int(batch.get("max_len", s))
        enc_out = encode(params, batch["frames"])
        x = params["embed"][tokens]
        x = shard(x, "batch", "seq", "embed")
        x, parts = _decoder_forward(params, x, enc_out, remat=False,
                                    collect=True, lens=lens)
        (k_l, v_l), (xk_l, xv_l) = parts
        x_last = jnp.take_along_axis(
            x, jnp.maximum(lens - 1, 0)[:, None, None], axis=1)[:, 0]
        x_last = rms_norm(x_last, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x_last, params["lm_head"])

        cache = init_cache(b, max_len)
        lay = jax.vmap(lambda kk, vv: attn.prefill_cache_layout(kk, vv, lens, max_len))
        kc, vc, sp = lay(k_l, v_l)
        cache.update(k=kc, v=vc, xk=xk_l, xv=xv_l, slot_pos=sp[0], pos=lens)
        return logits, cache

    def decode_step(params, cache, tokens):
        x = params["embed"][tokens]
        pos = cache["pos"]
        idx = lambda a, l: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False)
        put = lambda a, u, l: jax.lax.dynamic_update_index_in_dim(a, u, l, 0)

        def body(l, carry):
            y, kc, vc, sp = carry
            p_l = jax.tree.map(lambda a: idx(a, l), params["decoder"])
            cl = {"k": idx(kc, l), "v": idx(vc, l),
                  "slot_pos": cache["slot_pos"], "pos": pos}
            y, nc = _dec_block_step(p_l, y, cl, idx(cache["xk"], l),
                                    idx(cache["xv"], l), cfg)
            return (y, put(kc, nc["k"], l), put(vc, nc["v"], l),
                    nc["slot_pos"])

        x, k_n, v_n, sp_n = indexed_layer_loop(
            cfg.n_layers, body, (x, cache["k"], cache["v"], cache["slot_pos"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])
        new_cache = dict(cache)
        new_cache.update(k=k_n, v=v_n, slot_pos=sp_n, pos=pos + 1)
        return logits, new_cache

    def extend(params, cache, tokens, lens_new):
        raise NotImplementedError(
            "enc-dec extend: cross-cache is session-static; the engine "
            "re-prefills the decoder (see serving/engine.py)")

    return {"init": init, "param_axes": param_axes, "loss": loss,
            "prefill": prefill, "decode_step": decode_step, "extend": extend,
            "init_cache": init_cache, "family": "encdec", "encode": encode}
