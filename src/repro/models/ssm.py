"""Linear-recurrence token mixers: RWKV6 ("Finch") and Mamba2 (SSD).

TPU adaptation (DESIGN.md §3): the GPU reference kernels are warp-level
sequential scans. Here the parallel (train/prefill) form is *chunkwise*:
within a chunk of C=16 tokens everything is masked matmuls with RELATIVE
decays (every exponent <= 0, so no 1/w-style overflow paths anywhere), and
the state is propagated across chunks with a small lax.scan. Decode is the
exact one-step recurrence. ``repro/kernels/wkv6.py`` / ``ssd.py`` implement
the same chunk math as Pallas kernels; these jnp forms are their oracles'
twins (tests cross-check all three).

RWKV6 recurrence (per head; r,k,w,u in R^dk, v in R^dv, state S in R^{dk,dv}):
    o_t = r_t @ (S_{t-1} + (u * k_t)^T v_t)
    S_t = diag(w_t) @ S_{t-1} + k_t^T v_t,     w_t = exp(-exp(w_raw_t))

Mamba2/SSD (per head; scalar decay a_t, x_t in R^hd, B_t,C_t in R^dstate):
    S_t = a_t S_{t-1} + dt_t (x_t outer B_t)
    y_t = S_t @ C_t + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.scan_config import chunk_scan_checkpointed
from repro.models.layers import group_norm_heads, normal_init, rms_norm

CHUNK = 16
LORA_MIX = 32
LORA_DECAY = 64


def _pad_chunks(x, c: int, axis: int = 1):
    s = x.shape[axis]
    pad = (-s) % c
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, pad


# =====================================================================
# RWKV6
# =====================================================================

def rwkv6_init(key, cfg, dtype):
    d, h, hd = cfg.d_model, cfg.ssm_heads, cfg.ssm_state
    ks = jax.random.split(key, 20)
    loras = {}
    for i, name in enumerate(("r", "k", "v", "g", "w")):
        rank = LORA_DECAY if name == "w" else LORA_MIX
        loras[f"A_{name}"] = normal_init(ks[i], (d, rank), d, dtype)
        loras[f"B_{name}"] = normal_init(ks[5 + i], (rank, d), rank, dtype)
        loras[f"mu_{name}"] = jnp.zeros((d,), dtype)
    return {
        "mu_x": jnp.zeros((d,), dtype),
        **loras,
        "w0": jnp.full((d,), -0.6, dtype),  # decay ~ exp(-exp(-0.6)) ~ 0.58
        "u": normal_init(ks[10], (h, hd), hd, dtype),
        "wr": normal_init(ks[11], (d, d), d, dtype),
        "wk": normal_init(ks[12], (d, d), d, dtype),
        "wv": normal_init(ks[13], (d, d), d, dtype),
        "wgate": normal_init(ks[14], (d, d), d, dtype),
        "wo": normal_init(ks[15], (d, d), d, dtype,
                          scale=1.0 / max(2 * cfg.n_layers, 1) ** 0.5),
        "gn_w": jnp.ones((d,), dtype),
        "gn_b": jnp.zeros((d,), dtype),
        # channel mix
        "cm_mu_k": jnp.zeros((d,), dtype),
        "cm_mu_r": jnp.zeros((d,), dtype),
        "cm_k": normal_init(ks[16], (d, cfg.d_ff), d, dtype),
        "cm_v": normal_init(ks[17], (cfg.d_ff, d), cfg.d_ff, dtype),
        "cm_r": normal_init(ks[18], (d, d), d, dtype),
    }


def rwkv6_axes(cfg):
    ax = {
        "mu_x": "embed", "w0": "embed",
        "u": "heads head_dim",
        "wr": "embed inner", "wk": "embed inner", "wv": "embed inner",
        "wgate": "embed inner", "wo": "inner embed",
        "gn_w": "embed", "gn_b": "embed",
        "cm_mu_k": "embed", "cm_mu_r": "embed",
        "cm_k": "embed ff", "cm_v": "ff embed", "cm_r": "embed inner",
    }
    for name in ("r", "k", "v", "g", "w"):
        ax[f"A_{name}"] = "embed lora_rank"
        ax[f"B_{name}"] = "lora_rank embed"
        ax[f"mu_{name}"] = "embed"
    return ax


def _rwkv6_projections(p, x, xx, cfg):
    """Data-dependent token-shift mixes + projections.

    x: [..., D] current; xx: [..., D] previous token's x (shift).
    Returns r,k,v [.., H, hd], gate [.., D], log_w [.., H, hd].
    """
    h, hd = cfg.ssm_heads, cfg.ssm_state
    sx = xx - x
    xbase = x + sx * p["mu_x"]
    mixed = {}
    for name in ("r", "k", "v", "g", "w"):
        lora = jnp.einsum("...r,rd->...d", jnp.tanh(
            jnp.einsum("...d,dr->...r", xbase, p[f"A_{name}"])), p[f"B_{name}"])
        mixed[name] = x + sx * (p[f"mu_{name}"] + lora)
    r = jnp.einsum("...d,de->...e", mixed["r"], p["wr"])
    k = jnp.einsum("...d,de->...e", mixed["k"], p["wk"])
    v = jnp.einsum("...d,de->...e", mixed["v"], p["wv"])
    gate = jax.nn.silu(jnp.einsum("...d,de->...e", mixed["g"], p["wgate"]))
    w_raw = p["w0"] + jnp.einsum("...r,rd->...d", jnp.tanh(
        jnp.einsum("...d,dr->...r", mixed["w"], p[f"A_w"])), p["B_w"])
    log_w = -jnp.exp(w_raw.astype(jnp.float32))  # log of decay in (-inf, 0)
    split = lambda t: t.reshape(*t.shape[:-1], h, hd)
    return split(r), split(k), split(v), gate, split(log_w)


def wkv6_chunked(r, k, v, log_w, u, s0):
    """Chunkwise-parallel WKV6. r,k,v,log_w: [B,S,H,hd] (fp32 math),
    u: [H,hd], s0: [B,H,hd,hd] initial state. Returns (o [B,S,H,hd], sT)."""
    b, s, h, hd = r.shape
    c = CHUNK
    (r, _), (k, _), (v, _) = _pad_chunks(r, c), _pad_chunks(k, c), _pad_chunks(v, c)
    log_w, pad = _pad_chunks(log_w, c)  # padded log_w = 0 -> w = 1 (identity)
    n = r.shape[1] // c
    f32 = lambda t: t.astype(jnp.float32)
    # keep scan inputs in the model dtype (halves the saved-for-backward
    # buffers); convert to f32 inside the chunk body. log_w stays f32 for
    # decay precision.
    rc = r.reshape(b, n, c, h, hd).transpose(1, 0, 3, 2, 4)  # [n,B,H,C,hd]
    kc = k.reshape(b, n, c, h, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n, c, h, hd).transpose(1, 0, 3, 2, 4)
    lw = f32(log_w).reshape(b, n, c, h, hd).transpose(1, 0, 3, 2, 4)

    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)  # strict lower: s < t

    def chunk_step(state, inp):
        rc_, kc_, vc_, lw_ = inp  # [B,H,C,hd]
        rc_, kc_, vc_ = f32(rc_), f32(kc_), f32(vc_)
        p = jnp.cumsum(lw_, axis=2)          # inclusive  Σ_{u<=t}
        p_shift = p - lw_                    # exclusive  Σ_{u<t}
        # inter-chunk: o_t += (r_t * exp(p_shift_t)) @ S_in
        r_dec = rc_ * jnp.exp(p_shift)
        o = jnp.einsum("bhtd,bhdv->bhtv", r_dec, state)
        # intra-chunk: decay(t,s) = exp(p_shift[t] - p[s]), s < t (all <= 0)
        dec = jnp.exp(
            jnp.where(tri[None, None, :, :, None],
                      p_shift[:, :, :, None, :] - p[:, :, None, :, :], -jnp.inf))
        a = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rc_, kc_, dec)
        # bonus diagonal: r_t . (u * k_t)
        diag = jnp.einsum("bhtd,hd,bhtd->bht", rc_, u.astype(jnp.float32), kc_)
        a = a + diag[..., None] * jnp.eye(c, dtype=jnp.float32)
        o = o + jnp.einsum("bhts,bhsv->bhtv", a, vc_)
        # state update: S_out = diag(exp(p_last)) S_in + sum_s (k_s*exp(p_last-p_s))^T v_s
        p_last = p[:, :, -1:, :]             # [B,H,1,hd]
        k_dec = kc_ * jnp.exp(p_last - p)
        new_state = state * jnp.exp(p_last[:, :, 0, :])[..., None] + jnp.einsum(
            "bhsd,bhsv->bhdv", k_dec, vc_)
        return new_state, o

    sT, o = chunk_scan_checkpointed(chunk_step, f32(s0), (rc, kc, vc, lw), n)
    o = o.transpose(1, 0, 3, 2, 4).reshape(b, n * c, h, hd)
    return o[:, :s], sT


def wkv6_step(r, k, v, log_w, u, state):
    """Exact one-token recurrence. r,k,v,log_w: [B,H,hd]; state: [B,H,hd,hd]."""
    f32 = lambda t: t.astype(jnp.float32)
    r, k, v, log_w = f32(r), f32(k), f32(v), f32(log_w)
    kv = jnp.einsum("bhd,bhv->bhdv", k, v)
    o = jnp.einsum("bhd,bhdv->bhv", r, state + u.astype(jnp.float32)[None, :, :, None] * kv)
    new_state = state * jnp.exp(log_w)[..., None] + kv
    return o, new_state


def rwkv6_time_mix(p, x, cfg, *, shift_state=None, wkv_state=None, parallel=True):
    """Full time-mix block. Parallel: x [B,S,D]; step: x [B,D]."""
    h, hd = cfg.ssm_heads, cfg.ssm_state
    if parallel:
        b, s, d = x.shape
        prev = jnp.zeros((b, 1, d), x.dtype) if shift_state is None else shift_state[:, None]
        xx = jnp.concatenate([prev, x[:, :-1]], axis=1)
        r, k, v, gate, log_w = _rwkv6_projections(p, x, xx, cfg)
        s0 = (jnp.zeros((b, h, hd, hd), jnp.float32) if wkv_state is None
              else wkv_state)
        o, sT = wkv6_chunked(r, k, v, log_w, p["u"], s0)
        o = o.reshape(b, s, h * hd).astype(x.dtype)
        o = group_norm_heads(o, p["gn_w"], p["gn_b"], h)
        out = jnp.einsum("bse,ed->bsd", o * gate, p["wo"])
        return out, (x[:, -1], sT)
    else:
        b, d = x.shape
        xx = shift_state
        r, k, v, gate, log_w = _rwkv6_projections(p, x, xx, cfg)
        o, sT = wkv6_step(r, k, v, log_w, p["u"], wkv_state)
        o = o.reshape(b, h * hd).astype(x.dtype)
        o = group_norm_heads(o, p["gn_w"], p["gn_b"], h)
        out = jnp.einsum("be,ed->bd", o * gate, p["wo"])
        return out, (x, sT)


def rwkv6_channel_mix(p, x, *, shift_state=None, parallel=True):
    if parallel:
        b, s, d = x.shape
        prev = jnp.zeros((b, 1, d), x.dtype) if shift_state is None else shift_state[:, None]
        xx = jnp.concatenate([prev, x[:, :-1]], axis=1)
        new_shift = x[:, -1]
    else:
        xx = shift_state
        new_shift = x
    sx = xx - x
    xk = x + sx * p["cm_mu_k"]
    xr = x + sx * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(jnp.einsum("...d,df->...f", xk, p["cm_k"])))
    kv = jnp.einsum("...f,fd->...d", kk, p["cm_v"])
    rr = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr, p["cm_r"]))
    return rr * kv, new_shift


# =====================================================================
# Mamba2 (SSD)
# =====================================================================

def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    di = 2 * d
    ds = cfg.ssm_state
    h = cfg.ssm_heads
    ks = jax.random.split(key, 4)
    return {
        "in_proj": normal_init(ks[0], (d, 2 * di + 2 * ds + h), d, dtype),
        "conv_w": normal_init(ks[1], (4, di), 4, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),  # a = exp(-exp(A_log)*dt)
        "D": jnp.ones((h,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gn_w": jnp.ones((di,), dtype),
        "out_proj": normal_init(ks[2], (di, d), di, dtype,
                                scale=1.0 / max(2 * cfg.n_layers, 1) ** 0.5),
    }


def mamba2_axes(cfg):
    return {
        "in_proj": "embed inner", "conv_w": "conv_k inner", "conv_b": "inner",
        "A_log": "heads", "D": "heads", "dt_bias": "heads",
        "gn_w": "inner", "out_proj": "inner embed",
    }


def _mamba2_split(p, xz, cfg):
    d = cfg.d_model
    di, ds, h = 2 * d, cfg.ssm_state, cfg.ssm_heads
    z, xr, bmat, cmat, dt = jnp.split(xz, [di, 2 * di, 2 * di + ds, 2 * di + 2 * ds], axis=-1)
    return z, xr, bmat, cmat, dt


def ssd_chunked(xh, bmat, cmat, dt, a_log, d_skip, s0):
    """Chunkwise SSD. xh: [B,S,H,hd]; bmat,cmat: [B,S,ds]; dt: [B,S,H] (post-
    softplus); a_log: [H] (A_log); s0: [B,H,hd,ds]. Returns (y, sT)."""
    b, s, h, hd = xh.shape
    ds = bmat.shape[-1]
    c = CHUNK
    f32 = lambda t: t.astype(jnp.float32)
    xh, _ = _pad_chunks(xh, c)
    bmat, _ = _pad_chunks(bmat, c)
    cmat, _ = _pad_chunks(cmat, c)
    dt, _ = _pad_chunks(f32(dt), c)  # padded dt = 0 -> la = 0 (identity), contribution 0
    n = xh.shape[1] // c
    la = -jnp.exp(a_log.astype(jnp.float32))[None, None, :] * dt  # [B,S',H] log decay <= 0

    xc = xh.reshape(b, n, c, h, hd).transpose(1, 0, 3, 2, 4)     # [n,B,H,C,hd]
    dtc = dt.reshape(b, n, c, h).transpose(1, 0, 3, 2)           # [n,B,H,C]
    lac = la.reshape(b, n, c, h).transpose(1, 0, 3, 2)           # [n,B,H,C]
    bc = bmat.reshape(b, n, c, ds).transpose(1, 0, 2, 3)         # [n,B,C,ds]
    cc = cmat.reshape(b, n, c, ds).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((c, c), bool))  # inclusive: s <= t

    def chunk_step(state, inp):
        xc_, dtc_, lac_, bc_, cc_ = inp
        xc_, bc_, cc_ = f32(xc_), f32(bc_), f32(cc_)
        p = jnp.cumsum(lac_, axis=-1)  # [B,H,C] inclusive
        # intra: M[t,s] = exp(p_t - p_s) * (C_t . B_s) * dt_s, s <= t
        cb = jnp.einsum("btn,bsn->bts", cc_, bc_)  # [B,C,C]
        dec = jnp.exp(jnp.where(tri[None, None], p[:, :, :, None] - p[:, :, None, :],
                                -jnp.inf))  # [B,H,C,C]
        m = cb[:, None] * dec * dtc_[:, :, None, :]
        y = jnp.einsum("bhts,bhsd->bhtd", m, xc_)
        # inter: y_t += exp(p_t) * (S_in @ C_t)
        y = y + jnp.einsum("bhdn,btn,bht->bhtd", state, cc_, jnp.exp(p))
        # state: S_out = exp(p_last) S_in + sum_s exp(p_last - p_s) dt_s x_s (x) B_s
        p_last = p[:, :, -1:]
        w = jnp.exp(p_last - p) * dtc_  # [B,H,C]
        new_state = (state * jnp.exp(p[:, :, -1])[..., None, None]
                     + jnp.einsum("bhs,bhsd,bsn->bhdn", w, xc_, bc_))
        return new_state, y

    sT, y = chunk_scan_checkpointed(chunk_step, f32(s0), (xc, dtc, lac, bc, cc), n)
    y = y.transpose(1, 0, 3, 2, 4).reshape(b, n * c, h, hd)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * xh  # xh already padded
    return y[:, :s], sT


def ssd_step(xh, bmat, cmat, dt, a_log, d_skip, state):
    """One-token SSD. xh: [B,H,hd]; bmat,cmat: [B,ds]; dt: [B,H]."""
    f32 = lambda t: t.astype(jnp.float32)
    xh, bmat, cmat, dt = f32(xh), f32(bmat), f32(cmat), f32(dt)
    a = jnp.exp(-jnp.exp(a_log.astype(jnp.float32))[None] * dt)  # [B,H]
    new_state = (state * a[..., None, None]
                 + jnp.einsum("bh,bhd,bn->bhdn", dt, xh, bmat))
    y = jnp.einsum("bhdn,bn->bhd", new_state, cmat)
    y = y + d_skip.astype(jnp.float32)[None, :, None] * xh
    return y, new_state


def mamba2_block(p, x, cfg, *, conv_state=None, ssm_state=None, parallel=True):
    """Full Mamba2 mixer. Parallel: x [B,S,D]; step: x [B,D]."""
    d = cfg.d_model
    di, ds, h = 2 * d, cfg.ssm_state, cfg.ssm_heads
    hd = di // h
    if parallel:
        b, s, _ = x.shape
        xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
        z, xr, bmat, cmat, dt_raw = _mamba2_split(p, xz, cfg)
        xr = shard(xr, "batch", "seq", "inner")
        # causal depthwise conv (kernel 4) over xr
        prev = (jnp.zeros((b, 3, di), xr.dtype) if conv_state is None else conv_state)
        xr_pad = jnp.concatenate([prev, xr], axis=1)
        xr_conv = sum(xr_pad[:, i : i + s] * p["conv_w"][i] for i in range(4))
        xr_conv = jax.nn.silu(xr_conv + p["conv_b"])
        new_conv = xr_pad[:, s : s + 3]

        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        xh = xr_conv.reshape(b, s, h, hd)
        s0 = (jnp.zeros((b, h, hd, ds), jnp.float32) if ssm_state is None else ssm_state)
        y, sT = ssd_chunked(xh, bmat, cmat, dt, p["A_log"], p["D"], s0)
        y = y.reshape(b, s, di).astype(x.dtype)
        y = rms_norm(y * jax.nn.silu(z), p["gn_w"], cfg.norm_eps)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
        return out, (new_conv, sT)
    else:
        b, _ = x.shape
        xz = jnp.einsum("bd,de->be", x, p["in_proj"])
        z, xr, bmat, cmat, dt_raw = _mamba2_split(p, xz, cfg)
        conv_in = jnp.concatenate([conv_state, xr[:, None]], axis=1)  # [B,4,di]
        xr_conv = jnp.einsum("bki,ki->bi", conv_in, p["conv_w"])
        xr_conv = jax.nn.silu(xr_conv + p["conv_b"])
        new_conv = conv_in[:, 1:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        xh = xr_conv.reshape(b, h, hd)
        y, sT = ssd_step(xh, bmat, cmat, dt, p["A_log"], p["D"], ssm_state)
        y = y.reshape(b, di).astype(x.dtype)
        y = rms_norm(y * jax.nn.silu(z), p["gn_w"], cfg.norm_eps)
        out = jnp.einsum("be,ed->bd", y, p["out_proj"])
        return out, (new_conv, sT)
